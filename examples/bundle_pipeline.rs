//! The bundle lifecycle, end to end: **learn → fuse → fit → calibrate
//! → serve**, with one `.bnb` artifact carrying the model across every
//! stage boundary.
//!
//! Run:  cargo run --release --example bundle_pipeline
//!
//! Steps: (1) generate a ground truth and sample a dataset; (2)
//! ring-learn with bundle emission on — `cges` fits + calibrates the
//! converged structure into the final artifact; (3) write the bundle
//! to disk and read it back
//! (binary codec round-trip), printing its JSON debug form; (4)
//! warm-start a compiled model from the decoded bundle and verify,
//! bit for bit, that it answers exactly like a cold compile while
//! recomputing zero collect messages; (5) serve the bundle over TCP
//! and drain with the shutdown sentinel. Exits non-zero on any
//! divergence — CI runs this as the bundle acceptance demo.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::coordinator::{cges, RingConfig};
use cges::engine::{CompiledModel, ServeConfig, Server};
use cges::infer::json::Json;
use cges::infer::EngineConfig;
use cges::model::{read_bundle, write_bundle};
use cges::util::Timer;

fn send_frame(writer: &mut impl Write, payload: &str) {
    let bytes = payload.as_bytes();
    writer.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
    writer.write_all(bytes).unwrap();
    writer.flush().unwrap();
}

fn recv_frame(reader: &mut impl Read) -> String {
    let mut len_bytes = [0u8; 4];
    reader.read_exact(&mut len_bytes).unwrap();
    let mut payload = vec![0u8; u32::from_le_bytes(len_bytes) as usize];
    reader.read_exact(&mut payload).unwrap();
    String::from_utf8(payload).unwrap()
}

fn main() -> anyhow::Result<()> {
    // (1) Ground truth + data (small: this demo runs in CI).
    let cfg = NetGenConfig {
        nodes: 24,
        edges: 32,
        max_parents: 2,
        card_range: (2, 3),
        ..Default::default()
    };
    let truth = generate(&cfg, 7);
    let data = Arc::new(forward_sample(&truth, 1500, 8));
    println!("domain: {} nodes, {} edges | 1500 rows", truth.n(), truth.dag.edge_count());

    // (2) Ring-learn with bundle emission: one fit + calibrate over
    // the converged structure becomes the final artifact.
    let t = Timer::start();
    let learned = cges(
        data.clone(),
        &RingConfig { k: 2, threads: 4, emit_bundle: true, ..Default::default() },
    )?;
    let bundle = learned.bundle.expect("emit_bundle produces an artifact");
    println!(
        "learned: BDeu {:.1}, {} rounds in {:.2}s -> bundle [{}], potentials {}",
        learned.score,
        learned.rounds,
        t.secs(),
        bundle.meta.producer,
        if bundle.has_potentials() { "calibrated" } else { "none" }
    );

    // (3) Persist and reload: the artifact is the interchange format.
    let path = std::env::temp_dir().join("bundle_pipeline_demo.bnb");
    write_bundle(&bundle, &path)?;
    let decoded = read_bundle(&path)?;
    let file_len = std::fs::metadata(&path)?.len();
    std::fs::remove_file(&path).ok();
    println!("codec: wrote + reloaded {} ({file_len} bytes)", path.display());
    println!("inspect: {}", decoded.to_debug_json());

    // (4) Warm-start from the decoded artifact; prove the contract.
    let warm = CompiledModel::from_bundle(&decoded)?;
    anyhow::ensure!(warm.is_warm_started(), "fingerprint should match its own compile");
    let cold = CompiledModel::compile(&decoded.bn)?;
    let mut ws = warm.new_scratch();
    let mut cs = cold.new_scratch();
    let evidence = vec![(0usize, 0usize), (5, 1)];
    let mut first_recomputes = 0;
    for (i, ev) in [&[][..], &evidence[..]].into_iter().enumerate() {
        let a = warm.marginals(&mut ws, ev)?;
        let b = cold.marginals(&mut cs, ev)?;
        if i == 0 {
            first_recomputes = ws.collect_recomputes();
            anyhow::ensure!(first_recomputes == 0, "warm start must skip the collect sweep");
        }
        anyhow::ensure!(
            a.log_evidence.to_bits() == b.log_evidence.to_bits(),
            "warm/cold log-evidence diverged"
        );
        for v in 0..decoded.bn.n() {
            for (x, y) in a.marginal(v).iter().zip(b.marginal(v)) {
                anyhow::ensure!(x.to_bits() == y.to_bits(), "warm/cold marginal diverged");
            }
        }
    }
    println!(
        "warm start: bit-identical to cold compile; collect messages recomputed on first \
         query: {first_recomputes} (cold side: {}; evidence queries later recomputed {})",
        cs.collect_recomputes(),
        ws.collect_recomputes()
    );

    // (5) Serve the bundle: one framed client, then the sentinel.
    let server = Server::from_bundle(
        &decoded,
        &EngineConfig::default(),
        ServeConfig { threads: 2, ..Default::default() },
    )?;
    anyhow::ensure!(server.warm_started(), "serving should adopt the potentials");
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    std::thread::scope(|s| -> anyhow::Result<()> {
        let server = &server;
        s.spawn(move || server.serve_tcp(&listener, None).expect("serve"));
        let stream = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        send_frame(
            &mut writer,
            &format!(
                r#"{{"id": 1, "type": "marginal", "targets": ["{}"], "evidence": {{"{}": 0}}}}"#,
                decoded.bn.names[23], decoded.bn.names[0]
            ),
        );
        let resp = recv_frame(&mut reader);
        let v = Json::parse(&resp).unwrap();
        anyhow::ensure!(v.get("ok").and_then(Json::as_bool) == Some(true), "query failed");
        println!("served  < {}", &resp[..resp.len().min(90)]);
        send_frame(&mut writer, r#"{"type": "shutdown"}"#);
        let ack = recv_frame(&mut reader);
        println!("shutdown < {ack}");
        Ok(())
    })?;
    println!("bundle pipeline complete: learn -> bundle -> warm serve, one artifact throughout");
    Ok(())
}
