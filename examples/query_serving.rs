//! End-to-end serving demo: ring-learn a structure, fit its CPTs, and
//! answer probabilistic queries three ways — the full
//! data → learn → **infer** loop the serve path productionizes.
//!
//! Run:  cargo run --release --example query_serving -- \
//!           [--nodes 60] [--edges 80] [--rows 3000] [--queries 200] [--seed 1]
//!
//! Steps: (1) generate a ground-truth network and sample a dataset;
//! (2) learn a structure with the k=2 ring; (3) fit Dirichlet-smoothed
//! CPTs onto the learned DAG; (4) compile a junction tree and
//! cross-check one query against variable elimination and likelihood
//! weighting; (5) measure full-posterior queries/sec; (6) answer one
//! JSON request through the same `QueryServer` the `cges serve`
//! subcommand exposes.

use std::sync::Arc;

use cges::bn::{fit, forward_sample, generate, NetGenConfig};
use cges::coordinator::{cges, RingConfig};
use cges::infer::{likelihood_weighting, ve_marginal, EngineConfig, JoinTree, QueryServer};
use cges::rng::Rng;
use cges::util::Timer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, dflt: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let nodes = get("--nodes", 60);
    let edges = get("--edges", 80);
    let rows = get("--rows", 3000);
    let queries = get("--queries", 200);
    let seed = get("--seed", 1) as u64;

    // (1) Ground truth + data.
    let cfg = NetGenConfig { nodes, edges, max_parents: 2, card_range: (2, 3), ..Default::default() };
    let truth = generate(&cfg, seed);
    let data = Arc::new(forward_sample(&truth, rows, seed + 1));
    println!(
        "domain: {} nodes, {} edges | {} rows sampled",
        truth.n(),
        truth.dag.edge_count(),
        rows
    );

    // (2) Ring-learn the structure.
    let t = Timer::start();
    let learned = cges(data.clone(), &RingConfig { k: 2, threads: 4, ..Default::default() })?;
    println!(
        "learned: BDeu {:.1}, {} edges, {} rounds in {:.2}s",
        learned.score,
        learned.dag.edge_count(),
        learned.rounds,
        t.secs()
    );

    // (3) Parameterize the learned structure.
    let t = Timer::start();
    let bn = fit(&learned.dag, &data, 1.0)?;
    println!("fitted: {} parameters in {:.3}s", bn.parameter_count(), t.secs());

    // (4) Compile the junction tree and cross-check the engines.
    let t = Timer::start();
    let jt = JoinTree::build(&bn)?;
    println!(
        "jointree: {} cliques, max clique state space {}, built in {:.3}s",
        jt.n_cliques(),
        jt.max_clique_states(),
        t.secs()
    );
    let target = nodes - 1;
    let evidence = vec![(0usize, 0usize)];
    let post = jt.posterior(&evidence)?;
    let ve = ve_marginal(&bn, target, &evidence)?;
    let lw = likelihood_weighting(&bn, &evidence, 100_000, seed + 7)?;
    println!("P({} | {}=0):", bn.names[target], bn.names[0]);
    println!("  jointree  {:?}", fmt3(post.marginal(target)));
    println!("  ve        {:?}", fmt3(&ve));
    println!("  lw (100k) {:?}", fmt3(lw.marginal(target)));
    let max_gap = ve
        .iter()
        .zip(post.marginal(target))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    anyhow::ensure!(max_gap < 1e-9, "exact engines disagree by {max_gap}");

    // (5) Serving throughput: every query is one evidence set and a
    // full propagation yielding all marginals.
    let mut rng = Rng::new(seed + 99);
    let t = Timer::start();
    for _ in 0..queries {
        let v = rng.gen_range(nodes);
        let s = rng.gen_range(bn.cards[v] as usize);
        jt.posterior(&[(v, s)])?;
    }
    let secs = t.secs();
    println!(
        "{queries} full-posterior queries in {secs:.2}s ({:.0} queries/sec)",
        queries as f64 / secs.max(1e-9)
    );

    // (6) The serve path, in-process.
    let mut server = QueryServer::new(&bn, &EngineConfig::default())?;
    let request = format!(
        r#"{{"id": 1, "type": "marginal", "targets": ["{}"], "evidence": {{"{}": 0}}}}"#,
        bn.names[target], bn.names[0]
    );
    println!("serve> {request}");
    println!("serve< {}", server.handle(&request));
    Ok(())
}

fn fmt3(dist: &[f64]) -> Vec<String> {
    dist.iter().map(|p| format!("{p:.4}")).collect()
}
