//! End-to-end serving demo on the bundle API: ring-learn straight into
//! a model bundle, warm-start the compiled engine from its shipped
//! potentials, and serve it to concurrent clients — the full
//! data → learn → **bundle** → **warm serve** loop.
//!
//! Run:  cargo run --release --example query_serving -- \
//!           [--nodes 60] [--edges 80] [--rows 3000] [--queries 200] \
//!           [--threads 4] [--seed 1]
//!
//! Steps: (1) generate a ground-truth network and sample a dataset;
//! (2) ring-learn with bundle emission on — `cges` fits + calibrates
//! the converged structure into a self-contained artifact; (3)
//! warm-start a `CompiledModel` from the bundle (zero
//! collect-message recomputation, verified against a cold compile
//! bit-for-bit and against variable elimination); (4) measure
//! full-posterior queries/sec single-threaded vs `--threads` workers
//! sharing the warm model with per-thread scratch; (5) start the
//! multi-client TCP server from the same bundle, hit it from parallel
//! framed clients with marginal, joint-MAP and batch requests, then
//! stop it with the shutdown sentinel.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::coordinator::{cges, RingConfig};
use cges::engine::{CompiledModel, ServeConfig, Server};
use cges::infer::json::Json;
use cges::infer::{ve_marginal, EngineConfig};
use cges::rng::Rng;
use cges::util::Timer;

fn send_frame(writer: &mut impl Write, payload: &str) {
    let bytes = payload.as_bytes();
    writer.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
    writer.write_all(bytes).unwrap();
    writer.flush().unwrap();
}

fn recv_frame(reader: &mut impl Read) -> String {
    let mut len_bytes = [0u8; 4];
    reader.read_exact(&mut len_bytes).unwrap();
    let mut payload = vec![0u8; u32::from_le_bytes(len_bytes) as usize];
    reader.read_exact(&mut payload).unwrap();
    String::from_utf8(payload).unwrap()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, dflt: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let nodes = get("--nodes", 60);
    let edges = get("--edges", 80);
    let rows = get("--rows", 3000);
    let queries = get("--queries", 200);
    let threads = get("--threads", 4).max(1);
    let seed = get("--seed", 1) as u64;

    // (1) Ground truth + data.
    let cfg = NetGenConfig { nodes, edges, max_parents: 2, card_range: (2, 3), ..Default::default() };
    let truth = generate(&cfg, seed);
    let data = Arc::new(forward_sample(&truth, rows, seed + 1));
    println!(
        "domain: {} nodes, {} edges | {} rows sampled",
        truth.n(),
        truth.dag.edge_count(),
        rows
    );

    // (2) Ring-learn straight into a model bundle: `cges` fits and
    // calibrates the converged structure into one self-contained
    // artifact (per-hop shipping is the federated `run_ring` path).
    let t = Timer::start();
    let learned = cges(
        data.clone(),
        &RingConfig { k: 2, threads: 4, emit_bundle: true, ..Default::default() },
    )?;
    let bundle = learned.bundle.expect("emit_bundle produces an artifact");
    println!(
        "learned: BDeu {:.1}, {} edges, {} rounds in {:.2}s -> bundle [{}] with {} parameters, potentials {}",
        learned.score,
        learned.dag.edge_count(),
        learned.rounds,
        t.secs(),
        bundle.meta.producer,
        bundle.bn.parameter_count(),
        if bundle.has_potentials() { "calibrated" } else { "none" }
    );
    let bn = bundle.bn.clone();

    // (3) Warm-start the compiled model from the bundle; the model is
    // Send + Sync and every query below shares this single allocation.
    let t = Timer::start();
    let model = CompiledModel::from_bundle(&bundle)?;
    println!(
        "compiled: {} cliques, max clique state space {}, built in {:.3}s ({})",
        model.n_cliques(),
        model.max_clique_states(),
        t.secs(),
        if model.is_warm_started() { "warm-started from shipped potentials" } else { "cold" }
    );
    let target = nodes - 1;
    let evidence = vec![(0usize, 0usize)];
    let mut scratch = model.new_scratch();
    let post = model.marginals(&mut scratch, &evidence)?;
    if model.is_warm_started() {
        // Cross-check the warm path against a cold compile, bit for bit.
        let cold = CompiledModel::compile(&bn)?;
        let mut cold_scratch = cold.new_scratch();
        let cold_post = cold.marginals(&mut cold_scratch, &evidence)?;
        anyhow::ensure!(
            post.log_evidence.to_bits() == cold_post.log_evidence.to_bits(),
            "warm and cold answers diverged"
        );
        println!("warm start verified: answers bit-identical to a cold compile");
    }
    let ve = ve_marginal(&bn, target, &evidence)?;
    let max_gap = ve
        .iter()
        .zip(post.marginal(target))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    anyhow::ensure!(max_gap < 1e-9, "exact engines disagree by {max_gap}");
    println!(
        "cross-check: P({} | {}=0) agrees with variable elimination to {max_gap:.1e}",
        bn.names[target], bn.names[0]
    );
    let (map_states, log_prob) = model.joint_map(&mut scratch, &evidence)?;
    println!(
        "joint MAP given {}=0: ln P = {log_prob:.4} (first states {:?}...)",
        bn.names[0],
        &map_states[..map_states.len().min(8)]
    );

    // (4) Serving throughput, single-threaded vs shared-model pool.
    let mut rng = Rng::new(seed + 99);
    let mut evidence_sets: Vec<Vec<(usize, usize)>> = Vec::with_capacity(queries);
    for _ in 0..queries {
        let v = rng.gen_range(nodes);
        let s = rng.gen_range(bn.cards[v] as usize);
        evidence_sets.push(vec![(v, s)]);
    }
    let t = Timer::start();
    for ev in &evidence_sets {
        model.marginals(&mut scratch, ev)?;
    }
    let single_qps = queries as f64 / t.secs().max(1e-9);
    println!("1 thread : {single_qps:.0} full-posterior queries/sec");
    let t = Timer::start();
    std::thread::scope(|s| {
        for w in 0..threads {
            let model = &model;
            let evidence_sets = &evidence_sets;
            s.spawn(move || {
                let mut scratch = model.new_scratch();
                let mut i = w;
                while i < evidence_sets.len() {
                    model.marginals(&mut scratch, &evidence_sets[i]).expect("query");
                    i += threads;
                }
            });
        }
    });
    let pool_qps = queries as f64 / t.secs().max(1e-9);
    println!(
        "{threads} threads: {pool_qps:.0} queries/sec ({:.2}x, one CompiledModel, per-thread scratch)",
        pool_qps / single_qps.max(1e-9)
    );

    // (5) The multi-client TCP server, built from the same bundle so
    // every handler thread's scratch starts warm: parallel framed
    // clients, a batch request, then the shutdown sentinel.
    let server = Server::from_bundle(
        &bundle,
        &EngineConfig::default(),
        ServeConfig { threads, ..Default::default() },
    )?;
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    println!(
        "serving on {addr} with {threads} handler threads{}",
        if server.warm_started() { " (warm-started)" } else { "" }
    );
    std::thread::scope(|s| {
        let server = &server;
        s.spawn(move || server.serve_tcp(&listener, None).expect("serve"));

        // Three concurrent clients, one marginal query each.
        let mut clients = Vec::new();
        for c in 0..3usize {
            let name = bn.names[c].clone();
            clients.push(s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream);
                send_frame(
                    &mut writer,
                    &format!(r#"{{"id": {c}, "type": "marginal", "targets": ["{name}"]}}"#),
                );
                let resp = recv_frame(&mut reader);
                let v = Json::parse(&resp).unwrap();
                assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
                resp
            }));
        }
        for (c, h) in clients.into_iter().enumerate() {
            let resp = h.join().unwrap();
            println!("client {c} < {}", &resp[..resp.len().min(100)]);
        }

        // One more client: a batch sharing an evidence prefix, a joint
        // MAP, and finally the shutdown sentinel.
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let batch = format!(
            r#"{{"id": 10, "type": "batch", "queries": [
                {{"id": 0, "targets": ["{t0}"], "evidence": {{"{e}": 0}}}},
                {{"id": 1, "targets": ["{t1}"], "evidence": {{"{e}": 0}}}},
                {{"id": 2, "type": "joint_map", "evidence": {{"{e}": 0}}}}
            ]}}"#,
            t0 = bn.names[target],
            t1 = bn.names[target / 2],
            e = bn.names[0],
        );
        send_frame(&mut writer, &batch);
        let resp = recv_frame(&mut reader);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let n_results = v.get("results").and_then(Json::as_array).map(|r| r.len()).unwrap_or(0);
        println!("batch   < {n_results} results, {} bytes (shared-prefix collect pass reused)", resp.len());

        send_frame(&mut writer, r#"{"type": "shutdown"}"#);
        let ack = recv_frame(&mut reader);
        println!("shutdown < {ack}");
        // serve_tcp returns once the sentinel latches; the scope joins
        // the server thread.
    });
    println!("server drained cleanly");
    Ok(())
}
