//! Federated-learning sketch (the paper's §5 future-work direction).
//!
//! K sites each hold a *horizontal shard* of the data that never
//! leaves the site. Each site learns a structure locally (its own
//! scorer, its own rows), and only the *structures* travel around the
//! ring, where they are fused and refined — privacy-preserving in the
//! sense that raw data is never shared, only models.
//!
//! This composes the library's public pieces (fusion + masked GES) into
//! a variant the paper only gestures at, showing the modularity claim.
//!
//! Run: `cargo run --release --example federated`

use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::data::Dataset;
use cges::fusion::fuse;
use cges::graph::Dag;
use cges::learn::{ges, GesConfig};
use cges::metrics::{evaluate, smhd};
use cges::score::BdeuScorer;

fn main() -> anyhow::Result<()> {
    let n = 40;
    let k = 4; // sites
    let rounds = 3;
    let truth = generate(
        &NetGenConfig { nodes: n, edges: 56, max_parents: 3, ..Default::default() },
        23,
    );
    let all = forward_sample(&truth, 6000, 9);

    // Horizontal split: site i gets rows i, i+k, i+2k, ... (disjoint).
    let shards: Vec<Arc<Dataset>> = (0..k)
        .map(|i| {
            let rows: Vec<usize> = (i..all.n_rows()).step_by(k).collect();
            Arc::new(all.select_rows(&rows))
        })
        .collect();
    println!(
        "federated ring: {k} sites x {} private rows each, {} vars",
        shards[0].n_rows(),
        n
    );

    // Per-site scorers: data never crosses sites (no shared cache —
    // scores are site-local statistics).
    let scorers: Vec<BdeuScorer> =
        shards.iter().map(|d| BdeuScorer::new(d.clone(), 10.0)).collect();

    let mut models: Vec<Dag> = vec![Dag::new(n); k];
    for round in 0..rounds {
        let prev = models.clone();
        for i in 0..k {
            // Receive predecessor's structure, fuse with own, refine on
            // local data only.
            let init = if round == 0 {
                Dag::new(n)
            } else {
                let (fused, _) = fuse(&[&prev[i], &prev[(i + k - 1) % k]]);
                fused
            };
            let r = ges(&scorers[i], &init, &GesConfig::default());
            models[i] = r.dag;
        }
        let avg_smhd: f64 = models.iter().map(|m| smhd(m, &truth.dag) as f64).sum::<f64>() / k as f64;
        println!("round {round}: avg site SMHD to truth = {avg_smhd:.1}");
    }

    // Final consensus: fuse all site models.
    let refs: Vec<&Dag> = models.iter().collect();
    let (consensus, _) = fuse(&refs);
    // Evaluate the consensus against each site's view and the truth.
    println!("\nconsensus: {} edges, SMHD to truth {}", consensus.edge_count(), smhd(&consensus, &truth.dag));
    for (i, sc) in scorers.iter().enumerate() {
        let rep = evaluate(&consensus, &truth.dag, sc);
        println!(
            "  site {i}: local BDeu/N {:.4}, skeleton F1 {:.3}",
            rep.bdeu_normalized, rep.f1
        );
    }

    // The raw union is dense (every site's edges survive); as in the
    // ring's stage 3, a local GES refinement from the consensus start
    // prunes it — still touching only local data.
    let refined = ges(&scorers[0], &consensus, &GesConfig::default());
    let solo_smhd = smhd(&models[0], &truth.dag);
    let refined_smhd = smhd(&refined.dag, &truth.dag);
    println!(
        "\nsite-0 alone SMHD {} | consensus refined at site-0: SMHD {} ({} edges)",
        solo_smhd,
        refined_smhd,
        refined.dag.edge_count()
    );
    Ok(())
}
