//! Federated-learning sketch (the paper's §5 future-work direction).
//!
//! K sites each hold a *horizontal shard* of the data that never
//! leaves the site. Each site learns a structure locally (its own
//! scorer, its own rows), and only the *structures* travel around the
//! ring, where they are fused and refined — privacy-preserving in the
//! sense that raw data is never shared, only models.
//!
//! Since the ring became a message-passing runtime, this example rides
//! the real thing: each site is a [`RingWorker`] bound to a *private*
//! scorer (no shared cache — scores are site-local statistics), and
//! [`run_ring`] wires them through the channel transport with the
//! same circulating-token convergence the distributed learner uses.
//! Swapping `RingMode::Channel` for `RingMode::Tcp` moves every model
//! across a socket — the federated deployment in miniature.
//!
//! Run: `cargo run --release --example federated`

use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::coordinator::{run_ring, BundleEmit, RingMode, RingRunOptions};
use cges::data::Dataset;
use cges::fusion::fuse;
use cges::graph::Dag;
use cges::learn::{ges, GesConfig, RingWorker};
use cges::metrics::{evaluate, smhd};
use cges::score::BdeuScorer;

fn main() -> anyhow::Result<()> {
    let n = 40;
    let k = 4; // sites
    let truth = generate(
        &NetGenConfig { nodes: n, edges: 56, max_parents: 3, ..Default::default() },
        23,
    );
    let all = forward_sample(&truth, 6000, 9);

    // Horizontal split: site i gets rows i, i+k, i+2k, ... (disjoint).
    let shards: Vec<Arc<Dataset>> = (0..k)
        .map(|i| {
            let rows: Vec<usize> = (i..all.n_rows()).step_by(k).collect();
            Arc::new(all.select_rows(&rows))
        })
        .collect();
    println!(
        "federated ring: {k} sites x {} private rows each, {} vars",
        shards[0].n_rows(),
        n
    );

    // Per-site scorers: data never crosses sites (no shared cache —
    // scores are site-local statistics).
    let scorers: Vec<BdeuScorer> =
        shards.iter().map(|d| BdeuScorer::new(d.clone(), 10.0)).collect();

    // One persistent worker per site; models travel, data does not.
    let workers: Vec<RingWorker> = scorers
        .iter()
        .map(|sc| RingWorker::new(sc.clone(), GesConfig { threads: 2, ..Default::default() }))
        .collect();
    // Bundle emission on: each site fits CPTs on its *own shard* and
    // ships a self-contained model artifact with its structure — the
    // FedGES model-as-message framing (raw rows still never leave a
    // site). `ship_bundles` also rides them on the ring links.
    let outcome = run_ring(
        workers,
        &RingRunOptions {
            max_rounds: 8,
            mode: RingMode::Channel,
            emit: Some(BundleEmit::default()),
            ship_bundles: true,
            ..Default::default()
        },
    )?;
    println!(
        "ring converged in {} rounds over the channel transport ({} model handoffs recorded)",
        outcome.rounds,
        outcome.records.len()
    );
    if let Some(b) = &outcome.best_bundle {
        println!(
            "best site shipped a bundle: {} vars, {} parameters, potentials: {}",
            b.n_vars(),
            b.bn.parameter_count(),
            if b.has_potentials() { "calibrated" } else { "none" }
        );
    }
    for round in 0..outcome.rounds {
        let hops: Vec<_> =
            outcome.records.iter().filter(|r| r.round == round).collect();
        let best = hops.iter().map(|r| r.score).fold(f64::NEG_INFINITY, f64::max);
        let avg_edges =
            hops.iter().map(|r| r.edges as f64).sum::<f64>() / hops.len().max(1) as f64;
        println!("round {round}: best local BDeu {best:.1}, avg edges {avg_edges:.1}");
    }
    let avg_smhd: f64 =
        outcome.models.iter().map(|m| smhd(m, &truth.dag) as f64).sum::<f64>() / k as f64;
    println!("final: avg site SMHD to truth = {avg_smhd:.1}");

    // Final consensus: fuse all site models.
    let refs: Vec<&Dag> = outcome.models.iter().collect();
    let (consensus, _) = fuse(&refs);
    // Evaluate the consensus against each site's view and the truth.
    println!(
        "\nconsensus: {} edges, SMHD to truth {}",
        consensus.edge_count(),
        smhd(&consensus, &truth.dag)
    );
    for (i, sc) in scorers.iter().enumerate() {
        let rep = evaluate(&consensus, &truth.dag, sc);
        println!(
            "  site {i}: local BDeu/N {:.4}, skeleton F1 {:.3}",
            rep.bdeu_normalized, rep.f1
        );
    }

    // The raw union is dense (every site's edges survive); as in the
    // ring's stage 3, a local GES refinement from the consensus start
    // prunes it — still touching only local data.
    let refined = ges(&scorers[0], &consensus, &GesConfig::default());
    let solo_smhd = smhd(&outcome.models[0], &truth.dag);
    let refined_smhd = smhd(&refined.dag, &truth.dag);
    println!(
        "\nsite-0 alone SMHD {} | consensus refined at site-0: SMHD {} ({} edges)",
        solo_smhd,
        refined_smhd,
        refined.dag.edge_count()
    );
    Ok(())
}
