//! Quickstart: the 60-second tour.
//!
//! Generates a small ground-truth network, samples a dataset, learns it
//! back with cGES-L (the paper's best configuration) and with plain
//! GES, and compares quality and wall time.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::coordinator::{cges, RingConfig};
use cges::graph::Dag;
use cges::learn::{ges, GesConfig};
use cges::metrics::evaluate;
use cges::score::BdeuScorer;
use cges::util::Timer;

fn main() -> anyhow::Result<()> {
    // 1. Ground truth: 60 variables, 85 edges.
    let truth = generate(
        &NetGenConfig { nodes: 60, edges: 85, max_parents: 3, ..Default::default() },
        7,
    );
    println!(
        "truth: {} nodes, {} edges, {} parameters",
        truth.n(),
        truth.dag.edge_count(),
        truth.parameter_count()
    );

    // 2. Data: 5000 complete instances.
    let data = Arc::new(forward_sample(&truth, 5000, 42));

    // 3. cGES-L with a 4-process ring.
    let t = Timer::start();
    let ring = cges(data.clone(), &RingConfig { k: 4, ..Default::default() })?;
    let ring_secs = t.secs();

    // 4. Plain (parallel) GES baseline.
    let t = Timer::start();
    let scorer = BdeuScorer::new(data.clone(), 10.0);
    let plain = ges(&scorer, &Dag::new(truth.n()), &GesConfig::default());
    let ges_secs = t.secs();

    // 5. Compare.
    let sc = BdeuScorer::new(data.clone(), 10.0);
    let r_ring = evaluate(&ring.dag, &truth.dag, &sc);
    let r_ges = evaluate(&plain.dag, &truth.dag, &sc);
    println!("\n{:<8} {:>12} {:>8} {:>8} {:>8}", "algo", "BDeu/N", "SMHD", "F1", "secs");
    println!(
        "{:<8} {:>12.4} {:>8} {:>8.3} {:>8.2}",
        "cges-l", r_ring.bdeu_normalized, r_ring.smhd, r_ring.f1, ring_secs
    );
    println!(
        "{:<8} {:>12.4} {:>8} {:>8.3} {:>8.2}",
        "ges", r_ges.bdeu_normalized, r_ges.smhd, r_ges.f1, ges_secs
    );
    println!(
        "\nring: {} rounds, cache hit rate {:.1}%",
        ring.rounds,
        100.0 * ring.telemetry.cache_hits as f64
            / (ring.telemetry.cache_hits + ring.telemetry.cache_misses).max(1) as f64
    );
    Ok(())
}
