//! Fusion demo: what the ring's message handling actually does.
//!
//! Two "processes" learn complementary halves of a network (disjoint
//! edge masks, as in stage 2), then their models are fused. The demo
//! shows the GHO order, the σ-consistent transforms, and that the
//! fusion is an I-map union recovering structure neither half had.
//!
//! Run: `cargo run --release --example fusion_demo`

use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::fusion::{fuse, sigma_consistent_imap};
use cges::graph::Dag;
use cges::learn::{ges, EdgeMask, GesConfig};
use cges::metrics::smhd;
use cges::score::BdeuScorer;

fn main() -> anyhow::Result<()> {
    let n = 30;
    let truth = generate(
        &NetGenConfig { nodes: n, edges: 42, max_parents: 3, ..Default::default() },
        11,
    );
    let data = Arc::new(forward_sample(&truth, 4000, 5));
    let scorer = BdeuScorer::new(data, 10.0);

    // Split the candidate pairs in two disjoint halves (even/odd sum).
    let mut m1 = EdgeMask::new(n);
    let mut m2 = EdgeMask::new(n);
    for i in 0..n {
        for j in (i + 1)..n {
            if (i + j) % 2 == 0 {
                m1.allow(i, j)
            } else {
                m2.allow(i, j)
            }
        }
    }

    let learn = |mask: EdgeMask| {
        let cfg = GesConfig { mask: Some(Arc::new(mask)), ..Default::default() };
        ges(&scorer, &Dag::new(n), &cfg)
    };
    let g1 = learn(m1);
    let g2 = learn(m2);
    println!(
        "local model A: {} edges, BDeu {:.1} | local model B: {} edges, BDeu {:.1}",
        g1.dag.edge_count(),
        g1.score,
        g2.dag.edge_count(),
        g2.score
    );

    // Fuse.
    let (fused, sigma) = fuse(&[&g1.dag, &g2.dag]);
    println!(
        "fused: {} edges (A ∪ B after σ-transform); σ head: {:?}...",
        fused.edge_count(),
        &sigma[..8.min(sigma.len())]
    );

    // Every σ-transformed input edge is present in the union.
    for (name, g) in [("A", &g1.dag), ("B", &g2.dag)] {
        let t = sigma_consistent_imap(g, &sigma);
        let missing = t.edges().iter().filter(|&&(u, v)| !fused.has_edge(u, v)).count();
        println!("  transform({name}): {} edges, {} missing from union", t.edge_count(), missing);
        assert_eq!(missing, 0);
    }

    // The fusion is a better starting point than either half alone.
    println!(
        "SMHD to truth — A: {}, B: {}, fused: {}",
        smhd(&g1.dag, &truth.dag),
        smhd(&g2.dag, &truth.dag),
        smhd(&fused, &truth.dag)
    );

    // Use the fusion as a GES starting point (what each ring worker
    // does each round) and watch the score climb.
    let refined = ges(&scorer, &fused, &GesConfig::default());
    println!(
        "GES from fusion: BDeu {:.1} -> {:.1} ({} edges, SMHD {})",
        scorer.score_dag(&fused),
        refined.score,
        refined.dag.edge_count(),
        smhd(&refined.dag, &truth.dag)
    );
    assert!(refined.score >= scorer.score_dag(&fused) - 1e-9);
    Ok(())
}
