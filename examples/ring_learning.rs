//! End-to-end driver: the paper's experiment on a real (scaled)
//! workload — proves all layers compose: Pallas-kernel artifacts (when
//! built) feed stage-1 partitioning via PJRT, the Rust ring coordinates
//! fusion + constrained GES, and the metrics reproduce the Table 2
//! rows for one domain.
//!
//! Run:  cargo run --release --example ring_learning -- [link|pigs|munin]
//!           [--scale 0.25] [--datasets 3] [--rows 2000] [--full] [--trace]
//!           [--transport channel|tcp|sync]
//!
//! `--full` = paper scale (724-1041 vars, 11 datasets x 5000 rows) —
//! expect hours, like the original. Defaults reproduce the *shape* of
//! the results in minutes. `--xla` sources stage-1 similarities from
//! the AOT artifact instead of the Rust fallback. `--transport` picks
//! the ring runtime: pipelined in-process actors (channel, default),
//! pipelined over loopback TCP through the wire codec (tcp), or the
//! barrier-synchronous deterministic scheduler (sync) — all three
//! produce the same (dag, score). Results land in EXPERIMENTS.md.

use std::path::PathBuf;
use std::sync::Arc;

use cges::bn::{forward_sample, load_domain, Domain};
use cges::coordinator::{cges, PartitionSource, RingConfig, RingMode};
use cges::graph::Dag;
use cges::learn::{fges, ges, FgesConfig, GesConfig};
use cges::metrics::evaluate;
use cges::score::BdeuScorer;
use cges::util::{mean, Timer};

struct Row {
    algo: String,
    bdeu_n: Vec<f64>,
    smhd: Vec<f64>,
    secs: Vec<f64>,
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let domain = args
        .iter()
        .find_map(|a| Domain::parse(a))
        .unwrap_or(Domain::Pigs);
    let full = args.iter().any(|a| a == "--full");
    let trace = args.iter().any(|a| a == "--trace");
    let get = |key: &str, dflt: f64| -> f64 {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let scale = if full { 1.0 } else { get("--scale", 0.25) };
    let n_datasets = if full { 11 } else { get("--datasets", 3.0) as usize };
    let rows = if full { 5000 } else { get("--rows", 2000.0) as usize };
    let threads = 8; // the paper's testbed width
    let mode = match args.iter().position(|a| a == "--transport") {
        None => RingMode::default(),
        Some(i) => {
            let v = args.get(i + 1).ok_or_else(|| {
                anyhow::anyhow!("--transport expects a value (channel|tcp|sync)")
            })?;
            RingMode::parse(v).ok_or_else(|| {
                anyhow::anyhow!("--transport: unknown mode '{v}' (channel|tcp|sync)")
            })?
        }
    };

    let truth = load_domain(domain, scale);
    println!(
        "domain {} (scale {scale}): {} nodes, {} edges | {} datasets x {} rows | {} threads | ring transport {}",
        domain.name(),
        truth.n(),
        truth.dag.edge_count(),
        n_datasets,
        rows,
        threads,
        mode.name()
    );

    // Stage-1 via the XLA artifact is opt-in here: at reduced bench
    // scales the one-time PJRT compile dominates the whole run and
    // would distort the Table-2c timing comparison (the artifact path
    // is validated in tests/runtime_xla.rs and measured in
    // benches/kernel_throughput.rs).
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts =
        args.iter().any(|a| a == "--xla") && artifacts.join("manifest.txt").exists();
    println!("stage-1 source: {}", if have_artifacts { "xla artifacts" } else { "rust fallback" });

    let mut rows_out: Vec<Row> = Vec::new();
    let algos: Vec<String> = vec![
        "fges".into(),
        "ges".into(),
        "cges 4".into(),
        "cges-l 4".into(),
    ];
    for algo in &algos {
        rows_out.push(Row { algo: algo.clone(), bdeu_n: vec![], smhd: vec![], secs: vec![] });
    }

    for ds in 0..n_datasets {
        let data = Arc::new(forward_sample(&truth, rows, 1000 + ds as u64));
        for (ai, algo) in algos.iter().enumerate() {
            let t = Timer::start();
            let dag = match algo.as_str() {
                "fges" => {
                    let sc = BdeuScorer::new(data.clone(), 10.0);
                    fges(&sc, &Dag::new(truth.n()), &FgesConfig { threads, ..Default::default() }).dag
                }
                "ges" => {
                    let sc = BdeuScorer::new(data.clone(), 10.0);
                    ges(&sc, &Dag::new(truth.n()), &GesConfig { threads, ..Default::default() }).dag
                }
                name => {
                    let k = name.split(' ').nth(1).unwrap().parse().unwrap();
                    let cfg = RingConfig {
                        k,
                        limit_inserts: name.starts_with("cges-l"),
                        threads,
                        partition_source: if have_artifacts {
                            PartitionSource::Artifacts(artifacts.clone())
                        } else {
                            PartitionSource::RustFallback
                        },
                        mode,
                        ..Default::default()
                    };
                    let r = cges(data.clone(), &cfg)?;
                    if trace && ds == 0 {
                        let path = format!("/tmp/cges_trace_{}_{}.tsv", domain.name(), name.replace(' ', ""));
                        r.telemetry.write_tsv(std::path::Path::new(&path))?;
                        println!("  convergence trace -> {path}");
                        for (round, best) in r.telemetry.round_best_scores() {
                            println!("    round {round}: best BDeu {best:.1}");
                        }
                    }
                    r.dag
                }
            };
            let secs = t.secs();
            let sc = BdeuScorer::new(data.clone(), 10.0);
            let report = evaluate(&dag, &truth.dag, &sc);
            println!(
                "  ds{ds} {algo:<9} BDeu/N {:>9.4}  SMHD {:>5}  {:>6.1}s",
                report.bdeu_normalized, report.smhd, secs
            );
            rows_out[ai].bdeu_n.push(report.bdeu_normalized);
            rows_out[ai].smhd.push(report.smhd as f64);
            rows_out[ai].secs.push(secs);
        }
    }

    println!("\n=== {} (avg over {n_datasets} datasets) ===", domain.name());
    println!("{:<10} {:>12} {:>8} {:>9}", "ALGO", "BDeu/N", "SMHD", "time(s)");
    let ges_time = rows_out.iter().find(|r| r.algo == "ges").map(|r| mean(&r.secs)).unwrap_or(0.0);
    for r in &rows_out {
        println!(
            "{:<10} {:>12.4} {:>8.1} {:>9.2}{}",
            r.algo,
            mean(&r.bdeu_n),
            mean(&r.smhd),
            mean(&r.secs),
            if r.algo.starts_with("cges") && ges_time > 0.0 {
                format!("   (speed-up vs GES: {:.2})", ges_time / mean(&r.secs))
            } else {
                String::new()
            }
        );
    }
    Ok(())
}
