//! Table 2 harness: regenerates the paper's three result tables —
//! (a) normalized BDeu, (b) SMHD, (c) CPU time — for all eight
//! algorithm configurations (FGES, GES, cGES{2,4,8}, cGES-L{2,4,8})
//! over the three domains.
//!
//! Default scale is reduced (25% nodes, 3 datasets x 2000 rows) so the
//! full grid completes in minutes; pass `--full` after `--` for the
//! paper's 100% / 11 x 5000 setting:
//!
//!   cargo bench --bench table2                 # reduced
//!   cargo bench --bench table2 -- --full       # paper scale
//!   cargo bench --bench table2 -- --domains pigs --scale 0.15
//!
//! The *shape* to check against the paper (EXPERIMENTS.md records each
//! run): cGES-L variants fastest at equal-or-near BDeu; FGES weakest
//! quality; 4/8 rings faster than 2.

use std::path::PathBuf;
use std::sync::Arc;

use cges::bn::{forward_sample, load_domain, Domain};
use cges::coordinator::{cges, PartitionSource, RingConfig};
use cges::graph::Dag;
use cges::learn::{fges, ges, FgesConfig, GesConfig};
use cges::metrics::evaluate;
use cges::score::BdeuScorer;
use cges::util::{mean, Timer};

const ALGOS: &[&str] = &["fges", "ges", "cges-2", "cges-4", "cges-8", "cges-l-2", "cges-l-4", "cges-l-8"];

fn main() -> anyhow::Result<()> {
    let wall = Timer::start();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let get = |key: &str| -> Option<String> {
        args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
    };
    let scale: f64 = if full { 1.0 } else { get("--scale").and_then(|v| v.parse().ok()).unwrap_or(0.25) };
    let datasets: usize = if full { 11 } else { get("--datasets").and_then(|v| v.parse().ok()).unwrap_or(3) };
    let rows: usize = if full { 5000 } else { get("--rows").and_then(|v| v.parse().ok()).unwrap_or(2000) };
    let threads: usize = get("--threads").and_then(|v| v.parse().ok()).unwrap_or(8);
    let domains: Vec<Domain> = match get("--domains") {
        Some(list) => list.split(',').filter_map(Domain::parse).collect(),
        None => vec![Domain::Pigs, Domain::Link, Domain::Munin],
    };

    // XLA stage-1 is opt-in (--xla): at reduced scale the one-time PJRT
    // compile would dominate Table 2c; see benches/kernel_throughput.rs.
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts = args.iter().any(|a| a == "--xla") && artifacts.join("manifest.txt").exists();

    println!(
        "# table2 harness: scale={scale} datasets={datasets} rows={rows} threads={threads} artifacts={}",
        have_artifacts
    );

    // results[domain][algo] = (bdeu_n, smhd, secs) vectors
    let mut bdeu = vec![vec![Vec::new(); ALGOS.len()]; domains.len()];
    let mut smhd = vec![vec![Vec::new(); ALGOS.len()]; domains.len()];
    let mut time = vec![vec![Vec::new(); ALGOS.len()]; domains.len()];

    for (di, &domain) in domains.iter().enumerate() {
        let truth = load_domain(domain, scale);
        eprintln!(
            "domain {}: {} nodes, {} edges",
            domain.name(),
            truth.n(),
            truth.dag.edge_count()
        );
        for ds in 0..datasets {
            let data = Arc::new(forward_sample(&truth, rows, 31_000 + ds as u64));
            for (ai, &algo) in ALGOS.iter().enumerate() {
                let t = Timer::start();
                let dag = run_algo(algo, &data, threads, have_artifacts.then(|| artifacts.clone()))?;
                let secs = t.secs();
                let sc = BdeuScorer::new(data.clone(), 10.0);
                let rep = evaluate(&dag, &truth.dag, &sc);
                eprintln!(
                    "  {} ds{ds} {algo:<9} bdeu/N {:>9.4} smhd {:>5} {:>7.1}s",
                    domain.name(),
                    rep.bdeu_normalized,
                    rep.smhd,
                    secs
                );
                bdeu[di][ai].push(rep.bdeu_normalized);
                smhd[di][ai].push(rep.smhd as f64);
                time[di][ai].push(secs);
            }
        }
    }

    let table = |title: &str, data: &[Vec<Vec<f64>>], fmt: &dyn Fn(f64) -> String| {
        println!("\n## Table 2{title}");
        print!("{:<8}", "Network");
        for a in ALGOS {
            print!(" {:>10}", a.to_uppercase());
        }
        println!();
        for (di, &domain) in domains.iter().enumerate() {
            print!("{:<8}", domain.name());
            // Bold-equivalent: mark the best with '*'.
            let means: Vec<f64> = (0..ALGOS.len()).map(|ai| mean(&data[di][ai])).collect();
            let best = if title.contains('a') {
                means.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            } else {
                means.iter().cloned().fold(f64::INFINITY, f64::min)
            };
            for m in &means {
                let mark = if (*m - best).abs() < 1e-9 { "*" } else { "" };
                print!(" {:>10}", format!("{}{}", fmt(*m), mark));
            }
            println!();
        }
    };

    table("a: BDeu score (normalized)", &bdeu, &|v| format!("{v:.4}"));
    table("b: SMHD", &smhd, &|v| format!("{v:.1}"));
    table("c: CPU time (s)", &time, &|v| format!("{v:.1}"));

    // §4.4 speed-up lines (cGES-L 4 vs GES, the paper's 3.02/2.70/2.23).
    println!("\n## Speed-ups (cGES-L 4 vs GES)");
    let ges_i = ALGOS.iter().position(|&a| a == "ges").unwrap();
    let cl4_i = ALGOS.iter().position(|&a| a == "cges-l-4").unwrap();
    for (di, &domain) in domains.iter().enumerate() {
        let s = mean(&time[di][ges_i]) / mean(&time[di][cl4_i]).max(1e-9);
        println!("{:<8} {:.2}x", domain.name(), s);
    }

    // Machine-readable perf record: one JSON file per run so the
    // trajectory across PRs can be diffed (BENCH_table2.json in CWD).
    let json = perf_record_json(
        scale,
        datasets,
        rows,
        threads,
        wall.secs(),
        &domains,
        &bdeu,
        &smhd,
        &time,
    );
    let out = "BENCH_table2.json";
    std::fs::write(out, &json)?;
    println!("\nperf record written to {out}");
    Ok(())
}

/// Hand-rolled JSON (the offline registry has no serde): the schema is
/// flat enough that formatting it directly is the simpler dependency.
#[allow(clippy::too_many_arguments)]
fn perf_record_json(
    scale: f64,
    datasets: usize,
    rows: usize,
    threads: usize,
    wall_secs: f64,
    domains: &[Domain],
    bdeu: &[Vec<Vec<f64>>],
    smhd: &[Vec<Vec<f64>>],
    time: &[Vec<Vec<f64>>],
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"table2\",");
    let _ = writeln!(s, "  \"scale\": {scale},");
    let _ = writeln!(s, "  \"datasets\": {datasets},");
    let _ = writeln!(s, "  \"rows\": {rows},");
    let _ = writeln!(s, "  \"threads\": {threads},");
    let _ = writeln!(s, "  \"wall_secs\": {wall_secs:.3},");
    s.push_str("  \"results\": [\n");
    let mut first = true;
    for (di, domain) in domains.iter().enumerate() {
        for (ai, algo) in ALGOS.iter().enumerate() {
            if !first {
                s.push_str(",\n");
            }
            first = false;
            let _ = write!(
                s,
                "    {{\"domain\": \"{}\", \"algo\": \"{}\", \"bdeu_n\": {:.6}, \"smhd\": {:.3}, \"secs\": {:.3}}}",
                domain.name(),
                algo,
                mean(&bdeu[di][ai]),
                mean(&smhd[di][ai]),
                mean(&time[di][ai])
            );
        }
    }
    s.push_str("\n  ]\n}\n");
    s
}

fn run_algo(
    algo: &str,
    data: &Arc<cges::data::Dataset>,
    threads: usize,
    artifacts: Option<PathBuf>,
) -> anyhow::Result<Dag> {
    let n = data.n_vars();
    Ok(match algo {
        "fges" => {
            let sc = BdeuScorer::new(data.clone(), 10.0);
            fges(&sc, &Dag::new(n), &FgesConfig { threads, ..Default::default() }).dag
        }
        "ges" => {
            let sc = BdeuScorer::new(data.clone(), 10.0);
            ges(&sc, &Dag::new(n), &GesConfig { threads, ..Default::default() }).dag
        }
        _ => {
            let limited = algo.starts_with("cges-l");
            let k: usize = algo.rsplit('-').next().unwrap().parse()?;
            let cfg = RingConfig {
                k,
                limit_inserts: limited,
                threads,
                partition_source: artifacts
                    .map(PartitionSource::Artifacts)
                    .unwrap_or(PartitionSource::RustFallback),
                ..Default::default()
            };
            cges(data.clone(), &cfg)?.dag
        }
    })
}
