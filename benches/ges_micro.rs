//! L3 microbenches: the GES hot paths — contingency counting, BDeu
//! local scores (fresh vs cached), operator evaluation, CPDAG
//! completion — measured in isolation. This is the profile the §Perf
//! iterations in EXPERIMENTS.md optimize against.
//!
//!   cargo bench --bench ges_micro -- [--rows 5000] [--n 200]

use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::graph::{complete_pdag, dag_to_cpdag};
use cges::learn::operators::best_insert;
use cges::score::{family_counts, BdeuScorer};
use cges::util::Timer;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warm-up.
    f();
    let t = Timer::start();
    for _ in 0..iters {
        f();
    }
    let total = t.secs();
    println!("{:<38} {:>10.2} µs/op   ({} iters, {:.3}s)", name, total / iters as f64 * 1e6, iters, total);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str| -> Option<String> {
        args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
    };
    let rows: usize = get("--rows").and_then(|v| v.parse().ok()).unwrap_or(5000);
    let n: usize = get("--n").and_then(|v| v.parse().ok()).unwrap_or(200);

    let bn = generate(
        &NetGenConfig { nodes: n, edges: n * 3 / 2, max_parents: 3, ..Default::default() },
        13,
    );
    let data = Arc::new(forward_sample(&bn, rows, 3));
    println!("# ges_micro: n={n} rows={rows}\n");

    // Counting.
    bench("family_counts / 0 parents", 2000, || {
        std::hint::black_box(family_counts(&data, 5, &[]));
    });
    bench("family_counts / 1 parent", 2000, || {
        std::hint::black_box(family_counts(&data, 5, &[7]));
    });
    bench("family_counts / 3 parents", 1000, || {
        std::hint::black_box(family_counts(&data, 5, &[7, 11, 13]));
    });

    // Scoring.
    let scorer = BdeuScorer::new(data.clone(), 10.0);
    bench("bdeu local (uncached)", 500, || {
        std::hint::black_box(scorer.local_uncached(5, &[7, 11]));
    });
    scorer.local(5, &[7, 11]);
    bench("bdeu local (cache hit)", 20_000, || {
        std::hint::black_box(scorer.local(5, &[7, 11]));
    });

    // Operator evaluation on the true CPDAG.
    let cpdag = dag_to_cpdag(&bn.dag);
    let (mut x, mut y) = (0, 1);
    'outer: for i in 0..n {
        for j in 0..n {
            if i != j && !cpdag.adjacent(i, j) {
                (x, y) = (i, j);
                break 'outer;
            }
        }
    }
    bench("best_insert on dense CPDAG", 500, || {
        std::hint::black_box(best_insert(&scorer, &cpdag, x, y, None));
    });

    // Graph machinery.
    bench("dag_to_cpdag", 200, || {
        std::hint::black_box(dag_to_cpdag(&bn.dag));
    });
    bench("complete_pdag (extend + relabel)", 100, || {
        std::hint::black_box(complete_pdag(&cpdag).unwrap());
    });

    let (hits, misses) = scorer.cache().stats();
    println!("\ncache: {hits} hits / {misses} misses");
}
