//! Ring-size scaling bench (Table 2c's k-columns + the §4.4 speed-up
//! discussion): wall time and quality of cGES / cGES-L as the ring
//! grows, against the GES baseline, at a fixed domain scale.
//!
//!   cargo bench --bench scaling -- [--domain link] [--scale 0.25]
//!       [--rows 2000] [--datasets 2] [--kmax 16]

use std::sync::Arc;

use cges::bn::{forward_sample, load_domain, Domain};
use cges::coordinator::{cges, RingConfig};
use cges::graph::Dag;
use cges::learn::{ges, GesConfig};
use cges::metrics::evaluate;
use cges::score::BdeuScorer;
use cges::util::{mean, Timer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str| -> Option<String> {
        args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
    };
    let domain = get("--domain").and_then(|d| Domain::parse(&d)).unwrap_or(Domain::Link);
    let scale: f64 = get("--scale").and_then(|v| v.parse().ok()).unwrap_or(0.25);
    let rows: usize = get("--rows").and_then(|v| v.parse().ok()).unwrap_or(2000);
    let datasets: usize = get("--datasets").and_then(|v| v.parse().ok()).unwrap_or(2);
    let kmax: usize = get("--kmax").and_then(|v| v.parse().ok()).unwrap_or(8);
    let threads = 8;

    let truth = load_domain(domain, scale);
    println!(
        "# scaling bench: {} scale={scale} ({} nodes, {} edges), {} datasets x {rows} rows",
        domain.name(),
        truth.n(),
        truth.dag.edge_count(),
        datasets
    );

    // Baseline GES.
    let mut ges_secs = Vec::new();
    let mut ges_bdeu = Vec::new();
    for ds in 0..datasets {
        let data = Arc::new(forward_sample(&truth, rows, 500 + ds as u64));
        let sc = BdeuScorer::new(data.clone(), 10.0);
        let t = Timer::start();
        let r = ges(&sc, &Dag::new(truth.n()), &GesConfig { threads, ..Default::default() });
        ges_secs.push(t.secs());
        let rep = evaluate(&r.dag, &truth.dag, &sc);
        ges_bdeu.push(rep.bdeu_normalized);
    }
    println!(
        "{:<12} {:>8} {:>12} {:>9}",
        "config", "k", "BDeu/N", "time(s)"
    );
    println!("{:<12} {:>8} {:>12.4} {:>9.2}", "ges", "-", mean(&ges_bdeu), mean(&ges_secs));

    for limited in [false, true] {
        let mut k = 2;
        while k <= kmax {
            let mut secs = Vec::new();
            let mut bdeu = Vec::new();
            let mut rounds = Vec::new();
            for ds in 0..datasets {
                let data = Arc::new(forward_sample(&truth, rows, 500 + ds as u64));
                let cfg = RingConfig { k, limit_inserts: limited, threads, ..Default::default() };
                let t = Timer::start();
                let r = cges(data.clone(), &cfg)?;
                secs.push(t.secs());
                rounds.push(r.rounds as f64);
                let sc = BdeuScorer::new(data, 10.0);
                bdeu.push(evaluate(&r.dag, &truth.dag, &sc).bdeu_normalized);
            }
            println!(
                "{:<12} {:>8} {:>12.4} {:>9.2}   speed-up {:.2}x, avg rounds {:.1}",
                if limited { "cges-l" } else { "cges" },
                k,
                mean(&bdeu),
                mean(&secs),
                mean(&ges_secs) / mean(&secs).max(1e-9),
                mean(&rounds)
            );
            k *= 2;
        }
    }
    Ok(())
}
