//! Scoring-core bench: the word-parallel counting engine vs the
//! retained scalar reference, per parent-set size × cardinality × row
//! count, plus the fused `local_pair` probe and an end-to-end GES run.
//!
//!   cargo bench --bench scoring                  # default sizes
//!   cargo bench --bench scoring -- --rows 50000 --nodes 80
//!
//! Three sections:
//!
//! * **families** — ns/family of `Counter::family_counts` (packed
//!   popcount / tiled / decode paths) against `CountMode::Reference`
//!   over 64 distinct random families per (card, rows, parents) cell.
//!   Every packed table is checked equal to the reference table before
//!   timing.
//! * **pair** — the fused `local_pair` (one superset count + one
//!   marginalization) against two independent uncached `local` calls
//!   on fresh scorers, per parent-set size.
//! * **ges** — end-to-end `ges()` wall time, packed vs reference
//!   engine, with the FES/BES evaluation split and cache/count-path
//!   statistics — the attribution view of the speedup.
//!
//! Writes `BENCH_score.json` (hand-rolled JSON, repo convention) for
//! the perf-records CI job.

use std::hint::black_box;
use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::data::Dataset;
use cges::graph::Dag;
use cges::learn::{ges, GesConfig};
use cges::rng::Rng;
use cges::score::{BdeuScorer, CountConfig, CountMode, Counter, CountsTable};
use cges::util::Timer;

/// Distinct families timed per grid cell (each counted once per rep —
/// distinct parent sets so the score cache can't short-circuit).
const FAMILIES: usize = 64;

struct FamilyCase {
    card: u32,
    rows: usize,
    parents: usize,
    reference_ns: f64,
    packed_ns: f64,
}

struct PairCase {
    parents: usize,
    two_pass_ns: f64,
    fused_ns: f64,
}

fn random_data(n_vars: usize, card: u32, rows: usize, seed: u64) -> Arc<Dataset> {
    let mut rng = Rng::new(seed);
    let cols = (0..n_vars)
        .map(|_| (0..rows).map(|_| rng.gen_range(card as usize) as u8).collect())
        .collect();
    Arc::new(Dataset::unnamed(vec![card; n_vars], cols))
}

/// `FAMILIES` distinct (child, parents) draws over `n_vars` columns.
fn draw_families(n_vars: usize, parents: usize, seed: u64) -> Vec<(usize, Vec<usize>)> {
    let mut rng = Rng::new(seed);
    (0..FAMILIES)
        .map(|_| {
            let mut picks = rng.sample_indices(n_vars, parents + 1);
            let child = picks.remove(0);
            (child, picks)
        })
        .collect()
}

fn table_of(c: &CountsTable) -> &[u32] {
    match c {
        CountsTable::Dense(v) => v,
        _ => panic!("bench families must be dense"),
    }
}

fn main() -> anyhow::Result<()> {
    let wall = Timer::start();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, dflt: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let n_vars = get("--vars", 24);
    let rows_small = get("--rows", 2000);
    let rows_large = get("--rows-large", 20000);
    let nodes = get("--nodes", 60);
    let seed = get("--seed", 1) as u64;

    println!("# scoring bench: vars={n_vars} rows={rows_small}/{rows_large} ges-nodes={nodes}");

    // ---- Section 1: per-family counting, packed vs reference --------
    let mut family_cases: Vec<FamilyCase> = Vec::new();
    for card in [2u32, 4] {
        for rows in [rows_small, rows_large] {
            let data = random_data(n_vars, card, rows, seed ^ (card as u64) << 8 ^ rows as u64);
            let reference = Counter::new(data.clone(), CountConfig::reference());
            let packed = Counter::new(data.clone(), CountConfig::default());
            for parents in [0usize, 1, 2, 3] {
                let fams = draw_families(n_vars, parents, seed + parents as u64);
                // Pin every packed table to the reference before timing.
                for (child, ps) in &fams {
                    let a = reference.family_counts(*child, ps);
                    let b = packed.family_counts(*child, ps);
                    assert_eq!(
                        table_of(&a.table),
                        table_of(&b.table),
                        "packed diverged: child {child} parents {ps:?}"
                    );
                }
                let reps = (2_000_000 / rows).max(2);
                let t = Timer::start();
                for _ in 0..reps {
                    for (child, ps) in &fams {
                        black_box(reference.family_counts(*child, ps).total());
                    }
                }
                let ref_secs = t.secs();
                let t = Timer::start();
                for _ in 0..reps {
                    for (child, ps) in &fams {
                        black_box(packed.family_counts(*child, ps).total());
                    }
                }
                let packed_secs = t.secs();
                let per = |s: f64| s * 1e9 / (reps * FAMILIES) as f64;
                family_cases.push(FamilyCase {
                    card,
                    rows,
                    parents,
                    reference_ns: per(ref_secs),
                    packed_ns: per(packed_secs),
                });
            }
        }
    }
    for c in &family_cases {
        println!(
            "count card={} rows={:>6} parents={}: reference {:>10.0} ns/family, \
             packed {:>10.0} ns/family, {:.2}x",
            c.card,
            c.rows,
            c.parents,
            c.reference_ns,
            c.packed_ns,
            c.reference_ns / c.packed_ns.max(1e-12)
        );
    }

    // ---- Section 2: fused local_pair vs two independent locals ------
    let mut pair_cases: Vec<PairCase> = Vec::new();
    let data = random_data(n_vars, 3, rows_small, seed ^ 0xFA11);
    for parents in [0usize, 1, 2] {
        let fams = draw_families(n_vars - 1, parents, seed * 7 + parents as u64);
        let x = n_vars - 1; // never drawn above: always a fresh insert
        let reps = 8usize;
        let t = Timer::start();
        for _ in 0..reps {
            // Fresh scorer per rep: every probe is cold.
            let sc = BdeuScorer::new(data.clone(), 10.0);
            for (child, ps) in &fams {
                let mut sup = ps.clone();
                sup.push(x);
                black_box(sc.local_uncached(*child, &sup));
                black_box(sc.local_uncached(*child, ps));
            }
        }
        let two_pass = t.secs();
        let t = Timer::start();
        for _ in 0..reps {
            let sc = BdeuScorer::new(data.clone(), 10.0);
            for (child, ps) in &fams {
                black_box(sc.local_pair(*child, ps, x));
            }
        }
        let fused = t.secs();
        let per = |s: f64| s * 1e9 / (reps * FAMILIES) as f64;
        pair_cases.push(PairCase {
            parents,
            two_pass_ns: per(two_pass),
            fused_ns: per(fused),
        });
    }
    for c in &pair_cases {
        println!(
            "pair parents={}: two-pass {:>10.0} ns/delta, fused {:>10.0} ns/delta, {:.2}x",
            c.parents,
            c.two_pass_ns,
            c.fused_ns,
            c.two_pass_ns / c.fused_ns.max(1e-12)
        );
    }

    // ---- Section 3: end-to-end GES, packed vs reference engine ------
    let truth = generate(
        &NetGenConfig { nodes, edges: nodes + nodes / 3, ..Default::default() },
        seed,
    );
    let ges_data = Arc::new(forward_sample(&truth, rows_small, seed ^ 0xDA7A));
    let run = |mode: CountMode| {
        let cfg = CountConfig { mode, ..Default::default() };
        let sc = BdeuScorer::with_count_config(ges_data.clone(), 10.0, cfg);
        let t = Timer::start();
        let r = ges(&sc, &Dag::new(nodes), &GesConfig::default());
        (t.secs(), r, sc)
    };
    let (ref_secs, ref_r, _) = run(CountMode::Reference);
    let (packed_secs, packed_r, packed_sc) = run(CountMode::Packed);
    assert_eq!(
        ref_r.score.to_bits(),
        packed_r.score.to_bits(),
        "packed GES diverged from reference"
    );
    let (hits, misses) = packed_sc.cache().stats();
    let cs = packed_sc.count_stats();
    println!(
        "ges n={nodes}: reference {ref_secs:.2}s, packed {packed_secs:.2}s ({:.2}x); \
         evals fes={} bes={}; cache {hits}h/{misses}m; \
         counts popcount={} blocked={} dense={} sparse={} derived={} tables {}h/{}m",
        ref_secs / packed_secs.max(1e-12),
        packed_r.fes_evaluations,
        packed_r.bes_evaluations,
        cs.popcount,
        cs.blocked,
        cs.dense,
        cs.sparse,
        cs.derived,
        cs.table_hits,
        cs.table_misses
    );

    let wall_secs = wall.secs();
    let json = perf_record_json(
        n_vars,
        nodes,
        &family_cases,
        &pair_cases,
        (ref_secs, packed_secs),
        (packed_r.fes_evaluations, packed_r.bes_evaluations),
        (hits, misses),
        (cs.popcount, cs.blocked, cs.dense, cs.sparse, cs.derived, cs.table_hits, cs.table_misses),
        wall_secs,
    );
    let out = "BENCH_score.json";
    std::fs::write(out, &json)?;
    println!("\nperf record written to {out} (wall {wall_secs:.1}s)");
    Ok(())
}

/// Hand-rolled JSON (no serde offline) — same convention as the other
/// perf records.
#[allow(clippy::too_many_arguments)]
fn perf_record_json(
    vars: usize,
    ges_nodes: usize,
    family_cases: &[FamilyCase],
    pair_cases: &[PairCase],
    ges_secs: (f64, f64),
    ges_evals: (u64, u64),
    cache: (u64, u64),
    counts: (u64, u64, u64, u64, u64, u64, u64),
    wall_secs: f64,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"scoring\",");
    let _ = writeln!(s, "  \"vars\": {vars},");
    let _ = writeln!(s, "  \"families_per_cell\": {FAMILIES},");
    let _ = writeln!(s, "  \"count_cases\": [");
    for (i, c) in family_cases.iter().enumerate() {
        let comma = if i + 1 == family_cases.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"card\": {}, \"rows\": {}, \"parents\": {}, \
             \"reference_ns_per_family\": {:.1}, \"packed_ns_per_family\": {:.1}, \
             \"speedup\": {:.3}}}{comma}",
            c.card,
            c.rows,
            c.parents,
            c.reference_ns,
            c.packed_ns,
            c.reference_ns / c.packed_ns.max(1e-12)
        );
    }
    let _ = writeln!(s, "  ],");
    let _ = writeln!(s, "  \"pair_cases\": [");
    for (i, c) in pair_cases.iter().enumerate() {
        let comma = if i + 1 == pair_cases.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"parents\": {}, \"two_pass_ns_per_delta\": {:.1}, \
             \"fused_ns_per_delta\": {:.1}, \"speedup\": {:.3}}}{comma}",
            c.parents,
            c.two_pass_ns,
            c.fused_ns,
            c.two_pass_ns / c.fused_ns.max(1e-12)
        );
    }
    let _ = writeln!(s, "  ],");
    let _ = writeln!(s, "  \"ges_nodes\": {ges_nodes},");
    let _ = writeln!(s, "  \"ges_reference_secs\": {:.3},", ges_secs.0);
    let _ = writeln!(s, "  \"ges_packed_secs\": {:.3},", ges_secs.1);
    let _ = writeln!(s, "  \"ges_speedup\": {:.3},", ges_secs.0 / ges_secs.1.max(1e-12));
    let _ = writeln!(s, "  \"ges_fes_evaluations\": {},", ges_evals.0);
    let _ = writeln!(s, "  \"ges_bes_evaluations\": {},", ges_evals.1);
    let _ = writeln!(s, "  \"score_cache_hits\": {},", cache.0);
    let _ = writeln!(s, "  \"score_cache_misses\": {},", cache.1);
    let _ = writeln!(s, "  \"count_popcount\": {},", counts.0);
    let _ = writeln!(s, "  \"count_blocked\": {},", counts.1);
    let _ = writeln!(s, "  \"count_dense\": {},", counts.2);
    let _ = writeln!(s, "  \"count_sparse\": {},", counts.3);
    let _ = writeln!(s, "  \"count_derived\": {},", counts.4);
    let _ = writeln!(s, "  \"table_cache_hits\": {},", counts.5);
    let _ = writeln!(s, "  \"table_cache_misses\": {},", counts.6);
    let _ = writeln!(s, "  \"wall_secs\": {wall_secs:.2}");
    s.push_str("}\n");
    s
}
