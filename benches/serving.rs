//! Serving bench: multi-thread query throughput over one shared
//! compiled model, plus batch-vs-singleton amortization.
//!
//!   cargo bench --bench serving                        # 120-var default
//!   cargo bench --bench serving -- --nodes 200 --queries 800
//!
//! Three measurements on a fitted netgen domain:
//!
//! * **threads scaling** — the same query stream partitioned over 1,
//!   4 and 8 handler threads, each with its own `Scratch` against one
//!   `CompiledModel` (the `serve --threads` hot path, minus sockets);
//! * **singleton** — one query per propagation, cold scratch per query
//!   (PR 2 serving semantics) and warm scratch in arrival order;
//! * **batch** — the same queries processed in canonical-evidence
//!   order on one warm scratch, the `"type": "batch"` execution shape
//!   (collect messages of shared evidence prefixes are reused).
//!
//! Writes `BENCH_serve.json` so serving throughput is tracked from PR
//! to PR next to `BENCH_infer.json`/`BENCH_table2.json`.

use cges::bn::{fit, forward_sample, generate, NetGenConfig};
use cges::engine::CompiledModel;
use cges::rng::Rng;
use cges::util::Timer;

fn main() -> anyhow::Result<()> {
    let wall = Timer::start();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, dflt: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let nodes = get("--nodes", 120);
    let edges = get("--edges", 150);
    let rows = get("--rows", 2000);
    let queries = get("--queries", 400);
    let group = get("--group", 8).max(1); // queries per shared evidence prefix
    let seed = get("--seed", 1) as u64;

    println!("# serving bench: nodes={nodes} edges={edges} rows={rows} queries={queries} group={group}");

    let cfg =
        NetGenConfig { nodes, edges, max_parents: 2, card_range: (2, 3), ..Default::default() };
    let truth = generate(&cfg, seed);
    let data = forward_sample(&truth, rows, seed ^ 0xDA7A);
    let bn = fit(&truth.dag, &data, 1.0)?;

    let t = Timer::start();
    let model = CompiledModel::compile(&bn)?;
    let build_secs = t.secs();
    println!(
        "compiled: {} cliques, max clique state space {}, built in {build_secs:.3}s",
        model.n_cliques(),
        model.max_clique_states()
    );

    // Query stream with batch-like structure: `group` consecutive
    // queries share a two-variable evidence prefix and vary a third
    // variable — the shape the batch endpoint sorts for.
    let mut rng = Rng::new(seed + 17);
    let mut evidence_sets: Vec<Vec<(usize, usize)>> = Vec::with_capacity(queries);
    while evidence_sets.len() < queries {
        let a = rng.gen_range(nodes);
        let b = (a + 1 + rng.gen_range(nodes - 1)) % nodes;
        let sa = rng.gen_range(bn.cards[a] as usize);
        let sb = rng.gen_range(bn.cards[b] as usize);
        for _ in 0..group {
            if evidence_sets.len() >= queries {
                break;
            }
            let c = (b + 1 + rng.gen_range(nodes - 1)) % nodes;
            let mut ev = vec![(a, sa), (b, sb)];
            if c != a && c != b {
                ev.push((c, rng.gen_range(bn.cards[c] as usize)));
            }
            evidence_sets.push(ev);
        }
    }

    // Threads scaling: static partition of the stream, one scratch per
    // worker, shared &model.
    let mut thread_qps = [0.0f64; 3];
    for (slot, threads) in [1usize, 4, 8].into_iter().enumerate() {
        let t = Timer::start();
        std::thread::scope(|s| {
            for w in 0..threads {
                let model = &model;
                let evidence_sets = &evidence_sets;
                s.spawn(move || {
                    let mut scratch = model.new_scratch();
                    let mut i = w;
                    while i < evidence_sets.len() {
                        model
                            .marginals(&mut scratch, &evidence_sets[i])
                            .expect("bench query must succeed");
                        i += threads;
                    }
                });
            }
        });
        let qps = queries as f64 / t.secs().max(1e-9);
        thread_qps[slot] = qps;
        println!("threads {threads}: {qps:.1} full-posterior queries/sec");
    }

    // Singleton, cold scratch per query (PR 2 serving semantics).
    let t = Timer::start();
    for ev in &evidence_sets {
        let mut scratch = model.new_scratch();
        model.marginals(&mut scratch, ev)?;
    }
    let singleton_cold_qps = queries as f64 / t.secs().max(1e-9);
    println!("singleton (cold scratch): {singleton_cold_qps:.1} queries/sec");

    // Singleton, one warm scratch in arrival order.
    let t = Timer::start();
    {
        let mut scratch = model.new_scratch();
        for ev in &evidence_sets {
            model.marginals(&mut scratch, ev)?;
        }
    }
    let singleton_warm_qps = queries as f64 / t.secs().max(1e-9);
    println!("singleton (warm scratch): {singleton_warm_qps:.1} queries/sec");

    // Batch execution shape: canonical-evidence order, one warm
    // scratch — prefix collect passes are shared.
    let mut sorted_sets = evidence_sets.clone();
    for ev in &mut sorted_sets {
        ev.sort_unstable();
    }
    sorted_sets.sort();
    let t = Timer::start();
    {
        let mut scratch = model.new_scratch();
        for ev in &sorted_sets {
            model.marginals(&mut scratch, ev)?;
        }
    }
    let batch_qps = queries as f64 / t.secs().max(1e-9);
    println!("batch (evidence-sorted, warm scratch): {batch_qps:.1} queries/sec");

    let wall_secs = wall.secs();
    let json = perf_record_json(
        nodes,
        edges,
        rows,
        queries,
        group,
        build_secs,
        thread_qps,
        singleton_cold_qps,
        singleton_warm_qps,
        batch_qps,
        wall_secs,
    );
    let out = "BENCH_serve.json";
    std::fs::write(out, &json)?;
    println!("\nperf record written to {out} (wall {wall_secs:.1}s)");
    Ok(())
}

/// Hand-rolled JSON (no serde offline) — same convention as the other
/// perf records.
#[allow(clippy::too_many_arguments)]
fn perf_record_json(
    nodes: usize,
    edges: usize,
    rows: usize,
    queries: usize,
    group: usize,
    build_secs: f64,
    thread_qps: [f64; 3],
    singleton_cold_qps: f64,
    singleton_warm_qps: f64,
    batch_qps: f64,
    wall_secs: f64,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"serving\",");
    let _ = writeln!(s, "  \"nodes\": {nodes},");
    let _ = writeln!(s, "  \"edges\": {edges},");
    let _ = writeln!(s, "  \"rows\": {rows},");
    let _ = writeln!(s, "  \"queries\": {queries},");
    let _ = writeln!(s, "  \"group\": {group},");
    let _ = writeln!(s, "  \"compile_secs\": {build_secs:.4},");
    let _ = writeln!(s, "  \"qps_threads_1\": {:.2},", thread_qps[0]);
    let _ = writeln!(s, "  \"qps_threads_4\": {:.2},", thread_qps[1]);
    let _ = writeln!(s, "  \"qps_threads_8\": {:.2},", thread_qps[2]);
    let _ = writeln!(s, "  \"singleton_cold_qps\": {singleton_cold_qps:.2},");
    let _ = writeln!(s, "  \"singleton_warm_qps\": {singleton_warm_qps:.2},");
    let _ = writeln!(s, "  \"batch_qps\": {batch_qps:.2},");
    let _ = writeln!(s, "  \"wall_secs\": {wall_secs:.2}");
    s.push_str("}\n");
    s
}
