//! Serving bench: multi-thread query throughput over one shared
//! compiled model, plus batch-vs-singleton amortization.
//!
//!   cargo bench --bench serving                        # 120-var default
//!   cargo bench --bench serving -- --nodes 200 --queries 800
//!
//! Three measurements on a fitted netgen domain:
//!
//! * **threads scaling** — the same query stream partitioned over 1,
//!   4 and 8 handler threads, each with its own `Scratch` against one
//!   `CompiledModel` (the `serve --threads` hot path, minus sockets);
//! * **singleton** — one query per propagation, cold scratch per query
//!   (PR 2 serving semantics) and warm scratch in arrival order;
//! * **batch** — the same queries processed in canonical-evidence
//!   order on one warm scratch, the `"type": "batch"` execution shape
//!   (collect messages of shared evidence prefixes are reused);
//! * **runtime grid** — the fleet event loop vs the thread pool over
//!   real loopback TCP with window-8 pipelined clients, across
//!   connections 1/4/8 and (fleet) 1 or 2 hosted models with live
//!   switch churn. Tail latency comes from each runtime's own
//!   `{"type": "stats"}` endpoint; the full snapshots land in
//!   `BENCH_fleet_stats.json` / `BENCH_pool_stats.json` for the CI
//!   artifact.
//!
//! Writes `BENCH_serve.json` so serving throughput is tracked from PR
//! to PR next to `BENCH_infer.json`/`BENCH_table2.json`.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};

use cges::bn::{fit, forward_sample, generate, NetGenConfig};
use cges::engine::{CompiledModel, FleetConfig, FleetServer, ServeConfig, Server};
use cges::infer::json::Json;
use cges::infer::EngineConfig;
use cges::model::{bundle_fingerprint, Bundle, BundleMeta};
use cges::rng::Rng;
use cges::util::Timer;

/// Pipelining window per client connection.
const WINDOW: usize = 8;

fn main() -> anyhow::Result<()> {
    let wall = Timer::start();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, dflt: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let nodes = get("--nodes", 120);
    let edges = get("--edges", 150);
    let rows = get("--rows", 2000);
    let queries = get("--queries", 400);
    let group = get("--group", 8).max(1); // queries per shared evidence prefix
    let seed = get("--seed", 1) as u64;

    println!("# serving bench: nodes={nodes} edges={edges} rows={rows} queries={queries} group={group}");

    let cfg =
        NetGenConfig { nodes, edges, max_parents: 2, card_range: (2, 3), ..Default::default() };
    let truth = generate(&cfg, seed);
    let data = forward_sample(&truth, rows, seed ^ 0xDA7A);
    let bn = fit(&truth.dag, &data, 1.0)?;

    let t = Timer::start();
    let model = CompiledModel::compile(&bn)?;
    let build_secs = t.secs();
    println!(
        "compiled: {} cliques, max clique state space {}, built in {build_secs:.3}s",
        model.n_cliques(),
        model.max_clique_states()
    );

    // Query stream with batch-like structure: `group` consecutive
    // queries share a two-variable evidence prefix and vary a third
    // variable — the shape the batch endpoint sorts for.
    let mut rng = Rng::new(seed + 17);
    let mut evidence_sets: Vec<Vec<(usize, usize)>> = Vec::with_capacity(queries);
    while evidence_sets.len() < queries {
        let a = rng.gen_range(nodes);
        let b = (a + 1 + rng.gen_range(nodes - 1)) % nodes;
        let sa = rng.gen_range(bn.cards[a] as usize);
        let sb = rng.gen_range(bn.cards[b] as usize);
        for _ in 0..group {
            if evidence_sets.len() >= queries {
                break;
            }
            let c = (b + 1 + rng.gen_range(nodes - 1)) % nodes;
            let mut ev = vec![(a, sa), (b, sb)];
            if c != a && c != b {
                ev.push((c, rng.gen_range(bn.cards[c] as usize)));
            }
            evidence_sets.push(ev);
        }
    }

    // Threads scaling: static partition of the stream, one scratch per
    // worker, shared &model.
    let mut thread_qps = [0.0f64; 3];
    for (slot, threads) in [1usize, 4, 8].into_iter().enumerate() {
        let t = Timer::start();
        std::thread::scope(|s| {
            for w in 0..threads {
                let model = &model;
                let evidence_sets = &evidence_sets;
                s.spawn(move || {
                    let mut scratch = model.new_scratch();
                    let mut i = w;
                    while i < evidence_sets.len() {
                        model
                            .marginals(&mut scratch, &evidence_sets[i])
                            .expect("bench query must succeed");
                        i += threads;
                    }
                });
            }
        });
        let qps = queries as f64 / t.secs().max(1e-9);
        thread_qps[slot] = qps;
        println!("threads {threads}: {qps:.1} full-posterior queries/sec");
    }

    // Singleton, cold scratch per query (PR 2 serving semantics).
    let t = Timer::start();
    for ev in &evidence_sets {
        let mut scratch = model.new_scratch();
        model.marginals(&mut scratch, ev)?;
    }
    let singleton_cold_qps = queries as f64 / t.secs().max(1e-9);
    println!("singleton (cold scratch): {singleton_cold_qps:.1} queries/sec");

    // Singleton, one warm scratch in arrival order.
    let t = Timer::start();
    {
        let mut scratch = model.new_scratch();
        for ev in &evidence_sets {
            model.marginals(&mut scratch, ev)?;
        }
    }
    let singleton_warm_qps = queries as f64 / t.secs().max(1e-9);
    println!("singleton (warm scratch): {singleton_warm_qps:.1} queries/sec");

    // Batch execution shape: canonical-evidence order, one warm
    // scratch — prefix collect passes are shared.
    let mut sorted_sets = evidence_sets.clone();
    for ev in &mut sorted_sets {
        ev.sort_unstable();
    }
    sorted_sets.sort();
    let t = Timer::start();
    {
        let mut scratch = model.new_scratch();
        for ev in &sorted_sets {
            model.marginals(&mut scratch, ev)?;
        }
    }
    let batch_qps = queries as f64 / t.secs().max(1e-9);
    println!("batch (evidence-sorted, warm scratch): {batch_qps:.1} queries/sec");

    // ---- Runtime grid: fleet event loop vs thread pool over TCP ----

    // Two distinguishable models: the fitted network and a heavier
    // smoothed refit (different CPTs, same structure), so the fleet's
    // two-model cells churn a real hot swap under load.
    let meta_a = BundleMeta { producer: "bench-a".into(), rounds: 0, score: 0.0, ess: 1.0 };
    let bundle_a = Bundle::calibrated_within(bn.clone(), meta_a, u64::MAX);
    let meta_b = BundleMeta { producer: "bench-b".into(), rounds: 0, score: 0.0, ess: 5.0 };
    let bundle_b = Bundle::calibrated_within(fit(&truth.dag, &data, 5.0)?, meta_b, u64::MAX);

    // The framed request stream: one marginal query per evidence set.
    let req_texts: Vec<String> = evidence_sets
        .iter()
        .enumerate()
        .map(|(i, ev)| {
            let cells: Vec<String> =
                ev.iter().map(|&(v, s)| format!("\"{}\": {s}", bn.names[v])).collect();
            format!(r#"{{"id": {i}, "type": "marginal", "evidence": {{{}}}}}"#, cells.join(", "))
        })
        .collect();

    let mut fleet_qps = [[0.0f64; 2]; 3]; // [conns slot][models slot]
    let mut pool_qps = [0.0f64; 3];
    let mut fleet_stats = String::new();
    let mut pool_stats = String::new();
    let mut fleet_p99 = 0.0f64;
    let mut pool_p99 = 0.0f64;
    for (slot, conns) in [1usize, 4, 8].into_iter().enumerate() {
        for (mslot, n_models) in [1usize, 2].into_iter().enumerate() {
            let fleet = FleetServer::new(
                EngineConfig::default(),
                FleetConfig { workers: 4, ..Default::default() },
            );
            fleet.load_bundle(&bundle_a)?;
            if n_models == 2 {
                fleet.load_bundle(&bundle_b)?;
            }
            let listener = TcpListener::bind(("127.0.0.1", 0))?;
            let addr = listener.local_addr()?;
            let qps = std::thread::scope(|s| {
                let fleet = &fleet;
                let server = s.spawn(move || fleet.serve(&listener, None).unwrap());
                // Live hot-swap churn while the clients drive load.
                let stop = std::sync::atomic::AtomicBool::new(false);
                let churn = if n_models == 2 {
                    let stop = &stop;
                    let fps = [bundle_fingerprint(&bundle_a), bundle_fingerprint(&bundle_b)];
                    Some(s.spawn(move || {
                        let mut flip = 0usize;
                        while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                            flip += 1;
                            fleet.switch_to(fps[flip % 2]).unwrap();
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                    }))
                } else {
                    None
                };
                let t = Timer::start();
                drive_clients(addr, conns, &req_texts);
                let qps = req_texts.len() as f64 / t.secs().max(1e-9);
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
                if let Some(h) = churn {
                    h.join().unwrap();
                }
                shutdown(addr);
                server.join().unwrap();
                qps
            });
            fleet_qps[slot][mslot] = qps;
            println!("fleet conns {conns} models {n_models}: {qps:.1} queries/sec");
            if conns == 8 && n_models == 2 {
                fleet_stats = fleet.handle(r#"{"id": 0, "type": "stats"}"#);
                fleet_p99 = stats_p99(&fleet_stats);
            }
        }

        let pool = Server::from_bundle(
            &bundle_a,
            &EngineConfig::default(),
            ServeConfig { threads: 4, ..Default::default() },
        )?;
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let addr = listener.local_addr()?;
        let qps = std::thread::scope(|s| {
            let pool = &pool;
            let server = s.spawn(move || pool.serve_tcp(&listener, None).unwrap());
            let t = Timer::start();
            drive_clients(addr, conns, &req_texts);
            let qps = req_texts.len() as f64 / t.secs().max(1e-9);
            shutdown(addr);
            server.join().unwrap();
            qps
        });
        pool_qps[slot] = qps;
        println!("pool  conns {conns}: {qps:.1} queries/sec");
        if conns == 8 {
            let mut scratch = pool.new_scratch();
            pool_stats = pool.handle(&mut scratch, r#"{"id": 0, "type": "stats"}"#);
            pool_p99 = stats_p99(&pool_stats);
        }
    }
    println!(
        "p99 serve.latency_ns (conns 8): fleet {fleet_p99:.0} vs pool {pool_p99:.0} \
         (from each runtime's stats endpoint)"
    );
    std::fs::write("BENCH_fleet_stats.json", &fleet_stats)?;
    std::fs::write("BENCH_pool_stats.json", &pool_stats)?;
    println!("stats snapshots written to BENCH_fleet_stats.json / BENCH_pool_stats.json");

    let wall_secs = wall.secs();
    let json = perf_record_json(&PerfRecord {
        nodes,
        edges,
        rows,
        queries,
        group,
        build_secs,
        thread_qps,
        singleton_cold_qps,
        singleton_warm_qps,
        batch_qps,
        fleet_qps,
        pool_qps,
        fleet_p99,
        pool_p99,
        wall_secs,
    });
    let out = "BENCH_serve.json";
    std::fs::write(out, &json)?;
    println!("\nperf record written to {out} (wall {wall_secs:.1}s)");
    Ok(())
}

fn send_frame(writer: &mut impl Write, payload: &str) {
    let bytes = payload.as_bytes();
    writer.write_all(&(bytes.len() as u32).to_le_bytes()).unwrap();
    writer.write_all(bytes).unwrap();
    writer.flush().unwrap();
}

fn recv_frame(reader: &mut impl Read) -> String {
    let mut len_bytes = [0u8; 4];
    reader.read_exact(&mut len_bytes).unwrap();
    let len = u32::from_le_bytes(len_bytes) as usize;
    let mut payload = vec![0u8; len];
    reader.read_exact(&mut payload).unwrap();
    String::from_utf8(payload).unwrap()
}

/// Drive `conns` concurrent clients, each pipelining its share of the
/// request stream [`WINDOW`] frames deep.
fn drive_clients(addr: std::net::SocketAddr, conns: usize, reqs: &[String]) {
    std::thread::scope(|s| {
        for c in 0..conns {
            s.spawn(move || {
                let mine: Vec<&String> = reqs.iter().skip(c).step_by(conns).collect();
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream);
                let mut sent = 0usize;
                while sent < mine.len().min(WINDOW) {
                    send_frame(&mut writer, mine[sent]);
                    sent += 1;
                }
                for _ in 0..mine.len() {
                    let resp = recv_frame(&mut reader);
                    assert!(resp.contains("\"ok\": true"), "bench query failed: {resp}");
                    if sent < mine.len() {
                        send_frame(&mut writer, mine[sent]);
                        sent += 1;
                    }
                }
            });
        }
    });
}

/// Stop a serving runtime via its own wire protocol.
fn shutdown(addr: std::net::SocketAddr) {
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);
    send_frame(&mut writer, r#"{"type": "shutdown"}"#);
    recv_frame(&mut reader);
}

/// `serve.latency_ns` p99 out of a `{"type": "stats"}` response.
fn stats_p99(stats_response: &str) -> f64 {
    Json::parse(stats_response)
        .ok()
        .and_then(|v| {
            v.get("stats")?
                .get("histograms")?
                .get("serve.latency_ns")?
                .get("p99")?
                .as_f64()
        })
        .unwrap_or(0.0)
}

/// Everything the perf record captures.
struct PerfRecord {
    nodes: usize,
    edges: usize,
    rows: usize,
    queries: usize,
    group: usize,
    build_secs: f64,
    thread_qps: [f64; 3],
    singleton_cold_qps: f64,
    singleton_warm_qps: f64,
    batch_qps: f64,
    /// Fleet qps, `[connections 1/4/8][models 1/2]`.
    fleet_qps: [[f64; 2]; 3],
    /// Thread-pool qps at connections 1/4/8.
    pool_qps: [f64; 3],
    fleet_p99: f64,
    pool_p99: f64,
    wall_secs: f64,
}

/// Hand-rolled JSON (no serde offline) — same convention as the other
/// perf records.
fn perf_record_json(r: &PerfRecord) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"serving\",");
    let _ = writeln!(s, "  \"nodes\": {},", r.nodes);
    let _ = writeln!(s, "  \"edges\": {},", r.edges);
    let _ = writeln!(s, "  \"rows\": {},", r.rows);
    let _ = writeln!(s, "  \"queries\": {},", r.queries);
    let _ = writeln!(s, "  \"group\": {},", r.group);
    let _ = writeln!(s, "  \"compile_secs\": {:.4},", r.build_secs);
    let _ = writeln!(s, "  \"qps_threads_1\": {:.2},", r.thread_qps[0]);
    let _ = writeln!(s, "  \"qps_threads_4\": {:.2},", r.thread_qps[1]);
    let _ = writeln!(s, "  \"qps_threads_8\": {:.2},", r.thread_qps[2]);
    let _ = writeln!(s, "  \"singleton_cold_qps\": {:.2},", r.singleton_cold_qps);
    let _ = writeln!(s, "  \"singleton_warm_qps\": {:.2},", r.singleton_warm_qps);
    let _ = writeln!(s, "  \"batch_qps\": {:.2},", r.batch_qps);
    for (slot, conns) in [1usize, 4, 8].into_iter().enumerate() {
        for (mslot, n_models) in [1usize, 2].into_iter().enumerate() {
            let _ = writeln!(
                s,
                "  \"fleet_qps_c{conns}_m{n_models}\": {:.2},",
                r.fleet_qps[slot][mslot]
            );
        }
        let _ = writeln!(s, "  \"pool_qps_c{conns}\": {:.2},", r.pool_qps[slot]);
    }
    let _ = writeln!(s, "  \"fleet_p99_latency_ns\": {:.0},", r.fleet_p99);
    let _ = writeln!(s, "  \"pool_p99_latency_ns\": {:.0},", r.pool_p99);
    let _ = writeln!(s, "  \"wall_secs\": {:.2}", r.wall_secs);
    s.push_str("}\n");
    s
}
