//! Inference bench: jointree build time and queries/sec on a
//! netgen domain, with VE and likelihood weighting as comparators.
//!
//!   cargo bench --bench inference                       # 120-var default
//!   cargo bench --bench inference -- --nodes 400 --queries 100
//!
//! Each "query" is one random single-variable evidence set; the
//! jointree path answers with *all* marginals (the serve shape), VE
//! answers one random target marginal, LW answers all marginals from
//! `--samples` particles. Writes `BENCH_infer.json` so the perf
//! trajectory is tracked from PR to PR next to `BENCH_table2.json`.

use cges::bn::{fit, forward_sample, generate, NetGenConfig};
use cges::graph::moral_graph;
use cges::infer::{likelihood_weighting, triangulate, ve_marginal, JoinTree};
use cges::rng::Rng;
use cges::util::Timer;

/// Past this clique state space the exact engine is skipped (matches
/// the serve path's auto fallback).
const EXACT_BUDGET: u64 = 1 << 24;

fn main() -> anyhow::Result<()> {
    let wall = Timer::start();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, dflt: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let nodes = get("--nodes", 120);
    let edges = get("--edges", 150);
    let rows = get("--rows", 2000);
    let queries = get("--queries", 200);
    let samples = get("--samples", 2000);
    let seed = get("--seed", 1) as u64;

    println!("# inference bench: nodes={nodes} edges={edges} rows={rows} queries={queries} lw_samples={samples}");

    let cfg = NetGenConfig { nodes, edges, max_parents: 2, card_range: (2, 3), ..Default::default() };
    let truth = generate(&cfg, seed);
    let data = forward_sample(&truth, rows, seed ^ 0xDA7A);

    let t = Timer::start();
    let bn = fit(&truth.dag, &data, 1.0)?;
    let fit_secs = t.secs();
    println!("fit: {} parameters in {fit_secs:.3}s", bn.parameter_count());

    let tri = triangulate(&moral_graph(&bn.dag), &bn.cards);
    println!(
        "treewidth proxy: max clique {} vars / {} states",
        tri.max_clique_vars, tri.max_clique_states
    );

    let (build_secs, jointree_qps) = if tri.max_clique_states <= EXACT_BUDGET {
        let t = Timer::start();
        let jt = JoinTree::build(&bn)?;
        let build_secs = t.secs();
        println!("jointree: {} cliques built in {build_secs:.3}s", jt.n_cliques());

        let mut rng = Rng::new(seed + 11);
        let t = Timer::start();
        for _ in 0..queries {
            let v = rng.gen_range(nodes);
            let s = rng.gen_range(bn.cards[v] as usize);
            jt.posterior(&[(v, s)])?;
        }
        let qps = queries as f64 / t.secs().max(1e-9);
        println!("jointree: {qps:.1} full-posterior queries/sec");
        (build_secs, qps)
    } else {
        println!("jointree: skipped (past exact budget {EXACT_BUDGET})");
        (0.0, 0.0)
    };

    // VE: one random target marginal per query.
    let mut rng = Rng::new(seed + 23);
    let t = Timer::start();
    let mut ve_ok = 0usize;
    for _ in 0..queries {
        let v = rng.gen_range(nodes);
        let s = rng.gen_range(bn.cards[v] as usize);
        let target = (v + 1 + rng.gen_range(nodes - 1)) % nodes;
        if ve_marginal(&bn, target, &[(v, s)]).is_ok() {
            ve_ok += 1;
        }
    }
    let ve_qps = ve_ok as f64 / t.secs().max(1e-9);
    println!("ve: {ve_qps:.1} single-marginal queries/sec ({ve_ok}/{queries} within cap)");

    // LW: all marginals from `samples` particles per query.
    let mut rng = Rng::new(seed + 37);
    let t = Timer::start();
    for i in 0..queries {
        let v = rng.gen_range(nodes);
        let s = rng.gen_range(bn.cards[v] as usize);
        likelihood_weighting(&bn, &[(v, s)], samples, seed + i as u64)?;
    }
    let lw_qps = queries as f64 / t.secs().max(1e-9);
    println!("lw: {lw_qps:.1} sampled-posterior queries/sec");

    let wall_secs = wall.secs();
    let json = perf_record_json(
        nodes,
        edges,
        rows,
        queries,
        samples,
        (tri.max_clique_vars, tri.max_clique_states),
        fit_secs,
        build_secs,
        [jointree_qps, ve_qps, lw_qps],
        wall_secs,
    );
    let out = "BENCH_infer.json";
    std::fs::write(out, &json)?;
    println!("\nperf record written to {out} (wall {wall_secs:.1}s)");
    Ok(())
}

/// Hand-rolled JSON (no serde offline) — same convention as table2.
#[allow(clippy::too_many_arguments)]
fn perf_record_json(
    nodes: usize,
    edges: usize,
    rows: usize,
    queries: usize,
    samples: usize,
    tri: (usize, u64),
    fit_secs: f64,
    build_secs: f64,
    qps: [f64; 3],
    wall_secs: f64,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"inference\",");
    let _ = writeln!(s, "  \"nodes\": {nodes},");
    let _ = writeln!(s, "  \"edges\": {edges},");
    let _ = writeln!(s, "  \"rows\": {rows},");
    let _ = writeln!(s, "  \"queries\": {queries},");
    let _ = writeln!(s, "  \"lw_samples\": {samples},");
    let _ = writeln!(s, "  \"max_clique_vars\": {},", tri.0);
    let _ = writeln!(s, "  \"max_clique_states\": {},", tri.1);
    let _ = writeln!(s, "  \"fit_secs\": {fit_secs:.4},");
    let _ = writeln!(s, "  \"jointree_build_secs\": {build_secs:.4},");
    let _ = writeln!(s, "  \"jointree_qps\": {:.2},", qps[0]);
    let _ = writeln!(s, "  \"ve_qps\": {:.2},", qps[1]);
    let _ = writeln!(s, "  \"lw_qps\": {:.2},", qps[2]);
    let _ = writeln!(s, "  \"wall_secs\": {wall_secs:.2}");
    s.push_str("}\n");
    s
}
