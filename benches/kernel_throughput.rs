//! Stage-1 similarity throughput: the AOT XLA artifact (L1 Pallas
//! kernel under PJRT) vs the threaded Rust fallback, across dataset
//! shapes. Reports wall time and effective pair-score throughput —
//! the L1/L2 half of the §Perf record in EXPERIMENTS.md.
//!
//!   cargo bench --bench kernel_throughput -- [--rows 2000] [--reps 3]

use std::path::PathBuf;
use std::sync::Arc;

use cges::bn::{forward_sample, generate, NetGenConfig};
use cges::score::pairwise_similarity;
use cges::util::Timer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str| -> Option<String> {
        args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
    };
    let rows: usize = get("--rows").and_then(|v| v.parse().ok()).unwrap_or(2000);
    let reps: usize = get("--reps").and_then(|v| v.parse().ok()).unwrap_or(3);
    let threads = cges::util::num_threads();

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let runtime = cges::runtime::SimilarityRuntime::load(&artifacts).ok();
    println!(
        "# kernel_throughput: rows={rows} reps={reps} threads={threads} xla={}",
        runtime.is_some()
    );
    println!(
        "{:>6} {:>6} {:>6} | {:>12} {:>12} | {:>12} {:>12}",
        "n", "m", "r", "rust(s)", "Mpairs/s", "xla(s)", "Mpairs/s"
    );

    for &(n, r_max) in &[(64usize, 4u32), (128, 4), (128, 8), (256, 8)] {
        let bn = generate(
            &NetGenConfig {
                nodes: n,
                edges: n * 3 / 2,
                card_range: (2, r_max),
                ..Default::default()
            },
            99,
        );
        let data = Arc::new(forward_sample(&bn, rows, 7));
        let pairs = (n * n) as f64 / 1e6;

        // Rust fallback.
        let mut rust_best = f64::INFINITY;
        for _ in 0..reps {
            let t = Timer::start();
            let s = pairwise_similarity(&data, 10.0, threads);
            std::hint::black_box(&s.s);
            rust_best = rust_best.min(t.secs());
        }

        // XLA artifact (compile once, measure steady-state execution).
        let (xla_s, xla_tp) = match &runtime {
            Some(rt) if rt.supports(&data) => {
                let _warm = rt.pairwise(&data, 10.0)?; // includes compile
                let mut best = f64::INFINITY;
                for _ in 0..reps {
                    let t = Timer::start();
                    let s = rt.pairwise(&data, 10.0)?;
                    std::hint::black_box(&s.s);
                    best = best.min(t.secs());
                }
                (format!("{best:.3}"), format!("{:.2}", pairs / best))
            }
            _ => ("n/a".into(), "-".into()),
        };

        println!(
            "{:>6} {:>6} {:>6} | {:>12.3} {:>12.2} | {:>12} {:>12}",
            n,
            rows,
            r_max,
            rust_best,
            pairs / rust_best,
            xla_s,
            xla_tp
        );
    }
    println!(
        "\nNote: the XLA path runs the Pallas kernel in interpret mode on the CPU\n\
         PJRT plugin and pads to the artifact's static shape — absolute numbers\n\
         measure the AOT plumbing, not TPU kernel performance (see DESIGN.md §7)."
    );
    Ok(())
}
