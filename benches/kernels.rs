//! Factor-kernel bench: blocked kernels vs the retained scalar
//! reference, per table size and operand shape, plus the end-to-end
//! serving effect.
//!
//!   cargo bench --bench kernels                  # default sizes
//!   cargo bench --bench kernels -- --nodes 200 --queries 200
//!
//! Three microbench ops on random factors over `k` variables of
//! cardinality 4 (tables of 4^k cells), each against three operand
//! shapes — the operand scope a *prefix* of the walk (stride-1 inner
//! runs for the operand), a *suffix* (stride-1 runs for the walk,
//! constant operand), and *interleaved* (worst case, small blocks):
//!
//! * **product** — `Factor::product` vs `reference::product`;
//! * **marginalize** — `Factor::marginalize_to` vs
//!   `reference::marginalize_to`;
//! * **fused** — `kernel::absorb_marginalize_into` vs scalar
//!   product-then-marginalize (the collect-message shape).
//!
//! Every blocked result is checked bit-identical to its scalar
//! counterpart before timing. The serving section fits a netgen
//! domain and compares `CompiledModel::marginals` (warm scratch and
//! cold scratch) against `marginals_reference` (the pre-rework scalar
//! engine). Writes `BENCH_kernels.json` so the kernel speedups are
//! tracked from PR to PR next to the other perf records.

use std::hint::black_box;

use cges::bn::{fit, forward_sample, generate, NetGenConfig};
use cges::engine::CompiledModel;
use cges::infer::factor::Factor;
use cges::infer::kernel::{self, reference};
use cges::rng::Rng;
use cges::util::Timer;

/// Past this clique state space the engine section is skipped
/// (matches the serve path's auto fallback).
const EXACT_BUDGET: u64 = 1 << 24;
const CARD: usize = 4;

struct Case {
    op: &'static str,
    shape: &'static str,
    cells: usize,
    scalar_ns: f64,
    blocked_ns: f64,
}

fn random_factor(vars: Vec<usize>, rng: &mut Rng) -> Factor {
    let cards = vec![CARD; vars.len()];
    let size = CARD.pow(vars.len() as u32);
    let table: Vec<f64> = (0..size).map(|_| rng.f64() + 0.01).collect();
    Factor { vars, cards, table }
}

/// Operand/kept variable pattern over a walk of `k` vars (global ids
/// `0..k`): the first half, the last half, or every other variable.
fn pattern(k: usize, shape: &str) -> Vec<usize> {
    match shape {
        "prefix" => (0..k / 2).collect(),
        "suffix" => (k / 2..k).collect(),
        _ => (0..k).step_by(2).collect(),
    }
}

fn time_pair(
    reps: usize,
    mut scalar: impl FnMut() -> f64,
    mut blocked: impl FnMut() -> f64,
) -> (f64, f64) {
    // One checked warm-up call each, then timed loops.
    let a = scalar();
    let b = blocked();
    assert_eq!(a.to_bits(), b.to_bits(), "blocked kernel diverged from scalar reference");
    let t = Timer::start();
    let mut acc = 0.0;
    for _ in 0..reps {
        acc += scalar();
    }
    let scalar_secs = t.secs();
    black_box(acc);
    let t = Timer::start();
    let mut acc = 0.0;
    for _ in 0..reps {
        acc += blocked();
    }
    let blocked_secs = t.secs();
    black_box(acc);
    (scalar_secs, blocked_secs)
}

fn main() -> anyhow::Result<()> {
    let wall = Timer::start();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, dflt: usize| -> usize {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(dflt)
    };
    let nodes = get("--nodes", 120);
    let edges = get("--edges", 150);
    let rows = get("--rows", 2000);
    let queries = get("--queries", 200);
    let seed = get("--seed", 1) as u64;

    println!("# kernel bench: card={CARD} nodes={nodes} edges={edges} queries={queries}");

    let mut cases: Vec<Case> = Vec::new();
    let mut rng = Rng::new(seed ^ 0x5EED);
    for k in [4usize, 6, 8] {
        let cells = CARD.pow(k as u32);
        let reps = (8_000_000 / cells).max(8);
        let walk = random_factor((0..k).collect(), &mut rng);
        for shape in ["prefix", "suffix", "interleaved"] {
            let sub_vars = pattern(k, shape);
            let sub = random_factor(sub_vars.clone(), &mut rng);

            // product: clique × message.
            let (s, b) = time_pair(
                reps,
                || reference::product(&walk, &sub).table.iter().sum::<f64>(),
                || Factor::product(&walk, &sub).table.iter().sum::<f64>(),
            );
            cases.push(Case {
                op: "product",
                shape,
                cells,
                scalar_ns: ns_per_cell(s, reps, cells),
                blocked_ns: ns_per_cell(b, reps, cells),
            });

            // marginalize: clique → separator.
            let (s, b) = time_pair(
                reps,
                || reference::marginalize_to(&walk, &sub_vars).table.iter().sum::<f64>(),
                || walk.marginalize_to(&sub_vars).table.iter().sum::<f64>(),
            );
            cases.push(Case {
                op: "marginalize",
                shape,
                cells,
                scalar_ns: ns_per_cell(s, reps, cells),
                blocked_ns: ns_per_cell(b, reps, cells),
            });

            // fused absorb-and-marginalize vs scalar product + marginalize,
            // into a retained buffer (the zero-allocation serving shape).
            let mut sm = Vec::new();
            kernel::subset_strides_into(&walk.vars, &walk.cards, &sub.vars, &mut sm);
            let out_size = CARD.pow(sub_vars.len() as u32);
            let mut out = vec![0.0; out_size];
            let (s, b) = time_pair(
                reps,
                || {
                    let p = reference::product(&walk, &sub);
                    reference::marginalize_to(&p, &sub_vars).table.iter().sum::<f64>()
                },
                || {
                    kernel::absorb_marginalize_into(
                        &mut out, &walk.table, &sub.table, &walk.cards, &sm, &sm, false,
                    );
                    out.iter().sum::<f64>()
                },
            );
            cases.push(Case {
                op: "fused",
                shape,
                cells,
                scalar_ns: ns_per_cell(s, reps, cells),
                blocked_ns: ns_per_cell(b, reps, cells),
            });
        }
    }
    for c in &cases {
        println!(
            "{:<12} {:<12} {:>8} cells: scalar {:>7.2} ns/cell, blocked {:>7.2} ns/cell, {:.2}x",
            c.op,
            c.shape,
            c.cells,
            c.scalar_ns,
            c.blocked_ns,
            c.scalar_ns / c.blocked_ns.max(1e-12)
        );
    }

    // End-to-end: the serving engine against its retained scalar self.
    let cfg =
        NetGenConfig { nodes, edges, max_parents: 2, card_range: (2, 3), ..Default::default() };
    let truth = generate(&cfg, seed);
    let data = forward_sample(&truth, rows, seed ^ 0xDA7A);
    let bn = fit(&truth.dag, &data, 1.0)?;
    let model = CompiledModel::compile(&bn)?;
    let serving = if model.max_clique_states() <= EXACT_BUDGET {
        let evidence: Vec<(usize, usize)> = {
            let mut r = Rng::new(seed + 11);
            (0..queries)
                .map(|_| {
                    let v = r.gen_range(nodes);
                    (v, r.gen_range(bn.cards[v] as usize))
                })
                .collect()
        };
        let t = Timer::start();
        for &(v, st) in &evidence {
            black_box(model.marginals_reference(&[(v, st)])?);
        }
        let scalar_qps = queries as f64 / t.secs().max(1e-9);
        let t = Timer::start();
        for &(v, st) in &evidence {
            let mut s = model.new_scratch();
            black_box(model.marginals(&mut s, &[(v, st)])?);
        }
        let cold_qps = queries as f64 / t.secs().max(1e-9);
        let mut s = model.new_scratch();
        let t = Timer::start();
        for &(v, st) in &evidence {
            black_box(model.marginals(&mut s, &[(v, st)])?);
        }
        let warm_qps = queries as f64 / t.secs().max(1e-9);
        println!(
            "serving: scalar {scalar_qps:.1} q/s, blocked cold {cold_qps:.1} q/s, \
             blocked warm {warm_qps:.1} q/s"
        );
        Some((scalar_qps, cold_qps, warm_qps))
    } else {
        println!("serving: skipped (past exact budget {EXACT_BUDGET})");
        None
    };

    let wall_secs = wall.secs();
    let json = perf_record_json(nodes, edges, rows, queries, &cases, serving, wall_secs);
    let out = "BENCH_kernels.json";
    std::fs::write(out, &json)?;
    println!("\nperf record written to {out} (wall {wall_secs:.1}s)");
    Ok(())
}

fn ns_per_cell(secs: f64, reps: usize, cells: usize) -> f64 {
    secs * 1e9 / (reps as f64 * cells as f64)
}

/// Hand-rolled JSON (no serde offline) — same convention as the other
/// perf records.
fn perf_record_json(
    nodes: usize,
    edges: usize,
    rows: usize,
    queries: usize,
    cases: &[Case],
    serving: Option<(f64, f64, f64)>,
    wall_secs: f64,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"kernels\",");
    let _ = writeln!(s, "  \"card\": {CARD},");
    let _ = writeln!(s, "  \"nodes\": {nodes},");
    let _ = writeln!(s, "  \"edges\": {edges},");
    let _ = writeln!(s, "  \"rows\": {rows},");
    let _ = writeln!(s, "  \"queries\": {queries},");
    let _ = writeln!(s, "  \"cases\": [");
    for (i, c) in cases.iter().enumerate() {
        let comma = if i + 1 == cases.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"op\": \"{}\", \"shape\": \"{}\", \"cells\": {}, \
             \"scalar_ns_per_cell\": {:.3}, \"blocked_ns_per_cell\": {:.3}, \
             \"speedup\": {:.3}}}{comma}",
            c.op,
            c.shape,
            c.cells,
            c.scalar_ns,
            c.blocked_ns,
            c.scalar_ns / c.blocked_ns.max(1e-12)
        );
    }
    let _ = writeln!(s, "  ],");
    match serving {
        Some((scalar, cold, warm)) => {
            let _ = writeln!(s, "  \"serving_scalar_qps\": {scalar:.2},");
            let _ = writeln!(s, "  \"serving_blocked_cold_qps\": {cold:.2},");
            let _ = writeln!(s, "  \"serving_blocked_warm_qps\": {warm:.2},");
            let _ = writeln!(s, "  \"serving_speedup_warm\": {:.3},", warm / scalar.max(1e-12));
        }
        None => {
            let _ = writeln!(s, "  \"serving_scalar_qps\": null,");
            let _ = writeln!(s, "  \"serving_blocked_cold_qps\": null,");
            let _ = writeln!(s, "  \"serving_blocked_warm_qps\": null,");
            let _ = writeln!(s, "  \"serving_speedup_warm\": null,");
        }
    }
    let _ = writeln!(s, "  \"wall_secs\": {wall_secs:.2}");
    s.push_str("}\n");
    s
}
