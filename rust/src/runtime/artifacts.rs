//! Artifact registry: discover AOT-compiled HLO modules and pick the
//! cheapest shape-config a dataset fits into.
//!
//! `make artifacts` (python/compile/aot.py) writes one
//! `similarity_<name>.hlo.txt` per static shape-config plus a
//! `manifest.txt` with `name n m r_max block file` lines. HLO shapes
//! are static, so a dataset is padded up to the chosen config:
//! * padded instances/cells carry state `r_max`, which the kernel's
//!   one-hot iota comparison maps to zero contribution;
//! * padded variables carry cardinality 1 and are cropped from the
//!   result.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One exported shape-config.
#[derive(Clone, Debug)]
pub struct ArtifactConfig {
    pub name: String,
    pub n: usize,
    pub m: usize,
    pub r_max: usize,
    pub block: usize,
    pub path: PathBuf,
}

impl ArtifactConfig {
    /// Padded-problem cost proxy (execution time scales with n²·m·r²).
    pub fn cost(&self) -> u128 {
        (self.n as u128) * (self.n as u128) * (self.m as u128) * (self.r_max as u128).pow(2)
    }

    /// Does a dataset with the given shape fit?
    pub fn fits(&self, n: usize, m: usize, max_card: usize) -> bool {
        self.n >= n && self.m >= m && self.r_max >= max_card
    }
}

/// Parse `manifest.txt` in an artifacts directory.
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactConfig>> {
    let manifest = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&manifest)
        .with_context(|| format!("read {} (run `make artifacts`)", manifest.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 6 {
            bail!("manifest line {}: expected 6 fields, got {}", lineno + 1, f.len());
        }
        out.push(ArtifactConfig {
            name: f[0].to_string(),
            n: f[1].parse().context("n")?,
            m: f[2].parse().context("m")?,
            r_max: f[3].parse().context("r_max")?,
            block: f[4].parse().context("block")?,
            path: dir.join(f[5]),
        });
    }
    Ok(out)
}

/// Cheapest config that fits `(n, m, max_card)`.
pub fn pick_config<'a>(
    configs: &'a [ArtifactConfig],
    n: usize,
    m: usize,
    max_card: usize,
) -> Option<&'a ArtifactConfig> {
    configs
        .iter()
        .filter(|c| c.fits(n, m, max_card))
        .min_by_key(|c| c.cost())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfgs() -> Vec<ArtifactConfig> {
        let mk = |name: &str, n, m, r| ArtifactConfig {
            name: name.into(),
            n,
            m,
            r_max: r,
            block: 8,
            path: PathBuf::from(format!("{name}.hlo.txt")),
        };
        vec![mk("small", 128, 1024, 8), mk("large", 512, 5000, 8), mk("wide", 1088, 5000, 22)]
    }

    #[test]
    fn picks_cheapest_fit() {
        let c = cfgs();
        assert_eq!(pick_config(&c, 100, 1000, 4).unwrap().name, "small");
        assert_eq!(pick_config(&c, 300, 5000, 8).unwrap().name, "large");
        assert_eq!(pick_config(&c, 300, 5000, 21).unwrap().name, "wide");
        assert!(pick_config(&c, 2000, 5000, 8).is_none());
    }

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join("cges_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "tiny 32 256 4 8 similarity_tiny.hlo.txt\n# comment\nsmall 128 1024 8 8 similarity_small.hlo.txt\n",
        )
        .unwrap();
        let cfgs = read_manifest(&dir).unwrap();
        assert_eq!(cfgs.len(), 2);
        assert_eq!(cfgs[0].name, "tiny");
        assert_eq!(cfgs[1].n, 128);
        std::fs::remove_dir_all(&dir).ok();
    }
}
