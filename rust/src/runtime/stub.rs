//! Offline stand-in for the PJRT executor (built when the `xla` cargo
//! feature is off, i.e. in environments without the `xla` crate).
//!
//! [`SimilarityRuntime`] here is an *uninhabited* type: `load` always
//! fails with an explanatory error, so no value of the type can exist
//! and the artifact code paths are provably dead. Callers keep
//! compiling unchanged and take their documented Rust-fallback branch
//! (`score::pairwise_similarity`).

use std::path::Path;

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::runtime::artifacts::ArtifactConfig;
use crate::score::PairwiseScores;

/// Uninhabited placeholder for the PJRT-backed similarity executor.
pub enum SimilarityRuntime {}

impl SimilarityRuntime {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        bail!(
            "built without the `xla` feature; cannot execute artifacts in {} \
             (rebuild with `--features xla` and the xla crate available, \
             or drop --artifacts to use the Rust fallback)",
            artifacts_dir.display()
        )
    }

    /// Platform string (never reachable: the type is uninhabited).
    pub fn platform(&self) -> String {
        match *self {}
    }

    /// Available shape-configs (never reachable).
    pub fn configs(&self) -> &[ArtifactConfig] {
        match *self {}
    }

    /// Does some config fit this dataset? (never reachable).
    pub fn supports(&self, _data: &Dataset) -> bool {
        match *self {}
    }

    /// Execute the similarity model (never reachable).
    pub fn pairwise(&self, _data: &Dataset, _ess: f64) -> Result<PairwiseScores> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = SimilarityRuntime::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("xla"), "error should name the missing feature: {msg}");
    }
}
