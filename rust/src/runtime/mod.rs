//! AOT bridge: load `artifacts/*.hlo.txt` (lowered once from the JAX
//! L2 model) and execute them via the PJRT CPU client on the Rust
//! learning path.
//!
//! The PJRT executor needs the `xla` crate, which the offline build
//! environment does not provide. It is therefore gated behind the
//! `xla` cargo feature: without it, [`SimilarityRuntime`] is the
//! uninhabited stub from [`stub`] whose `load` fails with a clear
//! message, and all callers (coordinator stage 1, the `partition`
//! subcommand, benches) fall back to `score::pairwise_similarity`.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(not(feature = "xla"))]
pub mod stub;

pub use artifacts::{pick_config, read_manifest, ArtifactConfig};
#[cfg(feature = "xla")]
pub use pjrt::SimilarityRuntime;
#[cfg(not(feature = "xla"))]
pub use stub::SimilarityRuntime;
