//! AOT bridge: load `artifacts/*.hlo.txt` (lowered once from the JAX
//! L2 model) and execute them via the PJRT CPU client on the Rust
//! learning path.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{pick_config, read_manifest, ArtifactConfig};
pub use pjrt::SimilarityRuntime;
