//! PJRT runtime: load the AOT-lowered similarity module and execute it
//! from the Rust hot path. Python never runs here — the HLO text was
//! produced once by `make artifacts`.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** (not a
//! serialized proto — xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit
//! instruction ids) → `HloModuleProto::from_text_file` →
//! `PjRtClient::compile` → `execute`. Compiled executables are cached
//! per shape-config.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::data::Dataset;
use crate::runtime::artifacts::{pick_config, read_manifest, ArtifactConfig};
use crate::score::PairwiseScores;

/// PJRT-backed executor for the pairwise-similarity artifact.
pub struct SimilarityRuntime {
    client: xla::PjRtClient,
    configs: Vec<ArtifactConfig>,
    compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl SimilarityRuntime {
    /// Load the artifact registry and start a CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let configs = read_manifest(artifacts_dir)?;
        if configs.is_empty() {
            anyhow::bail!("no artifact configs in {}", artifacts_dir.display());
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(SimilarityRuntime { client, configs, compiled: Mutex::new(HashMap::new()) })
    }

    /// Platform string (telemetry).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Available shape-configs.
    pub fn configs(&self) -> &[ArtifactConfig] {
        &self.configs
    }

    /// Does some config fit this dataset?
    pub fn supports(&self, data: &Dataset) -> bool {
        pick_config(&self.configs, data.n_vars(), data.n_rows(), data.max_card() as usize)
            .is_some()
    }

    fn compile(&self, cfg: &ArtifactConfig) -> Result<()> {
        let mut cache = self.compiled.lock().expect("compile cache poisoned");
        if cache.contains_key(&cfg.name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            cfg.path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", cfg.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", cfg.name))?;
        cache.insert(cfg.name.clone(), exe);
        Ok(())
    }

    /// Execute the similarity model on `data`: returns the same
    /// `(S, empty)` as `score::pairwise_similarity` (f32 precision).
    ///
    /// Padding: instances and variables beyond the dataset get state
    /// `r_max` (one-hot zero row → contributes nothing); padded
    /// variables get cardinality 1 and are cropped from the output.
    pub fn pairwise(&self, data: &Dataset, ess: f64) -> Result<PairwiseScores> {
        let n = data.n_vars();
        let m = data.n_rows();
        let max_card = data.max_card() as usize;
        let cfg = pick_config(&self.configs, n, m, max_card)
            .ok_or_else(|| {
                anyhow!("no artifact config fits n={n} m={m} r={max_card}; re-run aot.py with a bigger config")
            })?
            .clone();
        self.compile(&cfg)?;

        // Build padded inputs (row-major (n_pad, m_pad) int32).
        let pad_state = cfg.r_max as i32;
        let mut flat = vec![pad_state; cfg.n * cfg.m];
        for v in 0..n {
            let col = data.col(v);
            let row = &mut flat[v * cfg.m..v * cfg.m + m];
            for (dst, &s) in row.iter_mut().zip(col) {
                *dst = s as i32;
            }
        }
        let mut cards = vec![1.0f32; cfg.n];
        for v in 0..n {
            cards[v] = data.card(v) as f32;
        }
        let data_lit = xla::Literal::vec1(&flat)
            .reshape(&[cfg.n as i64, cfg.m as i64])
            .map_err(|e| anyhow!("reshape data: {e:?}"))?;
        let cards_lit = xla::Literal::vec1(&cards);
        let ess_lit = xla::Literal::vec1(&[ess as f32])
            .reshape(&[1, 1])
            .map_err(|e| anyhow!("reshape ess: {e:?}"))?;

        let cache = self.compiled.lock().expect("compile cache poisoned");
        let exe = cache.get(&cfg.name).expect("compiled above");
        let result = exe
            .execute::<xla::Literal>(&[data_lit, cards_lit, ess_lit])
            .map_err(|e| anyhow!("execute {}: {e:?}", cfg.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        drop(cache);

        let (s_lit, e_lit) =
            result.to_tuple2().map_err(|e| anyhow!("expected 2-tuple: {e:?}"))?;
        let s_flat: Vec<f32> = s_lit.to_vec().map_err(|e| anyhow!("S to_vec: {e:?}"))?;
        let e_flat: Vec<f32> = e_lit.to_vec().map_err(|e| anyhow!("E to_vec: {e:?}"))?;

        // Crop padding.
        let mut s = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in 0..n {
                s[i][j] = s_flat[i * cfg.n + j] as f64;
            }
        }
        let empty: Vec<f64> = e_flat[..n].iter().map(|&x| x as f64).collect();
        Ok(PairwiseScores { s, empty })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{forward_sample, generate, NetGenConfig};

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    /// Compares the XLA artifact against the Rust fallback — the
    /// cross-layer correctness check. Skips (with a note) when
    /// artifacts have not been built.
    #[test]
    fn artifact_matches_rust_fallback() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = match SimilarityRuntime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => panic!("runtime load failed: {e:#}"),
        };
        let bn = generate(
            &NetGenConfig { nodes: 24, edges: 30, card_range: (2, 4), ..Default::default() },
            77,
        );
        let data = forward_sample(&bn, 200, 3);
        assert!(rt.supports(&data));
        let xla_scores = rt.pairwise(&data, 10.0).expect("artifact execution");
        let rust_scores = crate::score::pairwise_similarity(&data, 10.0, 2);
        for i in 0..data.n_vars() {
            assert!(
                (xla_scores.empty[i] - rust_scores.empty[i]).abs()
                    < 1e-2 + 1e-4 * rust_scores.empty[i].abs(),
                "empty[{i}]: {} vs {}",
                xla_scores.empty[i],
                rust_scores.empty[i]
            );
            for j in 0..data.n_vars() {
                if i == j {
                    continue;
                }
                let (a, b) = (xla_scores.s[i][j], rust_scores.s[i][j]);
                assert!(
                    (a - b).abs() < 1e-2 + 1e-4 * b.abs(),
                    "S[{i}][{j}]: xla {a} vs rust {b}"
                );
            }
        }
    }
}
