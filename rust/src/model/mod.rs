//! The model bundle — one versioned artifact from ring-learn to
//! warm-started serving.
//!
//! The ring circulates *models* between processors, yet until this
//! subsystem the crate's public API moved loose pieces: `cges`
//! returned a bare [`Dag`](crate::graph::Dag), `fit` re-read data to
//! attach CPTs, and every [`CompiledModel`] cold-started a two-pass
//! calibration. A [`Bundle`] is the self-contained artifact that
//! closes the loop — the one currency every subsystem speaks:
//!
//! * **domain + structure + parameters** — a full
//!   [`DiscreteBn`] (names, cardinalities, DAG, fitted CPTs);
//! * **calibrated potentials** (optional) — the evidence-free
//!   collect-pass messages of the compiled jointree plus the
//!   [schedule fingerprint](crate::engine::CompiledModel::schedule_fingerprint)
//!   they calibrate, so a consumer whose compile reproduces the same
//!   schedule warm-starts with **zero** collect-message recomputation
//!   ([`CompiledModel::from_bundle`]) and still answers bit-identically
//!   to a cold compile (messages ship as exact IEEE-754 bits and are
//!   the same bits a local collect would produce);
//! * **provenance header** ([`BundleMeta`]) — producer string, ring
//!   rounds, BDeu score and the fit `ess`, so an artifact found on
//!   disk or received over the wire explains itself.
//!
//! Lifecycle: **learn** (ring) → **fuse** → **fit** → **calibrate** →
//! **serve**. The ring ships bundles between workers when the
//! capability flag is on ([`ModelMsg`](crate::coordinator::ModelMsg)
//! grows an optional bundle payload), [`cges`](crate::coordinator::cges)
//! emits one for the final model, the CLI persists them as `.bnb`
//! files ([`codec`]: magic + version byte, length-prefixed, refusing
//! unknown versions), and [`Server::from_bundle`](crate::engine::Server::from_bundle)
//! serves them warm. BIF remains supported as an import/export
//! conversion format.

pub mod codec;

pub use codec::{
    bundle_from_bytes, bundle_to_bytes, decode_bundle, encode_bundle, read_bundle, write_bundle,
    BUNDLE_CODEC_VERSION, BUNDLE_MAGIC, MAX_BUNDLE_BYTES,
};

use anyhow::Result;

use crate::bn::DiscreteBn;
use crate::data::Dataset;
use crate::engine::CompiledModel;
use crate::graph::{moral_graph, Dag};
use crate::infer::json::Json;
use crate::infer::triangulate::triangulate;

/// Provenance and telemetry header of a bundle.
#[derive(Clone, Debug)]
pub struct BundleMeta {
    /// Free-form producer tag (e.g. `"cges k=4"` or `"import-bif"`).
    pub producer: String,
    /// Ring rounds behind the structure (0 when not ring-learned).
    pub rounds: u32,
    /// BDeu score of the structure (NaN when unknown).
    pub score: f64,
    /// Equivalent sample size the CPTs were fitted with.
    pub ess: f64,
}

impl BundleMeta {
    /// Header for an artifact converted from another format.
    pub fn imported(producer: &str) -> BundleMeta {
        BundleMeta { producer: producer.to_string(), rounds: 0, score: f64::NAN, ess: f64::NAN }
    }
}

/// Evidence-free calibration of a compiled jointree: one normalized
/// collect message (and its log-normalizer) per clique of the frozen
/// schedule, in clique order. Root cliques, which send no message,
/// carry their untouched length-1 buffer so the vectors stay aligned
/// with the schedule.
#[derive(Clone, Debug)]
pub struct CalibratedPotentials {
    /// Fingerprint of the compiled schedule (and parameters) these
    /// messages calibrate — see
    /// [`CompiledModel::schedule_fingerprint`].
    pub fingerprint: u64,
    /// Collect messages clique → schedule parent.
    pub messages: Vec<Vec<f64>>,
    /// Log-normalizer of each message.
    pub logz: Vec<f64>,
}

/// A self-contained, versioned model artifact: domain, structure,
/// fitted CPTs, optional calibrated jointree potentials and a
/// provenance header. See the [module docs](self) for the lifecycle.
#[derive(Clone)]
pub struct Bundle {
    /// Provenance / telemetry header.
    pub meta: BundleMeta,
    /// The fitted network.
    pub bn: DiscreteBn,
    /// Warm-start payload, when the producer calibrated one.
    pub potentials: Option<CalibratedPotentials>,
}

/// Summary form (tables elided — the binary codec owns the full
/// contents), so message types carrying a bundle keep their `Debug`.
impl std::fmt::Debug for Bundle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Bundle")
            .field("producer", &self.meta.producer)
            .field("rounds", &self.meta.rounds)
            .field("n_vars", &self.bn.n())
            .field("edges", &self.bn.dag.edge_count())
            .field("potentials", &self.potentials.is_some())
            .finish()
    }
}

impl Bundle {
    /// Wrap a fitted network without potentials (cold-start artifact).
    pub fn from_bn(bn: DiscreteBn, meta: BundleMeta) -> Bundle {
        Bundle { meta, bn, potentials: None }
    }

    /// Wrap a fitted network and attach calibrated potentials when the
    /// jointree fits the clique-state-space `budget` (the same budget
    /// notion as [`EngineConfig::budget`](crate::infer::EngineConfig)).
    /// Never fails: past the budget — or on any compile/calibrate
    /// error — the bundle simply ships without potentials and
    /// consumers cold-start.
    pub fn calibrated_within(bn: DiscreteBn, meta: BundleMeta, budget: u64) -> Bundle {
        let tri = triangulate(&moral_graph(&bn.dag), &bn.cards);
        let potentials = if tri.max_clique_states <= budget {
            CompiledModel::compile_from(&bn, tri).ok().and_then(|m| m.calibrate().ok())
        } else {
            None
        };
        Bundle { meta, bn, potentials }
    }

    /// Fit CPTs for `dag` from `data` (with `meta.ess`) and calibrate
    /// within `budget` — the one-call path from a learned structure to
    /// a servable artifact.
    pub fn fit_calibrated(
        dag: &Dag,
        data: &Dataset,
        budget: u64,
        meta: BundleMeta,
    ) -> Result<Bundle> {
        let bn = crate::bn::fit(dag, data, meta.ess)?;
        Ok(Bundle::calibrated_within(bn, meta, budget))
    }

    /// Number of variables.
    pub fn n_vars(&self) -> usize {
        self.bn.n()
    }

    /// Variable names, in network order.
    pub fn names(&self) -> &[String] {
        &self.bn.names
    }

    /// Does this bundle carry a warm-start payload?
    pub fn has_potentials(&self) -> bool {
        self.potentials.is_some()
    }

    /// JSON debug form: the header, the domain shape and the
    /// potentials summary — everything but the raw tables, which the
    /// binary codec owns. For humans and log lines, not for
    /// round-tripping.
    pub fn to_debug_json(&self) -> Json {
        let meta = Json::Obj(vec![
            ("producer".into(), Json::Str(self.meta.producer.clone())),
            ("rounds".into(), Json::Num(self.meta.rounds as f64)),
            ("score".into(), Json::Num(self.meta.score)),
            ("ess".into(), Json::Num(self.meta.ess)),
        ]);
        let vars: Vec<Json> = (0..self.bn.n())
            .map(|v| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(self.bn.names[v].clone())),
                    ("card".into(), Json::Num(self.bn.cards[v] as f64)),
                    ("parents".into(), Json::Num(self.bn.cpts[v].parents.len() as f64)),
                ])
            })
            .collect();
        let potentials = match &self.potentials {
            None => Json::Null,
            Some(p) => Json::Obj(vec![
                ("fingerprint".into(), Json::Str(format!("{:016x}", p.fingerprint))),
                ("cliques".into(), Json::Num(p.messages.len() as f64)),
                (
                    "message_cells".into(),
                    Json::Num(p.messages.iter().map(|m| m.len()).sum::<usize>() as f64),
                ),
            ]),
        };
        Json::Obj(vec![
            ("format".into(), Json::Str("bnb".into())),
            ("version".into(), Json::Num(BUNDLE_CODEC_VERSION as f64)),
            ("meta".into(), meta),
            ("n_vars".into(), Json::Num(self.bn.n() as f64)),
            ("edges".into(), Json::Num(self.bn.dag.edge_count() as f64)),
            ("parameters".into(), Json::Num(self.bn.parameter_count() as f64)),
            ("variables".into(), Json::Arr(vars)),
            ("potentials".into(), potentials),
        ])
    }
}

/// Content fingerprint of a bundle: FNV-1a over the canonical `.bnb`
/// encoding ([`bundle_to_bytes`]), so two bundles share a fingerprint
/// exactly when they serialize to the same bytes (same header, same
/// structure, same CPT bits, same potentials). This is the key the
/// serving fleet's multi-model registry files bundles under — see
/// [`crate::engine::fleet`] — and it uses the same FNV-1a constants as
/// [`CompiledModel::schedule_fingerprint`].
pub fn bundle_fingerprint(bundle: &Bundle) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in &bundle_to_bytes(bundle) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical 16-hex-digit spelling of a bundle fingerprint — the form
/// the control plane speaks on the wire and the per-model metric names
/// embed (`serve.<fp>.latency_ns`).
pub fn fingerprint_hex(fp: u64) -> String {
    format!("{fp:016x}")
}

/// Parse a [`fingerprint_hex`] string back to the fingerprint
/// (case-insensitive; at most 16 hex digits, no sign or prefix).
pub fn parse_fingerprint(text: &str) -> Option<u64> {
    if text.is_empty() || text.len() > 16 || !text.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(text, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn calibrated_within_attaches_or_degrades_by_budget() {
        let meta = BundleMeta { producer: "t".into(), rounds: 0, score: 0.0, ess: 1.0 };
        let warm = Bundle::calibrated_within(tiny_bn(), meta.clone(), u64::MAX);
        assert!(warm.has_potentials());
        let p = warm.potentials.as_ref().unwrap();
        assert_eq!(p.messages.len(), p.logz.len());

        // Budget 0 excludes every clique: the bundle degrades to a
        // cold-start artifact instead of failing.
        let cold = Bundle::calibrated_within(tiny_bn(), meta, 0);
        assert!(!cold.has_potentials());
    }

    #[test]
    fn bundle_fingerprint_is_stable_and_content_sensitive() {
        let meta = BundleMeta { producer: "fp".into(), rounds: 1, score: -3.5, ess: 1.0 };
        let a = Bundle::calibrated_within(tiny_bn(), meta.clone(), u64::MAX);
        let fp = bundle_fingerprint(&a);

        // Stable across the codec round-trip (the hash is over the
        // canonical encoding, which round-trips bit-exactly).
        let back = bundle_from_bytes(&bundle_to_bytes(&a)).expect("round-trip");
        assert_eq!(bundle_fingerprint(&back), fp);

        // Any content change — here the provenance header — moves it.
        let mut b = a.clone();
        b.meta.producer = "fp2".into();
        assert_ne!(bundle_fingerprint(&b), fp);

        // Hex form round-trips and rejects junk.
        assert_eq!(parse_fingerprint(&fingerprint_hex(fp)), Some(fp));
        assert_eq!(parse_fingerprint(&fingerprint_hex(fp).to_uppercase()), Some(fp));
        assert_eq!(fingerprint_hex(fp).len(), 16);
        assert_eq!(parse_fingerprint(""), None);
        assert_eq!(parse_fingerprint("xyz"), None);
        assert_eq!(parse_fingerprint("+12"), None);
        assert_eq!(parse_fingerprint("00112233445566778"), None);
    }

    #[test]
    fn debug_json_is_parseable_and_summarizes() {
        let meta = BundleMeta { producer: "dbg".into(), rounds: 2, score: -5.0, ess: 1.0 };
        let b = Bundle::calibrated_within(tiny_bn(), meta, u64::MAX);
        let text = b.to_debug_json().to_string();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("n_vars").and_then(Json::as_usize), Some(2));
        assert_eq!(
            v.get("meta").and_then(|m| m.get("producer")).and_then(Json::as_str),
            Some("dbg")
        );
        assert!(v.get("potentials").and_then(|p| p.get("cliques")).is_some());
    }
}
