//! Binary wire/file codec for model bundles (`.bnb`).
//!
//! Extends the [`graph::codec`](crate::graph::codec) idiom — little
//! endian, fixed width, length prefixed, fully validating — to the
//! whole model artifact. The format is deliberately dumb so a
//! non-Rust consumer can reimplement it in an afternoon:
//!
//! ```text
//! 4 ×  u8              magic "cBNB"
//! u8   version         (currently 1; unknown versions are refused)
//! u32  producer_len    + that many UTF-8 bytes   (provenance header)
//! u32  rounds
//! f64  score
//! f64  ess
//! u32  n               variable count
//! n ×  (u32 len + bytes, u32 card)               domain
//! dag  sub-frame       (graph::codec, self-validating)
//! n ×  (u32 table_len, table_len × f64)          CPTs, dag-parent order
//! u8   has_potentials  (0 or 1)
//! u64  fingerprint     ┐
//! u32  n_cliques       │ present only when
//! c ×  (u32 msg_len,   │ has_potentials = 1
//!       msg_len × f64, │
//!       f64 logz)      ┘
//! ```
//!
//! CPT parent sets are *not* encoded: they are exactly the DAG parents
//! in ascending order (the invariant [`DiscreteBn::validate`] pins),
//! so the decoder reconstructs them from the structure sub-frame and a
//! mismatched `table_len` is a hard error. Every declared length is
//! checked against the remaining payload before any buffer is
//! allocated for it, the total frame is capped through the same
//! [`util::ensure_frame_len`](crate::util::ensure_frame_len) guard
//! (and wording) as the ring transport and the query server, and
//! `f64` cells round-trip bit-exactly — which is what lets a consumer
//! warm-start from shipped potentials and still answer bit-identically
//! to a cold compile.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bn::{Cpt, DiscreteBn};
use crate::graph::codec::{
    decode_dag, encode_dag, put_f64, put_u32, put_u64, take_f64, take_u32, take_u64, take_u8,
};
use crate::model::{Bundle, BundleMeta, CalibratedPotentials};
use crate::util::ensure_frame_len;

/// Magic bytes opening every bundle frame.
pub const BUNDLE_MAGIC: [u8; 4] = *b"cBNB";

/// Current bundle-format version byte. Decoding refuses any other
/// value (forward-refusing: a newer producer's frame errors cleanly
/// instead of being half-read).
pub const BUNDLE_CODEC_VERSION: u8 = 1;

/// Hard cap on one encoded bundle (file or wire sub-frame). Generous —
/// a million-parameter network with calibrated potentials is still an
/// order of magnitude below it — but bounds what a corrupt length
/// field can make the decoder allocate.
pub const MAX_BUNDLE_BYTES: u32 = 256 << 20;

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn take_str(input: &mut &[u8]) -> Result<String> {
    let len = take_u32(input)? as usize;
    if len > input.len() {
        bail!("truncated frame: string of {len} bytes, {} left", input.len());
    }
    let (head, rest) = input.split_at(len);
    let s = std::str::from_utf8(head).context("string field is not UTF-8")?;
    *input = rest;
    Ok(s.to_string())
}

/// Guard a declared `f64` count against the remaining payload before
/// allocating for it (the codec never trusts a length field).
fn ensure_f64s(input: &[u8], count: usize, what: &str) -> Result<()> {
    if count > input.len() / 8 {
        bail!("{what} declares {count} cells but only {} bytes remain", input.len());
    }
    Ok(())
}

fn take_f64s(input: &mut &[u8], count: usize, what: &str) -> Result<Vec<f64>> {
    ensure_f64s(*input, count, what)?;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(take_f64(input)?);
    }
    Ok(out)
}

/// Append the wire encoding of a bundle to `buf`.
pub fn encode_bundle(bundle: &Bundle, buf: &mut Vec<u8>) {
    buf.extend_from_slice(&BUNDLE_MAGIC);
    buf.push(BUNDLE_CODEC_VERSION);
    put_str(buf, &bundle.meta.producer);
    put_u32(buf, bundle.meta.rounds);
    put_f64(buf, bundle.meta.score);
    put_f64(buf, bundle.meta.ess);

    let bn = &bundle.bn;
    put_u32(buf, bn.n() as u32);
    for v in 0..bn.n() {
        put_str(buf, &bn.names[v]);
        put_u32(buf, bn.cards[v]);
    }
    encode_dag(&bn.dag, buf);
    for cpt in &bn.cpts {
        put_u32(buf, cpt.table.len() as u32);
        for &x in &cpt.table {
            put_f64(buf, x);
        }
    }

    match &bundle.potentials {
        None => buf.push(0),
        Some(p) => {
            buf.push(1);
            put_u64(buf, p.fingerprint);
            put_u32(buf, p.messages.len() as u32);
            for (msg, &lz) in p.messages.iter().zip(&p.logz) {
                put_u32(buf, msg.len() as u32);
                for &x in msg {
                    put_f64(buf, x);
                }
                put_f64(buf, lz);
            }
        }
    }
}

/// Wire encoding of a bundle as an owned buffer.
pub fn bundle_to_bytes(bundle: &Bundle) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_bundle(bundle, &mut buf);
    buf
}

/// Decode a bundle from the front of `input`, advancing the cursor
/// past it (bundles can ride inside larger frames, e.g. ring model
/// messages). Fully validating: magic, version, every length field,
/// CPT shapes against the decoded structure, and the network
/// invariants via [`DiscreteBn::validate`].
pub fn decode_bundle(input: &mut &[u8]) -> Result<Bundle> {
    if input.len() < 4 || input[..4] != BUNDLE_MAGIC {
        bail!("not a bundle frame (bad magic; expected \"cBNB\")");
    }
    *input = &input[4..];
    let version = take_u8(input)?;
    if version != BUNDLE_CODEC_VERSION {
        bail!("unsupported bundle codec version {version} (expected {BUNDLE_CODEC_VERSION})");
    }
    let producer = take_str(input)?;
    let rounds = take_u32(input)?;
    let score = take_f64(input)?;
    let ess = take_f64(input)?;

    let n = take_u32(input)? as usize;
    let mut names = Vec::with_capacity(n.min(input.len()));
    let mut cards = Vec::with_capacity(n.min(input.len()));
    for i in 0..n {
        let name = take_str(input)?;
        if name.is_empty() {
            bail!("variable {i} has an empty name");
        }
        names.push(name);
        let card = take_u32(input)?;
        if card == 0 {
            bail!("variable {i} has cardinality 0");
        }
        cards.push(card);
    }
    let dag = decode_dag(input)?;
    if dag.n() != n {
        bail!("structure has {} nodes but the domain declares {n}", dag.n());
    }

    let mut cpts = Vec::with_capacity(n);
    for v in 0..n {
        let mut parents: Vec<usize> = dag.parents(v).iter().collect();
        parents.sort_unstable();
        // Saturating width math: adversarial cardinalities must fail
        // the shape check, not overflow it into a false match.
        let cells = parents
            .iter()
            .map(|&p| cards[p] as u64)
            .fold(cards[v] as u64, u64::saturating_mul);
        let table_len = take_u32(input)? as usize;
        if table_len as u64 != cells {
            bail!("variable {v}: CPT declares {table_len} cells but the structure implies {cells}");
        }
        let table = take_f64s(input, table_len, "CPT")?;
        cpts.push(Cpt { parents, table, r: cards[v] as usize });
    }
    let bn = DiscreteBn { dag, names, cards, cpts };
    bn.validate().map_err(|e| anyhow::anyhow!("decoded network failed validation: {e}"))?;

    let potentials = match take_u8(input)? {
        0 => None,
        1 => {
            let fingerprint = take_u64(input)?;
            let nc = take_u32(input)? as usize;
            let mut messages = Vec::with_capacity(nc.min(input.len()));
            let mut logz = Vec::with_capacity(nc.min(input.len()));
            for c in 0..nc {
                let len = take_u32(input)? as usize;
                let msg = take_f64s(input, len, "calibrated message")?;
                if msg.iter().any(|x| !x.is_finite() || *x < 0.0) {
                    bail!("calibrated message {c} has a non-finite or negative cell");
                }
                messages.push(msg);
                let lz = take_f64(input)?;
                if !lz.is_finite() {
                    bail!("calibrated message {c} has a non-finite normalizer");
                }
                logz.push(lz);
            }
            Some(CalibratedPotentials { fingerprint, messages, logz })
        }
        other => bail!("bad potentials flag {other} (expected 0 or 1)"),
    };

    Ok(Bundle { meta: BundleMeta { producer, rounds, score, ess }, bn, potentials })
}

/// Decode a bundle from an exact buffer (trailing bytes are an error).
pub fn bundle_from_bytes(bytes: &[u8]) -> Result<Bundle> {
    let mut cursor = bytes;
    let bundle = decode_bundle(&mut cursor)?;
    if !cursor.is_empty() {
        bail!("{} trailing bytes after bundle frame", cursor.len());
    }
    Ok(bundle)
}

/// Write a bundle to a `.bnb` file.
pub fn write_bundle(bundle: &Bundle, path: &Path) -> Result<()> {
    let bytes = bundle_to_bytes(bundle);
    let len = u32::try_from(bytes.len()).context("bundle too large for u32 length")?;
    ensure_frame_len("outgoing", len, MAX_BUNDLE_BYTES)?;
    std::fs::write(path, bytes).with_context(|| format!("write bundle {}", path.display()))?;
    Ok(())
}

/// Read a bundle from a `.bnb` file. The size cap is enforced on the
/// file's metadata *before* anything is read, so a mistyped path to a
/// multi-gigabyte file is rejected without buffering it.
pub fn read_bundle(path: &Path) -> Result<Bundle> {
    let meta =
        std::fs::metadata(path).with_context(|| format!("stat bundle {}", path.display()))?;
    let len = u32::try_from(meta.len())
        .map_err(|_| anyhow::anyhow!("bundle file exceeds the u32 frame space"))?;
    ensure_frame_len("incoming", len, MAX_BUNDLE_BYTES)?;
    let bytes =
        std::fs::read(path).with_context(|| format!("read bundle {}", path.display()))?;
    bundle_from_bytes(&bytes)
        .with_context(|| format!("decode bundle {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    fn tiny_bundle(potentials: bool) -> Bundle {
        let bn = tiny_bn();
        let meta = BundleMeta {
            producer: "unit-test".into(),
            rounds: 3,
            score: -12.5,
            ess: 1.0,
        };
        if potentials {
            Bundle::calibrated_within(bn, meta, u64::MAX)
        } else {
            Bundle::from_bn(bn, meta)
        }
    }

    #[test]
    fn roundtrip_with_and_without_potentials() {
        for pots in [false, true] {
            let b = tiny_bundle(pots);
            let bytes = bundle_to_bytes(&b);
            let back = bundle_from_bytes(&bytes).unwrap();
            assert_eq!(back.meta.producer, "unit-test");
            assert_eq!(back.meta.rounds, 3);
            assert_eq!(back.meta.score.to_bits(), (-12.5f64).to_bits());
            assert_eq!(back.bn.names, b.bn.names);
            assert_eq!(back.bn.cards, b.bn.cards);
            assert_eq!(back.bn.dag.edges(), b.bn.dag.edges());
            for (a, c) in back.bn.cpts.iter().zip(&b.bn.cpts) {
                assert_eq!(a.parents, c.parents);
                for (x, y) in a.table.iter().zip(&c.table) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            assert_eq!(back.potentials.is_some(), pots);
            if let (Some(bp), Some(cp)) = (&back.potentials, &b.potentials) {
                assert_eq!(bp.fingerprint, cp.fingerprint);
                assert_eq!(bp.messages.len(), cp.messages.len());
                for (m1, m2) in bp.messages.iter().zip(&cp.messages) {
                    for (x, y) in m1.iter().zip(m2) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                for (x, y) in bp.logz.iter().zip(&cp.logz) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
    }

    #[test]
    fn frames_concatenate_inside_a_larger_buffer() {
        let a = tiny_bundle(true);
        let b = tiny_bundle(false);
        let mut buf = Vec::new();
        encode_bundle(&a, &mut buf);
        encode_bundle(&b, &mut buf);
        let mut cursor = buf.as_slice();
        let a2 = decode_bundle(&mut cursor).unwrap();
        let b2 = decode_bundle(&mut cursor).unwrap();
        assert!(cursor.is_empty());
        assert!(a2.potentials.is_some());
        assert!(b2.potentials.is_none());
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_trailing() {
        let bytes = bundle_to_bytes(&tiny_bundle(true));

        let mut magic = bytes.clone();
        magic[0] = b'X';
        assert!(bundle_from_bytes(&magic).unwrap_err().to_string().contains("magic"));

        let mut ver = bytes.clone();
        ver[4] = 99;
        assert!(bundle_from_bytes(&ver).unwrap_err().to_string().contains("version 99"));

        for cut in [0, 4, 5, bytes.len() / 3, bytes.len() - 1] {
            assert!(bundle_from_bytes(&bytes[..cut]).is_err(), "cut at {cut} decoded");
        }

        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(bundle_from_bytes(&trailing).is_err());
    }

    #[test]
    fn rejects_corrupt_length_fields_without_huge_allocs() {
        // Blow up the producer length field: the declared size exceeds
        // the remaining payload, so the decoder must refuse before
        // allocating.
        let bytes = bundle_to_bytes(&tiny_bundle(false));
        let mut bad = bytes.clone();
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(bundle_from_bytes(&bad).is_err());
    }
}
