//! cges — CLI for the ring-distributed Bayesian-network learner.
//!
//! Subcommands:
//!   gen-net    generate a ground-truth network (paper analogs or random)
//!   sample     forward-sample a dataset from a .bif network
//!   partition  show the stage-1 edge partition for a dataset
//!   learn      run cges / cges-l / ges / fges on a dataset
//!   eval       score a learned structure against truth + data

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use cges::bn::{forward_sample, generate, load_domain, read_bif, write_bif, Domain, NetGenConfig};
use cges::cli::Args;
use cges::coordinator::{cges as run_cges, PartitionSource, RingConfig, RingMode};
use cges::data::{read_csv, write_csv, Dataset};
use cges::graph::Dag;
use cges::learn::{fges, ges, FgesConfig, GesConfig};
use cges::metrics::evaluate;
use cges::partition::{partition_edges, partition_stats};
use cges::score::BdeuScorer;
use cges::util::Timer;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "gen-net" => cmd_gen_net(rest),
        "sample" => cmd_sample(rest),
        "partition" => cmd_partition(rest),
        "learn" => cmd_learn(rest),
        "eval" => cmd_eval(rest),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (see `cges help`)"),
    }
}

const HELP: &str = "\
cges — ring-based distributed Bayesian-network structure learning

USAGE: cges <subcommand> [options]

SUBCOMMANDS
  gen-net    --family link|pigs|munin|random --out net.bif
             [--scale 1.0] [--nodes N --edges E --max-parents P] [--seed S]
  sample     --net net.bif --out data.csv [--rows 5000] [--seed S]
  partition  --data data.csv --k 4 [--ess 10] [--artifacts DIR]
  learn      --algo cges|cges-l|ges|fges --data data.csv [--out learned.dag]
             [--k 4] [--ess 10] [--threads N] [--artifacts DIR]
             [--trace trace.tsv] [--max-rounds 50]
             [--transport channel|tcp|sync]   ring execution mode:
             channel = pipelined in-process actors (default),
             tcp     = pipelined over loopback TCP (wire codec),
             sync    = deterministic barrier scheduler
  eval       --learned learned.dag|.bif --truth net.bif --data data.csv [--ess 10]
";

fn cmd_gen_net(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(
        &["family", "out", "scale", "nodes", "edges", "max-parents", "seed"],
        &[],
    )?;
    let family = a.get("family").unwrap_or("random");
    let seed: u64 = a.get_parse("seed", 1)?;
    let scale: f64 = a.get_parse("scale", 1.0)?;
    let bn = if let Some(domain) = Domain::parse(family) {
        load_domain(domain, scale)
    } else if family == "random" {
        let cfg = NetGenConfig {
            nodes: a.get_parse("nodes", 50)?,
            edges: a.get_parse("edges", 75)?,
            max_parents: a.get_parse("max-parents", 3)?,
            ..Default::default()
        };
        generate(&cfg, seed)
    } else {
        bail!("unknown family '{family}' (link|pigs|munin|random)");
    };
    let out = PathBuf::from(a.require("out")?);
    write_bif(&bn, &out)?;
    println!(
        "wrote {}: {} nodes, {} edges, max parents {}, {} parameters",
        out.display(),
        bn.n(),
        bn.dag.edge_count(),
        bn.dag.max_in_degree(),
        bn.parameter_count()
    );
    Ok(())
}

fn cmd_sample(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["net", "out", "rows", "seed"], &[])?;
    let bn = read_bif(Path::new(a.require("net")?))?;
    let rows: usize = a.get_parse("rows", 5000)?;
    let seed: u64 = a.get_parse("seed", 1)?;
    let data = forward_sample(&bn, rows, seed);
    let out = PathBuf::from(a.require("out")?);
    write_csv(&data, &out)?;
    println!("wrote {}: {} rows x {} vars", out.display(), rows, data.n_vars());
    Ok(())
}

/// Stage-1 similarity source from an optional artifacts dir.
fn similarity_source(artifacts: Option<&str>) -> PartitionSource {
    match artifacts {
        Some(dir) => PartitionSource::Artifacts(PathBuf::from(dir)),
        None => PartitionSource::RustFallback,
    }
}

fn cmd_partition(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["data", "k", "ess", "artifacts", "threads"], &[])?;
    let data = Arc::new(read_csv(Path::new(a.require("data")?))?);
    let k: usize = a.get_parse("k", 4)?;
    let ess: f64 = a.get_parse("ess", 10.0)?;
    let threads: usize = a.get_parse("threads", cges::util::num_threads())?;

    let t = Timer::start();
    let (pw, source) = match similarity_source(a.get("artifacts")) {
        PartitionSource::Artifacts(dir) => {
            let rt = cges::runtime::SimilarityRuntime::load(&dir)?;
            (rt.pairwise(&data, ess)?, format!("xla:{}", rt.platform()))
        }
        PartitionSource::RustFallback => (
            cges::score::pairwise_similarity(&data, ess, threads),
            "rust-fallback".to_string(),
        ),
    };
    let sim_secs = t.secs();
    let masks = partition_edges(&pw.s, k);
    let stats = partition_stats(&masks, data.n_vars());
    println!("similarity: {source} in {sim_secs:.2}s");
    println!(
        "partition into k={k}: sizes {:?} (total {} / expected {})",
        stats.sizes, stats.total, stats.expected
    );
    Ok(())
}

fn cmd_learn(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(
        &[
            "algo",
            "data",
            "out",
            "k",
            "ess",
            "threads",
            "artifacts",
            "trace",
            "max-rounds",
            "max-parents",
            "transport",
        ],
        &[],
    )?;
    let algo = a.require("algo")?;
    let data = Arc::new(read_csv(Path::new(a.require("data")?))?);
    let ess: f64 = a.get_parse("ess", 10.0)?;
    let threads: usize = a.get_parse("threads", cges::util::num_threads())?;
    let k: usize = a.get_parse("k", 4)?;
    let n = data.n_vars();

    let t = Timer::start();
    let (dag, score) = match algo {
        "cges" | "cges-l" => {
            let mode = match a.get("transport") {
                None => RingMode::default(),
                Some(name) => RingMode::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("--transport: unknown mode '{name}' (channel|tcp|sync)"))?,
            };
            let cfg = RingConfig {
                k,
                limit_inserts: algo == "cges-l",
                ess,
                threads,
                max_rounds: a.get_parse("max-rounds", 50)?,
                partition_source: similarity_source(a.get("artifacts")),
                fine_tune: true,
                max_parents: a.get("max-parents").map(|v| v.parse()).transpose()?,
                mode,
            };
            let r = run_cges(data.clone(), &cfg)?;
            println!(
                "ring [{}] converged in {} rounds (partition {:.2}s [{}], learning {:.2}s, fine-tune {:.2}s; cache {}/{} hit/computed)",
                r.telemetry.transport,
                r.rounds,
                r.telemetry.partition_secs,
                r.telemetry.partition_source,
                r.telemetry.learning_secs,
                r.telemetry.fine_tune_secs,
                r.telemetry.cache_hits,
                r.telemetry.cache_misses,
            );
            if let Some(path) = a.get("trace") {
                r.telemetry.write_tsv(Path::new(path))?;
                println!("trace written to {path}");
            }
            (r.dag, r.score)
        }
        "ges" => {
            let sc = BdeuScorer::new(data.clone(), ess);
            let r = ges(&sc, &Dag::new(n), &GesConfig { threads, ..Default::default() });
            (r.dag, r.score)
        }
        "fges" => {
            let sc = BdeuScorer::new(data.clone(), ess);
            let r = fges(&sc, &Dag::new(n), &FgesConfig { threads, ..Default::default() });
            (r.dag, r.score)
        }
        other => bail!("unknown algo '{other}' (cges|cges-l|ges|fges)"),
    };
    let secs = t.secs();
    println!(
        "{algo}: score {score:.4} (normalized {:.4}), {} edges, {secs:.2}s",
        score / data.n_rows() as f64,
        dag.edge_count()
    );

    if let Some(out) = a.get("out") {
        write_structure(&dag, data.names(), Path::new(out))?;
        println!("structure written to {out}");
    }
    Ok(())
}

/// Write a learned structure as an edge list (`.dag` text format:
/// one `parent<TAB>child` line per edge, names resolved).
fn write_structure(dag: &Dag, names: &[String], path: &Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for (u, v) in dag.edges() {
        writeln!(f, "{}\t{}", names[u], names[v])?;
    }
    Ok(())
}

/// Read a structure written by [`write_structure`].
fn read_structure(path: &Path, data: &Dataset) -> Result<Dag> {
    let text = std::fs::read_to_string(path)?;
    let mut dag = Dag::new(data.n_vars());
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut it = line.split('\t');
        let (u, v) =
            (it.next().context("missing parent")?, it.next().context("missing child")?);
        let ui =
            data.index_of(u).with_context(|| format!("line {}: unknown var {u}", lineno + 1))?;
        let vi =
            data.index_of(v).with_context(|| format!("line {}: unknown var {v}", lineno + 1))?;
        dag.add_edge(ui, vi);
    }
    Ok(dag)
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["learned", "truth", "data", "ess"], &[])?;
    let data = Arc::new(read_csv(Path::new(a.require("data")?))?);
    let ess: f64 = a.get_parse("ess", 10.0)?;
    let truth = read_bif(Path::new(a.require("truth")?))?;
    let learned_path = Path::new(a.require("learned")?);
    let learned = if learned_path.extension().map(|e| e == "bif").unwrap_or(false) {
        read_bif(learned_path)?.dag
    } else {
        read_structure(learned_path, &data)?
    };
    let sc = BdeuScorer::new(data.clone(), ess);
    let r = evaluate(&learned, &truth.dag, &sc);
    println!(
        "BDeu {:.4} (normalized {:.4}) | SMHD {} | edges {} | skeleton P {:.3} R {:.3} F1 {:.3}",
        r.bdeu, r.bdeu_normalized, r.smhd, r.edges, r.precision, r.recall, r.f1
    );
    Ok(())
}
