//! cges — CLI for the ring-distributed Bayesian-network learner.
//!
//! Subcommands:
//!   gen-net    generate a ground-truth network (paper analogs or random)
//!   sample     forward-sample a dataset from a .bif network
//!   partition  show the stage-1 edge partition for a dataset
//!   learn      run cges / cges-l / ges / fges on a dataset (optionally
//!              emitting a .bnb model bundle)
//!   eval       score a learned structure against truth + data
//!   fit        fit CPTs for a learned structure into a .bnb bundle
//!              (calibrated for warm serving) or a legacy .bif
//!   query      answer marginal queries against a .bnb bundle (or .bif)
//!   serve      answer JSON queries over stdin or a loopback TCP
//!              listener, warm-starting from bundle potentials
//!   inspect    print a bundle's JSON debug form
//!   import-bif convert a .bif network into a .bnb bundle
//!   export-bif convert a .bnb bundle back to .bif
//!   obs        merge per-process observability artifacts (Chrome
//!              traces / metrics snapshots) into one timeline and one
//!              registry, with optional Prometheus exposition output

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use cges::bn::{
    fit, forward_sample, generate, load_domain, read_bif, write_bif, DiscreteBn, Domain,
    NetGenConfig,
};
use cges::cli::Args;
use cges::coordinator::{
    cges as run_cges, FaultPlan, FaultPolicy, PartitionSource, RingConfig, RingMode,
};
use cges::data::{read_csv, write_csv, Dataset};
use cges::engine::protocol::DEFAULT_MAX_BATCH;
use cges::engine::server::DEFAULT_MAX_FRAME_BYTES;
use cges::engine::{FleetConfig, FleetServer, ServeConfig, Server, SharedEngine};
use cges::graph::Dag;
use cges::infer::{ve_marginal, EngineConfig, Method};
use cges::learn::{fges, ges, FgesConfig, GesConfig};
use cges::metrics::evaluate;
use cges::model::{read_bundle, write_bundle, Bundle, BundleMeta};
use cges::partition::{partition_edges, partition_stats};
use cges::score::BdeuScorer;
use cges::util::Timer;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "gen-net" => cmd_gen_net(rest),
        "sample" => cmd_sample(rest),
        "partition" => cmd_partition(rest),
        "learn" => cmd_learn(rest),
        "eval" => cmd_eval(rest),
        "fit" => cmd_fit(rest),
        "query" => cmd_query(rest),
        "serve" => cmd_serve(rest),
        "inspect" => cmd_inspect(rest),
        "import-bif" => cmd_import_bif(rest),
        "export-bif" => cmd_export_bif(rest),
        "obs" => cmd_obs(rest),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (see `cges help`)"),
    }
}

const HELP: &str = "\
cges — ring-based distributed Bayesian-network structure learning

USAGE: cges <subcommand> [options]

SUBCOMMANDS
  gen-net    --family link|pigs|munin|random --out net.bif
             [--scale 1.0] [--nodes N --edges E --max-parents P] [--seed S]
  sample     --net net.bif --out data.csv [--rows 5000] [--seed S]
  partition  --data data.csv --k 4 [--ess 10] [--artifacts DIR]
  learn      --algo cges|cges-l|ges|fges --data data.csv [--out learned.dag]
             [--bundle model.bnb] [--bundle-ess 1] [--k 4] [--ess 10]
             [--threads N] [--artifacts DIR] [--trace trace.tsv|trace.json]
             [--metrics metrics.json|metrics.prom] [--max-rounds 50]
             [--obs-wire]
             --trace with a .json path writes a Chrome trace-event file
             (per-worker wait/codec/fuse/ges span lanes; load in
             Perfetto or chrome://tracing); any other extension keeps
             the per-hop TSV. --metrics writes a registry snapshot:
             a .prom path gets Prometheus exposition text, anything
             else JSON. --metrics also starts a /proc self-sampler
             (proc.rss_bytes / proc.user_secs / proc.sys_secs /
             proc.threads gauges). --obs-wire piggybacks worker span
             batches and metric deltas on ring messages (clock-aligned
             at the coordinator), so --trace/--metrics cover every
             worker in one timeline and one registry
             [--transport channel|tcp|sync]   ring execution mode:
             channel = pipelined in-process actors (default),
             tcp     = pipelined over loopback TCP (wire codec),
             sync    = deterministic barrier scheduler
             --bundle writes the final model as a self-contained .bnb
             artifact (structure + fitted CPTs + calibrated potentials)
             [--ring-timeout-ms MS]  straggler policy: bound the
             per-round wait for the predecessor's model; past it the
             round is skipped (worker steps on its own model) and the
             skip lands in ring.faults.* / the #summary faults field.
             Unset = block forever (legacy behavior). Worker dropouts
             heal either way: the ring re-links around a dead worker
             and redistributes its edge subset.
             [--fault-plan SPEC]  scripted fault injection (debugging
             the fault machinery; channel/tcp transports only). SPEC is
             comma-separated <action>:w<worker>@<hop>[:<param>] events:
             kill:w2@1 (panic worker 2 at its 2nd send), drop:w0@3,
             delay:w1@2:250ms, corrupt:w3@1, dup:w0@2. Faults show up
             in logs (CGES_LOG=warn), metrics and the trace
  eval       --learned learned.dag|.bif|.bnb --truth net.bif --data data.csv [--ess 10]
  fit        --structure learned.dag|.bif|.bnb --data data.csv --out fitted.bnb
             [--ess 1] [--budget 4194304]
             Dirichlet-smoothed ML CPTs: P = (N_jk + e/qr) / (N_j + e/q)
             .bnb output is calibrated for warm serving (within --budget);
             a .bif output path keeps the legacy interchange format
  query      --model fitted.bnb|.bif --target A[,B...] [--evidence \"X1=0,X2=s1\"]
             [--method auto|jointree|ve|lw] [--samples 20000] [--seed 1]
             [--budget 4194304]   (budget = max clique state space for exact)
  serve      --model fitted.bnb|.bif [--listen 127.0.0.1:7878] [--threads N]
             [--method auto|jointree|lw] [--samples 20000] [--seed 1] [--budget N]
             [--batch 256] [--max-frame-bytes 1048576] [--idle-timeout-ms MS]
             [--trace trace.json] [--metrics metrics.json|metrics.prom]
             [--fleet --models a.bnb,b.bnb [--workers N] [--no-control]]
             {\"type\":\"stats\"} answers a live metrics snapshot (request
             latency/frame-size/batch-depth histograms + counters);
             {\"type\":\"stats\",\"format\":\"prometheus\"} answers the same
             registry as Prometheus exposition text;
             {\"type\":\"stats_reset\",\"confirm\":true} zeroes it. --trace /
             --metrics write span + metrics files on shutdown (a .prom
             metrics path selects exposition text) and start the /proc
             self-sampler gauges.
             --idle-timeout-ms reaps connections idle between frames
             (counted in serve.conns_reaped) and fails reads stalled
             mid-frame, so quiet clients cannot pin handler threads
             CGES_LOG=error|warn|info|debug filters server-side logging
             a .bnb bundle with calibrated potentials warm-starts every
             handler thread (zero cold collect sweeps)
             stdin mode (default): one JSON query per line, one JSON answer per line
             TCP mode (--listen): u32-LE length-prefixed JSON frames, N handler
             threads over one shared compiled model; {\"type\":\"shutdown\"} stops
             query shape: {\"id\":1,\"type\":\"marginal\"|\"map\"|\"joint_map\",
                           \"targets\":[\"X3\"],\"evidence\":{\"X0\":0}}
             batch shape: {\"id\":2,\"type\":\"batch\",\"queries\":[...]} (answers
             match singletons; shared-evidence prefixes amortize propagation)
             --fleet swaps the thread pool for the event-loop runtime
             (requires --listen): one nonblocking I/O thread + --workers
             compute cores, pipelined keep-alive framing, and a
             multi-model registry keyed by bundle fingerprint. --models
             loads a comma list of bundles (first becomes active); the
             control plane hot-swaps under live traffic:
             {\"type\":\"load_model\",\"path\":\"m.bnb\"} loads on the server,
             {\"type\":\"switch\",\"model\":\"<fp>\"} points traffic at it,
             {\"type\":\"models\"} lists, {\"type\":\"unload\",...} drops an
             inactive model. --no-control refuses the mutating three
             (models stays readable). Query answers are byte-identical
             to the thread pool on the same bundle
  inspect    --bundle model.bnb          print the bundle's JSON debug form
  import-bif --bif net.bif --out net.bnb [--budget 4194304]
             [--no-calibrate]            convert + calibrate for warm serving
  export-bif --bundle model.bnb --out net.bif
  obs        merge <artifact...> [--out-trace merged.trace.json]
             [--out-metrics merged.metrics.json] [--out-prom merged.prom]
             join detached per-process obs artifacts offline: inputs
             are classified by content (JSON array = Chrome trace,
             snapshot object = metrics registry); traces land on
             distinct pids, metrics under proc<j>. prefixes when
             several. At least one --out-* is required.
";

fn cmd_gen_net(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(
        &["family", "out", "scale", "nodes", "edges", "max-parents", "seed"],
        &[],
    )?;
    let family = a.get("family").unwrap_or("random");
    let seed: u64 = a.get_parse("seed", 1)?;
    let scale: f64 = a.get_parse("scale", 1.0)?;
    let bn = if let Some(domain) = Domain::parse(family) {
        load_domain(domain, scale)
    } else if family == "random" {
        let cfg = NetGenConfig {
            nodes: a.get_parse("nodes", 50)?,
            edges: a.get_parse("edges", 75)?,
            max_parents: a.get_parse("max-parents", 3)?,
            ..Default::default()
        };
        generate(&cfg, seed)
    } else {
        bail!("unknown family '{family}' (link|pigs|munin|random)");
    };
    let out = PathBuf::from(a.require("out")?);
    write_bif(&bn, &out)?;
    println!(
        "wrote {}: {} nodes, {} edges, max parents {}, {} parameters",
        out.display(),
        bn.n(),
        bn.dag.edge_count(),
        bn.dag.max_in_degree(),
        bn.parameter_count()
    );
    Ok(())
}

fn cmd_sample(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["net", "out", "rows", "seed"], &[])?;
    let bn = read_bif(Path::new(a.require("net")?))?;
    let rows: usize = a.get_parse("rows", 5000)?;
    let seed: u64 = a.get_parse("seed", 1)?;
    let data = forward_sample(&bn, rows, seed);
    let out = PathBuf::from(a.require("out")?);
    write_csv(&data, &out)?;
    println!("wrote {}: {} rows x {} vars", out.display(), rows, data.n_vars());
    Ok(())
}

/// Stage-1 similarity source from an optional artifacts dir.
fn similarity_source(artifacts: Option<&str>) -> PartitionSource {
    match artifacts {
        Some(dir) => PartitionSource::Artifacts(PathBuf::from(dir)),
        None => PartitionSource::RustFallback,
    }
}

fn cmd_partition(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["data", "k", "ess", "artifacts", "threads"], &[])?;
    let data = Arc::new(read_csv(Path::new(a.require("data")?))?);
    let k: usize = a.get_parse("k", 4)?;
    let ess: f64 = a.get_parse("ess", 10.0)?;
    let threads: usize = a.get_parse("threads", cges::util::num_threads())?;

    let t = Timer::start();
    let (pw, source) = match similarity_source(a.get("artifacts")) {
        PartitionSource::Artifacts(dir) => {
            let rt = cges::runtime::SimilarityRuntime::load(&dir)?;
            (rt.pairwise(&data, ess)?, format!("xla:{}", rt.platform()))
        }
        PartitionSource::RustFallback => (
            cges::score::pairwise_similarity(&data, ess, threads),
            "rust-fallback".to_string(),
        ),
    };
    let sim_secs = t.secs();
    let masks = partition_edges(&pw.s, k);
    let stats = partition_stats(&masks, data.n_vars());
    println!("similarity: {source} in {sim_secs:.2}s");
    println!(
        "partition into k={k}: sizes {:?} (total {} / expected {})",
        stats.sizes, stats.total, stats.expected
    );
    Ok(())
}

fn cmd_learn(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &["obs-wire"])?;
    a.check_known(
        &[
            "algo",
            "data",
            "out",
            "bundle",
            "bundle-ess",
            "k",
            "ess",
            "threads",
            "artifacts",
            "trace",
            "metrics",
            "max-rounds",
            "max-parents",
            "transport",
            "ring-timeout-ms",
            "fault-plan",
        ],
        &["obs-wire"],
    )?;
    let algo = a.require("algo")?;
    let data = Arc::new(read_csv(Path::new(a.require("data")?))?);
    let ess: f64 = a.get_parse("ess", 10.0)?;
    let threads: usize = a.get_parse("threads", cges::util::num_threads())?;
    let k: usize = a.get_parse("k", 4)?;
    let n = data.n_vars();
    let bundle_out = a.get("bundle").map(str::to_string);
    let bundle_ess: f64 = a.get_parse("bundle-ess", 1.0)?;

    // Observability: --metrics collects the run's counters and
    // histograms into a registry written as JSON at the end; --trace
    // with a .json path records live spans and writes a Chrome
    // trace-event file (Perfetto-loadable), any other extension keeps
    // the legacy per-hop TSV.
    let trace_path = a.get("trace").map(str::to_string);
    let metrics_path = a.get("metrics").map(str::to_string);
    let want_chrome =
        trace_path.as_deref().map(|p| p.ends_with(".json")).unwrap_or(false);
    let registry = cges::obs::Registry::new();
    let tracer = cges::obs::Tracer::new(want_chrome);
    // Background /proc self-sampler: machine context (RSS, CPU time,
    // threads) lands in the same snapshot as the algorithmic series.
    let sys_sampler = metrics_path.as_ref().map(|_| {
        cges::obs::SysSampler::start(&registry, std::time::Duration::from_millis(500))
    });

    let t = Timer::start();
    let (dag, score, mut bundle) = match algo {
        "cges" | "cges-l" => {
            let mode = match a.get("transport") {
                None => RingMode::default(),
                Some(name) => RingMode::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("--transport: unknown mode '{name}' (channel|tcp|sync)"))?,
            };
            // Fault tolerance: --ring-timeout-ms arms the straggler
            // policy; --fault-plan scripts chaos (debug/testing only).
            let fault_policy = FaultPolicy {
                recv_timeout: a
                    .get("ring-timeout-ms")
                    .map(|v| v.parse::<u64>())
                    .transpose()
                    .context("--ring-timeout-ms")?
                    .map(std::time::Duration::from_millis),
                ..Default::default()
            };
            let fault_plan = a
                .get("fault-plan")
                .map(FaultPlan::parse)
                .transpose()
                .context("--fault-plan")?;
            let cfg = RingConfig {
                k,
                limit_inserts: algo == "cges-l",
                ess,
                threads,
                max_rounds: a.get_parse("max-rounds", 50)?,
                partition_source: similarity_source(a.get("artifacts")),
                fine_tune: true,
                max_parents: a.get("max-parents").map(|v| v.parse()).transpose()?,
                mode,
                emit_bundle: bundle_out.is_some(),
                bundle_ess,
                registry: metrics_path.is_some().then(|| registry.clone()),
                tracer: tracer.clone(),
                distributed_obs: a.flag("obs-wire"),
                fault_policy,
                fault_plan,
                ..Default::default()
            };
            let r = run_cges(data.clone(), &cfg)?;
            println!(
                "ring [{}] converged in {} rounds (partition {:.2}s [{}], learning {:.2}s, fine-tune {:.2}s; cache {}/{} hit/computed)",
                r.telemetry.transport,
                r.rounds,
                r.telemetry.partition_secs,
                r.telemetry.partition_source,
                r.telemetry.learning_secs,
                r.telemetry.fine_tune_secs,
                r.telemetry.cache_hits,
                r.telemetry.cache_misses,
            );
            if r.telemetry.faults.any() {
                let f = &r.telemetry.faults;
                println!(
                    "ring faults: {} timeout(s), {} skipped round(s), {} frame retr(ies), \
                     {} duplicate(s), {} death(s), {} healed",
                    f.timeouts, f.skips, f.retries, f.duplicates, f.deaths, f.healed
                );
            }
            if let Some(path) = &trace_path {
                if want_chrome {
                    tracer
                        .write_chrome(Path::new(path))
                        .with_context(|| format!("write chrome trace {path}"))?;
                    println!(
                        "chrome trace written to {path} (load in Perfetto or chrome://tracing)"
                    );
                } else {
                    r.telemetry.write_tsv(Path::new(path))?;
                    println!("trace written to {path}");
                }
            }
            (r.dag, r.score, r.bundle)
        }
        "ges" => {
            let sc = BdeuScorer::new(data.clone(), ess);
            sc.bind_obs(&registry);
            let r = ges(&sc, &Dag::new(n), &GesConfig { threads, ..Default::default() });
            r.export_obs(&registry);
            (r.dag, r.score, None)
        }
        "fges" => {
            let sc = BdeuScorer::new(data.clone(), ess);
            sc.bind_obs(&registry);
            let r = fges(&sc, &Dag::new(n), &FgesConfig { threads, ..Default::default() });
            r.export_obs(&registry);
            (r.dag, r.score, None)
        }
        other => bail!("unknown algo '{other}' (cges|cges-l|ges|fges)"),
    };
    let secs = t.secs();
    println!(
        "{algo}: score {score:.4} (normalized {:.4}), {} edges, {secs:.2}s",
        score / data.n_rows() as f64,
        dag.edge_count()
    );
    if let Some(mpath) = &metrics_path {
        registry.gauge("learn.total_secs").set(secs);
        drop(sys_sampler); // stop the background thread, then sample once more
        write_metrics(&registry, mpath)?;
        println!("metrics written to {mpath}");
    }

    if let Some(out) = a.get("out") {
        write_structure(&dag, data.names(), Path::new(out))?;
        println!("structure written to {out}");
    }
    if let Some(bpath) = bundle_out {
        // The ring emits one for cges runs; ges/fges build it here. A
        // fit failure degrades to a warning — the completed learning
        // run (and any --out structure, already written above) must
        // never be discarded over the artifact.
        if bundle.is_none() {
            let meta = BundleMeta {
                producer: format!("cges learn --algo {algo}"),
                rounds: 0,
                score,
                ess: bundle_ess,
            };
            match Bundle::fit_calibrated(&dag, &data, EngineConfig::default().budget, meta) {
                Ok(b) => bundle = Some(b),
                Err(e) => eprintln!(
                    "warning: cannot build the bundle ({e:#}); no {bpath} written — \
                     consider --max-parents to bound the largest family"
                ),
            }
        }
        if let Some(b) = bundle {
            write_bundle(&b, Path::new(&bpath))?;
            println!(
                "bundle written to {bpath}: {} vars, {} parameters, potentials {}",
                b.n_vars(),
                b.bn.parameter_count(),
                if b.has_potentials() { "calibrated" } else { "none (over budget)" }
            );
        }
    }
    Ok(())
}

/// Write a registry to `path`, taking one final `/proc` sample first
/// so the snapshot reflects end-of-run usage: a `.prom` extension
/// selects Prometheus exposition text, anything else the JSON
/// snapshot.
fn write_metrics(registry: &cges::obs::Registry, path: &str) -> Result<()> {
    cges::obs::sysinfo::sample_now(registry);
    let p = Path::new(path);
    if p.extension().map(|e| e == "prom").unwrap_or(false) {
        registry.write_prometheus(p).with_context(|| format!("write metrics {path}"))
    } else {
        registry.write_json(p).with_context(|| format!("write metrics {path}"))
    }
}

/// Write a learned structure as an edge list (`.dag` text format:
/// one `parent<TAB>child` line per edge, names resolved).
fn write_structure(dag: &Dag, names: &[String], path: &Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for (u, v) in dag.edges() {
        writeln!(f, "{}\t{}", names[u], names[v])?;
    }
    Ok(())
}

/// Read a structure written by [`write_structure`].
fn read_structure(path: &Path, data: &Dataset) -> Result<Dag> {
    let text = std::fs::read_to_string(path)?;
    let mut dag = Dag::new(data.n_vars());
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut it = line.split('\t');
        let (u, v) =
            (it.next().context("missing parent")?, it.next().context("missing child")?);
        let ui =
            data.index_of(u).with_context(|| format!("line {}: unknown var {u}", lineno + 1))?;
        let vi =
            data.index_of(v).with_context(|| format!("line {}: unknown var {v}", lineno + 1))?;
        dag.add_edge(ui, vi);
    }
    Ok(dag)
}

/// Re-index a BIF-declared DAG into a dataset's column order by
/// variable name (BIF declaration order need not match the CSV header;
/// fitting by raw index would silently permute the structure).
fn align_bif_dag(bn: &DiscreteBn, data: &Dataset) -> Result<Dag> {
    let map: Vec<usize> = bn
        .names
        .iter()
        .map(|name| {
            data.index_of(name)
                .ok_or_else(|| anyhow!("structure variable '{name}' not in the dataset"))
        })
        .collect::<Result<_>>()?;
    let mut dag = Dag::new(data.n_vars());
    for (u, v) in bn.dag.edges() {
        dag.add_edge(map[u], map[v]);
    }
    Ok(dag)
}

/// Does a path name a `.bnb` bundle?
fn is_bnb(path: &Path) -> bool {
    path.extension().map(|e| e == "bnb").unwrap_or(false)
}

/// Load a learned structure for fitting/eval: `.bnb` bundle, `.bif`
/// network or `.dag` edge list, name-aligned to the dataset columns.
fn read_any_structure(spath: &Path, data: &Dataset) -> Result<Dag> {
    if is_bnb(spath) {
        align_bif_dag(&read_bundle(spath)?.bn, data)
    } else if spath.extension().map(|e| e == "bif").unwrap_or(false) {
        align_bif_dag(&read_bif(spath)?, data)
    } else {
        read_structure(spath, data)
    }
}

fn cmd_fit(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["structure", "data", "out", "ess", "budget"], &[])?;
    let data = read_csv(Path::new(a.require("data")?))?;
    let spath = Path::new(a.require("structure")?);
    let dag = read_any_structure(spath, &data)?;
    let ess: f64 = a.get_parse("ess", 1.0)?;
    let out = PathBuf::from(a.require("out")?);
    let t = Timer::start();
    if is_bnb(&out) {
        let meta = BundleMeta { producer: "cges fit".into(), rounds: 0, score: f64::NAN, ess };
        let budget: u64 = a.get_parse("budget", EngineConfig::default().budget)?;
        let bundle = Bundle::fit_calibrated(&dag, &data, budget, meta)?;
        let secs = t.secs();
        write_bundle(&bundle, &out)?;
        println!(
            "fitted {} variables ({} edges, {} parameters, ess {ess}) from {} rows in {secs:.2}s -> {} (potentials {})",
            bundle.n_vars(),
            bundle.bn.dag.edge_count(),
            bundle.bn.parameter_count(),
            data.n_rows(),
            out.display(),
            if bundle.has_potentials() { "calibrated" } else { "none (over budget)" }
        );
    } else {
        let bn = fit(&dag, &data, ess)?;
        let secs = t.secs();
        write_bif(&bn, &out)?;
        println!(
            "fitted {} variables ({} edges, {} parameters, ess {ess}) from {} rows in {secs:.2}s -> {}",
            bn.n(),
            bn.dag.edge_count(),
            bn.parameter_count(),
            data.n_rows(),
            out.display()
        );
    }
    Ok(())
}

/// Parse `--evidence "X1=0,X2=s1"` against a network's variable names
/// (same lookup/state helpers as the serve protocol).
fn parse_evidence(spec: &str, bn: &DiscreteBn) -> Result<Vec<(usize, usize)>> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (name, state) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("evidence '{part}' is not name=state"))?;
        let name = name.trim();
        let v = cges::infer::var_index(&bn.names, name)?;
        let s = cges::infer::parse_state(state.trim(), bn.cards[v])
            .with_context(|| format!("evidence for '{name}'"))?;
        out.push((v, s));
    }
    Ok(out)
}

fn print_marginal(name: &str, dist: &[f64]) {
    let cells: Vec<String> =
        dist.iter().enumerate().map(|(s, p)| format!("s{s} {p:.6}")).collect();
    println!("P({name} | e): {}", cells.join("  "));
}

/// Load one model path as a bundle: `.bnb` files decode directly (and
/// may carry a warm-start payload); `.bif` files import as a
/// potential-less bundle.
fn load_bundle_at(path: &str) -> Result<Bundle> {
    let p = Path::new(path);
    if is_bnb(p) {
        read_bundle(p)
    } else {
        Ok(Bundle::from_bn(read_bif(p)?, BundleMeta::imported(&format!("bif:{path}"))))
    }
}

/// Load the model argument (`--model`, or the legacy `--net` alias) as
/// a bundle. Returns the path alongside for status lines.
fn load_model_bundle(a: &Args) -> Result<(Bundle, &str)> {
    let path = a
        .get("model")
        .or_else(|| a.get("net"))
        .ok_or_else(|| anyhow!("missing required option --model (a .bnb bundle or .bif)"))?;
    Ok((load_bundle_at(path)?, path))
}

fn cmd_query(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(
        &["model", "net", "target", "evidence", "method", "samples", "seed", "budget"],
        &[],
    )?;
    let (bundle, _) = load_model_bundle(&a)?;
    let bn = &bundle.bn;
    let method_name = a.get("method").unwrap_or("auto");
    let method = Method::parse(method_name)
        .ok_or_else(|| anyhow!("--method: unknown '{method_name}' (auto|jointree|ve|lw)"))?;
    let evidence = parse_evidence(a.get("evidence").unwrap_or(""), bn)?;
    let targets: Vec<usize> = a
        .require("target")?
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|name| cges::infer::var_index(&bn.names, name))
        .collect::<Result<_>>()?;
    ensure!(!targets.is_empty(), "--target lists no variables");

    let t = Timer::start();
    if method == Method::Ve {
        for &v in &targets {
            let dist = ve_marginal(bn, v, &evidence)?;
            print_marginal(&bn.names[v], &dist);
        }
        println!("engine ve | {} target(s) in {:.3}s", targets.len(), t.secs());
    } else {
        let cfg = EngineConfig {
            method,
            budget: a.get_parse("budget", EngineConfig::default().budget)?,
            samples: a.get_parse("samples", EngineConfig::default().samples)?,
            seed: a.get_parse("seed", 1)?,
        };
        let engine = SharedEngine::from_bundle(&bundle, &cfg)?;
        let mut scratch = engine.new_scratch();
        let post = engine.posterior(&mut scratch, &evidence)?;
        for &v in &targets {
            print_marginal(&bn.names[v], post.marginal(v));
        }
        println!(
            "engine {}{} | log P(evidence) = {:.6} | {:.3}s",
            engine.name(),
            if engine.warm_started() { " (warm-started)" } else { "" },
            post.log_evidence,
            t.secs()
        );
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &["fleet", "no-control"])?;
    a.check_known(
        &[
            "model",
            "net",
            "models",
            "listen",
            "method",
            "samples",
            "seed",
            "budget",
            "threads",
            "workers",
            "batch",
            "max-frame-bytes",
            "idle-timeout-ms",
            "trace",
            "metrics",
        ],
        &["fleet", "no-control"],
    )?;
    let method_name = a.get("method").unwrap_or("auto");
    let method = Method::parse(method_name)
        .ok_or_else(|| anyhow!("--method: unknown '{method_name}' (auto|jointree|lw)"))?;
    ensure!(method != Method::Ve, "serve engines are auto|jointree|lw");
    let cfg = EngineConfig {
        method,
        budget: a.get_parse("budget", EngineConfig::default().budget)?,
        samples: a.get_parse("samples", EngineConfig::default().samples)?,
        seed: a.get_parse("seed", 1)?,
    };
    let serve_cfg = ServeConfig {
        threads: a.get_parse("threads", cges::util::num_threads())?,
        max_frame_bytes: a.get_parse("max-frame-bytes", DEFAULT_MAX_FRAME_BYTES)?,
        max_batch: a.get_parse("batch", DEFAULT_MAX_BATCH)?,
        idle_timeout: a
            .get("idle-timeout-ms")
            .map(|v| v.parse::<u64>())
            .transpose()
            .context("--idle-timeout-ms")?
            .map(std::time::Duration::from_millis),
    };
    ensure!(serve_cfg.threads >= 1, "--threads must be at least 1");
    ensure!(serve_cfg.max_frame_bytes >= 64, "--max-frame-bytes must be at least 64");
    ensure!(serve_cfg.max_batch >= 1, "--batch must be at least 1");
    let trace_path = a.get("trace").map(str::to_string);
    let metrics_path = a.get("metrics").map(str::to_string);
    if a.flag("fleet") {
        return serve_fleet(&a, &cfg, &serve_cfg, trace_path, metrics_path);
    }
    let (bundle, net) = load_model_bundle(&a)?;
    let mut server = Server::from_bundle(&bundle, &cfg, serve_cfg.clone())?;
    if trace_path.is_some() {
        server.set_tracer(cges::obs::Tracer::new(true));
    }
    let sys_sampler = metrics_path.as_ref().map(|_| {
        cges::obs::SysSampler::start(server.registry(), std::time::Duration::from_millis(500))
    });
    let warm = if server.warm_started() { " warm-started from bundle potentials" } else { "" };
    match a.get("listen") {
        Some(addr) => {
            let listener =
                TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
            eprintln!(
                "serving {net} on {} (engine {}{warm}; {} handler thread(s); frames: u32 LE \
                 length + JSON, cap {} bytes; batch cap {}; send {{\"type\":\"shutdown\"}} to stop)",
                listener.local_addr().context("listener addr")?,
                server.engine_name(),
                serve_cfg.threads,
                serve_cfg.max_frame_bytes,
                serve_cfg.max_batch,
            );
            server.serve_tcp(&listener, None)?;
        }
        None => {
            eprintln!(
                "serving {net} on stdin/stdout (engine {}{warm}; one JSON query per line)",
                server.engine_name()
            );
            let stdin = std::io::stdin();
            let served = server.serve_lines(stdin.lock(), std::io::stdout().lock())?;
            eprintln!("served {served} queries");
        }
    }
    if let Some(p) = &trace_path {
        server
            .tracer()
            .write_chrome(Path::new(p))
            .with_context(|| format!("write chrome trace {p}"))?;
        eprintln!("trace written to {p}");
    }
    if let Some(p) = &metrics_path {
        drop(sys_sampler); // stop the background thread, then sample once more
        write_metrics(server.registry(), p)?;
        eprintln!("metrics written to {p}");
    }
    Ok(())
}

/// `serve --fleet`: the event-loop runtime hosting every `--models`
/// path behind one listener, with the control plane for live loads and
/// hot swaps.
fn serve_fleet(
    a: &Args,
    cfg: &EngineConfig,
    serve_cfg: &ServeConfig,
    trace_path: Option<String>,
    metrics_path: Option<String>,
) -> Result<()> {
    let addr = a
        .get("listen")
        .ok_or_else(|| anyhow!("--fleet requires --listen (the event loop serves TCP only)"))?;
    // `--models a.bnb,b.bnb` (first is active) and/or the single
    // `--model`; the fleet can also start empty and be populated over
    // the control plane.
    let mut paths: Vec<String> = Vec::new();
    if let Some(m) = a.get("model").or_else(|| a.get("net")) {
        paths.push(m.to_string());
    }
    if let Some(list) = a.get("models") {
        let listed = list.split(',').map(str::trim).filter(|s| !s.is_empty());
        paths.extend(listed.map(str::to_string));
    }
    let fleet_cfg = FleetConfig {
        workers: a.get_parse("workers", serve_cfg.threads)?,
        max_frame_bytes: serve_cfg.max_frame_bytes,
        max_batch: serve_cfg.max_batch,
        control: !a.flag("no-control"),
    };
    ensure!(fleet_cfg.workers >= 1, "--workers must be at least 1");
    ensure!(
        !paths.is_empty() || fleet_cfg.control,
        "an empty fleet with --no-control could never serve; name --models or drop --no-control"
    );
    let mut fleet = FleetServer::new(cfg.clone(), fleet_cfg.clone());
    if trace_path.is_some() {
        fleet.set_tracer(cges::obs::Tracer::new(true));
    }
    for path in &paths {
        let fp = fleet
            .load_bundle(&load_bundle_at(path)?)
            .with_context(|| format!("load model {path}"))?;
        eprintln!("loaded {path} as model {}", cges::model::fingerprint_hex(fp));
    }
    let sys_sampler = metrics_path.as_ref().map(|_| {
        cges::obs::SysSampler::start(fleet.registry(), std::time::Duration::from_millis(500))
    });
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    eprintln!(
        "fleet serving {} model(s) on {} ({} worker core(s) + 1 event loop; frames: u32 LE \
         length + JSON, cap {} bytes; batch cap {}; control plane {}; \
         send {{\"type\":\"shutdown\"}} to stop)",
        fleet.models().len(),
        listener.local_addr().context("listener addr")?,
        fleet_cfg.workers,
        fleet_cfg.max_frame_bytes,
        fleet_cfg.max_batch,
        if fleet_cfg.control { "on" } else { "off (--no-control)" },
    );
    fleet.serve(&listener, None)?;
    if let Some(p) = &trace_path {
        fleet
            .tracer()
            .write_chrome(Path::new(p))
            .with_context(|| format!("write chrome trace {p}"))?;
        eprintln!("trace written to {p}");
    }
    if let Some(p) = &metrics_path {
        drop(sys_sampler); // stop the background thread, then sample once more
        write_metrics(fleet.registry(), p)?;
        eprintln!("metrics written to {p}");
    }
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["bundle"], &[])?;
    let bundle = read_bundle(Path::new(a.require("bundle")?))?;
    println!("{}", bundle.to_debug_json());
    Ok(())
}

fn cmd_import_bif(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &["no-calibrate"])?;
    a.check_known(&["bif", "out", "budget"], &["no-calibrate"])?;
    let bif = a.require("bif")?;
    let bn = read_bif(Path::new(bif))?;
    let meta = BundleMeta::imported(&format!("import-bif {bif}"));
    let bundle = if a.flag("no-calibrate") {
        Bundle::from_bn(bn, meta)
    } else {
        let budget: u64 = a.get_parse("budget", EngineConfig::default().budget)?;
        Bundle::calibrated_within(bn, meta, budget)
    };
    let out = PathBuf::from(a.require("out")?);
    write_bundle(&bundle, &out)?;
    println!(
        "imported {bif} -> {}: {} vars, {} parameters, potentials {}",
        out.display(),
        bundle.n_vars(),
        bundle.bn.parameter_count(),
        if bundle.has_potentials() { "calibrated" } else { "none" }
    );
    Ok(())
}

fn cmd_export_bif(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["bundle", "out"], &[])?;
    let bpath = a.require("bundle")?;
    let bundle = read_bundle(Path::new(bpath))?;
    let out = PathBuf::from(a.require("out")?);
    write_bif(&bundle.bn, &out)?;
    println!(
        "exported {bpath} -> {}: {} vars, {} edges (potentials dropped; BIF carries none)",
        out.display(),
        bundle.n_vars(),
        bundle.bn.dag.edge_count()
    );
    Ok(())
}

fn cmd_obs(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["out-trace", "out-metrics", "out-prom"], &[])?;
    match a.pos(0) {
        Some("merge") => {}
        Some(other) => bail!("unknown obs action '{other}' (expected `obs merge`)"),
        None => bail!(
            "usage: cges obs merge <artifact...> \
             [--out-trace T.json] [--out-metrics M.json] [--out-prom P.prom]"
        ),
    }
    let inputs: Vec<PathBuf> =
        (1..a.n_pos()).filter_map(|i| a.pos(i)).map(PathBuf::from).collect();
    ensure!(!inputs.is_empty(), "obs merge needs at least one input artifact");
    let (out_trace, out_metrics, out_prom) =
        (a.get("out-trace"), a.get("out-metrics"), a.get("out-prom"));
    ensure!(
        out_trace.is_some() || out_metrics.is_some() || out_prom.is_some(),
        "obs merge: name at least one output (--out-trace, --out-metrics or --out-prom)"
    );
    let merged = cges::obs::merge::merge_files(&inputs)?;
    println!(
        "merged {} trace input(s) ({} events) and {} metrics input(s)",
        merged.traces_in, merged.trace_events, merged.metrics_in
    );
    if let Some(p) = out_trace {
        std::fs::write(p, &merged.trace_json)
            .with_context(|| format!("write merged trace {p}"))?;
        println!("merged trace written to {p} (load in Perfetto or chrome://tracing)");
    }
    if let Some(p) = out_metrics {
        merged
            .registry
            .write_json(Path::new(p))
            .with_context(|| format!("write merged metrics {p}"))?;
        println!("merged metrics written to {p}");
    }
    if let Some(p) = out_prom {
        merged
            .registry
            .write_prometheus(Path::new(p))
            .with_context(|| format!("write prometheus text {p}"))?;
        println!("prometheus exposition written to {p}");
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &[])?;
    a.check_known(&["learned", "truth", "data", "ess"], &[])?;
    let data = Arc::new(read_csv(Path::new(a.require("data")?))?);
    let ess: f64 = a.get_parse("ess", 10.0)?;
    let truth = read_bif(Path::new(a.require("truth")?))?;
    let learned = read_any_structure(Path::new(a.require("learned")?), &data)?;
    let sc = BdeuScorer::new(data.clone(), ess);
    let r = evaluate(&learned, &truth.dag, &sc);
    println!(
        "BDeu {:.4} (normalized {:.4}) | SMHD {} | edges {} | skeleton P {:.3} R {:.3} F1 {:.3}",
        r.bdeu, r.bdeu_normalized, r.smhd, r.edges, r.precision, r.recall, r.f1
    );
    Ok(())
}
