//! Multi-client query serving over a shared compiled engine.
//!
//! [`Server`] owns one [`SharedEngine`] and answers the
//! [`protocol`] request surface over two media:
//!
//! * **TCP** ([`Server::serve_tcp`]) — a bounded pool of
//!   connection-handler threads, each with its own [`Scratch`],
//!   pulling accepted connections from a queue. Requests and
//!   responses are `u32` little-endian length prefix plus JSON
//!   payload, the same framing idiom as the ring's
//!   [`transport`](crate::coordinator::transport) wire format, with a
//!   configurable per-frame cap sharing the transport's
//!   oversized-frame wording ([`crate::util::ensure_frame_len`]).
//!   A `{"type": "shutdown"}` sentinel stops the accept loop and
//!   drains the pool gracefully: in-flight requests finish and flush,
//!   then connections close. A client that vanishes mid-stream
//!   (reset, SIGPIPE-style broken pipe) fails only its own
//!   connection.
//! * **lines** ([`Server::serve_lines`]) — the original
//!   newline-delimited JSON adapter over any `BufRead`/`Write` pair
//!   (the CLI wires stdin/stdout), one response per request line,
//!   single-threaded by construction.
//!
//! Because the engine is shared behind `&self` and every propagation
//! runs in caller-owned scratch, N clients cost N scratches — the
//! compiled model (the big allocation) exists once.
//!
//! The pool is thread-per-connection: a persistent connection occupies
//! its handler for the connection's lifetime, so size
//! [`ServeConfig::threads`] to the number of *concurrent clients* you
//! expect (the CLI defaults to the core count), not to request
//! volume. Idle and even mid-frame-stalled connections stop blocking
//! shutdown: every read path polls the shutdown latch on its idle
//! timeout. With [`ServeConfig::idle_timeout`] set, a reaper closes
//! connections that sit quiet between frames (counted in
//! `serve.conns_reaped`) and fails reads that stall mid-frame, so a
//! wedged client can never pin a handler thread for good.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::bn::DiscreteBn;
use crate::engine::protocol::{self, DEFAULT_MAX_BATCH};
use crate::engine::{Scratch, SharedEngine};
use crate::infer::json::Json;
use crate::infer::EngineConfig;
use crate::obs;
use crate::util::ensure_frame_len;

/// Default cap on one framed request/response (1 MiB; the ring
/// transport uses its own, larger cap for model frames). CLI
/// `--max-frame-bytes`.
pub const DEFAULT_MAX_FRAME_BYTES: u32 = 1 << 20;

/// How often an idle connection read wakes up to check the shutdown
/// flag.
const IDLE_POLL: Duration = Duration::from_millis(200);

/// Patience for a frame caught mid-transit during the shutdown drain:
/// frames the client already pipelined get answered, but a client
/// trickling bytes cannot hold shutdown hostage longer than this.
const DRAIN_GRACE: Duration = Duration::from_secs(1);

/// Socket read timeout during the drain (short: the drain's job is to
/// flush what is buffered and get out).
const DRAIN_POLL: Duration = Duration::from_millis(25);

/// Serving parameters (transport-level; engine selection lives in
/// [`EngineConfig`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Connection-handler threads for [`Server::serve_tcp`].
    pub threads: usize,
    /// Per-frame byte cap (requests and responses).
    pub max_frame_bytes: u32,
    /// Max sub-queries per batch request.
    pub max_batch: usize,
    /// Reap a connection idle longer than this between frames (and cap
    /// how long a client may stall *mid*-frame before the read fails).
    /// `None` keeps connections alive indefinitely — a quiet
    /// persistent client holds its handler thread, so bounded pools
    /// serving untrusted clients should set this. CLI
    /// `--idle-timeout-ms`.
    pub idle_timeout: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            threads: 1,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            max_batch: DEFAULT_MAX_BATCH,
            idle_timeout: None,
        }
    }
}

/// Pre-created handles for the serving metrics, so the hot path never
/// takes the registry's name-map lock (handles are `Arc`s onto the
/// same atomics a `{"type": "stats"}` snapshot reads).
struct ServeMetrics {
    requests: obs::Counter,
    errors: obs::Counter,
    conns_accepted: obs::Counter,
    conns_failed: obs::Counter,
    conns_reaped: obs::Counter,
    conns_closed: obs::Counter,
    /// True gauge of connections currently held by handlers (or queued
    /// for one): +1 at accept, −1 when the handler finishes — on the
    /// clean-EOF, idle-reap *and* failure paths alike, so
    /// `accepted == closed` and `open == 0` hold at quiescence.
    conns_open: obs::Gauge,
    latency: obs::Hist,
    frame_bytes: obs::Hist,
    batch_depth: obs::Hist,
}

impl ServeMetrics {
    fn bind(reg: &obs::Registry) -> ServeMetrics {
        ServeMetrics {
            requests: reg.counter("serve.requests"),
            errors: reg.counter("serve.errors"),
            conns_accepted: reg.counter("serve.conns_accepted"),
            conns_failed: reg.counter("serve.conns_failed"),
            conns_reaped: reg.counter("serve.conns_reaped"),
            conns_closed: reg.counter("serve.conns_closed"),
            conns_open: reg.gauge("serve.conns_open"),
            latency: reg.hist("serve.latency_ns"),
            frame_bytes: reg.hist("serve.frame_bytes"),
            batch_depth: reg.hist("serve.batch_depth"),
        }
    }
}

/// A query server bound to one fitted network: a shared engine, the
/// serve configuration, the shutdown latch and the observability
/// surface (metrics registry + tracer). Every server carries its own
/// registry — `{"type": "stats"}` always answers — and callers that
/// aggregate metrics elsewhere swap in theirs with
/// [`Server::bind_registry`].
pub struct Server {
    engine: SharedEngine,
    cfg: ServeConfig,
    shutdown: AtomicBool,
    registry: obs::Registry,
    tracer: obs::Tracer,
    metrics: ServeMetrics,
}

impl Server {
    fn assemble(engine: SharedEngine, cfg: ServeConfig) -> Server {
        let registry = obs::Registry::new();
        let metrics = ServeMetrics::bind(&registry);
        Server {
            engine,
            cfg,
            shutdown: AtomicBool::new(false),
            registry,
            tracer: obs::Tracer::disabled(),
            metrics,
        }
    }

    /// Compile an engine for `bn` per `engine_cfg` and wrap it for
    /// serving per `cfg`.
    pub fn new(bn: &DiscreteBn, engine_cfg: &EngineConfig, cfg: ServeConfig) -> Result<Server> {
        Ok(Self::assemble(SharedEngine::build(bn, engine_cfg)?, cfg))
    }

    /// Serve a model bundle: the exact engine warm-starts from the
    /// bundle's shipped potentials when its schedule fingerprint
    /// matches ([`SharedEngine::from_bundle`]), so the first query on
    /// every handler thread skips the cold collect sweep.
    pub fn from_bundle(
        bundle: &crate::model::Bundle,
        engine_cfg: &EngineConfig,
        cfg: ServeConfig,
    ) -> Result<Server> {
        Ok(Self::assemble(SharedEngine::from_bundle(bundle, engine_cfg)?, cfg))
    }

    /// Swap in an externally owned registry (CLI `--metrics`): the
    /// serving metrics re-register there and `{"type": "stats"}`
    /// snapshots it, so serve counters land next to whatever else the
    /// caller aggregates.
    pub fn bind_registry(&mut self, registry: obs::Registry) {
        self.metrics = ServeMetrics::bind(&registry);
        self.registry = registry;
    }

    /// Enable span tracing (CLI `--trace`): request, collect and
    /// distribute spans record into `tracer`'s lanes, one per handler
    /// thread.
    pub fn set_tracer(&mut self, tracer: obs::Tracer) {
        self.tracer = tracer;
    }

    /// The metrics registry `{"type": "stats"}` snapshots.
    pub fn registry(&self) -> &obs::Registry {
        &self.registry
    }

    /// The span tracer (disabled unless [`Server::set_tracer`] ran).
    pub fn tracer(&self) -> &obs::Tracer {
        &self.tracer
    }

    /// Did the engine warm-start from shipped potentials?
    pub fn warm_started(&self) -> bool {
        self.engine.warm_started()
    }

    /// The shared engine (for in-process querying next to serving).
    pub fn engine(&self) -> &SharedEngine {
        &self.engine
    }

    /// Which engine backs this server (`"jointree"` or `"lw"`).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Fresh per-thread propagation buffers.
    pub fn new_scratch(&self) -> Scratch {
        self.engine.new_scratch()
    }

    /// Has the shutdown sentinel been received?
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Answer one JSON request with one JSON response. The shutdown
    /// sentinel is acknowledged and latches the shutdown flag; every
    /// request lands in the serve metrics (`serve.requests`,
    /// `serve.latency_ns`, `serve.errors`).
    pub fn handle(&self, scratch: &mut Scratch, request: &str) -> String {
        let mut th = self.tracer.handle(0);
        self.handle_traced(scratch, &mut th, request)
    }

    /// [`Server::handle`] recording its request span into a caller
    /// thread's trace lane (the TCP pool keeps one handle per handler
    /// thread so lanes stay per-worker).
    pub fn handle_traced(
        &self,
        scratch: &mut Scratch,
        th: &mut obs::TraceHandle,
        request: &str,
    ) -> String {
        let t0 = th.start();
        let sw = obs::Stopwatch::start();
        let (label, response) = self.respond(scratch, request);
        self.metrics.requests.inc();
        self.metrics.latency.record(sw.elapsed_ns());
        th.end(t0, label, "serve");
        response
    }

    /// Dispatch one request and name it for the trace span. The
    /// server-level types (`stats`, `stats_reset`, shutdown) answer
    /// here; everything else goes through [`protocol::answer`]
    /// unchanged, so query responses are byte-identical to a server
    /// without observability attached.
    fn respond(&self, scratch: &mut Scratch, request: &str) -> (&'static str, String) {
        let parsed = match Json::parse(request) {
            Ok(v) => v,
            Err(e) => {
                self.metrics.errors.inc();
                let resp = protocol::error_response(Json::Null, &format!("bad json: {e:#}"));
                return ("bad_json", resp.to_string());
            }
        };
        let id = parsed.get("id").cloned().unwrap_or(Json::Null);
        match parsed.get("type").and_then(Json::as_str) {
            Some("shutdown") => {
                self.shutdown.store(true, Ordering::SeqCst);
                ("shutdown", protocol::shutdown_response(id).to_string())
            }
            Some("stats") => {
                // `"format": "prometheus"` swaps the JSON snapshot for
                // text exposition (as a string field, so the framed
                // protocol stays JSON); the default is byte-identical
                // to the pre-format responses.
                let prom =
                    parsed.get("format").and_then(Json::as_str) == Some("prometheus");
                let mut fields = vec![
                    ("id".to_string(), id),
                    ("ok".to_string(), Json::Bool(true)),
                    ("engine".to_string(), Json::Str(self.engine.name().to_string())),
                ];
                if prom {
                    fields.push(("format".to_string(), Json::Str("prometheus".to_string())));
                    fields.push(("stats".to_string(), Json::Str(self.registry.to_prometheus())));
                } else {
                    fields.push(("stats".to_string(), self.registry.snapshot()));
                }
                ("stats", Json::Obj(fields).to_string())
            }
            Some("stats_reset") => {
                // Guarded: zeroing live metrics is destructive to
                // anyone else scraping them, so demand an explicit
                // confirm field.
                if parsed.get("confirm").and_then(Json::as_bool) == Some(true) {
                    self.registry.reset();
                    let resp = Json::Obj(vec![
                        ("id".to_string(), id),
                        ("ok".to_string(), Json::Bool(true)),
                        ("reset".to_string(), Json::Bool(true)),
                    ]);
                    ("stats_reset", resp.to_string())
                } else {
                    self.metrics.errors.inc();
                    let resp = protocol::error_response(
                        id,
                        "stats_reset requires \"confirm\": true",
                    );
                    ("stats_reset", resp.to_string())
                }
            }
            qtype => {
                if qtype == Some("batch") {
                    if let Some(qs) = parsed.get("queries").and_then(Json::as_array) {
                        self.metrics.batch_depth.record(qs.len() as u64);
                    }
                }
                let resp = protocol::answer(&self.engine, scratch, &parsed, self.cfg.max_batch);
                if resp.get("ok").and_then(Json::as_bool) == Some(false) {
                    self.metrics.errors.inc();
                }
                let label = match qtype {
                    Some("map") => "map",
                    Some("joint_map") => "joint_map",
                    Some("batch") => "batch",
                    None | Some("marginal") => "marginal",
                    Some(_) => "other",
                };
                (label, resp.to_string())
            }
        }
    }

    /// Serve newline-delimited JSON until the reader closes or the
    /// shutdown sentinel arrives; returns the number of requests
    /// answered.
    pub fn serve_lines<R: BufRead, W: Write>(&self, reader: R, mut writer: W) -> Result<usize> {
        let mut scratch = self.engine.new_scratch();
        scratch.attach_tracer(self.tracer.handle(0));
        let mut th = self.tracer.handle(0);
        let mut served = 0usize;
        for line in reader.lines() {
            let line = line.context("read request line")?;
            if line.trim().is_empty() {
                continue;
            }
            let response = self.handle_traced(&mut scratch, &mut th, &line);
            writeln!(writer, "{response}").context("write response")?;
            writer.flush().context("flush response")?;
            served += 1;
            if self.is_shutting_down() {
                break;
            }
        }
        Ok(served)
    }

    /// Serve length-prefixed JSON frames over TCP with a bounded pool
    /// of `cfg.threads` handler threads. `max_conns` bounds the number
    /// of accepted connections (tests); `None` serves until the
    /// shutdown sentinel. Returns after every accepted connection has
    /// drained.
    pub fn serve_tcp(&self, listener: &TcpListener, max_conns: Option<usize>) -> Result<()> {
        let local = listener.local_addr().context("listener addr")?;
        // The shutdown wake-up must be a *connectable* address: an
        // unspecified bind (0.0.0.0 / ::) is reached via loopback.
        let wake = if local.ip().is_unspecified() {
            let loopback: std::net::IpAddr = if local.is_ipv4() {
                std::net::Ipv4Addr::LOCALHOST.into()
            } else {
                std::net::Ipv6Addr::LOCALHOST.into()
            };
            SocketAddr::new(loopback, local.port())
        } else {
            local
        };
        let threads = self.cfg.threads.max(1);
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(2 * threads);
        let rx = Mutex::new(rx);
        std::thread::scope(|scope| -> Result<()> {
            for t in 0..threads {
                let rx = &rx;
                scope.spawn(move || {
                    let mut scratch = self.engine.new_scratch();
                    // One trace lane per handler thread: request spans
                    // and the propagation spans nested inside them
                    // share the thread's tid.
                    scratch.attach_tracer(self.tracer.handle(t as u32));
                    let mut th = self.tracer.handle(t as u32);
                    loop {
                        // Hold the lock only for the dequeue, never
                        // while handling a connection.
                        let next = rx.lock().expect("connection queue poisoned").recv();
                        let Ok(stream) = next else { break };
                        let peer = stream.peer_addr().ok();
                        let result = self.serve_conn(stream, &mut scratch, &mut th, wake);
                        // Every accepted connection ends exactly here —
                        // clean EOF, idle reap or failure — so the open
                        // gauge and closed counter stay truthful on all
                        // paths.
                        self.metrics.conns_open.add(-1.0);
                        self.metrics.conns_closed.inc();
                        if let Err(e) = result {
                            self.metrics.conns_failed.inc();
                            match peer {
                                Some(p) => {
                                    obs::log::error(format_args!("connection {p}: {e:#}"))
                                }
                                None => obs::log::error(format_args!("connection: {e:#}")),
                            }
                        }
                    }
                });
            }
            let mut conns = 0usize;
            loop {
                if self.is_shutting_down() {
                    break;
                }
                if let Some(m) = max_conns {
                    if conns >= m {
                        break;
                    }
                }
                let (stream, _) = listener.accept().context("accept query connection")?;
                if self.is_shutting_down() {
                    // The wake connection a handler opened after the
                    // sentinel; nothing to serve on it.
                    break;
                }
                conns += 1;
                self.metrics.conns_accepted.inc();
                self.metrics.conns_open.add(1.0);
                tx.send(stream).expect("connection pool alive");
            }
            // Closing the queue lets idle handlers exit; the scope
            // join below waits for the busy ones to drain.
            drop(tx);
            Ok(())
        })
    }

    /// Handle one framed connection until EOF or shutdown.
    fn serve_conn(
        &self,
        stream: TcpStream,
        scratch: &mut Scratch,
        th: &mut obs::TraceHandle,
        wake: SocketAddr,
    ) -> Result<()> {
        stream.set_nodelay(true).ok();
        // Idle reads wake periodically so a latched shutdown can close
        // quiet persistent connections too.
        stream.set_read_timeout(Some(IDLE_POLL)).ok();
        let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
        let mut writer = BufWriter::new(stream);
        let cap = self.cfg.max_frame_bytes;
        loop {
            let Some(len) = self.read_len_prefix(&mut reader)? else {
                return Ok(());
            };
            ensure_frame_len("incoming", len, cap)?;
            self.metrics.frame_bytes.record(len as u64);
            let mut payload = vec![0u8; len as usize];
            self.read_exact_patient(&mut reader, &mut payload, "frame payload")?;
            let text = String::from_utf8(payload).context("request frame is not UTF-8")?;

            let response = self.handle_traced(scratch, th, &text);
            let out = response.as_bytes();
            let out_len = u32::try_from(out.len()).context("response too large for u32 prefix")?;
            ensure_frame_len("outgoing", out_len, cap)?;
            self.metrics.frame_bytes.record(out_len as u64);
            writer.write_all(&out_len.to_le_bytes()).context("write response length")?;
            writer.write_all(out).context("write response payload")?;
            writer.flush().context("flush response")?;

            if self.is_shutting_down() {
                // Drain, don't drop: a pipelining client may have
                // queued frames behind the sentinel before it could see
                // the acknowledgement. Answer what is already buffered,
                // then wake the acceptor and close.
                self.drain_buffered(&mut reader, &mut writer, scratch, th)?;
                let _ = TcpStream::connect(wake);
                return Ok(());
            }
        }
    }

    /// After shutdown latches: keep answering frames the client
    /// already pipelined, closing as soon as the stream goes quiet.
    /// Mid-transit frames get [`DRAIN_GRACE`] patience, so a
    /// byte-trickling client cannot hold shutdown hostage.
    fn drain_buffered(
        &self,
        reader: &mut BufReader<TcpStream>,
        writer: &mut BufWriter<TcpStream>,
        scratch: &mut Scratch,
        th: &mut obs::TraceHandle,
    ) -> Result<()> {
        // Shorten the poll: from here on a timeout with nothing read
        // means "drained, close" rather than "keep waiting".
        reader.get_ref().set_read_timeout(Some(DRAIN_POLL)).ok();
        let cap = self.cfg.max_frame_bytes;
        loop {
            let Some(len) = read_len_prefix_draining(reader)? else {
                return Ok(());
            };
            ensure_frame_len("incoming", len, cap)?;
            self.metrics.frame_bytes.record(len as u64);
            let mut payload = vec![0u8; len as usize];
            read_exact_draining(reader, &mut payload, "frame payload")?;
            let text = String::from_utf8(payload).context("request frame is not UTF-8")?;

            let response = self.handle_traced(scratch, th, &text);
            let out = response.as_bytes();
            let out_len = u32::try_from(out.len()).context("response too large for u32 prefix")?;
            ensure_frame_len("outgoing", out_len, cap)?;
            self.metrics.frame_bytes.record(out_len as u64);
            writer.write_all(&out_len.to_le_bytes()).context("write response length")?;
            writer.write_all(out).context("write response payload")?;
            writer.flush().context("flush response")?;
        }
    }

    /// Read one 4-byte length prefix. `Ok(None)` = clean EOF between
    /// frames, an idle connection observed after shutdown latched, or
    /// an idle connection past [`ServeConfig::idle_timeout`] (the
    /// reaper: counted in `serve.conns_reaped`, closed quietly).
    fn read_len_prefix(&self, reader: &mut impl Read) -> Result<Option<u32>> {
        let mut buf = [0u8; 4];
        let mut got = 0usize;
        let idle_since = std::time::Instant::now();
        while got < 4 {
            match reader.read(&mut buf[got..]) {
                Ok(0) => {
                    if got == 0 {
                        return Ok(None);
                    }
                    bail!("eof inside frame length");
                }
                Ok(k) => got += k,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    // Idle between frames: close quietly once shutdown
                    // latched; mid-prefix a latched shutdown closes
                    // loudly (the client half-sent a frame).
                    if self.is_shutting_down() {
                        if got == 0 {
                            return Ok(None);
                        }
                        bail!("shutdown while awaiting frame length");
                    }
                    if let Some(cap) = self.cfg.idle_timeout {
                        if idle_since.elapsed() >= cap {
                            if got == 0 {
                                // Between frames: the reaper. Frees the
                                // handler thread for the next client.
                                self.metrics.conns_reaped.inc();
                                obs::log::warn(format_args!(
                                    "reaped idle connection (> {cap:?} between frames)"
                                ));
                                return Ok(None);
                            }
                            bail!("client stalled inside frame length (> {cap:?})");
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("read frame length"),
            }
        }
        Ok(Some(u32::from_le_bytes(buf)))
    }

    /// Finish filling `buf`, riding out read timeouts. Mid-frame we
    /// keep waiting (abandoning an in-flight frame would desync the
    /// stream) — unless shutdown latches, or the client makes no
    /// progress for [`ServeConfig::idle_timeout`]; either way the
    /// connection closes so a stalled client cannot pin its handler
    /// thread and block the pool from draining.
    fn read_exact_patient(&self, reader: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
        let mut got = 0usize;
        let mut last_progress = std::time::Instant::now();
        while got < buf.len() {
            match reader.read(&mut buf[got..]) {
                Ok(0) => bail!("eof inside {what}"),
                Ok(k) => {
                    got += k;
                    last_progress = std::time::Instant::now();
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if self.is_shutting_down() {
                        bail!("shutdown while awaiting {what}");
                    }
                    if let Some(cap) = self.cfg.idle_timeout {
                        if last_progress.elapsed() >= cap {
                            bail!(
                                "client stalled inside {what} ({got}/{} bytes, > {cap:?} \
                                 without progress)",
                                buf.len()
                            );
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).with_context(|| format!("read {what}")),
            }
        }
        Ok(())
    }
}

/// Drain-phase variant of `read_len_prefix`. Shutdown is *latched* by
/// now, so the regular helpers (which bail the moment they observe the
/// latch) cannot be reused; here quiet-between-frames means "drained,
/// close" (`Ok(None)`) and only a mid-prefix stall past [`DRAIN_GRACE`]
/// fails the connection.
fn read_len_prefix_draining(reader: &mut impl Read) -> Result<Option<u32>> {
    let mut buf = [0u8; 4];
    let mut got = 0usize;
    let start = std::time::Instant::now();
    while got < 4 {
        match reader.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                bail!("eof inside frame length");
            }
            Ok(k) => got += k,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if got == 0 {
                    return Ok(None);
                }
                if start.elapsed() >= DRAIN_GRACE {
                    bail!("client stalled inside frame length during shutdown drain");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("read frame length"),
        }
    }
    Ok(Some(u32::from_le_bytes(buf)))
}

/// Drain-phase variant of `read_exact_patient`: finish the in-flight
/// frame with bounded patience instead of bailing on the latched
/// shutdown flag.
fn read_exact_draining(reader: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    let mut got = 0usize;
    let start = std::time::Instant::now();
    while got < buf.len() {
        match reader.read(&mut buf[got..]) {
            Ok(0) => bail!("eof inside {what}"),
            Ok(k) => got += k,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if start.elapsed() >= DRAIN_GRACE {
                    bail!("client stalled inside {what} during shutdown drain");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).with_context(|| format!("read {what}")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    fn server(cfg: ServeConfig) -> Server {
        Server::new(&tiny_bn(), &EngineConfig::default(), cfg).unwrap()
    }

    #[test]
    fn line_adapter_answers_and_stops_on_shutdown() {
        let s = server(ServeConfig::default());
        let input = b"{\"id\":1}\n{\"type\":\"shutdown\"}\n{\"id\":2}\n".to_vec();
        let mut out = Vec::new();
        let served = s.serve_lines(&input[..], &mut out).unwrap();
        // The request after the sentinel is never read.
        assert_eq!(served, 2);
        assert!(s.is_shutting_down());
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let ack = Json::parse(lines[1]).unwrap();
        assert_eq!(ack.get("shutdown").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn stats_endpoint_snapshots_and_guards_reset() {
        let s = server(ServeConfig::default());
        let mut scratch = s.new_scratch();
        s.handle(&mut scratch, r#"{"id": 1}"#);

        let v = Json::parse(&s.handle(&mut scratch, r#"{"id": 2, "type": "stats"}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(2));
        let stats = v.get("stats").expect("stats body");
        let counters = stats.get("counters").expect("counters map");
        assert!(counters.get("serve.requests").and_then(Json::as_f64).unwrap() >= 1.0);
        let hists = stats.get("histograms").expect("histograms map");
        let latency = hists.get("serve.latency_ns").expect("latency histogram");
        assert!(latency.get("count").and_then(Json::as_f64).unwrap() >= 1.0);
        assert!(latency.get("p50").and_then(Json::as_f64).unwrap() > 0.0);

        // Unconfirmed reset is refused and counts as an error.
        let v = Json::parse(&s.handle(&mut scratch, r#"{"type": "stats_reset"}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));

        // Confirmed reset zeroes the counters.
        let v = Json::parse(&s.handle(&mut scratch, r#"{"type": "stats_reset", "confirm": true}"#))
            .unwrap();
        assert_eq!(v.get("reset").and_then(Json::as_bool), Some(true));
        let v = Json::parse(&s.handle(&mut scratch, r#"{"type": "stats"}"#)).unwrap();
        let reqs = v
            .get("stats")
            .and_then(|st| st.get("counters"))
            .and_then(|c| c.get("serve.requests"))
            .and_then(Json::as_f64)
            .unwrap();
        // Since the reset only the reset acknowledgement itself was
        // metered before this snapshot was taken.
        assert!(reqs <= 1.0, "reset did not zero serve.requests: {reqs}");
        assert!(!s.is_shutting_down());
    }

    #[test]
    fn stats_prometheus_format_serves_exposition_text() {
        let s = server(ServeConfig::default());
        let mut scratch = s.new_scratch();
        s.handle(&mut scratch, r#"{"id": 1}"#);

        let raw = s.handle(&mut scratch, r#"{"id": 2, "type": "stats", "format": "prometheus"}"#);
        let v = Json::parse(&raw).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("format").and_then(Json::as_str), Some("prometheus"));
        let text = v.get("stats").and_then(Json::as_str).expect("stats is exposition text");
        assert!(text.contains("# TYPE serve_requests counter"), "{text}");
        assert!(text.contains("_bucket{le=\"+Inf\"}"), "{text}");

        // The default format stays a JSON object, not a string.
        let v = Json::parse(&s.handle(&mut scratch, r#"{"id": 3, "type": "stats"}"#)).unwrap();
        assert!(v.get("stats").and_then(Json::as_str).is_none());
        assert!(v.get("stats").and_then(|st| st.get("counters")).is_some());
    }

    #[test]
    fn idle_connections_are_reaped() {
        let s = server(ServeConfig {
            idle_timeout: Some(Duration::from_millis(250)),
            ..Default::default()
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            // One healthy round-trip first: the reaper must only fire
            // on *idleness*, not on connections that are slow to start.
            let req = br#"{"id":1}"#;
            stream.write_all(&(req.len() as u32).to_le_bytes()).unwrap();
            stream.write_all(req).unwrap();
            let mut len = [0u8; 4];
            stream.read_exact(&mut len).unwrap();
            let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
            stream.read_exact(&mut body).unwrap();
            // Then go quiet and hold the connection open: the server
            // must close it (EOF here) rather than pin the handler.
            let mut probe = [0u8; 1];
            let n = stream.read(&mut probe).unwrap_or(0);
            assert_eq!(n, 0, "server should close the idle connection");
        });
        // Returns only once the handler pool drains — i.e. once the
        // idle connection was reaped.
        s.serve_tcp(&listener, Some(1)).unwrap();
        client.join().unwrap();
        assert_eq!(s.registry().counter_value("serve.conns_reaped"), Some(1));
        assert_eq!(s.registry().counter_value("serve.conns_failed"), Some(0));
    }

    fn send_frame(stream: &mut TcpStream, payload: &[u8]) {
        stream.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
        stream.write_all(payload).unwrap();
    }

    fn recv_frame(stream: &mut TcpStream) -> String {
        let mut len = [0u8; 4];
        stream.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        stream.read_exact(&mut body).unwrap();
        String::from_utf8(body).unwrap()
    }

    #[test]
    fn conn_accounting_balances_on_reap_and_stall_paths() {
        let s = server(ServeConfig {
            threads: 2,
            idle_timeout: Some(Duration::from_millis(250)),
            ..Default::default()
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reaped = std::thread::spawn(move || {
            // One healthy round trip, then quiet: the idle reaper path.
            let mut stream = TcpStream::connect(addr).unwrap();
            send_frame(&mut stream, br#"{"id":1}"#);
            recv_frame(&mut stream);
            let mut probe = [0u8; 1];
            let n = stream.read(&mut probe).unwrap_or(0);
            assert_eq!(n, 0, "reaper should close the idle connection");
        });
        let stalled = std::thread::spawn(move || {
            // Declare a 100-byte frame, deliver 4 bytes, stall: the
            // mid-frame failure path.
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(&100u32.to_le_bytes()).unwrap();
            stream.write_all(b"{\"id").unwrap();
            let mut probe = [0u8; 1];
            let n = stream.read(&mut probe).unwrap_or(0);
            assert_eq!(n, 0, "stalled connection should be failed and closed");
        });
        s.serve_tcp(&listener, Some(2)).unwrap();
        reaped.join().unwrap();
        stalled.join().unwrap();

        // The arithmetic the gauge must satisfy on every exit path:
        // accepted == closed, open back to zero, and the two exit
        // reasons each counted once.
        let reg = s.registry();
        assert_eq!(reg.counter_value("serve.conns_accepted"), Some(2));
        assert_eq!(reg.counter_value("serve.conns_closed"), Some(2));
        assert_eq!(reg.counter_value("serve.conns_reaped"), Some(1));
        assert_eq!(reg.counter_value("serve.conns_failed"), Some(1));
        assert_eq!(reg.gauge_value("serve.conns_open"), Some(0.0));
    }

    #[test]
    fn shutdown_drains_pipelined_frames_before_closing() {
        let s = server(ServeConfig::default());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            // Pipeline two queries, the sentinel, and a query *behind*
            // the sentinel, all in one burst. The old behavior dropped
            // everything after the sentinel's response.
            send_frame(&mut stream, br#"{"id":1}"#);
            send_frame(&mut stream, br#"{"id":2,"type":"map"}"#);
            send_frame(&mut stream, br#"{"id":3,"type":"shutdown"}"#);
            send_frame(&mut stream, br#"{"id":4,"type":"joint_map"}"#);
            let mut responses = Vec::new();
            for _ in 0..4 {
                responses.push(Json::parse(&recv_frame(&mut stream)).unwrap());
            }
            for (i, v) in responses.iter().enumerate() {
                assert_eq!(v.get("id").and_then(Json::as_usize), Some(i + 1), "slot {i}");
                assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "slot {i}");
            }
            assert_eq!(responses[2].get("shutdown").and_then(Json::as_bool), Some(true));
            // Then the server closes the drained connection.
            let mut probe = [0u8; 1];
            let n = stream.read(&mut probe).unwrap_or(0);
            assert_eq!(n, 0, "connection should close after the drain");
        });
        s.serve_tcp(&listener, None).unwrap();
        client.join().unwrap();
        assert!(s.is_shutting_down());
        assert_eq!(s.registry().counter_value("serve.conns_failed"), Some(0));
        assert_eq!(s.registry().gauge_value("serve.conns_open"), Some(0.0));
    }

    #[test]
    fn handle_reports_errors_without_latching_shutdown() {
        let s = server(ServeConfig::default());
        let mut scratch = s.new_scratch();
        let v = Json::parse(&s.handle(&mut scratch, "not json")).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert!(!s.is_shutting_down());
        let v = Json::parse(&s.handle(&mut scratch, r#"{"id": 2}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    }
}
