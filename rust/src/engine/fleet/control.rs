//! Request dispatch for fleet workers: queries, server endpoints and
//! the model control plane.
//!
//! Query types (`marginal`, `map`, `joint_map`, `batch`) resolve the
//! registry's active [`ModelEntry`](super::registry::ModelEntry)
//! **once**, then run entirely on that `Arc` through
//! [`protocol::answer`] — so query responses are byte-identical to the
//! thread-pool [`Server`](crate::engine::Server) serving the same
//! bundle, and a concurrent `switch` never splits one request across
//! two models. The control plane adds four request types:
//!
//! | request | effect |
//! |---|---|
//! | `{"type": "load_model", "path": "m.bnb"}` | read + compile a bundle on the server host, file it by fingerprint |
//! | `{"type": "switch", "model": "<fp hex>"}` | point live traffic at a loaded model (the hot swap) |
//! | `{"type": "models"}` | list hosted models, the active one flagged |
//! | `{"type": "unload", "model": "<fp hex>"}` | drop an inactive model (in-flight `Arc`s finish first) |
//!
//! Mutating control types are refused when
//! [`FleetConfig::control`](super::FleetConfig) is off; `models` is
//! read-only and always answers. The `stats`, `stats_reset` and
//! `shutdown` endpoints keep their thread-pool shapes.

use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::engine::protocol;
use crate::infer::json::Json;
use crate::model::parse_fingerprint;
use crate::obs;
use crate::util::ensure_frame_len;

use super::registry::ModelEntry;
use super::FleetShared;

/// Answer one request text with one response text, metering it
/// (`serve.requests`, `serve.latency_ns`, the per-model histogram) and
/// recording a span into the worker's trace lane. `enqueued` is the
/// frame-complete time stamped by the event loop, so latency includes
/// queue wait — the honest number to compare against the thread pool,
/// whose latency clock also starts before dispatch.
pub(crate) fn respond(
    shared: &FleetShared,
    th: &mut obs::TraceHandle,
    request: &str,
    enqueued: Option<Instant>,
) -> String {
    let t0 = th.start();
    let sw = obs::Stopwatch::start();
    let out = dispatch(shared, request);
    shared.metrics.requests.inc();
    let ns = match enqueued {
        Some(at) => u64::try_from(at.elapsed().as_nanos()).unwrap_or(u64::MAX),
        None => sw.elapsed_ns(),
    };
    shared.metrics.latency.record(ns);
    if let Some(entry) = &out.model {
        entry.requests.inc();
        entry.latency.record(ns);
    }
    th.end(t0, out.label, "serve");
    cap_outgoing(shared, out.id, out.response)
}

/// Enforce the outgoing frame cap *in the worker*: an oversized
/// response is substituted with a typed error (same
/// [`ensure_frame_len`] wording as everywhere else) so the connection
/// survives — the event loop never has to tear a stream mid-frame.
fn cap_outgoing(shared: &FleetShared, id: Json, response: String) -> String {
    let cap = shared.cfg.max_frame_bytes;
    let message = match u32::try_from(response.len()) {
        Ok(len) => match ensure_frame_len("outgoing", len, cap) {
            Ok(()) => return response,
            Err(e) => format!("{e:#}"),
        },
        Err(_) => "response too large for u32 prefix".to_string(),
    };
    shared.metrics.errors.inc();
    protocol::error_response(id, &message).to_string()
}

struct Outcome {
    label: &'static str,
    /// The entry a query resolved (meters the per-model histogram).
    model: Option<Arc<ModelEntry>>,
    id: Json,
    response: String,
}

fn outcome(label: &'static str, model: Option<Arc<ModelEntry>>, id: Json, body: Json) -> Outcome {
    Outcome { label, model, id, response: body.to_string() }
}

fn refuse(shared: &FleetShared, label: &'static str, id: Json, message: &str) -> Outcome {
    shared.metrics.errors.inc();
    let body = protocol::error_response(id.clone(), message);
    outcome(label, None, id, body)
}

fn dispatch(shared: &FleetShared, request: &str) -> Outcome {
    let parsed = match Json::parse(request) {
        Ok(v) => v,
        Err(e) => return refuse(shared, "bad_json", Json::Null, &format!("bad json: {e:#}")),
    };
    let id = parsed.get("id").cloned().unwrap_or(Json::Null);
    match parsed.get("type").and_then(Json::as_str) {
        Some("shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            let body = protocol::shutdown_response(id.clone());
            outcome("shutdown", None, id, body)
        }
        Some("stats") => {
            let prom = parsed.get("format").and_then(Json::as_str) == Some("prometheus");
            let engine = shared
                .models
                .active()
                .map_or("none", |entry| entry.engine.name());
            let mut fields = vec![
                ("id".to_string(), id.clone()),
                ("ok".to_string(), Json::Bool(true)),
                ("engine".to_string(), Json::Str(engine.to_string())),
            ];
            if prom {
                fields.push(("format".to_string(), Json::Str("prometheus".to_string())));
                fields.push(("stats".to_string(), Json::Str(shared.registry.to_prometheus())));
            } else {
                fields.push(("stats".to_string(), shared.registry.snapshot()));
            }
            outcome("stats", None, id, Json::Obj(fields))
        }
        Some("stats_reset") => {
            if parsed.get("confirm").and_then(Json::as_bool) == Some(true) {
                shared.registry.reset();
                let body = Json::Obj(vec![
                    ("id".to_string(), id.clone()),
                    ("ok".to_string(), Json::Bool(true)),
                    ("reset".to_string(), Json::Bool(true)),
                ]);
                outcome("stats_reset", None, id, body)
            } else {
                refuse(shared, "stats_reset", id, "stats_reset requires \"confirm\": true")
            }
        }
        Some("load_model") => op_load(shared, id, &parsed),
        Some("switch") => op_switch(shared, id, &parsed),
        Some("models") => op_models(shared, id),
        Some("unload") => op_unload(shared, id, &parsed),
        qtype => {
            let Some(entry) = shared.models.active() else {
                return refuse(
                    shared,
                    "no_model",
                    id,
                    "no model loaded (control plane: load_model, then switch)",
                );
            };
            if qtype == Some("batch") {
                if let Some(qs) = parsed.get("queries").and_then(Json::as_array) {
                    shared.metrics.batch_depth.record(qs.len() as u64);
                }
            }
            let mut scratch = entry.checkout();
            let resp =
                protocol::answer(&entry.engine, &mut scratch, &parsed, shared.cfg.max_batch);
            entry.checkin(scratch);
            if resp.get("ok").and_then(Json::as_bool) == Some(false) {
                shared.metrics.errors.inc();
            }
            let label = match qtype {
                Some("map") => "map",
                Some("joint_map") => "joint_map",
                Some("batch") => "batch",
                None | Some("marginal") => "marginal",
                Some(_) => "other",
            };
            outcome(label, Some(entry), id, resp)
        }
    }
}

fn op_load(shared: &FleetShared, id: Json, req: &Json) -> Outcome {
    if !shared.cfg.control {
        return refuse(shared, "load_model", id, "control plane is disabled (--no-control)");
    }
    let Some(path) = req.get("path").and_then(Json::as_str) else {
        return refuse(
            shared,
            "load_model",
            id,
            "'path' must be a string (a .bnb bundle on the server host)",
        );
    };
    let loaded =
        crate::model::read_bundle(Path::new(path)).and_then(|bundle| shared.load(&bundle));
    match loaded {
        Err(e) => refuse(shared, "load_model", id, &format!("load_model: {e:#}")),
        Ok((entry, fresh)) => {
            if fresh {
                obs::log::info(format_args!("fleet: loaded model {} from {path}", entry.hex()));
            }
            let body = Json::Obj(vec![
                ("id".to_string(), id.clone()),
                ("ok".to_string(), Json::Bool(true)),
                ("model".to_string(), Json::Str(entry.hex())),
                ("engine".to_string(), Json::Str(entry.engine.name().to_string())),
                ("warm".to_string(), Json::Bool(entry.warm_started())),
                ("already_loaded".to_string(), Json::Bool(!fresh)),
                (
                    "active".to_string(),
                    Json::Bool(shared.models.active_fingerprint() == Some(entry.fingerprint)),
                ),
            ]);
            outcome("load_model", None, id, body)
        }
    }
}

fn parse_model_field(req: &Json) -> Result<u64, String> {
    let Some(text) = req.get("model").and_then(Json::as_str) else {
        return Err("'model' must be a fingerprint string (see {\"type\": \"models\"})".to_string());
    };
    parse_fingerprint(text)
        .ok_or_else(|| format!("'{text}' is not a model fingerprint (up to 16 hex digits)"))
}

fn op_switch(shared: &FleetShared, id: Json, req: &Json) -> Outcome {
    if !shared.cfg.control {
        return refuse(shared, "switch", id, "control plane is disabled (--no-control)");
    }
    let fp = match parse_model_field(req) {
        Ok(fp) => fp,
        Err(msg) => return refuse(shared, "switch", id, &msg),
    };
    match shared.activate(fp) {
        Err(e) => refuse(shared, "switch", id, &format!("switch: {e:#}")),
        Ok(entry) => {
            obs::log::info(format_args!("fleet: switched active model to {}", entry.hex()));
            let body = Json::Obj(vec![
                ("id".to_string(), id.clone()),
                ("ok".to_string(), Json::Bool(true)),
                ("active".to_string(), Json::Str(entry.hex())),
                ("engine".to_string(), Json::Str(entry.engine.name().to_string())),
                ("warm".to_string(), Json::Bool(entry.warm_started())),
            ]);
            outcome("switch", None, id, body)
        }
    }
}

fn op_models(shared: &FleetShared, id: Json) -> Outcome {
    let (active, entries) = shared.models.list();
    let models: Vec<Json> = entries
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("model".to_string(), Json::Str(e.hex())),
                ("producer".to_string(), Json::Str(e.producer.clone())),
                ("vars".to_string(), Json::Num(e.n_vars() as f64)),
                ("edges".to_string(), Json::Num(e.edges as f64)),
                ("engine".to_string(), Json::Str(e.engine.name().to_string())),
                ("warm".to_string(), Json::Bool(e.warm_started())),
                ("active".to_string(), Json::Bool(Some(e.fingerprint) == active)),
                ("requests".to_string(), Json::Num(e.requests.get() as f64)),
            ])
        })
        .collect();
    let body = Json::Obj(vec![
        ("id".to_string(), id.clone()),
        ("ok".to_string(), Json::Bool(true)),
        (
            "active".to_string(),
            active.map_or(Json::Null, |fp| Json::Str(crate::model::fingerprint_hex(fp))),
        ),
        ("models".to_string(), Json::Arr(models)),
    ]);
    outcome("models", None, id, body)
}

fn op_unload(shared: &FleetShared, id: Json, req: &Json) -> Outcome {
    if !shared.cfg.control {
        return refuse(shared, "unload", id, "control plane is disabled (--no-control)");
    }
    let fp = match parse_model_field(req) {
        Ok(fp) => fp,
        Err(msg) => return refuse(shared, "unload", id, &msg),
    };
    match shared.models.unload(fp) {
        Err(e) => refuse(shared, "unload", id, &format!("unload: {e:#}")),
        Ok(entry) => {
            shared.metrics.models_unloaded.inc();
            obs::log::info(format_args!("fleet: unloaded model {}", entry.hex()));
            let body = Json::Obj(vec![
                ("id".to_string(), id.clone()),
                ("ok".to_string(), Json::Bool(true)),
                ("unloaded".to_string(), Json::Str(entry.hex())),
            ]);
            outcome("unload", None, id, body)
        }
    }
}
