//! Per-connection state machine for the fleet event loop.
//!
//! One `Conn` owns a nonblocking `TcpStream` and the buffers around
//! it: a read buffer frames are parsed out of, a sequence-ordered
//! reassembly map for responses coming back from the worker pool (a
//! pipelined connection can have many requests in flight, and workers
//! finish them out of order), and a write buffer flushed as the socket
//! accepts bytes. The wire format is the crate-wide `u32` little-endian
//! length prefix plus JSON payload; the per-frame cap shares
//! [`ensure_frame_len`]'s wording with every other length-prefixed
//! medium, and an oversized declaration (or a non-UTF-8 payload)
//! produces a *typed error response* on the wire followed by a clean
//! close — not a torn connection — because past the bad prefix the
//! byte stream can no longer be trusted as frames.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use crate::util::ensure_frame_len;

/// Compact the read buffer once this many parsed bytes accumulate.
const COMPACT_AT: usize = 64 * 1024;

/// Stop filling while this much unparsed input is already buffered
/// (backpressure: a client blasting frames faster than the workers
/// drain them waits in its socket, not in our memory).
const FILL_HIGH_WATER: usize = 4 * 1024 * 1024;

/// One frame parsed out of a connection's read buffer.
pub(crate) enum Frame {
    /// A complete well-formed frame: dispatch `text` to a worker.
    Request {
        /// Response slot (responses flush in `seq` order).
        seq: u64,
        /// UTF-8 payload.
        text: String,
        /// Declared payload length (for the frame-size histogram).
        len: u32,
    },
    /// A protocol violation — oversized length declaration or
    /// non-UTF-8 payload. Queue `error` as the typed response for
    /// `seq`, then close once flushed (the stream past a bad prefix
    /// cannot be re-framed).
    Reject {
        /// Response slot.
        seq: u64,
        /// Human-readable violation, [`ensure_frame_len`] wording for
        /// oversize.
        error: String,
    },
}

/// State machine for one keep-alive, pipelined connection.
pub(crate) struct Conn {
    stream: TcpStream,
    /// Generation tag: completions carry it so a slab slot reused by a
    /// newer connection never receives a stale response.
    pub(crate) gen: u64,
    /// Peer address (for log lines).
    pub(crate) peer: Option<SocketAddr>,
    read_buf: Vec<u8>,
    /// Bytes of `read_buf` already consumed as frames.
    parsed: usize,
    write_buf: Vec<u8>,
    /// Bytes of `write_buf` already written to the socket.
    written: usize,
    /// Out-of-order responses waiting for their turn on the wire.
    pending: BTreeMap<u64, Vec<u8>>,
    next_seq: u64,
    next_write_seq: u64,
    /// Requests dispatched to workers, not yet completed.
    pub(crate) inflight: usize,
    /// Peer closed its write half.
    pub(crate) eof: bool,
    /// Protocol violation latched: stop reading, flush, close.
    pub(crate) closing: bool,
    /// Shutdown drain already did this connection's final read.
    pub(crate) drain_filled: bool,
}

impl Conn {
    /// Wrap an accepted (already nonblocking) stream.
    pub(crate) fn new(stream: TcpStream, peer: Option<SocketAddr>, gen: u64) -> Conn {
        Conn {
            stream,
            gen,
            peer,
            read_buf: Vec::new(),
            parsed: 0,
            write_buf: Vec::new(),
            written: 0,
            pending: BTreeMap::new(),
            next_seq: 0,
            next_write_seq: 0,
            inflight: 0,
            eof: false,
            closing: false,
            drain_filled: false,
        }
    }

    /// Pull everything the socket has into the read buffer (until
    /// `WouldBlock`, EOF, or the high-water bound). Returns whether any
    /// bytes arrived; an `Err` is a hard connection failure.
    pub(crate) fn fill(&mut self, tmp: &mut [u8]) -> std::io::Result<bool> {
        if self.eof || self.closing {
            return Ok(false);
        }
        let mut progress = false;
        while self.read_buf.len() - self.parsed < FILL_HIGH_WATER {
            match self.stream.read(tmp) {
                Ok(0) => {
                    self.eof = true;
                    return Ok(progress);
                }
                Ok(n) => {
                    self.read_buf.extend_from_slice(&tmp[..n]);
                    progress = true;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(progress)
    }

    /// Parse the next complete frame out of the read buffer, if one is
    /// there. `cap` is the per-frame byte cap; violations come back as
    /// [`Frame::Reject`] and latch [`Conn::closing`].
    pub(crate) fn next_frame(&mut self, cap: u32) -> Option<Frame> {
        if self.closing {
            return None;
        }
        self.compact();
        let avail = self.read_buf.len() - self.parsed;
        if avail < 4 {
            return None;
        }
        let len = u32::from_le_bytes(
            self.read_buf[self.parsed..self.parsed + 4].try_into().expect("4 bytes"),
        );
        if let Err(e) = ensure_frame_len("incoming", len, cap) {
            self.closing = true;
            let seq = self.next_seq;
            self.next_seq += 1;
            return Some(Frame::Reject { seq, error: format!("{e:#}") });
        }
        if avail - 4 < len as usize {
            return None;
        }
        let start = self.parsed + 4;
        let payload = &self.read_buf[start..start + len as usize];
        let seq = self.next_seq;
        self.next_seq += 1;
        let frame = match std::str::from_utf8(payload) {
            Ok(text) => {
                let text = text.to_string();
                self.inflight += 1;
                Frame::Request { seq, text, len }
            }
            Err(_) => {
                self.closing = true;
                Frame::Reject { seq, error: "request frame is not UTF-8".to_string() }
            }
        };
        self.parsed = start + len as usize;
        Some(frame)
    }

    /// File a response for slot `seq`; every response whose turn has
    /// come moves to the write buffer (pipelined responses leave in
    /// request order regardless of worker completion order).
    pub(crate) fn queue_response(&mut self, seq: u64, payload: &[u8]) {
        let mut framed = Vec::with_capacity(payload.len() + 4);
        framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        framed.extend_from_slice(payload);
        self.pending.insert(seq, framed);
        while let Some(buf) = self.pending.remove(&self.next_write_seq) {
            self.write_buf.extend_from_slice(&buf);
            self.next_write_seq += 1;
        }
    }

    /// Write as much of the write buffer as the socket accepts.
    /// Returns whether any bytes left; an `Err` is a hard failure.
    pub(crate) fn flush(&mut self) -> std::io::Result<bool> {
        let mut progress = false;
        while self.written < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.written..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ));
                }
                Ok(n) => {
                    self.written += n;
                    progress = true;
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    break;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if self.written == self.write_buf.len() && self.written > 0 {
            self.write_buf.clear();
            self.written = 0;
        }
        Ok(progress)
    }

    /// All owed responses computed and on the wire?
    fn settled(&self) -> bool {
        self.inflight == 0 && self.pending.is_empty() && self.written == self.write_buf.len()
    }

    /// Ready to close? The caller has already dispatched every
    /// complete buffered frame this iteration, so "done" is: some
    /// reason to stop (violation, peer EOF, fleet-wide drain) and
    /// nothing still owed to the peer.
    pub(crate) fn done(&self, draining: bool) -> bool {
        (self.closing || self.eof || draining) && self.settled()
    }

    /// Did the peer vanish mid-frame (EOF with a partial frame
    /// buffered)? Counted as a failed connection, not a clean close.
    pub(crate) fn dirty_eof(&self) -> bool {
        self.eof && !self.closing && self.read_buf.len() > self.parsed
    }

    fn compact(&mut self) {
        if self.parsed == self.read_buf.len() {
            self.read_buf.clear();
            self.parsed = 0;
        } else if self.parsed > COMPACT_AT {
            self.read_buf.drain(..self.parsed);
            self.parsed = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, Conn) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, peer) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (client, Conn::new(server, Some(peer), 1))
    }

    fn frame(payload: &[u8]) -> Vec<u8> {
        let mut out = (payload.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(payload);
        out
    }

    fn fill_until(conn: &mut Conn, tmp: &mut [u8], want: usize) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while conn.read_buf.len() < want {
            conn.fill(tmp).unwrap();
            assert!(std::time::Instant::now() < deadline, "fill timed out");
        }
    }

    #[test]
    fn pipelined_frames_parse_and_responses_reorder() {
        let (mut client, mut conn) = pair();
        let mut wire = frame(b"{\"id\":0}");
        wire.extend_from_slice(&frame(b"{\"id\":1}"));
        client.write_all(&wire).unwrap();

        let mut tmp = vec![0u8; 4096];
        fill_until(&mut conn, &mut tmp, wire.len());
        let Some(Frame::Request { seq: s0, text: t0, len: l0 }) = conn.next_frame(1024) else {
            panic!("first frame");
        };
        let Some(Frame::Request { seq: s1, text: t1, .. }) = conn.next_frame(1024) else {
            panic!("second frame");
        };
        assert!(conn.next_frame(1024).is_none());
        assert_eq!((s0, s1), (0, 1));
        assert_eq!(t0, "{\"id\":0}");
        assert_eq!(t1, "{\"id\":1}");
        assert_eq!(l0 as usize, t0.len());
        assert_eq!(conn.inflight, 2);

        // Worker 1 finishes first; its response must wait for slot 0.
        conn.inflight -= 1;
        conn.queue_response(1, b"second");
        assert!(conn.flush().is_ok());
        conn.inflight -= 1;
        conn.queue_response(0, b"first");
        while conn.flush().unwrap() {}
        assert!(conn.done(false) || conn.settled());

        let mut len = [0u8; 4];
        client.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        client.read_exact(&mut body).unwrap();
        assert_eq!(body, b"first");
        client.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        client.read_exact(&mut body).unwrap();
        assert_eq!(body, b"second");
    }

    #[test]
    fn oversize_prefix_rejects_with_frame_cap_wording_and_latches_close() {
        let (mut client, mut conn) = pair();
        client.write_all(&1024u32.to_le_bytes()).unwrap();
        client.write_all(&[0u8; 8]).unwrap();
        let mut tmp = vec![0u8; 4096];
        fill_until(&mut conn, &mut tmp, 4);
        let Some(Frame::Reject { seq, error }) = conn.next_frame(256) else {
            panic!("oversize must reject");
        };
        assert_eq!(seq, 0);
        let expected = format!("{:#}", ensure_frame_len("incoming", 1024, 256).unwrap_err());
        assert_eq!(error, expected, "wording parity with every other framed medium");
        assert!(conn.closing);
        assert!(conn.next_frame(256).is_none(), "no parsing past a bad prefix");

        conn.queue_response(seq, b"typed error");
        while conn.flush().unwrap() {}
        assert!(conn.done(false), "flushed violation closes cleanly");
    }

    #[test]
    fn partial_frame_waits_and_dirty_eof_is_detected() {
        let (mut client, mut conn) = pair();
        // Declare 100 bytes, deliver 10, vanish.
        client.write_all(&100u32.to_le_bytes()).unwrap();
        client.write_all(&[b'x'; 10]).unwrap();
        let mut tmp = vec![0u8; 4096];
        fill_until(&mut conn, &mut tmp, 14);
        assert!(conn.next_frame(1024).is_none(), "incomplete frame must wait");
        assert!(!conn.dirty_eof());
        drop(client);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !conn.eof {
            conn.fill(&mut tmp).unwrap();
            assert!(std::time::Instant::now() < deadline, "eof not observed");
        }
        assert!(conn.dirty_eof(), "mid-frame disconnect is a dirty close");
        assert!(conn.done(false), "nothing owed, ready to drop");
    }
}
