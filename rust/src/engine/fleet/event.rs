//! The readiness-polled event loop and the worker-core pool.
//!
//! One I/O thread owns the nonblocking listener and every
//! `conn::Conn`; `cfg.workers` compute threads own the
//! engines' scratches. The split is classic: the I/O loop only moves
//! bytes and parses frames (never runs inference), workers only
//! compute (never touch sockets). They meet on two unbounded channels
//! — jobs out, completions back — so the I/O loop can never stall on a
//! full queue while holding the sockets.
//!
//! Each loop iteration: drain the accept backlog, drain completions
//! into their connections' reorder maps, then per connection
//! fill → parse-and-dispatch → flush, and finally reap connections
//! with nothing left to say. When an iteration moves no bytes the loop
//! parks briefly instead of spinning (hand-rolled `std::net` has no
//! `epoll`; a sub-millisecond park is the portable readiness wait).
//!
//! Shutdown drains: once the sentinel latches, the loop stops
//! accepting, gives every connection one final read (so frames the
//! clients pipelined before seeing the ack are captured), answers
//! everything captured, flushes, then closes — bounded by
//! `DRAIN_DEADLINE` so a peer that stops reading its socket cannot
//! hold the fleet open.

use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::protocol;
use crate::infer::json::Json;
use crate::obs;

use super::conn::{Conn, Frame};
use super::{control, FleetShared};

/// Hard cap on how long the shutdown drain may take (a peer that
/// never reads its responses is cut off here).
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// Park time when an iteration made no progress.
const IDLE_PARK: Duration = Duration::from_micros(50);

/// Park time when additionally no connection is open.
const EMPTY_PARK: Duration = Duration::from_micros(500);

/// One parsed request on its way to a worker.
struct Job {
    slot: usize,
    gen: u64,
    seq: u64,
    text: String,
    /// Frame-complete time: latency measured from here includes queue
    /// wait.
    at: Instant,
}

/// One response on its way back to the event loop.
struct Completion {
    slot: usize,
    gen: u64,
    seq: u64,
    bytes: Vec<u8>,
}

/// Run the fleet: spawn the worker pool, then the event loop on the
/// calling thread. Returns once shutdown has drained (or, with
/// `max_conns`, once that many connections were accepted and all of
/// them closed — the test harness mode, mirroring
/// [`Server::serve_tcp`](crate::engine::Server::serve_tcp)).
pub(crate) fn serve(
    shared: &FleetShared,
    listener: &TcpListener,
    max_conns: Option<usize>,
) -> Result<()> {
    listener.set_nonblocking(true).context("set listener nonblocking")?;
    let workers = shared.cfg.workers.max(1);
    let (jobs_tx, jobs_rx) = mpsc::channel::<Job>();
    let (done_tx, done_rx) = mpsc::channel::<Completion>();
    let jobs_rx = Mutex::new(jobs_rx);
    std::thread::scope(|scope| -> Result<()> {
        for t in 0..workers {
            let jobs_rx = &jobs_rx;
            let done_tx = done_tx.clone();
            scope.spawn(move || worker_loop(shared, jobs_rx, &done_tx, t as u32));
        }
        drop(done_tx);
        let result = event_loop(shared, listener, max_conns, &jobs_tx, &done_rx);
        // Closing the job queue lets the workers exit; the scope join
        // waits for in-flight jobs (whose completions now go nowhere).
        drop(jobs_tx);
        result
    })
}

fn worker_loop(
    shared: &FleetShared,
    jobs: &Mutex<Receiver<Job>>,
    done: &Sender<Completion>,
    tid: u32,
) {
    let mut th = shared.tracer.handle(tid);
    loop {
        // Hold the lock only for the dequeue, never while computing.
        let next = jobs.lock().expect("fleet job queue poisoned").recv();
        let Ok(job) = next else { break };
        let response = control::respond(shared, &mut th, &job.text, Some(job.at));
        let _ = done.send(Completion {
            slot: job.slot,
            gen: job.gen,
            seq: job.seq,
            bytes: response.into_bytes(),
        });
    }
}

fn event_loop(
    shared: &FleetShared,
    listener: &TcpListener,
    max_conns: Option<usize>,
    jobs: &Sender<Job>,
    done: &Receiver<Completion>,
) -> Result<()> {
    let m = &shared.metrics;
    let cap = shared.cfg.max_frame_bytes;
    let mut slab: Vec<Option<Conn>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut gen: u64 = 0;
    let mut accepted = 0usize;
    let mut tmp = vec![0u8; 64 * 1024];
    let mut draining = false;
    let mut drain_started = Instant::now();
    loop {
        let mut progress = false;
        if !draining && shared.shutdown.load(Ordering::SeqCst) {
            draining = true;
            drain_started = Instant::now();
            progress = true;
        }

        // Accept everything the backlog has.
        if !draining && max_conns.is_none_or(|cap| accepted < cap) {
            loop {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        accepted += 1;
                        progress = true;
                        if let Err(e) = stream.set_nonblocking(true) {
                            m.conns_failed.inc();
                            obs::log::error(format_args!("fleet accept {peer}: {e}"));
                        } else {
                            stream.set_nodelay(true).ok();
                            gen += 1;
                            let conn = Conn::new(stream, Some(peer), gen);
                            let slot = free.pop().unwrap_or_else(|| {
                                slab.push(None);
                                slab.len() - 1
                            });
                            slab[slot] = Some(conn);
                            m.conns_accepted.inc();
                            m.conns_open.add(1.0);
                        }
                        if max_conns.is_some_and(|cap| accepted >= cap) {
                            break;
                        }
                    }
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        break;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e).context("accept fleet connection"),
                }
            }
        }

        // Route finished responses into their reorder maps. Stale
        // completions (connection already gone, or the slot reused by
        // a newer generation) are dropped on the floor.
        while let Ok(c) = done.try_recv() {
            progress = true;
            if let Some(conn) = slab.get_mut(c.slot).and_then(Option::as_mut) {
                if conn.gen == c.gen {
                    conn.inflight = conn.inflight.saturating_sub(1);
                    m.frame_bytes.record(c.bytes.len() as u64);
                    conn.queue_response(c.seq, &c.bytes);
                }
            }
        }

        // Per-connection I/O.
        let force_close = draining && drain_started.elapsed() >= DRAIN_DEADLINE;
        for slot in 0..slab.len() {
            let Some(conn) = slab[slot].as_mut() else { continue };
            let mut failed = false;

            // Read. During the drain each connection gets exactly one
            // final fill: frames already in flight are captured, but a
            // client that keeps streaming cannot stall shutdown.
            if !draining || !conn.drain_filled {
                if draining {
                    conn.drain_filled = true;
                }
                match conn.fill(&mut tmp) {
                    Ok(p) => progress |= p,
                    Err(e) => {
                        failed = true;
                        log_conn(conn, "read", &e);
                    }
                }
            }

            // Parse and dispatch every complete frame.
            if !failed {
                while let Some(frame) = conn.next_frame(cap) {
                    progress = true;
                    match frame {
                        Frame::Request { seq, text, len } => {
                            m.frame_bytes.record(len as u64);
                            m.pipeline_depth.record(conn.inflight as u64);
                            let _ = jobs.send(Job {
                                slot,
                                gen: conn.gen,
                                seq,
                                text,
                                at: Instant::now(),
                            });
                        }
                        Frame::Reject { seq, error } => {
                            m.frames_rejected.inc();
                            m.errors.inc();
                            let body = protocol::error_response(Json::Null, &error);
                            conn.queue_response(seq, body.to_string().as_bytes());
                        }
                    }
                }
            }

            // Write.
            if !failed {
                match conn.flush() {
                    Ok(p) => progress |= p,
                    Err(e) => {
                        failed = true;
                        log_conn(conn, "write", &e);
                    }
                }
            }

            // Reap.
            if failed || conn.done(draining) || force_close {
                progress = true;
                if failed || conn.dirty_eof() {
                    m.conns_failed.inc();
                }
                m.conns_open.add(-1.0);
                m.conns_closed.inc();
                slab[slot] = None;
                free.push(slot);
            }
        }

        let open = slab.iter().filter(|s| s.is_some()).count();
        if open == 0 && (draining || max_conns.is_some_and(|cap| accepted >= cap)) {
            return Ok(());
        }
        if !progress {
            std::thread::park_timeout(if open == 0 { EMPTY_PARK } else { IDLE_PARK });
        }
    }
}

fn log_conn(conn: &Conn, what: &str, e: &std::io::Error) {
    match conn.peer {
        Some(p) => obs::log::error(format_args!("fleet connection {p}: {what}: {e}")),
        None => obs::log::error(format_args!("fleet connection: {what}: {e}")),
    }
}
