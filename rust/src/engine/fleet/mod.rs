//! The serving fleet: an async, shard-aware runtime hosting many
//! models behind one port, with live bundle hot-swap.
//!
//! The thread-pool [`Server`](crate::engine::Server) pins one frozen
//! model per process and one OS thread per connection — fine for a
//! demo, wrong for a fleet. This subsystem replaces the runtime while
//! keeping the wire contract:
//!
//! * **event loop** ([`event`]) — one I/O thread over nonblocking
//!   `std::net` sockets (readiness-polled by hand; the offline
//!   toolchain has no mio/tokio), so thousands of keep-alive
//!   connections cost buffers, not threads. Connections are
//!   per-connection state machines ([`conn`]) speaking the same
//!   `u32`-length-prefix + JSON framing as the thread pool, with
//!   *pipelining*: a client may send many frames before reading;
//!   responses return in request order.
//! * **worker cores** — `workers` compute threads pull parsed
//!   requests from an unbounded queue and answer via the untouched
//!   [`protocol`](crate::engine::protocol) surface, so query
//!   responses are **byte-identical** to the thread-pool server on
//!   the same bundle.
//! * **multi-model registry** ([`registry`]) — bundles keyed by
//!   content fingerprint, each with its own engine and scratch pool
//!   (warm-started from shipped calibrations). The active model is a
//!   pointer; [`control`] hot-swaps it under live traffic with zero
//!   dropped in-flight queries.
//!
//! Observability: the shared `serve.*` metrics keep their thread-pool
//! names, per-model latency lands in `serve.<fp>.latency_ns`, and the
//! fleet adds `fleet.conns_accepted`/`fleet.conns_open` (gauge)/
//! `fleet.conns_closed`/`fleet.conns_failed`, `fleet.pipeline_depth`,
//! `fleet.frames_rejected`, `fleet.swaps`,
//! `fleet.models_loaded`/`fleet.models_unloaded`. Worker trace lanes
//! carry request spans like the thread pool's.

pub mod conn;
pub mod control;
pub mod event;
pub mod registry;

pub use registry::{ModelEntry, ModelRegistry};

use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::engine::protocol::DEFAULT_MAX_BATCH;
use crate::engine::server::DEFAULT_MAX_FRAME_BYTES;
use crate::infer::EngineConfig;
use crate::model::Bundle;
use crate::obs;

/// Fleet runtime parameters (engine selection stays in
/// [`EngineConfig`]).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Compute threads (the event loop itself is one more thread).
    pub workers: usize,
    /// Per-frame byte cap, requests and responses — enforced on the
    /// event-loop read path with the same
    /// [`ensure_frame_len`](crate::util::ensure_frame_len) wording as
    /// the thread pool, but answered as a typed error instead of a
    /// torn connection.
    pub max_frame_bytes: u32,
    /// Max sub-queries per batch request.
    pub max_batch: usize,
    /// Accept mutating control-plane requests (`load_model`, `switch`,
    /// `unload`). Off, they answer a typed error; `models` stays
    /// readable. CLI `--no-control` clears it.
    pub control: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            workers: 1,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            max_batch: DEFAULT_MAX_BATCH,
            control: true,
        }
    }
}

/// Pre-created handles for the fleet metrics (same idiom as the
/// thread pool's `ServeMetrics`: the hot path never takes the
/// registry's name-map lock).
pub(crate) struct FleetMetrics {
    pub(crate) requests: obs::Counter,
    pub(crate) errors: obs::Counter,
    pub(crate) latency: obs::Hist,
    pub(crate) frame_bytes: obs::Hist,
    pub(crate) batch_depth: obs::Hist,
    pub(crate) conns_accepted: obs::Counter,
    pub(crate) conns_open: obs::Gauge,
    pub(crate) conns_closed: obs::Counter,
    pub(crate) conns_failed: obs::Counter,
    pub(crate) pipeline_depth: obs::Hist,
    pub(crate) frames_rejected: obs::Counter,
    pub(crate) swaps: obs::Counter,
    pub(crate) models_loaded: obs::Counter,
    pub(crate) models_unloaded: obs::Counter,
}

impl FleetMetrics {
    fn bind(reg: &obs::Registry) -> FleetMetrics {
        FleetMetrics {
            requests: reg.counter("serve.requests"),
            errors: reg.counter("serve.errors"),
            latency: reg.hist("serve.latency_ns"),
            frame_bytes: reg.hist("serve.frame_bytes"),
            batch_depth: reg.hist("serve.batch_depth"),
            conns_accepted: reg.counter("fleet.conns_accepted"),
            conns_open: reg.gauge("fleet.conns_open"),
            conns_closed: reg.counter("fleet.conns_closed"),
            conns_failed: reg.counter("fleet.conns_failed"),
            pipeline_depth: reg.hist("fleet.pipeline_depth"),
            frames_rejected: reg.counter("fleet.frames_rejected"),
            swaps: reg.counter("fleet.swaps"),
            models_loaded: reg.counter("fleet.models_loaded"),
            models_unloaded: reg.counter("fleet.models_unloaded"),
        }
    }
}

/// Everything the event loop and the workers share.
pub(crate) struct FleetShared {
    pub(crate) cfg: FleetConfig,
    pub(crate) engine_cfg: EngineConfig,
    pub(crate) models: ModelRegistry,
    pub(crate) registry: obs::Registry,
    pub(crate) tracer: obs::Tracer,
    pub(crate) metrics: FleetMetrics,
    pub(crate) shutdown: AtomicBool,
}

impl FleetShared {
    /// Insert a bundle and meter a fresh load.
    pub(crate) fn load(&self, bundle: &Bundle) -> Result<(Arc<ModelEntry>, bool)> {
        let (entry, fresh) = self.models.insert(bundle, &self.engine_cfg)?;
        if fresh {
            self.metrics.models_loaded.inc();
        }
        Ok((entry, fresh))
    }

    /// Activate a model and meter the swap.
    pub(crate) fn activate(&self, fp: u64) -> Result<Arc<ModelEntry>> {
        let entry = self.models.activate(fp)?;
        self.metrics.swaps.inc();
        Ok(entry)
    }
}

/// The fleet runtime: model registry + control plane + event-loop
/// serving. Construct, load at least one bundle, then
/// [`serve`](FleetServer::serve).
pub struct FleetServer {
    shared: FleetShared,
}

impl FleetServer {
    /// A fleet with no models yet; `engine_cfg` governs how every
    /// loaded bundle compiles (method, budget, samples, seed).
    pub fn new(engine_cfg: EngineConfig, cfg: FleetConfig) -> FleetServer {
        let registry = obs::Registry::new();
        let metrics = FleetMetrics::bind(&registry);
        let models = ModelRegistry::new(&registry);
        FleetServer {
            shared: FleetShared {
                cfg,
                engine_cfg,
                models,
                registry,
                tracer: obs::Tracer::disabled(),
                metrics,
                shutdown: AtomicBool::new(false),
            },
        }
    }

    /// Load a bundle into the registry (idempotent; the first load
    /// becomes the active model). Returns its fingerprint.
    pub fn load_bundle(&self, bundle: &Bundle) -> Result<u64> {
        let (entry, _) = self.shared.load(bundle)?;
        Ok(entry.fingerprint)
    }

    /// [`FleetServer::load_bundle`] from a `.bnb` file.
    pub fn load_path(&self, path: &Path) -> Result<u64> {
        self.load_bundle(&crate::model::read_bundle(path)?)
    }

    /// Point live traffic at `fp` (the in-process form of the
    /// `{"type": "switch"}` control request).
    pub fn switch_to(&self, fp: u64) -> Result<()> {
        self.shared.activate(fp)?;
        Ok(())
    }

    /// The model registry (inspection and tests).
    pub fn models(&self) -> &ModelRegistry {
        &self.shared.models
    }

    /// Fingerprint of the active model.
    pub fn active_fingerprint(&self) -> Option<u64> {
        self.shared.models.active_fingerprint()
    }

    /// The metrics registry `{"type": "stats"}` snapshots.
    pub fn registry(&self) -> &obs::Registry {
        &self.shared.registry
    }

    /// Swap in an externally owned metrics registry (CLI `--metrics`).
    pub fn bind_registry(&mut self, registry: obs::Registry) {
        self.shared.metrics = FleetMetrics::bind(&registry);
        self.shared.models.bind_obs(&registry);
        self.shared.registry = registry;
    }

    /// Enable span tracing (one lane per worker core).
    pub fn set_tracer(&mut self, tracer: obs::Tracer) {
        self.shared.tracer = tracer;
    }

    /// The span tracer (disabled unless [`FleetServer::set_tracer`]).
    pub fn tracer(&self) -> &obs::Tracer {
        &self.shared.tracer
    }

    /// Has the shutdown sentinel been received?
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Answer one request in-process — the socket-free path for tests
    /// and embedding; identical dispatch to what a worker core runs.
    pub fn handle(&self, request: &str) -> String {
        let mut th = self.shared.tracer.handle(0);
        control::respond(&self.shared, &mut th, request, None)
    }

    /// Serve the listener until shutdown drains (or until `max_conns`
    /// connections were accepted and all of them closed — tests).
    pub fn serve(&self, listener: &TcpListener, max_conns: Option<usize>) -> Result<()> {
        event::serve(&self.shared, listener, max_conns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;
    use crate::infer::json::Json;
    use crate::model::{bundle_fingerprint, fingerprint_hex, write_bundle, BundleMeta};

    fn bundle(tag: &str) -> Bundle {
        let meta = BundleMeta { producer: tag.into(), rounds: 0, score: 0.0, ess: 1.0 };
        Bundle::calibrated_within(tiny_bn(), meta, u64::MAX)
    }

    fn parse(text: &str) -> Json {
        Json::parse(text).expect("fleet response is JSON")
    }

    #[test]
    fn queries_error_until_a_model_loads_then_match_threadpool_bytes() {
        let fleet = FleetServer::new(EngineConfig::default(), FleetConfig::default());
        let v = parse(&fleet.handle(r#"{"id": 1, "type": "marginal"}"#));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));

        let b = bundle("a");
        let fp = fleet.load_bundle(&b).unwrap();
        assert_eq!(fp, bundle_fingerprint(&b));
        assert_eq!(fleet.active_fingerprint(), Some(fp), "first load activates");

        // Byte-identity with the thread-pool server on the same bundle.
        let pool = crate::engine::Server::from_bundle(
            &b,
            &EngineConfig::default(),
            crate::engine::ServeConfig::default(),
        )
        .unwrap();
        let mut scratch = pool.new_scratch();
        for req in [
            r#"{"id": 1, "type": "marginal", "evidence": {"b": 1}}"#,
            r#"{"id": 2, "type": "map"}"#,
            r#"{"id": 3, "type": "joint_map", "evidence": {"a": 0}}"#,
            r#"{"id": 4, "type": "batch", "queries": [{"id": 0}, {"id": 1, "evidence": {"b": 0}}]}"#,
        ] {
            assert_eq!(fleet.handle(req), pool.handle(&mut scratch, req), "req: {req}");
        }
    }

    #[test]
    fn control_plane_load_switch_models_unload_roundtrip() {
        let fleet = FleetServer::new(EngineConfig::default(), FleetConfig::default());
        let dir = std::env::temp_dir();
        let path_a = dir.join(format!("cges_fleet_mod_a_{}.bnb", std::process::id()));
        let path_b = dir.join(format!("cges_fleet_mod_b_{}.bnb", std::process::id()));
        let (ba, bb) = (bundle("a"), bundle("b"));
        write_bundle(&ba, &path_a).unwrap();
        write_bundle(&bb, &path_b).unwrap();
        let (fa, fb) = (bundle_fingerprint(&ba), bundle_fingerprint(&bb));

        let v = parse(&fleet.handle(&format!(
            r#"{{"id": 1, "type": "load_model", "path": "{}"}}"#,
            path_a.display()
        )));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{v:?}");
        assert_eq!(v.get("model").and_then(Json::as_str), Some(fingerprint_hex(fa).as_str()));
        assert_eq!(v.get("warm").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("active").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("already_loaded").and_then(Json::as_bool), Some(false));

        let v = parse(&fleet.handle(&format!(
            r#"{{"id": 2, "type": "load_model", "path": "{}"}}"#,
            path_b.display()
        )));
        assert_eq!(v.get("active").and_then(Json::as_bool), Some(false));

        // Switch to B; the models list flips its active flag.
        let v = parse(&fleet.handle(&format!(
            r#"{{"id": 3, "type": "switch", "model": "{}"}}"#,
            fingerprint_hex(fb)
        )));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{v:?}");
        assert_eq!(v.get("active").and_then(Json::as_str), Some(fingerprint_hex(fb).as_str()));

        let v = parse(&fleet.handle(r#"{"id": 4, "type": "models"}"#));
        let fb_hex = fingerprint_hex(fb);
        assert_eq!(v.get("active").and_then(Json::as_str), Some(fb_hex.as_str()));
        let models = v.get("models").and_then(Json::as_array).unwrap();
        assert_eq!(models.len(), 2);
        for m in models {
            let is_b = m.get("model").and_then(Json::as_str) == Some(fb_hex.as_str());
            assert_eq!(m.get("active").and_then(Json::as_bool), Some(is_b));
            assert_eq!(m.get("engine").and_then(Json::as_str), Some("jointree"));
        }

        // The active model refuses to unload; the inactive one goes.
        let v = parse(&fleet.handle(&format!(
            r#"{{"id": 5, "type": "unload", "model": "{}"}}"#,
            fingerprint_hex(fb)
        )));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        let v = parse(&fleet.handle(&format!(
            r#"{{"id": 6, "type": "unload", "model": "{}"}}"#,
            fingerprint_hex(fa)
        )));
        assert_eq!(v.get("unloaded").and_then(Json::as_str), Some(fingerprint_hex(fa).as_str()));
        assert_eq!(fleet.models().len(), 1);

        // Junk fingerprints and unknown models answer typed errors.
        let v = parse(&fleet.handle(r#"{"id": 7, "type": "switch", "model": "nope!"}"#));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        let v = parse(&fleet.handle(&format!(
            r#"{{"id": 8, "type": "switch", "model": "{}"}}"#,
            fingerprint_hex(fa)
        )));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));

        std::fs::remove_file(&path_a).ok();
        std::fs::remove_file(&path_b).ok();
    }

    #[test]
    fn control_gate_refuses_mutations_but_not_models() {
        let fleet = FleetServer::new(
            EngineConfig::default(),
            FleetConfig { control: false, ..Default::default() },
        );
        fleet.load_bundle(&bundle("a")).unwrap();
        for req in [
            r#"{"type": "load_model", "path": "x.bnb"}"#,
            r#"{"type": "switch", "model": "00000000000000aa"}"#,
            r#"{"type": "unload", "model": "00000000000000aa"}"#,
        ] {
            let v = parse(&fleet.handle(req));
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "req: {req}");
            assert!(
                v.get("error").and_then(Json::as_str).unwrap().contains("control plane"),
                "req: {req}"
            );
        }
        let v = parse(&fleet.handle(r#"{"type": "models"}"#));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        // Queries are unaffected by the gate.
        let v = parse(&fleet.handle(r#"{"id": 1, "type": "marginal"}"#));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn stats_shape_matches_threadpool_and_shutdown_latches() {
        let fleet = FleetServer::new(EngineConfig::default(), FleetConfig::default());
        fleet.load_bundle(&bundle("a")).unwrap();
        fleet.handle(r#"{"id": 1}"#);
        let v = parse(&fleet.handle(r#"{"id": 2, "type": "stats"}"#));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("engine").and_then(Json::as_str), Some("jointree"));
        let stats = v.get("stats").expect("stats body");
        let counters = stats.get("counters").expect("counters map");
        assert!(counters.get("serve.requests").and_then(Json::as_f64).unwrap() >= 1.0);
        let hists = stats.get("histograms").expect("histograms map");
        assert!(
            hists.get("serve.latency_ns").and_then(|h| h.get("count")).is_some(),
            "shared latency histogram"
        );
        // The per-model histogram landed under the fingerprint name.
        let fp_hex = fingerprint_hex(fleet.active_fingerprint().unwrap());
        assert!(
            hists.get(&format!("serve.{fp_hex}.latency_ns")).is_some(),
            "per-model latency histogram missing from {hists:?}"
        );

        let v = parse(&fleet.handle(r#"{"type": "stats_reset"}"#));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "reset is guarded");

        assert!(!fleet.is_shutting_down());
        let v = parse(&fleet.handle(r#"{"id": 9, "type": "shutdown"}"#));
        assert_eq!(v.get("shutdown").and_then(Json::as_bool), Some(true));
        assert!(fleet.is_shutting_down());
    }

    #[test]
    fn oversized_response_is_substituted_with_typed_error() {
        // A tiny outgoing cap: any real marginal response exceeds it,
        // so the worker must substitute the typed cap error instead of
        // letting the event loop tear the connection.
        let fleet = FleetServer::new(
            EngineConfig::default(),
            FleetConfig { max_frame_bytes: 96, ..Default::default() },
        );
        fleet.load_bundle(&bundle("a")).unwrap();
        let raw = fleet.handle(r#"{"id": 1, "type": "marginal"}"#);
        assert!(raw.len() <= 96, "substituted response must fit the cap: {raw}");
        let v = parse(&raw);
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        assert!(v.get("error").and_then(Json::as_str).unwrap().contains("exceeds cap"));
    }
}
