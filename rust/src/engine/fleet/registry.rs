//! Fingerprint-keyed multi-model registry with per-model scratch
//! pools.
//!
//! Every hosted model is one [`ModelEntry`] behind an `Arc`, filed
//! under its content fingerprint
//! ([`bundle_fingerprint`](crate::model::bundle_fingerprint)). The
//! *active* model is just which fingerprint the registry currently
//! points at: a [`switch`](ModelRegistry::activate) is a pointer
//! exchange under a short write lock, and a worker that resolved the
//! old `Arc` before the swap finishes its request on that `Arc` — the
//! entry (engine, scratch pool) stays alive until the last in-flight
//! clone drops, which is exactly the zero-dropped-queries hot-swap
//! contract. Unloading is refused for the active model, so the control
//! plane can never yank the pointer queries are about to resolve.
//!
//! Engines are built *outside* the registry lock (compiles can take
//! seconds; queries keep resolving the active pointer meanwhile) via
//! [`SharedEngine::from_bundle`], so shipped calibrations warm-start
//! every scratch the pool hands out.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Result};

use crate::engine::{Scratch, SharedEngine};
use crate::infer::EngineConfig;
use crate::model::{bundle_fingerprint, fingerprint_hex, Bundle};
use crate::obs;

/// Idle scratches retained per model; checkins past the cap drop the
/// scratch instead (a bound on memory, not on concurrency — checkout
/// builds a fresh scratch when the pool is empty).
const SCRATCH_POOL_CAP: usize = 64;

/// One hosted model: the compiled engine, its provenance, its scratch
/// pool and its per-model serving metrics (`serve.<fp>.requests`,
/// `serve.<fp>.latency_ns`).
pub struct ModelEntry {
    /// Content fingerprint this entry is filed under.
    pub fingerprint: u64,
    /// The shared engine (exact compiled model or sampling fallback).
    pub engine: SharedEngine,
    /// Producer string from the bundle's provenance header.
    pub producer: String,
    /// Edge count of the fitted structure.
    pub edges: usize,
    /// Requests answered by this model.
    pub requests: obs::Counter,
    /// Per-model request latency histogram.
    pub latency: obs::Hist,
    scratches: Mutex<Vec<Scratch>>,
}

impl ModelEntry {
    /// Take a scratch from the pool, or build a fresh one (warm when
    /// the engine warm-started from shipped calibrations).
    pub fn checkout(&self) -> Scratch {
        if let Some(s) = self.scratches.lock().expect("scratch pool poisoned").pop() {
            return s;
        }
        self.engine.new_scratch()
    }

    /// Return a scratch after use (dropped past [`SCRATCH_POOL_CAP`]).
    pub fn checkin(&self, scratch: Scratch) {
        let mut pool = self.scratches.lock().expect("scratch pool poisoned");
        if pool.len() < SCRATCH_POOL_CAP {
            pool.push(scratch);
        }
    }

    /// Idle scratches currently pooled.
    pub fn pooled(&self) -> usize {
        self.scratches.lock().expect("scratch pool poisoned").len()
    }

    /// Did the engine warm-start from shipped potentials?
    pub fn warm_started(&self) -> bool {
        self.engine.warm_started()
    }

    /// Number of variables in the model.
    pub fn n_vars(&self) -> usize {
        self.engine.n_vars()
    }

    /// Canonical hex spelling of the fingerprint (wire form).
    pub fn hex(&self) -> String {
        fingerprint_hex(self.fingerprint)
    }
}

struct Inner {
    obs: obs::Registry,
    models: BTreeMap<u64, Arc<ModelEntry>>,
    active: Option<u64>,
}

/// The fleet's model table: fingerprint → [`ModelEntry`], plus the
/// active pointer. See the [module docs](self) for the hot-swap
/// contract.
pub struct ModelRegistry {
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    /// Empty registry; per-model metrics register into `obs`.
    pub fn new(obs: &obs::Registry) -> ModelRegistry {
        ModelRegistry {
            inner: RwLock::new(Inner { obs: obs.clone(), models: BTreeMap::new(), active: None }),
        }
    }

    /// Re-home per-model metric handles into `obs` (the CLI
    /// `--metrics` path swaps registries after construction).
    pub(crate) fn bind_obs(&self, obs: &obs::Registry) {
        let mut w = self.inner.write().expect("model registry poisoned");
        for entry in w.models.values() {
            let hex = entry.hex();
            obs.register_counter(&format!("serve.{hex}.requests"), &entry.requests);
            obs.register_hist(&format!("serve.{hex}.latency_ns"), &entry.latency);
        }
        w.obs = obs.clone();
    }

    /// Insert `bundle` (idempotent: an already-hosted fingerprint
    /// returns the existing entry with `false`). The first model ever
    /// inserted becomes active. The engine builds outside the lock.
    pub fn insert(&self, bundle: &Bundle, cfg: &EngineConfig) -> Result<(Arc<ModelEntry>, bool)> {
        let fp = bundle_fingerprint(bundle);
        if let Some(existing) = self.get(fp) {
            return Ok((existing, false));
        }
        let engine = SharedEngine::from_bundle(bundle, cfg)?;
        let entry = Arc::new(ModelEntry {
            fingerprint: fp,
            engine,
            producer: bundle.meta.producer.clone(),
            edges: bundle.bn.dag.edge_count(),
            requests: obs::Counter::new(),
            latency: obs::Hist::new(),
            scratches: Mutex::new(Vec::new()),
        });
        let mut w = self.inner.write().expect("model registry poisoned");
        if let Some(existing) = w.models.get(&fp) {
            // Raced with a concurrent load of the same bundle: keep
            // the first build, drop ours.
            return Ok((existing.clone(), false));
        }
        let hex = entry.hex();
        w.obs.register_counter(&format!("serve.{hex}.requests"), &entry.requests);
        w.obs.register_hist(&format!("serve.{hex}.latency_ns"), &entry.latency);
        w.models.insert(fp, entry.clone());
        if w.active.is_none() {
            w.active = Some(fp);
        }
        Ok((entry, true))
    }

    /// Point the active slot at `fp` — the hot swap. In-flight
    /// requests finish on the `Arc` they already resolved.
    pub fn activate(&self, fp: u64) -> Result<Arc<ModelEntry>> {
        let mut w = self.inner.write().expect("model registry poisoned");
        match w.models.get(&fp) {
            Some(entry) => {
                let entry = entry.clone();
                w.active = Some(fp);
                Ok(entry)
            }
            None => bail!(
                "no model {} in the registry ({} loaded)",
                fingerprint_hex(fp),
                w.models.len()
            ),
        }
    }

    /// The active entry — the pin point every query resolves once.
    pub fn active(&self) -> Option<Arc<ModelEntry>> {
        let r = self.inner.read().expect("model registry poisoned");
        r.active.and_then(|fp| r.models.get(&fp).cloned())
    }

    /// Fingerprint of the active model.
    pub fn active_fingerprint(&self) -> Option<u64> {
        self.inner.read().expect("model registry poisoned").active
    }

    /// Look up one entry by fingerprint.
    pub fn get(&self, fp: u64) -> Option<Arc<ModelEntry>> {
        self.inner.read().expect("model registry poisoned").models.get(&fp).cloned()
    }

    /// Remove `fp` from the registry. Refused for the active model
    /// (switch first); in-flight `Arc`s keep the removed entry alive
    /// until their requests finish, so nothing is yanked mid-query.
    pub fn unload(&self, fp: u64) -> Result<Arc<ModelEntry>> {
        let mut w = self.inner.write().expect("model registry poisoned");
        if w.active == Some(fp) {
            bail!("model {} is active; switch away before unloading", fingerprint_hex(fp));
        }
        match w.models.remove(&fp) {
            Some(entry) => Ok(entry),
            None => bail!("no model {} in the registry", fingerprint_hex(fp)),
        }
    }

    /// `(active fingerprint, entries in fingerprint order)`.
    pub fn list(&self) -> (Option<u64>, Vec<Arc<ModelEntry>>) {
        let r = self.inner.read().expect("model registry poisoned");
        (r.active, r.models.values().cloned().collect())
    }

    /// Number of hosted models.
    pub fn len(&self) -> usize {
        self.inner.read().expect("model registry poisoned").models.len()
    }

    /// True when no model is hosted.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;
    use crate::model::BundleMeta;

    fn bundle(tag: &str) -> Bundle {
        let meta = BundleMeta { producer: tag.into(), rounds: 0, score: 0.0, ess: 1.0 };
        Bundle::calibrated_within(tiny_bn(), meta, u64::MAX)
    }

    #[test]
    fn insert_activate_unload_lifecycle() {
        let obs = obs::Registry::new();
        let reg = ModelRegistry::new(&obs);
        let cfg = EngineConfig::default();
        assert!(reg.is_empty());
        assert!(reg.active().is_none());

        let (a, fresh_a) = reg.insert(&bundle("a"), &cfg).unwrap();
        assert!(fresh_a);
        assert!(a.warm_started(), "calibrated bundle must warm-start");
        // First insert auto-activates.
        assert_eq!(reg.active_fingerprint(), Some(a.fingerprint));

        // Idempotent re-insert returns the same entry.
        let (a2, fresh_a2) = reg.insert(&bundle("a"), &cfg).unwrap();
        assert!(!fresh_a2);
        assert!(Arc::ptr_eq(&a, &a2));

        let (b, fresh_b) = reg.insert(&bundle("b"), &cfg).unwrap();
        assert!(fresh_b);
        assert_ne!(a.fingerprint, b.fingerprint);
        assert_eq!(reg.len(), 2);
        // Second insert does not steal the active slot.
        assert_eq!(reg.active_fingerprint(), Some(a.fingerprint));

        // The active model cannot be unloaded.
        assert!(reg.unload(a.fingerprint).is_err());
        reg.activate(b.fingerprint).unwrap();
        assert_eq!(reg.active_fingerprint(), Some(b.fingerprint));
        reg.unload(a.fingerprint).unwrap();
        assert_eq!(reg.len(), 1);
        assert!(reg.get(a.fingerprint).is_none());
        assert!(reg.unload(a.fingerprint).is_err(), "double unload must fail");
        assert!(reg.activate(a.fingerprint).is_err(), "activate after unload must fail");

        // Per-model metrics registered under the fingerprint names.
        assert_eq!(obs.counter_value(&format!("serve.{}.requests", b.hex())), Some(0));
    }

    #[test]
    fn unloaded_entry_survives_for_inflight_arcs() {
        let obs = obs::Registry::new();
        let reg = ModelRegistry::new(&obs);
        let cfg = EngineConfig::default();
        let (a, _) = reg.insert(&bundle("a"), &cfg).unwrap();
        let (b, _) = reg.insert(&bundle("b"), &cfg).unwrap();
        reg.activate(b.fingerprint).unwrap();

        // "In-flight request" holds the Arc across the unload.
        let pinned = a.clone();
        reg.unload(a.fingerprint).unwrap();
        let mut s = pinned.checkout();
        let post = pinned.engine.posterior(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
        pinned.checkin(s);
        assert_eq!(pinned.pooled(), 1);
    }

    #[test]
    fn scratch_pool_reuses_and_caps() {
        let obs = obs::Registry::new();
        let reg = ModelRegistry::new(&obs);
        let (a, _) = reg.insert(&bundle("a"), &EngineConfig::default()).unwrap();
        assert_eq!(a.pooled(), 0);
        let s1 = a.checkout();
        let s2 = a.checkout();
        a.checkin(s1);
        a.checkin(s2);
        assert_eq!(a.pooled(), 2);
        let _ = a.checkout();
        assert_eq!(a.pooled(), 1);
    }
}
