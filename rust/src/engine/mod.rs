//! Concurrent batched query engine — the serving layer.
//!
//! PR 2's [`infer`](crate::infer) answered queries through a
//! single-threaded `&mut Engine`; this subsystem splits that into an
//! immutable [`CompiledModel`] (frozen jointree topology, CPT-assigned
//! potentials, precomputed message schedule *and per-edge kernel
//! plans* — `Send + Sync`, shared by reference or `Arc`) and cheap
//! per-thread [`Scratch`] buffer arenas, so `query(&self, &mut
//! Scratch, ..)` holds no lock and performs no table allocation on
//! the propagation hot path (the blocked kernels of
//! [`infer::kernel`](crate::infer::kernel) write into retained
//! buffers). On top of it:
//!
//! * [`SharedEngine`] — the concurrent analog of
//!   [`infer::Engine`](crate::infer::Engine): exact compiled model or
//!   seeded likelihood-weighting fallback, method/budget selection per
//!   the same [`EngineConfig`];
//! * [`protocol`] — the JSON request surface (`marginal`, `map`,
//!   `joint_map`, `batch`, shutdown sentinel), shared by every medium;
//! * [`server`] — a multi-client TCP server (bounded thread pool,
//!   per-connection framing, graceful shutdown) with the NDJSON line
//!   mode as a thin adapter. The server carries the crate's
//!   observability surface ([`obs`](crate::obs)): request latency,
//!   frame-size and batch-depth histograms plus connection counters,
//!   snapshotted by the `{"type": "stats"}` endpoint, and per-thread
//!   trace lanes with request / collect / distribute spans when a
//!   tracer is attached.
//!
//! `infer::Engine`, `infer::JoinTree` and `infer::QueryServer` remain
//! as compatibility shims over these types.

pub mod compiled;
pub mod fleet;
pub mod protocol;
pub mod server;

pub use compiled::{CompiledModel, Scratch};
pub use fleet::{FleetConfig, FleetServer, ModelRegistry};
pub use server::{Server, ServeConfig};

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::bn::DiscreteBn;
use crate::graph::moral_graph;
use crate::infer::triangulate::{triangulate, Triangulation};
use crate::infer::{likelihood_weighting, EngineConfig, Method, Posterior};
use crate::model::Bundle;

/// A compiled inference engine whose queries take `&self`: safe to
/// share across serving threads.
pub enum SharedEngine {
    /// Exact two-pass propagation over a compiled jointree.
    Exact(CompiledModel),
    /// Likelihood weighting over a retained copy of the network. Each
    /// query draws a fresh particle seed from the shared counter, so
    /// repeated identical queries are independent estimates; under
    /// concurrency the seed *assignment* to queries follows arrival
    /// order (the estimate sequence is deterministic only
    /// single-threaded).
    Sampled {
        /// The fitted network.
        bn: Box<DiscreteBn>,
        /// Particles per query.
        samples: usize,
        /// Base seed.
        seed: u64,
        /// Per-query sequence number.
        counter: AtomicU64,
    },
}

impl SharedEngine {
    /// Build an engine per `cfg` — same selection rules as
    /// [`infer::Engine::build`](crate::infer::Engine::build).
    pub fn build(bn: &DiscreteBn, cfg: &EngineConfig) -> Result<SharedEngine> {
        Self::select(bn, cfg, |tri| match tri {
            Some(tri) => CompiledModel::compile_from(bn, tri),
            None => CompiledModel::compile(bn),
        })
    }

    /// Build an engine from a model bundle — the same selection rules
    /// as [`build`](SharedEngine::build), except the exact path goes
    /// through [`CompiledModel::from_bundle`] so shipped calibrated
    /// potentials warm-start every scratch when the schedule
    /// fingerprint matches (and cold-start, bit-identically,
    /// otherwise).
    pub fn from_bundle(bundle: &Bundle, cfg: &EngineConfig) -> Result<SharedEngine> {
        Self::select(&bundle.bn, cfg, |tri| match tri {
            Some(tri) => CompiledModel::from_bundle_from(bundle, tri),
            None => CompiledModel::from_bundle(bundle),
        })
    }

    /// The one method-selection rule behind both constructors: `Auto`
    /// probes the treewidth and hands the triangulation to `exact` on
    /// success, `JoinTree` forces the exact path (no probe), `Lw`
    /// retains the network for sampling.
    fn select(
        bn: &DiscreteBn,
        cfg: &EngineConfig,
        exact: impl FnOnce(Option<Triangulation>) -> Result<CompiledModel>,
    ) -> Result<SharedEngine> {
        let sampled = |cfg: &EngineConfig| SharedEngine::Sampled {
            bn: Box::new(bn.clone()),
            samples: cfg.samples,
            seed: cfg.seed,
            counter: AtomicU64::new(0),
        };
        match cfg.method {
            Method::JoinTree => Ok(SharedEngine::Exact(exact(None)?)),
            Method::Lw => Ok(sampled(cfg)),
            Method::Auto => {
                let tri = triangulate(&moral_graph(&bn.dag), &bn.cards);
                if tri.max_clique_states <= cfg.budget {
                    Ok(SharedEngine::Exact(exact(Some(tri))?))
                } else {
                    Ok(sampled(cfg))
                }
            }
            Method::Ve => bail!(
                "variable elimination is per-query; use `query --method ve` or ve_marginal()"
            ),
        }
    }

    /// Engine name for telemetry and responses.
    pub fn name(&self) -> &'static str {
        match self {
            SharedEngine::Exact(_) => "jointree",
            SharedEngine::Sampled { .. } => "lw",
        }
    }

    /// Did the exact engine warm-start from shipped potentials?
    pub fn warm_started(&self) -> bool {
        match self {
            SharedEngine::Exact(m) => m.is_warm_started(),
            SharedEngine::Sampled { .. } => false,
        }
    }

    /// Variable names, in network order.
    pub fn names(&self) -> &[String] {
        match self {
            SharedEngine::Exact(m) => m.names(),
            SharedEngine::Sampled { bn, .. } => &bn.names,
        }
    }

    /// Cardinality of variable `v`.
    pub fn card(&self, v: usize) -> u32 {
        match self {
            SharedEngine::Exact(m) => m.card(v) as u32,
            SharedEngine::Sampled { bn, .. } => bn.cards[v],
        }
    }

    /// Number of variables.
    pub fn n_vars(&self) -> usize {
        self.names().len()
    }

    /// Fresh per-thread propagation buffers (empty for the sampling
    /// engine, which keeps no state between queries).
    pub fn new_scratch(&self) -> Scratch {
        match self {
            SharedEngine::Exact(m) => m.new_scratch(),
            SharedEngine::Sampled { .. } => Scratch::empty(),
        }
    }

    /// Posterior for one evidence set.
    pub fn posterior(&self, scratch: &mut Scratch, evidence: &[(usize, usize)]) -> Result<Posterior> {
        match self {
            SharedEngine::Exact(m) => m.marginals(scratch, evidence),
            SharedEngine::Sampled { bn, samples, seed, counter } => {
                let k = counter.fetch_add(1, Ordering::Relaxed);
                // splitmix-style spread so consecutive queries land on
                // well-separated particle streams.
                let qseed = seed ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
                likelihood_weighting(bn, evidence, *samples, qseed)
            }
        }
    }

    /// Exact joint MAP assignment (exact engine only).
    pub fn joint_map(
        &self,
        scratch: &mut Scratch,
        evidence: &[(usize, usize)],
    ) -> Result<(Vec<usize>, f64)> {
        match self {
            SharedEngine::Exact(m) => m.joint_map(scratch, evidence),
            SharedEngine::Sampled { .. } => bail!(
                "joint_map needs the exact engine (network exceeded the clique budget; \
                 raise --budget or force --method jointree)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn shared_engine_is_send_sync_and_selects_like_engine() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SharedEngine>();

        let bn = tiny_bn();
        let e = SharedEngine::build(&bn, &EngineConfig::default()).unwrap();
        assert_eq!(e.name(), "jointree");
        let mut s = e.new_scratch();
        let post = e.posterior(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);

        let cfg = EngineConfig { budget: 1, samples: 50_000, ..Default::default() };
        let e = SharedEngine::build(&bn, &cfg).unwrap();
        assert_eq!(e.name(), "lw");
        let mut s = e.new_scratch();
        let post = e.posterior(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 0.02);
        assert!(e.joint_map(&mut s, &[]).is_err());
    }
}
