//! The compiled-model / scratch split that makes serving concurrent,
//! and the precompiled kernel plans that make it allocation-free.
//!
//! [`CompiledModel`] is everything about a fitted network that never
//! changes between queries: the jointree topology (cliques, a *fixed*
//! rooted message schedule with per-clique parents, children and
//! separators), the evidence-free clique potentials with every CPT
//! multiplied in, each variable's home clique — and, since the blocked
//! kernel rework, a `CliquePlan` per schedule edge holding every
//! stride vector and blocked split a propagation needs. The kernel
//! walks never re-derive scopes, never call a `contains`/`position`
//! scan, and never sort anything (evidence canonicalization still
//! sorts two tiny scratch lists when the evidence set changes). It holds no interior mutability, so it is
//! `Send + Sync` and one `Arc` (or plain reference) can back any
//! number of connection-handler threads.
//!
//! [`Scratch`] is everything a propagation mutates: the
//! evidence-absorbed potentials, all message buffers, a per-clique
//! belief arena and one clique-sized work table. Every buffer has a
//! shape fixed at compile time, so steady-state queries perform **zero
//! heap allocations** in the kernel path — `marginalize_into` /
//! `product_into` / `absorb_marginalize_into` (the fused
//! message kernel that never materializes a clique product when a
//! single absorb feeds a marginalization) write into these retained
//! tables. Each serving thread owns one scratch, so the hot path
//! `marginals(&self, &mut Scratch, ..)` takes no lock anywhere.
//!
//! The scratch doubles as an incremental-evidence cache: collect-pass
//! messages are kept between queries together with the evidence each
//! clique has absorbed, and changing evidence only invalidates the
//! messages on the paths from re-absorbed cliques up to their roots
//! (a collect message depends exactly on the potentials in its
//! subtree). Consecutive queries that share an evidence prefix —
//! the shape the batch endpoint sorts for — therefore reuse every
//! message outside the changed subtrees, and identical evidence reuses
//! the entire collect pass.
//!
//! [`joint_map`](CompiledModel::joint_map) runs max-product over the
//! same compiled tree: a collect pass with max-marginalization, then a
//! root-to-leaf decode that argmaxes each clique belief consistent
//! with the states already decided (the running-intersection property
//! makes those exactly the parent separator). Ties break toward the
//! lowest mixed-radix table index, so concurrent and sequential runs
//! return byte-identical assignments.
//!
//! Every blocked path is bit-for-bit identical to the retained scalar
//! engine ([`marginals_reference`](CompiledModel::marginals_reference)
//! / [`joint_map_reference`](CompiledModel::joint_map_reference), the
//! verbatim pre-rework implementation over `kernel::reference` ops):
//! same multiplies, same accumulation order. `tests/serving.rs` pins
//! the equality to `to_bits`, which is what makes served responses
//! byte-identical before and after the kernel rework.

use anyhow::{bail, ensure, Result};

use crate::bn::DiscreteBn;
use crate::graph::moral_graph;
use crate::infer::factor::Factor;
use crate::infer::kernel::{self, reference, Split};
use crate::infer::triangulate::{triangulate, Triangulation};
use crate::infer::Posterior;
use crate::model::{Bundle, CalibratedPotentials};
use crate::util::BitSet;

/// Precompiled kernel layout for one clique of the frozen schedule:
/// the stride vectors and blocked splits every message touching this
/// clique needs, derived once at compile time.
struct CliquePlan {
    /// Natural (contiguous) strides of the clique's own table along
    /// its scope — the `a` operand of every clique-scope product.
    self_strides: Vec<usize>,
    /// Strides of the parent separator `sep[c]` along the clique scope
    /// (collect-marginalize output; down-absorb operand). All zeros at
    /// roots.
    sep_strides: Vec<usize>,
    /// `sep[c]` table size (up/down message length; 1 at roots).
    sep_size: usize,
    /// Blocked split of `sep_strides` against the clique walk.
    sep_split: Split,
    /// Aligned with `children[c]`: strides of `sep[child]` along
    /// *this* clique's scope (up-absorb operand; down-marginalize
    /// output), with their splits.
    child_strides: Vec<Vec<usize>>,
    child_splits: Vec<Split>,
}

/// A frozen, shareable compilation of one discrete Bayesian network:
/// jointree topology, CPT-assigned potentials, message schedule and
/// per-edge kernel plans.
pub struct CompiledModel {
    names: Vec<String>,
    cards: Vec<usize>,
    cliques: Vec<Vec<usize>>,
    /// Schedule parent of each clique (`None` for component roots).
    parent: Vec<Option<usize>>,
    /// Schedule children of each clique.
    children: Vec<Vec<usize>>,
    /// Separator between a clique and its schedule parent (empty for
    /// roots).
    sep: Vec<Vec<usize>>,
    /// BFS order over all components: every parent precedes its
    /// children, so iterating forward is the distribute order and
    /// backward the collect order.
    order: Vec<usize>,
    /// One root clique per tree component.
    roots: Vec<usize>,
    /// Evidence-free clique potentials (CPTs multiplied in).
    base: Vec<Factor>,
    /// For each variable, a clique containing its whole family.
    var_home: Vec<usize>,
    /// Vars homed at each clique (marginal-extraction grouping).
    home_vars: Vec<Vec<usize>>,
    /// Digit position of each variable inside its home clique's scope.
    var_pos: Vec<usize>,
    /// Per-clique kernel plans, aligned with `cliques`.
    plans: Vec<CliquePlan>,
    /// Largest clique table size (work-buffer length).
    max_table: usize,
    max_clique_states: u64,
    /// Shipped evidence-free collect messages (bundle warm start):
    /// every fresh scratch is seeded with these instead of an
    /// all-dirty cache, so the first queries skip the cold collect
    /// sweep entirely. `None` = cold compile.
    warm: Option<WarmStart>,
}

/// The warm-start payload after validation against this model's
/// schedule: per-clique collect messages and normalizers at exactly
/// the compiled shapes.
struct WarmStart {
    up: Vec<Vec<f64>>,
    up_logz: Vec<f64>,
}

/// Per-thread propagation state: current potentials, message buffers,
/// the belief arena and the incremental-evidence cache. Every table is
/// retained between queries at its fixed compiled shape, so
/// steady-state propagation allocates nothing. Create with
/// [`CompiledModel::new_scratch`]; reuse across queries for both the
/// buffers and the collect-message cache to pay off.
pub struct Scratch {
    /// Current potentials: base × absorbed evidence indicators
    /// (clique-scope tables).
    pots: Vec<Vec<f64>>,
    /// Evidence pairs currently absorbed into each clique (sorted).
    clique_ev: Vec<Vec<(usize, usize)>>,
    /// Cached collect messages clique → schedule parent (valid iff
    /// `!dirty`).
    up: Vec<Vec<f64>>,
    /// Log-normalizer of each cached collect message.
    up_logz: Vec<f64>,
    /// Is `up[c]` stale relative to `pots`?
    dirty: Vec<bool>,
    /// Distribute messages schedule-parent → clique (rebuilt per
    /// query).
    down: Vec<Vec<f64>>,
    /// Per-clique beliefs for the current query.
    bel: Vec<Vec<f64>>,
    /// Is `bel[c]` valid for the current query?
    bel_ok: Vec<bool>,
    /// Shared clique-sized product buffer.
    work: Vec<f64>,
    /// Canonical (sorted) evidence currently absorbed.
    evidence: Vec<(usize, usize)>,
    /// Reusable temporaries for evidence canonicalization.
    ev_tmp: Vec<(usize, usize)>,
    touched_tmp: Vec<usize>,
    cev_tmp: Vec<(usize, usize)>,
    /// Max-product message / clique-product arenas, sized lazily by
    /// the first `joint_map` on this scratch.
    max_up: Vec<Vec<f64>>,
    max_prod: Vec<Vec<f64>>,
    /// Collect messages recomputed on this scratch so far (the
    /// warm-start probe: a bundle-seeded scratch answers its first
    /// evidence-free query at exactly zero).
    collect_recomputes: u64,
    /// Optional span recorder: propagations on this scratch emit
    /// `collect` / `distribute` spans into it
    /// ([`Scratch::attach_tracer`]). `None` (and a disabled handle)
    /// cost one branch per query.
    trace: Option<crate::obs::TraceHandle>,
}

impl Scratch {
    /// A scratch with no buffers, for engines that never propagate
    /// (the sampling fallback).
    pub fn empty() -> Scratch {
        Scratch {
            pots: Vec::new(),
            clique_ev: Vec::new(),
            up: Vec::new(),
            up_logz: Vec::new(),
            dirty: Vec::new(),
            down: Vec::new(),
            bel: Vec::new(),
            bel_ok: Vec::new(),
            work: Vec::new(),
            evidence: Vec::new(),
            ev_tmp: Vec::new(),
            touched_tmp: Vec::new(),
            cev_tmp: Vec::new(),
            max_up: Vec::new(),
            max_prod: Vec::new(),
            collect_recomputes: 0,
            trace: None,
        }
    }

    /// How many collect messages this scratch has recomputed since
    /// creation. A warm-started scratch
    /// ([`CompiledModel::from_bundle`]) serves its first evidence-free
    /// query without recomputing any — the probe
    /// `tests/serving.rs` pins.
    pub fn collect_recomputes(&self) -> u64 {
        self.collect_recomputes
    }

    /// Attach a span recorder: subsequent propagations on this scratch
    /// emit `collect` / `distribute` spans (category `jointree`) into
    /// the handle's lane. The serving threads attach one per scratch;
    /// when the handle's tracer is disabled every probe is a single
    /// relaxed atomic load, so attaching a disabled handle is free.
    pub fn attach_tracer(&mut self, th: crate::obs::TraceHandle) {
        self.trace = Some(th);
    }
}

impl CompiledModel {
    /// Compile `bn` (moralizes and triangulates internally).
    pub fn compile(bn: &DiscreteBn) -> Result<CompiledModel> {
        let tri = triangulate(&moral_graph(&bn.dag), &bn.cards);
        Self::compile_from(bn, tri)
    }

    /// Compile from a precomputed triangulation of `bn`'s moral graph
    /// (budget probes reuse their triangulation instead of running
    /// min-fill twice).
    pub fn compile_from(bn: &DiscreteBn, tri: Triangulation) -> Result<CompiledModel> {
        let n = bn.n();
        ensure!(n > 0, "cannot compile a model over zero variables");
        let cards: Vec<usize> = bn.cards.iter().map(|&c| c as usize).collect();
        let cliques = tri.cliques;
        let nc = cliques.len();
        let clique_sets: Vec<BitSet> =
            cliques.iter().map(|c| BitSet::from_iter(n, c.iter().copied())).collect();

        // Maximum-weight spanning forest over separator sizes (Kruskal):
        // on a chordal graph's maximal cliques this yields a valid
        // junction tree (running intersection property).
        let mut candidates: Vec<(usize, usize, usize)> = Vec::new(); // (sep_size, i, j)
        for i in 0..nc {
            for j in (i + 1)..nc {
                let s = clique_sets[i].intersection(&clique_sets[j]).count();
                if s > 0 {
                    candidates.push((s, i, j));
                }
            }
        }
        candidates.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));

        let mut uf: Vec<usize> = (0..nc).collect();
        fn find(uf: &mut [usize], mut x: usize) -> usize {
            while uf[x] != x {
                uf[x] = uf[uf[x]];
                x = uf[x];
            }
            x
        }
        let mut adjacency: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); nc];
        for (_, i, j) in candidates {
            let (ri, rj) = (find(&mut uf, i), find(&mut uf, j));
            if ri == rj {
                continue;
            }
            uf[ri] = rj;
            let s: Vec<usize> = clique_sets[i].intersection(&clique_sets[j]).to_vec();
            adjacency[i].push((j, s.clone()));
            adjacency[j].push((i, s));
        }

        // Freeze the message schedule: root every component at its
        // lowest-index clique and BFS, so parents always precede
        // children in `order`.
        let mut parent: Vec<Option<usize>> = vec![None; nc];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); nc];
        let mut sep: Vec<Vec<usize>> = vec![Vec::new(); nc];
        let mut order: Vec<usize> = Vec::with_capacity(nc);
        let mut roots: Vec<usize> = Vec::new();
        let mut visited = vec![false; nc];
        for r in 0..nc {
            if visited[r] {
                continue;
            }
            visited[r] = true;
            roots.push(r);
            let mut head = order.len();
            order.push(r);
            while head < order.len() {
                let c = order[head];
                head += 1;
                for (o, s) in &adjacency[c] {
                    if !visited[*o] {
                        visited[*o] = true;
                        parent[*o] = Some(c);
                        children[c].push(*o);
                        sep[*o] = s.clone();
                        order.push(*o);
                    }
                }
            }
        }

        // Assign each family to the smallest containing clique and
        // multiply its CPT in.
        let mut base: Vec<Factor> =
            cliques.iter().map(|c| Factor::ones(c.clone(), &bn.cards)).collect();
        let mut var_home = vec![usize::MAX; n];
        for v in 0..n {
            let mut fam = BitSet::new(n);
            fam.insert(v);
            fam.union_with(bn.dag.parents(v));
            let mut chosen: Option<(u64, usize)> = None; // (state space, clique)
            for (ci, cs) in clique_sets.iter().enumerate() {
                if !fam.is_subset(cs) {
                    continue;
                }
                let weight = cliques[ci]
                    .iter()
                    .fold(1u64, |acc, &x| acc.saturating_mul(cards[x] as u64));
                let better = match chosen {
                    None => true,
                    Some((w, _)) => weight < w,
                };
                if better {
                    chosen = Some((weight, ci));
                }
            }
            let Some((_, ci)) = chosen else {
                bail!("family of variable {v} fits no clique — triangulation is inconsistent");
            };
            var_home[v] = ci;
            base[ci] = Factor::product(&base[ci], &Factor::from_cpt(bn, v));
        }

        // Precompile the kernel plans: one stride vector + split per
        // schedule edge, so queries never call `subset_strides_into`.
        let mut plans: Vec<CliquePlan> = Vec::with_capacity(nc);
        for c in 0..nc {
            let cvars = &cliques[c];
            let ccards = &base[c].cards;
            let mut self_strides = Vec::new();
            kernel::subset_strides_into(cvars, ccards, cvars, &mut self_strides);
            let mut sep_strides = Vec::new();
            kernel::subset_strides_into(cvars, ccards, &sep[c], &mut sep_strides);
            let sep_size: usize = sep[c].iter().map(|&v| cards[v]).product();
            let sep_split = Split::of(ccards, &sep_strides);
            let mut child_strides: Vec<Vec<usize>> = Vec::with_capacity(children[c].len());
            let mut child_splits: Vec<Split> = Vec::with_capacity(children[c].len());
            for &k in &children[c] {
                let mut s = Vec::new();
                kernel::subset_strides_into(cvars, ccards, &sep[k], &mut s);
                child_splits.push(Split::of(ccards, &s));
                child_strides.push(s);
            }
            plans.push(CliquePlan {
                self_strides,
                sep_strides,
                sep_size,
                sep_split,
                child_strides,
                child_splits,
            });
        }

        let mut home_vars: Vec<Vec<usize>> = vec![Vec::new(); nc];
        let mut var_pos = vec![0usize; n];
        for v in 0..n {
            let c = var_home[v];
            home_vars[c].push(v);
            var_pos[v] = cliques[c].binary_search(&v).expect("home clique contains the variable");
        }
        let max_table = base.iter().map(|f| f.table.len()).max().unwrap_or(1);

        Ok(CompiledModel {
            names: bn.names.clone(),
            cards,
            cliques,
            parent,
            children,
            sep,
            order,
            roots,
            base,
            var_home,
            home_vars,
            var_pos,
            plans,
            max_table,
            max_clique_states: tri.max_clique_states,
            warm: None,
        })
    }

    /// Compile `bundle.bn` and warm-start from its shipped calibrated
    /// potentials when the schedule fingerprint matches this build's
    /// compile (same triangulation, schedule and parameters) — every
    /// fresh scratch then starts with a valid evidence-free collect
    /// cache and the first queries skip the cold sweep. On a
    /// fingerprint or shape mismatch the model silently falls back to
    /// a cold compile; answers are bit-identical either way, because
    /// shipped messages are the exact bits a local collect produces.
    pub fn from_bundle(bundle: &Bundle) -> Result<CompiledModel> {
        let tri = triangulate(&moral_graph(&bundle.bn.dag), &bundle.bn.cards);
        Self::from_bundle_from(bundle, tri)
    }

    /// [`from_bundle`](CompiledModel::from_bundle) with a precomputed
    /// triangulation (budget probes reuse theirs).
    pub fn from_bundle_from(bundle: &Bundle, tri: Triangulation) -> Result<CompiledModel> {
        let mut model = Self::compile_from(&bundle.bn, tri)?;
        if let Some(p) = &bundle.potentials {
            let nc = model.cliques.len();
            let shapes_ok = p.messages.len() == nc
                && p.logz.len() == nc
                && model.plans.iter().zip(&p.messages).all(|(plan, m)| m.len() == plan.sep_size);
            if shapes_ok && p.fingerprint == model.schedule_fingerprint() {
                model.warm =
                    Some(WarmStart { up: p.messages.clone(), up_logz: p.logz.clone() });
            }
        }
        Ok(model)
    }

    /// Did this model warm-start from shipped potentials?
    pub fn is_warm_started(&self) -> bool {
        self.warm.is_some()
    }

    /// Fingerprint of everything a shipped collect message depends on:
    /// the domain cardinalities, the clique scopes, the frozen message
    /// schedule (parents, separators, BFS order, roots) and the bit
    /// patterns of the CPT-assigned base potentials. Two compiles with
    /// equal fingerprints produce bit-identical collect messages, so a
    /// consumer can adopt shipped ones; any drift (different
    /// triangulation heuristic, edited parameters) changes the
    /// fingerprint and the consumer cold-starts instead.
    pub fn schedule_fingerprint(&self) -> u64 {
        // FNV-1a over a canonical byte walk.
        fn eat(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        fn eat_usize(h: &mut u64, x: usize) {
            eat(h, &(x as u64).to_le_bytes());
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        eat_usize(&mut h, self.cards.len());
        for &c in &self.cards {
            eat_usize(&mut h, c);
        }
        eat_usize(&mut h, self.cliques.len());
        for clique in &self.cliques {
            eat_usize(&mut h, clique.len());
            for &v in clique {
                eat_usize(&mut h, v);
            }
        }
        for p in &self.parent {
            eat_usize(&mut h, p.map_or(0, |x| x + 1));
        }
        for s in &self.sep {
            eat_usize(&mut h, s.len());
            for &v in s {
                eat_usize(&mut h, v);
            }
        }
        for &c in &self.order {
            eat_usize(&mut h, c);
        }
        for &r in &self.roots {
            eat_usize(&mut h, r);
        }
        for f in &self.base {
            eat_usize(&mut h, f.table.len());
            for &x in &f.table {
                eat(&mut h, &x.to_bits().to_le_bytes());
            }
        }
        h
    }

    /// Run the evidence-free collect pass once and export the
    /// resulting messages as a shippable warm-start payload, stamped
    /// with this model's [schedule
    /// fingerprint](CompiledModel::schedule_fingerprint). A consumer
    /// whose compile reproduces the fingerprint adopts the messages
    /// verbatim ([`from_bundle`](CompiledModel::from_bundle)).
    pub fn calibrate(&self) -> Result<CalibratedPotentials> {
        let mut s = self.new_scratch();
        self.collect(&mut s)?;
        Ok(CalibratedPotentials {
            fingerprint: self.schedule_fingerprint(),
            messages: s.up,
            logz: s.up_logz,
        })
    }

    /// Number of variables.
    pub fn n_vars(&self) -> usize {
        self.cards.len()
    }

    /// Number of cliques.
    pub fn n_cliques(&self) -> usize {
        self.cliques.len()
    }

    /// Largest clique joint state space (treewidth proxy).
    pub fn max_clique_states(&self) -> u64 {
        self.max_clique_states
    }

    /// Variable names, in network order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Cardinality of variable `v`.
    pub fn card(&self, v: usize) -> usize {
        self.cards[v]
    }

    /// Fresh propagation buffers for this model (one per serving
    /// thread; queries then need only `&self`). Every table is
    /// allocated here at its final shape — queries only overwrite. On
    /// a warm-started model the collect-message cache is seeded from
    /// the bundle's shipped potentials — exactly the state a cold
    /// scratch reaches after one evidence-free query — so the first
    /// queries recompute no collect messages.
    pub fn new_scratch(&self) -> Scratch {
        let nc = self.cliques.len();
        let (up, up_logz, dirty) = match &self.warm {
            Some(w) => (w.up.clone(), w.up_logz.clone(), vec![false; nc]),
            None => (
                self.plans.iter().map(|p| vec![0.0; p.sep_size]).collect(),
                vec![0.0; nc],
                vec![true; nc],
            ),
        };
        Scratch {
            pots: self.base.iter().map(|f| f.table.clone()).collect(),
            clique_ev: vec![Vec::new(); nc],
            up,
            up_logz,
            dirty,
            down: self.plans.iter().map(|p| vec![0.0; p.sep_size]).collect(),
            bel: self.base.iter().map(|f| vec![0.0; f.table.len()]).collect(),
            bel_ok: vec![false; nc],
            work: vec![0.0; self.max_table],
            evidence: Vec::new(),
            ev_tmp: Vec::new(),
            touched_tmp: Vec::new(),
            cev_tmp: Vec::new(),
            max_up: Vec::new(),
            max_prod: Vec::new(),
            collect_recomputes: 0,
            trace: None,
        }
    }

    /// Range-check an evidence list (shared by the blocked and
    /// reference paths so both reject with identical wording).
    fn validate_evidence(&self, evidence: &[(usize, usize)]) -> Result<()> {
        let n = self.cards.len();
        for &(v, st) in evidence {
            ensure!(v < n, "evidence variable {v} out of range (n = {n})");
            ensure!(
                st < self.cards[v],
                "evidence state {st} out of range for variable {v} (cardinality {})",
                self.cards[v]
            );
        }
        Ok(())
    }

    /// Absorb `evidence` into the scratch potentials, invalidating
    /// exactly the cached collect messages whose subtree changed.
    /// Allocation-free in steady state: potentials are rebuilt in
    /// place (base copy + indicator masks) and the canonicalization
    /// temporaries live in the scratch.
    fn set_evidence(&self, s: &mut Scratch, evidence: &[(usize, usize)]) -> Result<()> {
        self.validate_evidence(evidence)?;
        s.ev_tmp.clear();
        s.ev_tmp.extend_from_slice(evidence);
        s.ev_tmp.sort_unstable();
        if s.ev_tmp == s.evidence {
            return Ok(());
        }
        // Cliques whose absorbed indicators may differ between the old
        // and new evidence sets.
        s.touched_tmp.clear();
        {
            let homes = s.ev_tmp.iter().chain(s.evidence.iter()).map(|&(v, _)| self.var_home[v]);
            s.touched_tmp.extend(homes);
        }
        s.touched_tmp.sort_unstable();
        s.touched_tmp.dedup();
        for &c in &s.touched_tmp {
            s.cev_tmp.clear();
            s.cev_tmp.extend(s.ev_tmp.iter().copied().filter(|&(v, _)| self.var_home[v] == c));
            if s.cev_tmp == s.clique_ev[c] {
                continue;
            }
            let base = &self.base[c];
            s.pots[c].copy_from_slice(&base.table);
            let pot = &mut s.pots[c];
            for &(v, st) in &s.cev_tmp {
                kernel::mask_assign(pot, &base.cards, self.var_pos[v], st);
            }
            // Copy rather than swap: each per-clique list keeps its own
            // monotone capacity, so steady state stays allocation-free.
            s.clique_ev[c].clear();
            s.clique_ev[c].extend_from_slice(&s.cev_tmp);
            // Invalidate every collect message between c and its root.
            // Dirtiness is kept upward-closed along schedule paths, so
            // the walk can stop at the first already-dirty hop.
            let mut x = c;
            loop {
                if s.dirty[x] {
                    break;
                }
                s.dirty[x] = true;
                match self.parent[x] {
                    Some(p) => x = p,
                    None => break,
                }
            }
        }
        std::mem::swap(&mut s.evidence, &mut s.ev_tmp);
        Ok(())
    }

    /// Collect pass: recompute only the stale messages (leaves toward
    /// roots), reusing every cached message whose subtree evidence is
    /// unchanged. Each message is produced by the fused
    /// absorb-and-marginalize kernel — the full clique product is
    /// materialized (into the shared work table) only when a clique
    /// has three or more incoming factors.
    fn collect(&self, s: &mut Scratch) -> Result<()> {
        for &c in self.order.iter().rev() {
            if self.parent[c].is_none() {
                s.dirty[c] = false;
                continue;
            }
            if !s.dirty[c] {
                continue;
            }
            s.collect_recomputes += 1;
            let plan = &self.plans[c];
            let kids = &self.children[c];
            let cards = &self.base[c].cards;
            // Buffers keep their compiled shape across queries (and
            // across bails — every early return puts them back), so
            // the kernels can overwrite without a redundant zero pass.
            let mut msg = std::mem::take(&mut s.up[c]);
            debug_assert_eq!(msg.len(), plan.sep_size);
            match kids.len() {
                0 => kernel::marginalize_into(
                    &mut msg,
                    &s.pots[c],
                    cards,
                    &plan.sep_strides,
                    plan.sep_split,
                    false,
                ),
                1 => kernel::absorb_marginalize_into(
                    &mut msg,
                    &s.pots[c],
                    &s.up[kids[0]],
                    cards,
                    &plan.child_strides[0],
                    &plan.sep_strides,
                    false,
                ),
                m => {
                    let tlen = s.pots[c].len();
                    let w = &mut s.work[..tlen];
                    kernel::product_into(
                        w,
                        &s.pots[c],
                        &s.up[kids[0]],
                        cards,
                        &plan.self_strides,
                        &plan.child_strides[0],
                    );
                    for j in 1..m - 1 {
                        kernel::mul_assign(
                            w,
                            &s.up[kids[j]],
                            cards,
                            &plan.child_strides[j],
                            plan.child_splits[j],
                        );
                    }
                    kernel::absorb_marginalize_into(
                        &mut msg,
                        w,
                        &s.up[kids[m - 1]],
                        cards,
                        &plan.child_strides[m - 1],
                        &plan.sep_strides,
                        false,
                    );
                }
            }
            let z: f64 = msg.iter().sum();
            if z <= 0.0 {
                s.up[c] = msg;
                bail!("evidence has probability zero");
            }
            let inv = 1.0 / z;
            msg.iter_mut().for_each(|x| *x *= inv);
            s.up_logz[c] = z.ln();
            s.up[c] = msg;
            s.dirty[c] = false;
        }
        Ok(())
    }

    /// Build clique `c`'s belief (pots × parent down-message × child
    /// up-messages, in the reference multiplication order) into the
    /// scratch belief arena.
    fn belief_into(&self, s: &mut Scratch, c: usize) {
        let plan = &self.plans[c];
        let kids = &self.children[c];
        let cards = &self.base[c].cards;
        let mut b = std::mem::take(&mut s.bel[c]);
        debug_assert_eq!(b.len(), s.pots[c].len());
        let has_down = self.parent[c].is_some();
        if !has_down && kids.is_empty() {
            b.copy_from_slice(&s.pots[c]);
        } else {
            let (m0, s0): (&[f64], &[usize]) = if has_down {
                (&s.down[c], &plan.sep_strides)
            } else {
                (&s.up[kids[0]], &plan.child_strides[0])
            };
            kernel::product_into(&mut b, &s.pots[c], m0, cards, &plan.self_strides, s0);
            let start = if has_down { 0 } else { 1 };
            for j in start..kids.len() {
                kernel::mul_assign(
                    &mut b,
                    &s.up[kids[j]],
                    cards,
                    &plan.child_strides[j],
                    plan.child_splits[j],
                );
            }
        }
        s.bel[c] = b;
    }

    /// Exact posterior over every variable given `evidence`
    /// (`(variable, state)` pairs). Errors on out-of-range evidence or
    /// evidence of probability zero. Lock-free (`&self` plus the
    /// caller's scratch) and allocation-free in the kernel path — only
    /// the returned [`Posterior`] owns fresh memory.
    pub fn marginals(&self, s: &mut Scratch, evidence: &[(usize, usize)]) -> Result<Posterior> {
        self.set_evidence(s, evidence)?;
        let t_collect = s.trace.as_ref().and_then(crate::obs::TraceHandle::start);
        self.collect(s)?;
        if let Some(th) = s.trace.as_mut() {
            th.end(t_collect, "collect", "jointree");
        }

        // Message normalizers plus the root belief masses telescope to
        // P(evidence), in log space. Root beliefs land in the arena —
        // the marginal pass below reuses them.
        let mut log_evidence: f64 = self
            .order
            .iter()
            .filter(|&&c| self.parent[c].is_some())
            .map(|&c| s.up_logz[c])
            .sum();
        s.bel_ok.fill(false);
        for &r in &self.roots {
            self.belief_into(s, r);
            let z: f64 = s.bel[r].iter().sum();
            if z <= 0.0 {
                bail!("evidence has probability zero");
            }
            log_evidence += z.ln();
            s.bel_ok[r] = true;
        }

        // Distribute pass, roots toward leaves. Not cached: each
        // message folds in every other branch of the tree, so almost
        // any evidence change would invalidate it anyway. The fused
        // kernel computes each message without materializing the
        // clique product unless ≥ 2 absorbs precede the marginalize.
        let t_dist = s.trace.as_ref().and_then(crate::obs::TraceHandle::start);
        for &c in &self.order {
            let kids = &self.children[c];
            if kids.is_empty() {
                continue;
            }
            let plan = &self.plans[c];
            let cards = &self.base[c].cards;
            let has_down = self.parent[c].is_some();
            for ki in 0..kids.len() {
                let k = kids[ki];
                let mut msg = std::mem::take(&mut s.down[k]);
                debug_assert_eq!(msg.len(), self.plans[k].sep_size);
                let last_sib = (0..kids.len()).rev().find(|&j| j != ki);
                let nops = has_down as usize + kids.len() - 1;
                if nops == 0 {
                    kernel::marginalize_into(
                        &mut msg,
                        &s.pots[c],
                        cards,
                        &plan.child_strides[ki],
                        plan.child_splits[ki],
                        false,
                    );
                } else if nops == 1 {
                    let (m0, s0): (&[f64], &[usize]) = if has_down {
                        (&s.down[c], &plan.sep_strides)
                    } else {
                        let j = last_sib.expect("one sibling operand");
                        (&s.up[kids[j]], &plan.child_strides[j])
                    };
                    kernel::absorb_marginalize_into(
                        &mut msg,
                        &s.pots[c],
                        m0,
                        cards,
                        s0,
                        &plan.child_strides[ki],
                        false,
                    );
                } else {
                    let tlen = s.pots[c].len();
                    let w = &mut s.work[..tlen];
                    let mut first = true;
                    if has_down {
                        kernel::product_into(
                            w,
                            &s.pots[c],
                            &s.down[c],
                            cards,
                            &plan.self_strides,
                            &plan.sep_strides,
                        );
                        first = false;
                    }
                    let last = last_sib.expect("nops >= 2 implies a sibling");
                    for j in 0..kids.len() {
                        if j == ki || j == last {
                            continue;
                        }
                        if first {
                            kernel::product_into(
                                w,
                                &s.pots[c],
                                &s.up[kids[j]],
                                cards,
                                &plan.self_strides,
                                &plan.child_strides[j],
                            );
                            first = false;
                        } else {
                            kernel::mul_assign(
                                w,
                                &s.up[kids[j]],
                                cards,
                                &plan.child_strides[j],
                                plan.child_splits[j],
                            );
                        }
                    }
                    kernel::absorb_marginalize_into(
                        &mut msg,
                        w,
                        &s.up[kids[last]],
                        cards,
                        &plan.child_strides[last],
                        &plan.child_strides[ki],
                        false,
                    );
                }
                let z: f64 = msg.iter().sum();
                if z <= 0.0 {
                    s.down[k] = msg;
                    bail!("evidence has probability zero");
                }
                let inv = 1.0 / z;
                msg.iter_mut().for_each(|x| *x *= inv);
                s.down[k] = msg;
            }
        }
        if let Some(th) = s.trace.as_mut() {
            th.end(t_dist, "distribute", "jointree");
        }

        // Calibrated beliefs → all single-variable marginals, built
        // clique by clique so each belief is assembled exactly once.
        let n = self.cards.len();
        let mut marginals: Vec<Vec<f64>> = vec![Vec::new(); n];
        for &c in &self.order {
            if self.home_vars[c].is_empty() {
                continue;
            }
            if !s.bel_ok[c] {
                self.belief_into(s, c);
                s.bel_ok[c] = true;
            }
            for &v in &self.home_vars[c] {
                let mut mv = vec![0.0; self.cards[v]];
                let (bel, cc) = (&s.bel[c], &self.base[c].cards);
                kernel::single_marginal_into(&mut mv, bel, cc, self.var_pos[v]);
                let z: f64 = mv.iter().sum();
                if z > 0.0 {
                    let inv = 1.0 / z;
                    mv.iter_mut().for_each(|x| *x *= inv);
                }
                marginals[v] = mv;
            }
        }

        Ok(Posterior { marginals, log_evidence })
    }

    /// Exact joint MAP: the single complete assignment maximizing
    /// P(x | evidence), with `ln max_x P(x, evidence)`. Max-product
    /// collect over the compiled tree, then a root-to-leaf decode; the
    /// returned assignment always agrees with the evidence. Per-clique
    /// ties break toward the lowest mixed-radix cell
    /// (see [`kernel::argmax_consistent`]), deterministically. The
    /// max-product tables live in a scratch arena sized by the first
    /// call, so repeated MAP queries allocate nothing but the result.
    pub fn joint_map(
        &self,
        s: &mut Scratch,
        evidence: &[(usize, usize)],
    ) -> Result<(Vec<usize>, f64)> {
        self.set_evidence(s, evidence)?;
        let nc = self.cliques.len();
        if s.max_prod.len() != nc {
            s.max_prod = self.base.iter().map(|f| vec![0.0; f.table.len()]).collect();
            s.max_up = self.plans.iter().map(|p| vec![0.0; p.sep_size]).collect();
        }

        // Max-product collect. Own message buffers: a different
        // semiring than the cached sum-product sweep (the sum cache
        // stays valid — both read the same absorbed potentials). The
        // pre-marginalization clique products are kept: the decode
        // pass below argmaxes exactly these, so recomputing them would
        // double the factor-product work per query.
        let mut log_map = 0.0f64;
        for &c in self.order.iter().rev() {
            let plan = &self.plans[c];
            let kids = &self.children[c];
            let cards = &self.base[c].cards;
            let mut prod = std::mem::take(&mut s.max_prod[c]);
            debug_assert_eq!(prod.len(), s.pots[c].len());
            if kids.is_empty() {
                prod.copy_from_slice(&s.pots[c]);
            } else {
                kernel::product_into(
                    &mut prod,
                    &s.pots[c],
                    &s.max_up[kids[0]],
                    cards,
                    &plan.self_strides,
                    &plan.child_strides[0],
                );
                for j in 1..kids.len() {
                    kernel::mul_assign(
                        &mut prod,
                        &s.max_up[kids[j]],
                        cards,
                        &plan.child_strides[j],
                        plan.child_splits[j],
                    );
                }
            }
            if self.parent[c].is_some() {
                let mut msg = std::mem::take(&mut s.max_up[c]);
                debug_assert_eq!(msg.len(), plan.sep_size);
                kernel::marginalize_into(
                    &mut msg,
                    &prod,
                    cards,
                    &plan.sep_strides,
                    plan.sep_split,
                    true,
                );
                let z = msg.iter().fold(0.0f64, |a, &b| a.max(b));
                if z <= 0.0 {
                    s.max_up[c] = msg;
                    s.max_prod[c] = prod;
                    bail!("evidence has probability zero");
                }
                let inv = 1.0 / z;
                msg.iter_mut().for_each(|x| *x *= inv);
                log_map += z.ln();
                s.max_up[c] = msg;
            }
            s.max_prod[c] = prod;
        }

        // Decode, roots toward leaves: argmax each clique product
        // consistent with the states already decided. By the running
        // intersection property the decided variables of a clique are
        // exactly its parent separator, so any consistent argmax
        // extends to a global maximizer.
        let n = self.cards.len();
        let mut assign: Vec<Option<usize>> = vec![None; n];
        let mut digits = [0usize; kernel::MAX_DIGITS];
        for &c in &self.order {
            let cv = &self.cliques[c];
            let val = kernel::argmax_consistent(
                cv,
                &self.base[c].cards,
                &s.max_prod[c],
                &assign,
                &mut digits[..cv.len()],
            );
            if val <= 0.0 {
                bail!("evidence has probability zero");
            }
            if self.parent[c].is_none() {
                // Root maxima close each component's MAP mass; inner
                // cliques' mass is already inside the messages.
                log_map += val.ln();
            }
            for (i, &v) in cv.iter().enumerate() {
                assign[v] = Some(digits[i]);
            }
        }
        let assignment: Vec<usize> =
            assign.into_iter().map(|a| a.expect("every variable lives in a clique")).collect();
        Ok((assignment, log_map))
    }

    /// The pre-rework scalar engine path, retained verbatim as the
    /// pinning oracle for the blocked kernels: fresh clone-and-allocate
    /// `kernel::reference` operations, no cache, no plans, no arena.
    /// `tests/serving.rs` asserts [`marginals`](CompiledModel::marginals)
    /// matches this bit-for-bit; `benches/kernels.rs` measures the
    /// speedup against it. Not a serving path.
    pub fn marginals_reference(&self, evidence: &[(usize, usize)]) -> Result<Posterior> {
        self.validate_evidence(evidence)?;
        let nc = self.cliques.len();
        let mut pots: Vec<Factor> = self.base.clone();
        for &(v, st) in evidence {
            let c = self.var_home[v];
            pots[c] = reference::product(&pots[c], &Factor::indicator(v, self.cards[v], st));
        }

        let mut up: Vec<Option<Factor>> = vec![None; nc];
        let mut up_logz = vec![0.0f64; nc];
        for &c in self.order.iter().rev() {
            if self.parent[c].is_none() {
                continue;
            }
            let mut f = pots[c].clone();
            for &k in &self.children[c] {
                f = reference::product(&f, up[k].as_ref().expect("child collect message ready"));
            }
            let mut m = reference::marginalize_to(&f, &self.sep[c]);
            let z = m.normalize();
            if z <= 0.0 {
                bail!("evidence has probability zero");
            }
            up_logz[c] = z.ln();
            up[c] = Some(m);
        }

        let mut log_evidence: f64 = self
            .order
            .iter()
            .filter(|&&c| self.parent[c].is_some())
            .map(|&c| up_logz[c])
            .sum();
        for &r in &self.roots {
            let mut b = pots[r].clone();
            for &k in &self.children[r] {
                b = reference::product(&b, up[k].as_ref().expect("root message ready"));
            }
            let z = b.total();
            if z <= 0.0 {
                bail!("evidence has probability zero");
            }
            log_evidence += z.ln();
        }

        let mut down: Vec<Option<Factor>> = vec![None; nc];
        for &c in &self.order {
            for &k in &self.children[c] {
                let mut f = pots[c].clone();
                if self.parent[c].is_some() {
                    f = reference::product(&f, down[c].as_ref().expect("parent message ready"));
                }
                for &k2 in &self.children[c] {
                    if k2 == k {
                        continue;
                    }
                    f = reference::product(&f, up[k2].as_ref().expect("sibling message ready"));
                }
                let mut m = reference::marginalize_to(&f, &self.sep[k]);
                if m.normalize() <= 0.0 {
                    bail!("evidence has probability zero");
                }
                down[k] = Some(m);
            }
        }

        let n = self.cards.len();
        let mut beliefs: Vec<Option<Factor>> = vec![None; nc];
        let mut marginals: Vec<Vec<f64>> = Vec::with_capacity(n);
        for v in 0..n {
            let c = self.var_home[v];
            if beliefs[c].is_none() {
                let mut b = pots[c].clone();
                if self.parent[c].is_some() {
                    b = reference::product(&b, down[c].as_ref().expect("down message ready"));
                }
                for &k in &self.children[c] {
                    b = reference::product(&b, up[k].as_ref().expect("up message ready"));
                }
                beliefs[c] = Some(b);
            }
            let b = beliefs[c].as_ref().expect("belief just built");
            let mut m = reference::marginalize_to(b, &[v]);
            m.normalize();
            marginals.push(m.table);
        }

        Ok(Posterior { marginals, log_evidence })
    }

    /// Scalar-reference joint MAP, the oracle counterpart of
    /// [`joint_map`](CompiledModel::joint_map) (see
    /// [`marginals_reference`](CompiledModel::marginals_reference)).
    pub fn joint_map_reference(&self, evidence: &[(usize, usize)]) -> Result<(Vec<usize>, f64)> {
        self.validate_evidence(evidence)?;
        let nc = self.cliques.len();
        let mut pots: Vec<Factor> = self.base.clone();
        for &(v, st) in evidence {
            let c = self.var_home[v];
            pots[c] = reference::product(&pots[c], &Factor::indicator(v, self.cards[v], st));
        }

        let mut up: Vec<Option<Factor>> = vec![None; nc];
        let mut prods: Vec<Option<Factor>> = vec![None; nc];
        let mut log_map = 0.0f64;
        for &c in self.order.iter().rev() {
            let mut f = pots[c].clone();
            for &k in &self.children[c] {
                f = reference::product(&f, up[k].as_ref().expect("child max-message ready"));
            }
            if self.parent[c].is_some() {
                let mut m = reference::max_marginalize_to(&f, &self.sep[c]);
                let z = m.table.iter().fold(0.0f64, |a, &b| a.max(b));
                if z <= 0.0 {
                    bail!("evidence has probability zero");
                }
                let inv = 1.0 / z;
                m.table.iter_mut().for_each(|x| *x *= inv);
                log_map += z.ln();
                up[c] = Some(m);
            }
            prods[c] = Some(f);
        }

        let n = self.cards.len();
        let mut assign: Vec<Option<usize>> = vec![None; n];
        for &c in &self.order {
            let b = prods[c].as_ref().expect("clique max-product ready");
            let (digits, val) = reference::argmax_consistent(b, &assign);
            if val <= 0.0 {
                bail!("evidence has probability zero");
            }
            if self.parent[c].is_none() {
                log_map += val.ln();
            }
            for (&v, &d) in b.vars.iter().zip(&digits) {
                assign[v] = Some(d);
            }
        }
        let assignment: Vec<usize> =
            assign.into_iter().map(|a| a.expect("every variable lives in a clique")).collect();
        Ok((assignment, log_map))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn compiled_model_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<CompiledModel>();
    }

    #[test]
    fn marginals_match_jointree_semantics() {
        let bn = tiny_bn();
        let m = CompiledModel::compile(&bn).unwrap();
        let mut s = m.new_scratch();
        let post = m.marginals(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
        assert!((post.marginal(1)[0] - 0.69).abs() < 1e-12);
        assert!(post.log_evidence.abs() < 1e-12);

        let post = m.marginals(&mut s, &[(1, 1)]).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8;
        assert!((post.log_evidence - pe.ln()).abs() < 1e-12);
        assert!((post.marginal(0)[0] - 0.07 / pe).abs() < 1e-12);

        // Back to no evidence on the same scratch: the cache must not
        // leak the old indicators.
        let post = m.marginals(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
        assert!(post.log_evidence.abs() < 1e-12);
    }

    #[test]
    fn blocked_path_is_bit_identical_to_reference() {
        let bn = tiny_bn();
        let m = CompiledModel::compile(&bn).unwrap();
        let mut s = m.new_scratch();
        for ev in [vec![], vec![(1usize, 1usize)], vec![(0, 0)], vec![]] {
            let got = m.marginals(&mut s, &ev).unwrap();
            let want = m.marginals_reference(&ev).unwrap();
            assert_eq!(got.log_evidence.to_bits(), want.log_evidence.to_bits());
            for v in 0..2 {
                for (a, b) in got.marginal(v).iter().zip(want.marginal(v)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "var {v}: {a} vs {b}");
                }
            }
            let (ga, gl) = m.joint_map(&mut s, &ev).unwrap();
            let (wa, wl) = m.joint_map_reference(&ev).unwrap();
            assert_eq!(ga, wa);
            assert_eq!(gl.to_bits(), wl.to_bits());
        }
    }

    #[test]
    fn joint_map_on_tiny_bn() {
        // Joint probabilities: (0,0)=0.63 (0,1)=0.07 (1,0)=0.06 (1,1)=0.24.
        let bn = tiny_bn();
        let m = CompiledModel::compile(&bn).unwrap();
        let mut s = m.new_scratch();
        let (x, lp) = m.joint_map(&mut s, &[]).unwrap();
        assert_eq!(x, vec![0, 0]);
        assert!((lp - 0.63f64.ln()).abs() < 1e-12);

        // Conditioning on b=1 flips the maximizer to (1,1).
        let (x, lp) = m.joint_map(&mut s, &[(1, 1)]).unwrap();
        assert_eq!(x, vec![1, 1]);
        assert!((lp - 0.24f64.ln()).abs() < 1e-12);
    }

    /// Three-node chain `a -> b -> c`: moralizes to two cliques, so
    /// the collect pass actually sends a message (tiny_bn compiles to
    /// a single clique and never would).
    fn chain_bn() -> crate::bn::DiscreteBn {
        use crate::bn::Cpt;
        crate::bn::DiscreteBn {
            dag: crate::graph::Dag::from_edges(3, &[(0, 1), (1, 2)]),
            names: vec!["a".into(), "b".into(), "c".into()],
            cards: vec![2, 2, 2],
            cpts: vec![
                Cpt { parents: vec![], table: vec![0.6, 0.4], r: 2 },
                Cpt { parents: vec![0], table: vec![0.7, 0.3, 0.2, 0.8], r: 2 },
                Cpt { parents: vec![1], table: vec![0.9, 0.1, 0.4, 0.6], r: 2 },
            ],
        }
    }

    #[test]
    fn warm_start_adopts_matching_potentials_and_refuses_foreign_ones() {
        use crate::model::{Bundle, BundleMeta};

        let bn = chain_bn();
        bn.validate().unwrap();
        let cold = CompiledModel::compile(&bn).unwrap();
        let meta = BundleMeta { producer: "t".into(), rounds: 0, score: 0.0, ess: 1.0 };
        let bundle = Bundle::calibrated_within(bn.clone(), meta, u64::MAX);
        assert!(bundle.has_potentials());

        let warm = CompiledModel::from_bundle(&bundle).unwrap();
        assert!(warm.is_warm_started());
        assert_eq!(warm.schedule_fingerprint(), cold.schedule_fingerprint());

        // First evidence-free query: zero collect recomputation, yet
        // bit-identical to the cold model.
        let mut ws = warm.new_scratch();
        let mut cs = cold.new_scratch();
        let got = warm.marginals(&mut ws, &[]).unwrap();
        assert_eq!(ws.collect_recomputes(), 0);
        let want = cold.marginals(&mut cs, &[]).unwrap();
        assert!(cs.collect_recomputes() > 0);
        assert_eq!(got.log_evidence.to_bits(), want.log_evidence.to_bits());
        for v in 0..3 {
            for (a, b) in got.marginal(v).iter().zip(want.marginal(v)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }

        // Evidence queries on the warm scratch recompute only the
        // invalidated paths and stay bit-identical. Evidence lands in
        // both cliques, so at least the non-root one resends.
        let got = warm.marginals(&mut ws, &[(0, 1), (2, 1)]).unwrap();
        let want = cold.marginals(&mut cs, &[(0, 1), (2, 1)]).unwrap();
        assert!(ws.collect_recomputes() > 0);
        assert_eq!(got.log_evidence.to_bits(), want.log_evidence.to_bits());

        // A tampered fingerprint falls back to a cold compile.
        let mut foreign = bundle.clone();
        foreign.potentials.as_mut().unwrap().fingerprint ^= 1;
        let fallback = CompiledModel::from_bundle(&foreign).unwrap();
        assert!(!fallback.is_warm_started());
        let mut fs = fallback.new_scratch();
        let p = fallback.marginals(&mut fs, &[]).unwrap();
        let want = cold.marginals(&mut cold.new_scratch(), &[]).unwrap();
        assert_eq!(p.log_evidence.to_bits(), want.log_evidence.to_bits());
        assert!(fs.collect_recomputes() > 0);
    }

    #[test]
    fn fingerprint_tracks_parameters_and_structure() {
        let bn = tiny_bn();
        let a = CompiledModel::compile(&bn).unwrap();
        let mut edited = bn.clone();
        edited.cpts[0].table = vec![0.6, 0.4];
        let b = CompiledModel::compile(&edited).unwrap();
        assert_ne!(a.schedule_fingerprint(), b.schedule_fingerprint());
        let c = CompiledModel::compile(&bn).unwrap();
        assert_eq!(a.schedule_fingerprint(), c.schedule_fingerprint());
    }

    #[test]
    fn rejects_bad_and_zero_probability_evidence() {
        let bn = tiny_bn();
        let m = CompiledModel::compile(&bn).unwrap();
        let mut s = m.new_scratch();
        assert!(m.marginals(&mut s, &[(5, 0)]).is_err());
        assert!(m.marginals(&mut s, &[(0, 9)]).is_err());
        assert!(m.marginals(&mut s, &[(0, 0), (0, 1)]).is_err());
        assert!(m.joint_map(&mut s, &[(0, 0), (0, 1)]).is_err());
        // The scratch stays usable after a zero-probability bail.
        let post = m.marginals(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
    }
}
