//! The compiled-model / scratch split that makes serving concurrent.
//!
//! [`CompiledModel`] is everything about a fitted network that never
//! changes between queries: the jointree topology (cliques, a *fixed*
//! rooted message schedule with per-clique parents, children and
//! separators), the evidence-free clique potentials with every CPT
//! multiplied in, and each variable's home clique. It holds no
//! interior mutability, so it is `Send + Sync` and one `Arc` (or plain
//! reference) can back any number of connection-handler threads.
//!
//! [`Scratch`] is everything a propagation mutates: the current
//! evidence-absorbed potentials and the message buffers. Each serving
//! thread owns one, so the hot path `marginals(&self, &mut Scratch,
//! ..)` takes no lock anywhere.
//!
//! The scratch doubles as an incremental-evidence cache: collect-pass
//! messages are kept between queries together with the evidence each
//! clique has absorbed, and changing evidence only invalidates the
//! messages on the paths from re-absorbed cliques up to their roots
//! (a collect message depends exactly on the potentials in its
//! subtree). Consecutive queries that share an evidence prefix —
//! the shape the batch endpoint sorts for — therefore reuse every
//! message outside the changed subtrees, and identical evidence reuses
//! the entire collect pass.
//!
//! [`joint_map`](CompiledModel::joint_map) runs max-product over the
//! same compiled tree: a collect pass with max-marginalization, then a
//! root-to-leaf decode that argmaxes each clique belief consistent
//! with the states already decided (the running-intersection property
//! makes those exactly the parent separator). Ties break toward the
//! lowest mixed-radix table index (see
//! [`Factor::argmax_consistent`]), so concurrent and sequential runs
//! return byte-identical assignments.

use anyhow::{bail, ensure, Result};

use crate::bn::DiscreteBn;
use crate::graph::moral_graph;
use crate::infer::factor::Factor;
use crate::infer::triangulate::{triangulate, Triangulation};
use crate::infer::Posterior;
use crate::util::BitSet;

/// A frozen, shareable compilation of one discrete Bayesian network:
/// jointree topology, CPT-assigned potentials and message schedule.
pub struct CompiledModel {
    names: Vec<String>,
    cards: Vec<usize>,
    cliques: Vec<Vec<usize>>,
    /// Schedule parent of each clique (`None` for component roots).
    parent: Vec<Option<usize>>,
    /// Schedule children of each clique.
    children: Vec<Vec<usize>>,
    /// Separator between a clique and its schedule parent (empty for
    /// roots).
    sep: Vec<Vec<usize>>,
    /// BFS order over all components: every parent precedes its
    /// children, so iterating forward is the distribute order and
    /// backward the collect order.
    order: Vec<usize>,
    /// One root clique per tree component.
    roots: Vec<usize>,
    /// Evidence-free clique potentials (CPTs multiplied in).
    base: Vec<Factor>,
    /// For each variable, a clique containing its whole family.
    var_home: Vec<usize>,
    max_clique_states: u64,
}

/// Per-thread propagation state: current potentials, message buffers
/// and the incremental-evidence cache. Create with
/// [`CompiledModel::new_scratch`]; reuse across queries for the
/// collect-message cache to pay off.
pub struct Scratch {
    /// Current potentials: base × absorbed evidence indicators.
    pots: Vec<Factor>,
    /// Evidence pairs currently absorbed into each clique (sorted).
    clique_ev: Vec<Vec<(usize, usize)>>,
    /// Cached collect message clique → schedule parent.
    up: Vec<Option<Factor>>,
    /// Log-normalizer of each cached collect message.
    up_logz: Vec<f64>,
    /// Is `up[c]` stale relative to `pots`?
    dirty: Vec<bool>,
    /// Distribute message schedule-parent → clique (rebuilt per query).
    down: Vec<Option<Factor>>,
    /// Canonical (sorted) evidence currently absorbed.
    evidence: Vec<(usize, usize)>,
}

impl Scratch {
    /// A scratch with no buffers, for engines that never propagate
    /// (the sampling fallback).
    pub fn empty() -> Scratch {
        Scratch {
            pots: Vec::new(),
            clique_ev: Vec::new(),
            up: Vec::new(),
            up_logz: Vec::new(),
            dirty: Vec::new(),
            down: Vec::new(),
            evidence: Vec::new(),
        }
    }
}

impl CompiledModel {
    /// Compile `bn` (moralizes and triangulates internally).
    pub fn compile(bn: &DiscreteBn) -> Result<CompiledModel> {
        let tri = triangulate(&moral_graph(&bn.dag), &bn.cards);
        Self::compile_from(bn, tri)
    }

    /// Compile from a precomputed triangulation of `bn`'s moral graph
    /// (budget probes reuse their triangulation instead of running
    /// min-fill twice).
    pub fn compile_from(bn: &DiscreteBn, tri: Triangulation) -> Result<CompiledModel> {
        let n = bn.n();
        ensure!(n > 0, "cannot compile a model over zero variables");
        let cards: Vec<usize> = bn.cards.iter().map(|&c| c as usize).collect();
        let cliques = tri.cliques;
        let nc = cliques.len();
        let clique_sets: Vec<BitSet> =
            cliques.iter().map(|c| BitSet::from_iter(n, c.iter().copied())).collect();

        // Maximum-weight spanning forest over separator sizes (Kruskal):
        // on a chordal graph's maximal cliques this yields a valid
        // junction tree (running intersection property).
        let mut candidates: Vec<(usize, usize, usize)> = Vec::new(); // (sep_size, i, j)
        for i in 0..nc {
            for j in (i + 1)..nc {
                let s = clique_sets[i].intersection(&clique_sets[j]).count();
                if s > 0 {
                    candidates.push((s, i, j));
                }
            }
        }
        candidates.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));

        let mut uf: Vec<usize> = (0..nc).collect();
        fn find(uf: &mut [usize], mut x: usize) -> usize {
            while uf[x] != x {
                uf[x] = uf[uf[x]];
                x = uf[x];
            }
            x
        }
        let mut adjacency: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); nc];
        for (_, i, j) in candidates {
            let (ri, rj) = (find(&mut uf, i), find(&mut uf, j));
            if ri == rj {
                continue;
            }
            uf[ri] = rj;
            let s: Vec<usize> = clique_sets[i].intersection(&clique_sets[j]).to_vec();
            adjacency[i].push((j, s.clone()));
            adjacency[j].push((i, s));
        }

        // Freeze the message schedule: root every component at its
        // lowest-index clique and BFS, so parents always precede
        // children in `order`.
        let mut parent: Vec<Option<usize>> = vec![None; nc];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); nc];
        let mut sep: Vec<Vec<usize>> = vec![Vec::new(); nc];
        let mut order: Vec<usize> = Vec::with_capacity(nc);
        let mut roots: Vec<usize> = Vec::new();
        let mut visited = vec![false; nc];
        for r in 0..nc {
            if visited[r] {
                continue;
            }
            visited[r] = true;
            roots.push(r);
            let mut head = order.len();
            order.push(r);
            while head < order.len() {
                let c = order[head];
                head += 1;
                for (o, s) in &adjacency[c] {
                    if !visited[*o] {
                        visited[*o] = true;
                        parent[*o] = Some(c);
                        children[c].push(*o);
                        sep[*o] = s.clone();
                        order.push(*o);
                    }
                }
            }
        }

        // Assign each family to the smallest containing clique and
        // multiply its CPT in.
        let mut base: Vec<Factor> =
            cliques.iter().map(|c| Factor::ones(c.clone(), &bn.cards)).collect();
        let mut var_home = vec![usize::MAX; n];
        for v in 0..n {
            let mut fam = BitSet::new(n);
            fam.insert(v);
            fam.union_with(bn.dag.parents(v));
            let mut chosen: Option<(u64, usize)> = None; // (state space, clique)
            for (ci, cs) in clique_sets.iter().enumerate() {
                if !fam.is_subset(cs) {
                    continue;
                }
                let weight = cliques[ci]
                    .iter()
                    .fold(1u64, |acc, &x| acc.saturating_mul(cards[x] as u64));
                let better = match chosen {
                    None => true,
                    Some((w, _)) => weight < w,
                };
                if better {
                    chosen = Some((weight, ci));
                }
            }
            let Some((_, ci)) = chosen else {
                bail!("family of variable {v} fits no clique — triangulation is inconsistent");
            };
            var_home[v] = ci;
            base[ci] = Factor::product(&base[ci], &Factor::from_cpt(bn, v));
        }

        Ok(CompiledModel {
            names: bn.names.clone(),
            cards,
            cliques,
            parent,
            children,
            sep,
            order,
            roots,
            base,
            var_home,
            max_clique_states: tri.max_clique_states,
        })
    }

    /// Number of variables.
    pub fn n_vars(&self) -> usize {
        self.cards.len()
    }

    /// Number of cliques.
    pub fn n_cliques(&self) -> usize {
        self.cliques.len()
    }

    /// Largest clique joint state space (treewidth proxy).
    pub fn max_clique_states(&self) -> u64 {
        self.max_clique_states
    }

    /// Variable names, in network order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Cardinality of variable `v`.
    pub fn card(&self, v: usize) -> usize {
        self.cards[v]
    }

    /// Fresh propagation buffers for this model (one per serving
    /// thread; queries then need only `&self`).
    pub fn new_scratch(&self) -> Scratch {
        let nc = self.cliques.len();
        Scratch {
            pots: self.base.clone(),
            clique_ev: vec![Vec::new(); nc],
            up: vec![None; nc],
            up_logz: vec![0.0; nc],
            dirty: vec![true; nc],
            down: vec![None; nc],
            evidence: Vec::new(),
        }
    }

    /// Absorb `evidence` into the scratch potentials, invalidating
    /// exactly the cached collect messages whose subtree changed.
    fn set_evidence(&self, s: &mut Scratch, evidence: &[(usize, usize)]) -> Result<()> {
        let n = self.cards.len();
        for &(v, st) in evidence {
            ensure!(v < n, "evidence variable {v} out of range (n = {n})");
            ensure!(
                st < self.cards[v],
                "evidence state {st} out of range for variable {v} (cardinality {})",
                self.cards[v]
            );
        }
        let mut ev: Vec<(usize, usize)> = evidence.to_vec();
        ev.sort_unstable();
        if ev == s.evidence {
            return Ok(());
        }
        // Cliques whose absorbed indicators may differ between the old
        // and new evidence sets.
        let mut touched: Vec<usize> =
            ev.iter().chain(s.evidence.iter()).map(|&(v, _)| self.var_home[v]).collect();
        touched.sort_unstable();
        touched.dedup();
        for &c in &touched {
            let new_ev: Vec<(usize, usize)> =
                ev.iter().copied().filter(|&(v, _)| self.var_home[v] == c).collect();
            if new_ev == s.clique_ev[c] {
                continue;
            }
            let mut pot = self.base[c].clone();
            for &(v, st) in &new_ev {
                pot = Factor::product(&pot, &Factor::indicator(v, self.cards[v], st));
            }
            s.pots[c] = pot;
            s.clique_ev[c] = new_ev;
            // Invalidate every collect message between c and its root.
            // Dirtiness is kept upward-closed along schedule paths, so
            // the walk can stop at the first already-dirty hop.
            let mut x = c;
            loop {
                if s.dirty[x] {
                    break;
                }
                s.dirty[x] = true;
                match self.parent[x] {
                    Some(p) => x = p,
                    None => break,
                }
            }
        }
        s.evidence = ev;
        Ok(())
    }

    /// Collect pass: recompute only the stale messages (leaves toward
    /// roots), reusing every cached message whose subtree evidence is
    /// unchanged.
    fn collect(&self, s: &mut Scratch) -> Result<()> {
        for &c in self.order.iter().rev() {
            if self.parent[c].is_none() {
                s.dirty[c] = false;
                continue;
            }
            if !s.dirty[c] {
                continue;
            }
            let mut f = s.pots[c].clone();
            for &k in &self.children[c] {
                let inc = s.up[k].as_ref().expect("child collect message ready");
                f = Factor::product(&f, inc);
            }
            let mut m = f.marginalize_to(&self.sep[c]);
            let z = m.normalize();
            if z <= 0.0 {
                bail!("evidence has probability zero");
            }
            s.up_logz[c] = z.ln();
            s.up[c] = Some(m);
            s.dirty[c] = false;
        }
        Ok(())
    }

    /// Exact posterior over every variable given `evidence`
    /// (`(variable, state)` pairs). Errors on out-of-range evidence or
    /// evidence of probability zero. Lock-free: `&self` plus the
    /// caller's scratch.
    pub fn marginals(&self, s: &mut Scratch, evidence: &[(usize, usize)]) -> Result<Posterior> {
        self.set_evidence(s, evidence)?;
        self.collect(s)?;

        // Message normalizers plus the root belief masses telescope to
        // P(evidence), in log space.
        let mut log_evidence: f64 = self
            .order
            .iter()
            .filter(|&&c| self.parent[c].is_some())
            .map(|&c| s.up_logz[c])
            .sum();
        for &r in &self.roots {
            let mut b = s.pots[r].clone();
            for &k in &self.children[r] {
                b = Factor::product(&b, s.up[k].as_ref().expect("root message ready"));
            }
            let z = b.total();
            if z <= 0.0 {
                bail!("evidence has probability zero");
            }
            log_evidence += z.ln();
        }

        // Distribute pass, roots toward leaves. Not cached: each
        // message folds in every other branch of the tree, so almost
        // any evidence change would invalidate it anyway.
        for &c in &self.order {
            for &k in &self.children[c] {
                let mut f = s.pots[c].clone();
                if self.parent[c].is_some() {
                    f = Factor::product(&f, s.down[c].as_ref().expect("parent message ready"));
                }
                for &k2 in &self.children[c] {
                    if k2 == k {
                        continue;
                    }
                    f = Factor::product(&f, s.up[k2].as_ref().expect("sibling message ready"));
                }
                let mut m = f.marginalize_to(&self.sep[k]);
                if m.normalize() <= 0.0 {
                    bail!("evidence has probability zero");
                }
                s.down[k] = Some(m);
            }
        }

        // Calibrated beliefs → all single-variable marginals.
        let n = self.cards.len();
        let mut beliefs: Vec<Option<Factor>> = vec![None; self.cliques.len()];
        let mut marginals: Vec<Vec<f64>> = Vec::with_capacity(n);
        for v in 0..n {
            let c = self.var_home[v];
            if beliefs[c].is_none() {
                let mut b = s.pots[c].clone();
                if self.parent[c].is_some() {
                    b = Factor::product(&b, s.down[c].as_ref().expect("down message ready"));
                }
                for &k in &self.children[c] {
                    b = Factor::product(&b, s.up[k].as_ref().expect("up message ready"));
                }
                beliefs[c] = Some(b);
            }
            marginals.push(beliefs[c].as_ref().expect("belief just built").marginal_of(v));
        }

        Ok(Posterior { marginals, log_evidence })
    }

    /// Exact joint MAP: the single complete assignment maximizing
    /// P(x | evidence), with `ln max_x P(x, evidence)`. Max-product
    /// collect over the compiled tree, then a root-to-leaf decode; the
    /// returned assignment always agrees with the evidence. Per-clique
    /// ties break toward the lowest mixed-radix cell (see
    /// [`Factor::argmax_consistent`]), deterministically.
    pub fn joint_map(
        &self,
        s: &mut Scratch,
        evidence: &[(usize, usize)],
    ) -> Result<(Vec<usize>, f64)> {
        self.set_evidence(s, evidence)?;
        let nc = self.cliques.len();

        // Max-product collect. Own message buffers: a different
        // semiring than the cached sum-product sweep (the sum cache
        // stays valid — both read the same absorbed potentials). The
        // pre-marginalization clique products are kept: the decode
        // pass below argmaxes exactly these, so recomputing them would
        // double the factor-product work per query.
        let mut up: Vec<Option<Factor>> = vec![None; nc];
        let mut prods: Vec<Option<Factor>> = vec![None; nc];
        let mut log_map = 0.0f64;
        for &c in self.order.iter().rev() {
            let mut f = s.pots[c].clone();
            for &k in &self.children[c] {
                f = Factor::product(&f, up[k].as_ref().expect("child max-message ready"));
            }
            if self.parent[c].is_some() {
                let mut m = f.max_marginalize_to(&self.sep[c]);
                let z = m.table.iter().fold(0.0f64, |a, &b| a.max(b));
                if z <= 0.0 {
                    bail!("evidence has probability zero");
                }
                let inv = 1.0 / z;
                m.table.iter_mut().for_each(|x| *x *= inv);
                log_map += z.ln();
                up[c] = Some(m);
            }
            prods[c] = Some(f);
        }

        // Decode, roots toward leaves: argmax each clique belief
        // consistent with the states already decided. By the running
        // intersection property the decided variables of a clique are
        // exactly its parent separator, so any consistent argmax
        // extends to a global maximizer.
        let n = self.cards.len();
        let mut assign: Vec<Option<usize>> = vec![None; n];
        for &c in &self.order {
            let b = prods[c].as_ref().expect("clique max-product ready");
            let (digits, val) = b.argmax_consistent(&assign);
            if val <= 0.0 {
                bail!("evidence has probability zero");
            }
            if self.parent[c].is_none() {
                // Root maxima close each component's MAP mass; inner
                // cliques' mass is already inside the messages.
                log_map += val.ln();
            }
            for (&v, &d) in b.vars.iter().zip(&digits) {
                assign[v] = Some(d);
            }
        }
        let assignment: Vec<usize> =
            assign.into_iter().map(|a| a.expect("every variable lives in a clique")).collect();
        Ok((assignment, log_map))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn compiled_model_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<CompiledModel>();
    }

    #[test]
    fn marginals_match_jointree_semantics() {
        let bn = tiny_bn();
        let m = CompiledModel::compile(&bn).unwrap();
        let mut s = m.new_scratch();
        let post = m.marginals(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
        assert!((post.marginal(1)[0] - 0.69).abs() < 1e-12);
        assert!(post.log_evidence.abs() < 1e-12);

        let post = m.marginals(&mut s, &[(1, 1)]).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8;
        assert!((post.log_evidence - pe.ln()).abs() < 1e-12);
        assert!((post.marginal(0)[0] - 0.07 / pe).abs() < 1e-12);

        // Back to no evidence on the same scratch: the cache must not
        // leak the old indicators.
        let post = m.marginals(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
        assert!(post.log_evidence.abs() < 1e-12);
    }

    #[test]
    fn joint_map_on_tiny_bn() {
        // Joint probabilities: (0,0)=0.63 (0,1)=0.07 (1,0)=0.06 (1,1)=0.24.
        let bn = tiny_bn();
        let m = CompiledModel::compile(&bn).unwrap();
        let mut s = m.new_scratch();
        let (x, lp) = m.joint_map(&mut s, &[]).unwrap();
        assert_eq!(x, vec![0, 0]);
        assert!((lp - 0.63f64.ln()).abs() < 1e-12);

        // Conditioning on b=1 flips the maximizer to (1,1).
        let (x, lp) = m.joint_map(&mut s, &[(1, 1)]).unwrap();
        assert_eq!(x, vec![1, 1]);
        assert!((lp - 0.24f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_and_zero_probability_evidence() {
        let bn = tiny_bn();
        let m = CompiledModel::compile(&bn).unwrap();
        let mut s = m.new_scratch();
        assert!(m.marginals(&mut s, &[(5, 0)]).is_err());
        assert!(m.marginals(&mut s, &[(0, 9)]).is_err());
        assert!(m.marginals(&mut s, &[(0, 0), (0, 1)]).is_err());
        assert!(m.joint_map(&mut s, &[(0, 0), (0, 1)]).is_err());
        // The scratch stays usable after a zero-probability bail.
        let post = m.marginals(&mut s, &[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
    }
}
