//! The JSON query protocol, shared by every serving medium.
//!
//! One JSON object per request, one per response; the same shapes ride
//! the NDJSON line mode and the length-prefixed TCP frames. Evidence
//! states are indices or `s<k>` names; `targets` defaults to every
//! variable.
//!
//! ```json
//! {"id": 1, "type": "marginal", "targets": ["X3"], "evidence": {"X0": 0}}
//! {"id": 2, "type": "map", "evidence": {"X1": "s1"}}
//! {"id": 3, "type": "joint_map", "evidence": {"X1": 1}}
//! {"id": 4, "type": "batch", "queries": [{"id": 0, ...}, {"id": 1, ...}]}
//! {"type": "shutdown"}
//! ```
//!
//! * `marginal` answers `"marginals": {name: [p...]}`;
//! * `map` answers `"map": {name: state}` — *per-variable* posterior
//!   modes (each variable's own argmax, ties to the lowest state);
//! * `joint_map` answers `"assignment": {name: state}` plus
//!   `"log_prob"` — the single most probable *complete* assignment,
//!   from a max-product sweep (not the same thing as `map` once
//!   variables are correlated);
//! * `batch` carries sub-queries and answers `"results": [...]`, one
//!   full response object per sub-query in request order. Before
//!   answering, sub-queries are *processed* in canonical-evidence
//!   order so consecutive ones share evidence prefixes and the scratch
//!   message cache reuses their collect passes; answers are identical
//!   to issuing the queries one at a time (exact engine).
//! * `shutdown` is the serving sentinel; media decide what it stops
//!   (the TCP server drains its pool, the line adapter returns).
//!
//! Responses echo `id`, report the engine and, for posterior queries,
//! `log_evidence`. Failures answer `{"ok": false, "error": ...}`
//! without dropping the stream; inside a batch, a failing sub-query
//! yields a failing *sub-result* while its siblings still answer.

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::engine::{Scratch, SharedEngine};
use crate::infer::json::Json;
use crate::infer::Posterior;

/// Default cap on sub-queries per batch request (CLI `--batch`).
pub const DEFAULT_MAX_BATCH: usize = 256;

/// Answer one JSON request text with one JSON response text.
pub fn handle_request(
    engine: &SharedEngine,
    scratch: &mut Scratch,
    request: &str,
    max_batch: usize,
) -> String {
    let parsed = match Json::parse(request) {
        Ok(v) => v,
        Err(e) => return error_response(Json::Null, &format!("bad json: {e:#}")).to_string(),
    };
    answer(engine, scratch, &parsed, max_batch).to_string()
}

/// Answer one parsed request; never errors (failures become error
/// response objects).
pub fn answer(engine: &SharedEngine, scratch: &mut Scratch, req: &Json, max_batch: usize) -> Json {
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    match answer_inner(engine, scratch, req, max_batch) {
        Ok(body) => body,
        Err(e) => error_response(id, &format!("{e:#}")),
    }
}

/// Is this request the shutdown sentinel?
pub fn is_shutdown(req: &Json) -> bool {
    req.get("type").and_then(Json::as_str) == Some("shutdown")
}

/// Acknowledgement for the shutdown sentinel.
pub fn shutdown_response(id: Json) -> Json {
    Json::Obj(vec![
        ("id".to_string(), id),
        ("ok".to_string(), Json::Bool(true)),
        ("shutdown".to_string(), Json::Bool(true)),
    ])
}

/// A failure response echoing the request id.
pub fn error_response(id: Json, message: &str) -> Json {
    Json::Obj(vec![
        ("id".to_string(), id),
        ("ok".to_string(), Json::Bool(false)),
        ("error".to_string(), Json::Str(message.to_string())),
    ])
}

fn answer_inner(
    engine: &SharedEngine,
    scratch: &mut Scratch,
    req: &Json,
    max_batch: usize,
) -> Result<Json> {
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    let qtype = match req.get("type") {
        None => "marginal",
        Some(t) => t.as_str().ok_or_else(|| anyhow!("'type' must be a string"))?,
    };
    match qtype {
        "marginal" | "map" => {
            let targets = parse_targets(engine, req)?;
            let evidence = parse_evidence(engine, req)?;
            let post = engine.posterior(scratch, &evidence)?;
            Ok(compose_posterior(engine, id, qtype, &targets, &post))
        }
        "joint_map" => {
            let evidence = parse_evidence(engine, req)?;
            let (assignment, log_prob) = engine.joint_map(scratch, &evidence)?;
            Ok(compose_joint_map(engine, id, &assignment, log_prob))
        }
        "batch" => answer_batch(engine, scratch, id, req, max_batch),
        other => bail!("unknown query type '{other}' (marginal|map|joint_map|batch)"),
    }
}

fn answer_batch(
    engine: &SharedEngine,
    scratch: &mut Scratch,
    id: Json,
    req: &Json,
    max_batch: usize,
) -> Result<Json> {
    let queries = req
        .get("queries")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("'queries' must be an array"))?;
    ensure!(!queries.is_empty(), "batch lists no queries");
    ensure!(
        queries.len() <= max_batch,
        "batch of {} queries exceeds cap {max_batch} (--batch)",
        queries.len()
    );
    ensure!(
        queries.iter().all(|q| q.get("type").and_then(Json::as_str) != Some("batch")),
        "batches do not nest"
    );

    // Process in canonical-evidence order so adjacent sub-queries share
    // evidence prefixes: the scratch collect-message cache then reuses
    // every message whose subtree evidence did not change between
    // neighbors (identical evidence reuses the whole collect pass).
    // Results go back into request order, so the reordering is
    // invisible in the response.
    let keys: Vec<Vec<(usize, usize)>> = queries
        .iter()
        .map(|q| {
            let mut ev = parse_evidence(engine, q).unwrap_or_default();
            ev.sort_unstable();
            ev
        })
        .collect();
    let mut by_evidence: Vec<usize> = (0..queries.len()).collect();
    by_evidence.sort_by(|&a, &b| keys[a].cmp(&keys[b]).then(a.cmp(&b)));

    let mut results: Vec<Json> = vec![Json::Null; queries.len()];
    for &i in &by_evidence {
        results[i] = answer(engine, scratch, &queries[i], max_batch);
    }
    Ok(Json::Obj(vec![
        ("id".to_string(), id),
        ("ok".to_string(), Json::Bool(true)),
        ("engine".to_string(), Json::Str(engine.name().to_string())),
        ("results".to_string(), Json::Arr(results)),
    ]))
}

fn parse_targets(engine: &SharedEngine, req: &Json) -> Result<Vec<usize>> {
    let names = engine.names();
    match req.get("targets") {
        None => Ok((0..names.len()).collect()),
        Some(t) => {
            let items = t.as_array().ok_or_else(|| anyhow!("'targets' must be an array"))?;
            if items.is_empty() {
                Ok((0..names.len()).collect())
            } else {
                items
                    .iter()
                    .map(|x| {
                        let name = x.as_str().ok_or_else(|| anyhow!("target must be a string"))?;
                        crate::infer::var_index(names, name)
                    })
                    .collect()
            }
        }
    }
}

fn parse_evidence(engine: &SharedEngine, req: &Json) -> Result<Vec<(usize, usize)>> {
    let mut evidence: Vec<(usize, usize)> = Vec::new();
    if let Some(ev) = req.get("evidence") {
        let entries = ev.as_object().ok_or_else(|| anyhow!("'evidence' must be an object"))?;
        for (name, val) in entries {
            let v = crate::infer::var_index(engine.names(), name)?;
            let s = state_index(val, engine.card(v))
                .with_context(|| format!("evidence for '{name}'"))?;
            evidence.push((v, s));
        }
    }
    Ok(evidence)
}

/// Parse an evidence state: a non-negative integer, or an `s<k>` /
/// integer string (string forms share [`crate::infer::parse_state`]
/// with the CLI).
fn state_index(val: &Json, card: u32) -> Result<usize> {
    match val {
        Json::Num(_) => {
            let s = val
                .as_usize()
                .ok_or_else(|| anyhow!("state must be a non-negative integer"))?;
            ensure!(s < card as usize, "state {s} out of range (cardinality {card})");
            Ok(s)
        }
        Json::Str(text) => crate::infer::parse_state(text, card),
        _ => bail!("state must be an integer or a state name"),
    }
}

fn compose_posterior(
    engine: &SharedEngine,
    id: Json,
    qtype: &str,
    targets: &[usize],
    post: &Posterior,
) -> Json {
    let names = engine.names();
    let mut fields: Vec<(String, Json)> = vec![
        ("id".to_string(), id),
        ("ok".to_string(), Json::Bool(true)),
        ("engine".to_string(), Json::Str(engine.name().to_string())),
        ("log_evidence".to_string(), Json::Num(post.log_evidence)),
    ];
    if qtype == "map" {
        let modes: Vec<(String, Json)> = targets
            .iter()
            .map(|&v| (names[v].clone(), Json::Num(post.mode(v) as f64)))
            .collect();
        fields.push(("map".to_string(), Json::Obj(modes)));
    } else {
        let margs: Vec<(String, Json)> = targets
            .iter()
            .map(|&v| {
                let dist: Vec<Json> = post.marginal(v).iter().map(|&p| Json::Num(p)).collect();
                (names[v].clone(), Json::Arr(dist))
            })
            .collect();
        fields.push(("marginals".to_string(), Json::Obj(margs)));
    }
    Json::Obj(fields)
}

fn compose_joint_map(engine: &SharedEngine, id: Json, assignment: &[usize], log_prob: f64) -> Json {
    let names = engine.names();
    let cells: Vec<(String, Json)> = assignment
        .iter()
        .enumerate()
        .map(|(v, &s)| (names[v].clone(), Json::Num(s as f64)))
        .collect();
    Json::Obj(vec![
        ("id".to_string(), id),
        ("ok".to_string(), Json::Bool(true)),
        ("engine".to_string(), Json::Str(engine.name().to_string())),
        ("log_prob".to_string(), Json::Num(log_prob)),
        ("assignment".to_string(), Json::Obj(cells)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;
    use crate::infer::EngineConfig;

    fn engine() -> SharedEngine {
        SharedEngine::build(&tiny_bn(), &EngineConfig::default()).unwrap()
    }

    #[test]
    fn joint_map_request_roundtrip() {
        let e = engine();
        let mut s = e.new_scratch();
        let resp =
            handle_request(&e, &mut s, r#"{"id": 3, "type": "joint_map", "evidence": {"b": 1}}"#, 8);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(3));
        let a = v.get("assignment").unwrap();
        assert_eq!(a.get("a").and_then(Json::as_usize), Some(1));
        assert_eq!(a.get("b").and_then(Json::as_usize), Some(1));
        let lp = v.get("log_prob").and_then(Json::as_f64).unwrap();
        assert!((lp - 0.24f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn batch_results_keep_request_order() {
        let e = engine();
        let mut s = e.new_scratch();
        let req = r#"{"id": 9, "type": "batch", "queries": [
            {"id": 0, "type": "marginal", "evidence": {"b": 1}},
            {"id": 1, "type": "marginal"},
            {"id": 2, "targets": ["nope"]},
            {"id": 3, "type": "joint_map"}
        ]}"#;
        let v = Json::parse(&handle_request(&e, &mut s, req, 8)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(9));
        let results = v.get("results").and_then(Json::as_array).unwrap();
        assert_eq!(results.len(), 4);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.get("id").and_then(Json::as_usize), Some(i), "slot {i}");
        }
        assert_eq!(results[0].get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(results[2].get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(results[3].get("assignment").unwrap().get("a").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn batch_caps_and_nesting_are_rejected() {
        let e = engine();
        let mut s = e.new_scratch();
        let over = r#"{"type": "batch", "queries": [{}, {}, {}]}"#;
        let v = Json::parse(&handle_request(&e, &mut s, over, 2)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        let nested = r#"{"type": "batch", "queries": [{"type": "batch", "queries": []}]}"#;
        let v = Json::parse(&handle_request(&e, &mut s, nested, 8)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
        let empty = r#"{"type": "batch", "queries": []}"#;
        let v = Json::parse(&handle_request(&e, &mut s, empty, 8)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn shutdown_sentinel_detection() {
        let req = Json::parse(r#"{"id": 1, "type": "shutdown"}"#).unwrap();
        assert!(is_shutdown(&req));
        assert!(!is_shutdown(&Json::parse(r#"{"type": "map"}"#).unwrap()));
        let ack = shutdown_response(Json::Num(1.0)).to_string();
        let v = Json::parse(&ack).unwrap();
        assert_eq!(v.get("shutdown").and_then(Json::as_bool), Some(true));
    }
}
