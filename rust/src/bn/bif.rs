//! BIF (Bayesian Interchange Format) parser + writer.
//!
//! The paper's domains (`link`, `pigs`, `munin`) are distributed by the
//! bnlearn repository as `.bif` files. This module reads/writes the
//! discrete subset of the format so real repository files drop straight
//! into the pipeline; the `bn::repo` analogs are used when the originals
//! are not on disk (offline environment — see DESIGN.md §Substitutions).
//!
//! Supported grammar (whitespace-insensitive):
//!   network <name> { }
//!   variable <name> { type discrete [ k ] { s0, s1, ... }; }
//!   probability ( <child> ) { table p0, ..., p_{r-1}; }
//!   probability ( <child> | p1, p2 ) { (s_a, s_b) p0, ...; ... }

use std::collections::HashMap;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::bn::{Cpt, DiscreteBn};
use crate::graph::Dag;

/// Parse a `.bif` file.
pub fn read_bif(path: &Path) -> Result<DiscreteBn> {
    let text = std::fs::read_to_string(path).with_context(|| format!("open {}", path.display()))?;
    parse_bif(&text)
}

/// Parse BIF text.
pub fn parse_bif(text: &str) -> Result<DiscreteBn> {
    let toks = tokenize(text);
    let mut p = Parser { toks, pos: 0 };
    p.parse()
}

fn tokenize(text: &str) -> Vec<String> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '/' if chars.peek() == Some(&'/') => {
                // line comment
                for c2 in chars.by_ref() {
                    if c2 == '\n' {
                        break;
                    }
                }
            }
            '{' | '}' | '(' | ')' | '[' | ']' | ',' | ';' | '|' => {
                if !cur.is_empty() {
                    toks.push(std::mem::take(&mut cur));
                }
                toks.push(c.to_string());
            }
            c if c.is_whitespace() => {
                if !cur.is_empty() {
                    toks.push(std::mem::take(&mut cur));
                }
            }
            _ => cur.push(c),
        }
    }
    if !cur.is_empty() {
        toks.push(cur);
    }
    toks
}

struct Parser {
    toks: Vec<String>,
    pos: usize,
}

struct VarDecl {
    name: String,
    states: Vec<String>,
}

impl Parser {
    fn peek(&self) -> Option<&str> {
        self.toks.get(self.pos).map(|s| s.as_str())
    }

    fn next(&mut self) -> Result<&str> {
        let t = self.toks.get(self.pos).ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, want: &str) -> Result<()> {
        let pos = self.pos;
        let t = self.next()?;
        if t != want {
            bail!("expected '{want}', got '{t}' at token {pos}");
        }
        Ok(())
    }

    fn skip_block(&mut self) -> Result<()> {
        self.expect("{")?;
        let mut depth = 1;
        while depth > 0 {
            match self.next()? {
                "{" => depth += 1,
                "}" => depth -= 1,
                _ => {}
            }
        }
        Ok(())
    }

    fn parse(&mut self) -> Result<DiscreteBn> {
        let mut vars: Vec<VarDecl> = Vec::new();
        let mut probs: Vec<(String, Vec<String>, Vec<(Vec<String>, Vec<f64>)>, Option<Vec<f64>>)> =
            Vec::new();

        while let Some(tok) = self.peek() {
            match tok {
                "network" => {
                    self.next()?;
                    while self.peek() != Some("{") {
                        self.next()?;
                    }
                    self.skip_block()?;
                }
                "variable" => {
                    self.next()?;
                    let name = self.next()?.to_string();
                    self.expect("{")?;
                    let mut states = Vec::new();
                    while self.peek() != Some("}") {
                        if self.peek() == Some("type") {
                            self.next()?; // type
                            self.expect("discrete")?;
                            self.expect("[")?;
                            let _k: usize = self.next()?.parse().context("state count")?;
                            self.expect("]")?;
                            self.expect("{")?;
                            loop {
                                let t = self.next()?;
                                match t {
                                    "}" => break,
                                    "," => {}
                                    s => states.push(s.to_string()),
                                }
                            }
                            self.expect(";")?;
                        } else {
                            self.next()?;
                        }
                    }
                    self.expect("}")?;
                    vars.push(VarDecl { name, states });
                }
                "probability" => {
                    self.next()?;
                    self.expect("(")?;
                    let child = self.next()?.to_string();
                    let mut parents = Vec::new();
                    if self.peek() == Some("|") {
                        self.next()?;
                        loop {
                            let t = self.next()?;
                            match t {
                                ")" => break,
                                "," => {}
                                s => parents.push(s.to_string()),
                            }
                        }
                    } else {
                        self.expect(")")?;
                    }
                    self.expect("{")?;
                    let mut rows: Vec<(Vec<String>, Vec<f64>)> = Vec::new();
                    let mut table: Option<Vec<f64>> = None;
                    while self.peek() != Some("}") {
                        match self.peek() {
                            Some("table") => {
                                self.next()?;
                                let mut vals = Vec::new();
                                loop {
                                    let t = self.next()?;
                                    match t {
                                        ";" => break,
                                        "," => {}
                                        v => vals.push(v.parse::<f64>().context("table value")?),
                                    }
                                }
                                table = Some(vals);
                            }
                            Some("(") => {
                                self.next()?;
                                let mut cfg = Vec::new();
                                loop {
                                    let t = self.next()?;
                                    match t {
                                        ")" => break,
                                        "," => {}
                                        s => cfg.push(s.to_string()),
                                    }
                                }
                                let mut vals = Vec::new();
                                loop {
                                    let t = self.next()?;
                                    match t {
                                        ";" => break,
                                        "," => {}
                                        v => vals.push(v.parse::<f64>().context("cpt value")?),
                                    }
                                }
                                rows.push((cfg, vals));
                            }
                            _ => {
                                self.next()?;
                            }
                        }
                    }
                    self.expect("}")?;
                    probs.push((child, parents, rows, table));
                }
                _ => {
                    self.next()?;
                }
            }
        }

        // Assemble the network.
        let n = vars.len();
        let index: HashMap<&str, usize> =
            vars.iter().enumerate().map(|(i, v)| (v.name.as_str(), i)).collect();
        let state_index: Vec<HashMap<&str, usize>> = vars
            .iter()
            .map(|v| v.states.iter().enumerate().map(|(i, s)| (s.as_str(), i)).collect())
            .collect();
        let cards: Vec<u32> = vars.iter().map(|v| v.states.len() as u32).collect();

        let mut dag = Dag::new(n);
        let mut cpts: Vec<Option<Cpt>> = (0..n).map(|_| None).collect();
        for (child, parents, rows, table) in probs {
            let c = *index.get(child.as_str()).ok_or_else(|| anyhow!("unknown var {child}"))?;
            let pidx: Vec<usize> = parents
                .iter()
                .map(|p| index.get(p.as_str()).copied().ok_or_else(|| anyhow!("unknown parent {p}")))
                .collect::<Result<_>>()?;
            for &p in &pidx {
                dag.add_edge(p, c);
            }
            let r = cards[c] as usize;
            // CPT parent order: ascending variable index (our convention);
            // remap each BIF row from the file's parent order.
            let mut sorted = pidx.clone();
            sorted.sort_unstable();
            let q: usize = sorted.iter().map(|&p| cards[p] as usize).product();
            let mut tbl = vec![0.0f64; q * r];
            if let Some(mut vals) = table {
                if !pidx.is_empty() {
                    // BIF dialects disagree on the enumeration order of a
                    // flat `table` under parents; guessing would silently
                    // permute the CPT. Demand the unambiguous row form.
                    bail!(
                        "{child}: `table` form with parents is ambiguous across BIF dialects; \
                         list one (parent states) row per configuration instead"
                    );
                }
                if vals.len() != r {
                    bail!("{child}: table has {} values, expected {r}", vals.len());
                }
                check_cpt_row(&child, "table", &mut vals)?;
                tbl.copy_from_slice(&vals);
            } else {
                let mut filled = vec![false; q];
                for (cfg_states, mut vals) in rows {
                    if cfg_states.len() != pidx.len() || vals.len() != r {
                        bail!("{child}: malformed cpt row");
                    }
                    check_cpt_row(&child, &format!("({})", cfg_states.join(", ")), &mut vals)?;
                    let mut cfg = 0usize;
                    for (p_file, sname) in pidx.iter().zip(&cfg_states) {
                        let s = *state_index[*p_file]
                            .get(sname.as_str())
                            .ok_or_else(|| anyhow!("unknown state {sname} of parent"))?;
                        // stride of p_file within sorted order
                        let mut stride = 1usize;
                        for &sp in sorted.iter() {
                            if sp == *p_file {
                                break;
                            }
                            stride *= cards[sp] as usize;
                        }
                        cfg += stride * s;
                    }
                    if filled[cfg] {
                        bail!(
                            "{child}: duplicate CPT row for parent configuration ({})",
                            cfg_states.join(", ")
                        );
                    }
                    filled[cfg] = true;
                    tbl[cfg * r..(cfg + 1) * r].copy_from_slice(&vals);
                }
                let missing = filled.iter().filter(|&&f| !f).count();
                if missing > 0 {
                    bail!(
                        "{child}: {missing} of {q} parent configurations have no CPT row \
                         (downstream inference would silently read zeros)"
                    );
                }
            }
            cpts[c] = Some(Cpt { parents: sorted, table: tbl, r });
        }

        let cpts: Vec<Cpt> = cpts
            .into_iter()
            .enumerate()
            .map(|(i, c)| c.ok_or_else(|| anyhow!("no probability block for {}", vars[i].name)))
            .collect::<Result<_>>()?;
        let bn = DiscreteBn {
            dag,
            names: vars.into_iter().map(|v| v.name).collect(),
            cards,
            cpts,
        };
        bn.validate().map_err(|e| anyhow!("invalid BN: {e}"))?;
        Ok(bn)
    }
}

/// Probability-row sanity for BIF input: every value must be a finite
/// probability and the row must sum to ~1 (print-rounding tolerance).
/// Valid rows are renormalized to sum exactly 1, so files written at
/// limited precision never leak drift into inference. A clear error
/// here beats silent NaN/zero propagation downstream.
fn check_cpt_row(child: &str, row_desc: &str, vals: &mut [f64]) -> Result<()> {
    for &v in vals.iter() {
        if !v.is_finite() || !(-1e-9..=1.0 + 1e-9).contains(&v) {
            bail!("{child}: probability {v} out of [0, 1] in row {row_desc}");
        }
    }
    let sum: f64 = vals.iter().sum();
    if (sum - 1.0).abs() > 1e-3 {
        bail!("{child}: CPT row {row_desc} sums to {sum}, expected 1");
    }
    for v in vals.iter_mut() {
        *v = (*v / sum).clamp(0.0, 1.0);
    }
    Ok(())
}

/// Write a network as BIF (states named `s0..s{r-1}`).
pub fn write_bif(bn: &DiscreteBn, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "network unknown {{\n}}")?;
    for v in 0..bn.n() {
        let states: Vec<String> = (0..bn.cards[v]).map(|s| format!("s{s}")).collect();
        writeln!(
            f,
            "variable {} {{\n  type discrete [ {} ] {{ {} }};\n}}",
            bn.names[v],
            bn.cards[v],
            states.join(", ")
        )?;
    }
    for v in 0..bn.n() {
        let cpt = &bn.cpts[v];
        if cpt.parents.is_empty() {
            let vals: Vec<String> = cpt.table.iter().map(|p| format!("{p:.10}")).collect();
            writeln!(
                f,
                "probability ( {} ) {{\n  table {};\n}}",
                bn.names[v],
                vals.join(", ")
            )?;
        } else {
            let pnames: Vec<&str> = cpt.parents.iter().map(|&p| bn.names[p].as_str()).collect();
            writeln!(f, "probability ( {} | {} ) {{", bn.names[v], pnames.join(", "))?;
            for cfg in 0..cpt.q() {
                // decode mixed-radix cfg into parent states
                let mut rem = cfg;
                let mut states = Vec::new();
                for &p in &cpt.parents {
                    let c = bn.cards[p] as usize;
                    states.push(format!("s{}", rem % c));
                    rem /= c;
                }
                let vals: Vec<String> = cpt.row(cfg).iter().map(|p| format!("{p:.10}")).collect();
                writeln!(f, "  ({}) {};", states.join(", "), vals.join(", "))?;
            }
            writeln!(f, "}}")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
network test {
}
variable rain {
  type discrete [ 2 ] { yes, no };
}
variable sprinkler {
  type discrete [ 2 ] { on, off };
}
variable wet {
  type discrete [ 2 ] { wet, dry };
}
probability ( rain ) {
  table 0.2, 0.8;
}
probability ( sprinkler ) {
  table 0.3, 0.7;
}
probability ( wet | rain, sprinkler ) {
  (yes, on) 0.99, 0.01;
  (yes, off) 0.8, 0.2;
  (no, on) 0.9, 0.1;
  (no, off) 0.05, 0.95;
}
"#;

    #[test]
    fn parses_sample() {
        let bn = parse_bif(SAMPLE).unwrap();
        assert_eq!(bn.n(), 3);
        assert_eq!(bn.cards, vec![2, 2, 2]);
        let wet = bn.names.iter().position(|n| n == "wet").unwrap();
        assert_eq!(bn.dag.parents(wet).count(), 2);
        // P(wet=wet | rain=yes, sprinkler=on) = 0.99
        // parents sorted = [rain=0, sprinkler=1]; cfg (yes=0, on=0) -> 0
        assert!((bn.cpts[wet].row(0)[0] - 0.99).abs() < 1e-9);
        // cfg (no=1, on=0) -> stride rain=1 -> cfg 1
        assert!((bn.cpts[wet].row(1)[0] - 0.9).abs() < 1e-9);
        bn.validate().unwrap();
    }

    #[test]
    fn rejects_row_that_does_not_sum_to_one() {
        let bad = SAMPLE.replace("table 0.2, 0.8;", "table 0.6, 0.6;");
        let e = parse_bif(&bad).unwrap_err();
        assert!(format!("{e}").contains("sums to"), "unexpected error: {e}");
    }

    #[test]
    fn rejects_out_of_range_probability() {
        // Sums to 1 but leaves [0, 1] — the sum check alone would miss it.
        let bad = SAMPLE.replace("(yes, on) 0.99, 0.01;", "(yes, on) 1.4, -0.4;");
        let e = parse_bif(&bad).unwrap_err();
        assert!(format!("{e}").contains("out of [0, 1]"), "unexpected error: {e}");
    }

    #[test]
    fn rejects_missing_and_duplicate_rows() {
        let missing = SAMPLE.replace("(no, off) 0.05, 0.95;", "");
        let e = parse_bif(&missing).unwrap_err();
        assert!(format!("{e}").contains("no CPT row"), "unexpected error: {e}");

        let dup = SAMPLE.replace("(no, off) 0.05, 0.95;", "(yes, on) 0.5, 0.5;");
        let e = parse_bif(&dup).unwrap_err();
        assert!(format!("{e}").contains("duplicate CPT row"), "unexpected error: {e}");
    }

    #[test]
    fn rejects_table_form_under_parents() {
        // A flat `table` for a conditioned node must be a clear error,
        // not a length panic or a silently permuted CPT.
        let bad = SAMPLE.replace(
            "probability ( wet | rain, sprinkler ) {\n  (yes, on) 0.99, 0.01;",
            "probability ( wet | rain, sprinkler ) {\n  table 0.99, 0.01;\n  (yes, on) 0.99, 0.01;",
        );
        let e = parse_bif(&bad).unwrap_err();
        assert!(format!("{e}").contains("ambiguous"), "unexpected error: {e}");
    }

    #[test]
    fn renormalizes_print_rounded_rows() {
        // 1/3 + 2/3 at 7 digits sums to 0.9999999 — inside tolerance,
        // and the parsed row must come back exactly normalized.
        let rounded = SAMPLE.replace("table 0.2, 0.8;", "table 0.3333333, 0.6666666;");
        let bn = parse_bif(&rounded).unwrap();
        let rain = bn.names.iter().position(|n| n == "rain").unwrap();
        let row = bn.cpts[rain].row(0);
        assert!((row.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((row[0] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn roundtrip_through_writer() {
        let bn = crate::bn::netgen::generate(&crate::bn::NetGenConfig::default(), 5);
        let tmp = std::env::temp_dir().join("cges_bif_roundtrip.bif");
        write_bif(&bn, &tmp).unwrap();
        let back = read_bif(&tmp).unwrap();
        assert_eq!(back.n(), bn.n());
        assert_eq!(back.cards, bn.cards);
        let mut e1 = bn.dag.edges();
        let mut e2 = back.dag.edges();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2);
        // CPT values survive within print precision.
        for v in 0..bn.n() {
            for (a, b) in bn.cpts[v].table.iter().zip(&back.cpts[v].table) {
                assert!((a - b).abs() < 1e-8);
            }
        }
        std::fs::remove_file(&tmp).ok();
    }
}
