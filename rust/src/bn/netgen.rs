//! Random network generation: structured DAGs + Dirichlet CPTs.
//!
//! `bn::repo` uses these generators to build the LINK/PIGS/MUNIN
//! analogs; they are also the workload source for property tests and
//! the scaling benches. The topology generator grows a DAG with a
//! target edge count, a hard max-parents cap and mild locality
//! (preferring edges between nearby indices, which mimics the blocked,
//! repeated-substructure layout of the real bnlearn networks and gives
//! the edge-clustering stage real structure to find).

use crate::bn::{Cpt, DiscreteBn};
use crate::graph::Dag;
use crate::rng::Rng;

/// Topology + parameter configuration for a generated network.
#[derive(Clone, Debug)]
pub struct NetGenConfig {
    /// Number of variables.
    pub nodes: usize,
    /// Target edge count (best effort under `max_parents`).
    pub edges: usize,
    /// Hard cap on parents per node.
    pub max_parents: usize,
    /// Inclusive cardinality range, sampled per variable.
    pub card_range: (u32, u32),
    /// Locality window: candidate parents are drawn within this index
    /// distance first (0 = fully random).
    pub locality: usize,
    /// Dirichlet concentration for CPT rows (<1 = sharp, informative
    /// distributions, as in the real repository networks).
    pub alpha: f64,
}

impl Default for NetGenConfig {
    fn default() -> Self {
        NetGenConfig {
            nodes: 50,
            edges: 75,
            max_parents: 3,
            card_range: (2, 4),
            locality: 12,
            alpha: 0.5,
        }
    }
}

/// Generate a random DAG per the config (deterministic in `seed`).
pub fn random_dag(cfg: &NetGenConfig, seed: u64) -> Dag {
    let n = cfg.nodes;
    let mut rng = Rng::new(seed ^ 0xD1CE);
    // Random topological order; edges always point forward in it.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut pos = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        pos[v] = i;
    }

    let mut g = Dag::new(n);
    let mut added = 0usize;
    let mut attempts = 0usize;
    let max_attempts = cfg.edges * 50;
    while added < cfg.edges && attempts < max_attempts {
        attempts += 1;
        // Child uniform; parent from the locality window before it.
        let ci = rng.gen_range_in(1, n);
        let child = order[ci];
        let lo = if cfg.locality > 0 && ci > cfg.locality { ci - cfg.locality } else { 0 };
        let pi = rng.gen_range_in(lo, ci);
        let parent = order[pi];
        if g.has_edge(parent, child) || g.parents(child).count() >= cfg.max_parents {
            continue;
        }
        g.add_edge(parent, child);
        added += 1;
    }
    debug_assert!(g.is_acyclic());
    let _ = pos;
    g
}

/// Attach random Dirichlet CPTs to a structure.
pub fn random_cpts(dag: &Dag, cards: &[u32], alpha: f64, seed: u64) -> Vec<Cpt> {
    let mut rng = Rng::new(seed ^ 0xC9_7A);
    (0..dag.n())
        .map(|v| {
            let mut parents: Vec<usize> = dag.parents(v).iter().collect();
            parents.sort_unstable();
            let r = cards[v] as usize;
            let q: usize = parents.iter().map(|&p| cards[p] as usize).product();
            let mut table = Vec::with_capacity(q * r);
            for _ in 0..q {
                table.extend(rng.dirichlet(r, alpha));
            }
            Cpt { parents, table, r }
        })
        .collect()
}

/// Generate a full network: structure, cardinalities and CPTs.
pub fn generate(cfg: &NetGenConfig, seed: u64) -> DiscreteBn {
    let mut rng = Rng::new(seed);
    let dag = random_dag(cfg, seed);
    let (lo, hi) = cfg.card_range;
    let cards: Vec<u32> = (0..cfg.nodes).map(|_| rng.gen_range_in(lo as usize, hi as usize + 1) as u32).collect();
    let cpts = random_cpts(&dag, &cards, cfg.alpha, seed);
    let names = (0..cfg.nodes).map(|i| format!("X{i}")).collect();
    let bn = DiscreteBn { dag, names, cards, cpts };
    debug_assert!(bn.validate().is_ok());
    bn
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_config() {
        let cfg = NetGenConfig { nodes: 60, edges: 90, max_parents: 3, ..Default::default() };
        let bn = generate(&cfg, 42);
        bn.validate().unwrap();
        assert_eq!(bn.n(), 60);
        assert!(bn.dag.max_in_degree() <= 3);
        // Best-effort edge count should land close to the target.
        let e = bn.dag.edge_count();
        assert!(e >= 80, "only {e} edges added");
        for &c in &bn.cards {
            assert!((2..=4).contains(&c));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = NetGenConfig::default();
        let a = generate(&cfg, 1);
        let b = generate(&cfg, 1);
        let c = generate(&cfg, 2);
        assert_eq!(a.dag.edges(), b.dag.edges());
        assert_eq!(a.cards, b.cards);
        assert_ne!(a.dag.edges(), c.dag.edges());
    }

    #[test]
    fn cpt_rows_normalized() {
        let bn = generate(&NetGenConfig::default(), 9);
        for cpt in &bn.cpts {
            for cfg in 0..cpt.q() {
                let s: f64 = cpt.row(cfg).iter().sum();
                assert!((s - 1.0).abs() < 1e-9);
            }
        }
    }
}
