//! Paper-domain analogs: LINK, PIGS, MUNIN.
//!
//! The experiments in the paper use the three largest discrete bnlearn
//! networks. Offline we cannot fetch the `.bif` originals, so this
//! module generates deterministic analogs matched on every Table 1
//! statistic that drives algorithmic behaviour: node count, edge count,
//! max parents, and the cardinality profile (which together determine
//! the parameter count scale). If real `.bif` files are present (e.g.
//! dropped into `$CGES_BIF_DIR`), `load_domain` prefers them — the rest
//! of the system is agnostic to the source. See DESIGN.md
//! §Substitutions for the fidelity argument.

use std::path::PathBuf;

use crate::bn::netgen::{generate, NetGenConfig};
use crate::bn::DiscreteBn;

/// The paper's three benchmark domains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// 724 nodes, 1125 edges, ≤3 parents, mostly binary/ternary.
    Link,
    /// 441 nodes, 592 edges, ≤2 parents, all 3-state.
    Pigs,
    /// 1041 nodes, 1397 edges, ≤3 parents, up to 21 states.
    Munin,
}

impl Domain {
    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<Domain> {
        match s.to_ascii_lowercase().as_str() {
            "link" => Some(Domain::Link),
            "pigs" => Some(Domain::Pigs),
            "munin" => Some(Domain::Munin),
            _ => None,
        }
    }

    /// Canonical lowercase name.
    pub fn name(&self) -> &'static str {
        match self {
            Domain::Link => "link",
            Domain::Pigs => "pigs",
            Domain::Munin => "munin",
        }
    }

    /// Table 1 reference stats: (nodes, edges, max_parents).
    pub fn paper_stats(&self) -> (usize, usize, usize) {
        match self {
            Domain::Link => (724, 1125, 3),
            Domain::Pigs => (441, 592, 2),
            Domain::Munin => (1041, 1397, 3),
        }
    }

    /// Generator config reproducing the Table 1 profile.
    pub fn config(&self) -> NetGenConfig {
        match self {
            Domain::Link => NetGenConfig {
                nodes: 724,
                edges: 1125,
                max_parents: 3,
                card_range: (2, 4),
                locality: 20,
                alpha: 0.4,
            },
            Domain::Pigs => NetGenConfig {
                nodes: 441,
                edges: 592,
                max_parents: 2,
                card_range: (3, 3),
                locality: 16,
                alpha: 0.3,
            },
            Domain::Munin => NetGenConfig {
                nodes: 1041,
                edges: 1397,
                max_parents: 3,
                card_range: (2, 21),
                locality: 24,
                alpha: 0.4,
            },
        }
    }

    /// Scaled-down config (factor in (0, 1]) keeping density and arity:
    /// used by the default bench scale so `cargo bench` completes in
    /// minutes (`--full` restores factor 1.0 = paper scale).
    pub fn scaled_config(&self, factor: f64) -> NetGenConfig {
        let base = self.config();
        let nodes = ((base.nodes as f64 * factor).round() as usize).max(16);
        let edges = ((base.edges as f64 * factor).round() as usize).max(nodes / 2);
        NetGenConfig { nodes, edges, ..base }
    }
}

/// Deterministic seed per domain (analog identity is stable across
/// machines and runs).
fn domain_seed(d: Domain) -> u64 {
    match d {
        Domain::Link => 0x11_4B,
        Domain::Pigs => 0x91_65,
        Domain::Munin => 0x30_17,
    }
}

/// Load a domain: real `.bif` from `$CGES_BIF_DIR` if present, else the
/// generated analog (optionally scaled).
pub fn load_domain(d: Domain, scale: f64) -> DiscreteBn {
    if (scale - 1.0).abs() < 1e-9 {
        if let Ok(dir) = std::env::var("CGES_BIF_DIR") {
            let path = PathBuf::from(dir).join(format!("{}.bif", d.name()));
            if path.exists() {
                match crate::bn::bif::read_bif(&path) {
                    Ok(bn) => return bn,
                    Err(e) => eprintln!("warning: failed to parse {}: {e}; using analog", path.display()),
                }
            }
        }
    }
    generate(&d.scaled_config(scale), domain_seed(d))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analog_stats_match_table1() {
        for d in [Domain::Pigs, Domain::Link] {
            let bn = load_domain(d, 1.0);
            let (nodes, edges, maxp) = d.paper_stats();
            assert_eq!(bn.n(), nodes, "{:?} nodes", d);
            // Edge targeting is best-effort under the parent cap.
            assert!(
                (bn.dag.edge_count() as f64 - edges as f64).abs() / edges as f64 <= 0.05,
                "{:?}: {} edges vs paper {edges}",
                d,
                bn.dag.edge_count()
            );
            assert!(bn.dag.max_in_degree() <= maxp);
            bn.validate().unwrap();
        }
    }

    #[test]
    fn pigs_all_ternary() {
        let bn = load_domain(Domain::Pigs, 0.2);
        assert!(bn.cards.iter().all(|&c| c == 3));
    }

    #[test]
    fn scaling_preserves_density() {
        let full = Domain::Link.config();
        let half = Domain::Link.scaled_config(0.5);
        let d_full = full.edges as f64 / full.nodes as f64;
        let d_half = half.edges as f64 / half.nodes as f64;
        assert!((d_full - d_half).abs() < 0.1);
    }

    #[test]
    fn domain_parse_roundtrip() {
        for d in [Domain::Link, Domain::Pigs, Domain::Munin] {
            assert_eq!(Domain::parse(d.name()), Some(d));
        }
        assert_eq!(Domain::parse("nope"), None);
    }
}
