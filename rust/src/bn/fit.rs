//! CPT estimation: a learned structure + data → a queryable network.
//!
//! Dirichlet-smoothed maximum likelihood with the BDeu-style prior the
//! scorer already assumes: cell pseudo-count `ess / (q·r)`, so
//! `P(x_k | pa_j) = (N_jk + ess/(q r)) / (N_j + ess/q)`. Unobserved
//! parent configurations fall back to the uniform prior instead of
//! NaN, and the sufficient statistics come from the same
//! [`family_counts`] kernel the learners count with — fitting a
//! 1000-variable network is one counting pass per family.

use anyhow::{anyhow, bail, ensure, Result};

use crate::bn::{Cpt, DiscreteBn};
use crate::data::Dataset;
use crate::graph::Dag;
use crate::score::counts::{family_counts, CountsTable};

/// Largest CPT (`q·r` cells) `fit` materializes. Kept at the dense
/// counting limit so the sufficient statistics are always a dense
/// table; a learned family past this is a modeling bug, not a memory
/// plan.
const MAX_CPT_CELLS: u64 = 4 << 20;

/// Fit Dirichlet-smoothed maximum-likelihood CPTs for `dag` from
/// `data` (`ess` > 0 is the equivalent sample size, matching the
/// scorer's η).
pub fn fit(dag: &Dag, data: &Dataset, ess: f64) -> Result<DiscreteBn> {
    ensure!(
        dag.n() == data.n_vars(),
        "structure has {} nodes but data has {} variables",
        dag.n(),
        data.n_vars()
    );
    ensure!(ess > 0.0 && ess.is_finite(), "ess must be positive and finite (got {ess})");
    ensure!(dag.is_acyclic(), "structure has a cycle");

    let mut cpts = Vec::with_capacity(dag.n());
    for v in 0..dag.n() {
        let parents: Vec<usize> = dag.parents(v).iter().collect(); // ascending
        let r = data.card(v) as usize;
        let q64: u64 = parents.iter().map(|&p| data.card(p) as u64).product();
        let cells = q64.saturating_mul(r as u64);
        if cells > MAX_CPT_CELLS {
            bail!(
                "family of {} has {q64} parent configurations ({cells} cells > cap {MAX_CPT_CELLS}); \
                 reduce its parent set before fitting",
                data.name(v)
            );
        }
        let q = q64 as usize;
        let a_cell = ess / (q * r) as f64;
        let a_cfg = ess / q as f64;

        let counts = family_counts(data, v, &parents);
        let dense = match &counts.table {
            CountsTable::Dense(c) => c,
            _ => {
                // Unreachable: MAX_CPT_CELLS is below the dense limit,
                // so neither sparse form can be produced here.
                bail!("internal error: sparse counts for a {cells}-cell family")
            }
        };
        let mut table = vec![0.0f64; q * r];
        for (row, hist) in table.chunks_exact_mut(r).zip(dense.chunks_exact(r)) {
            let nj: u64 = hist.iter().map(|&x| x as u64).sum();
            let denom = nj as f64 + a_cfg;
            for (slot, &njk) in row.iter_mut().zip(hist) {
                *slot = (njk as f64 + a_cell) / denom;
            }
        }
        cpts.push(Cpt { parents, table, r });
    }

    let bn = DiscreteBn {
        dag: dag.clone(),
        names: data.names().to_vec(),
        cards: data.cards().to_vec(),
        cpts,
    };
    bn.validate().map_err(|e| anyhow!("fitted network failed validation: {e}"))?;
    Ok(bn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;
    use crate::bn::sampler::forward_sample;

    #[test]
    fn recovers_generating_cpts() {
        let truth = tiny_bn();
        let data = forward_sample(&truth, 50_000, 11);
        let fitted = fit(&truth.dag, &data, 1.0).unwrap();
        fitted.validate().unwrap();
        assert_eq!(fitted.names, truth.names);
        for (fc, tc) in fitted.cpts.iter().zip(&truth.cpts) {
            assert_eq!(fc.parents, tc.parents);
            for (a, b) in fc.table.iter().zip(&tc.table) {
                assert!((a - b).abs() < 0.02, "fitted {a} vs true {b}");
            }
        }
    }

    #[test]
    fn unobserved_configs_get_uniform_prior() {
        // One-column dataset never shows state 2 of a 3-state variable.
        let data = Dataset::unnamed(vec![3], vec![vec![0, 0, 1]]);
        let dag = Dag::new(1);
        let bn = fit(&dag, &data, 3.0).unwrap();
        // counts [2, 1, 0], alpha_cell = 1 -> probs (3,2,1)/6.
        let t = &bn.cpts[0].table;
        assert!((t[0] - 0.5).abs() < 1e-12);
        assert!((t[1] - 2.0 / 6.0).abs() < 1e-12);
        assert!((t[2] - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn empty_dataset_is_pure_prior() {
        let data = Dataset::unnamed(vec![2, 2], vec![Vec::new(), Vec::new()]);
        let dag = Dag::from_edges(2, &[(0, 1)]);
        let bn = fit(&dag, &data, 8.0).unwrap();
        for cpt in &bn.cpts {
            for cfg in 0..cpt.q() {
                for &p in cpt.row(cfg) {
                    assert!((p - 0.5).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let truth = tiny_bn();
        let data = forward_sample(&truth, 10, 3);
        assert!(fit(&Dag::new(3), &data, 1.0).is_err()); // n mismatch
        assert!(fit(&truth.dag, &data, 0.0).is_err()); // ess must be > 0
        assert!(fit(&truth.dag, &data, -1.0).is_err());
    }
}
