//! Bayesian-network substrate: representation, BIF interchange,
//! generators (paper-domain analogs) and forward sampling.

pub mod bif;
pub mod fit;
pub mod netgen;
pub mod network;
pub mod repo;
pub mod sampler;

pub use bif::{parse_bif, read_bif, write_bif};
pub use fit::fit;
pub use netgen::{generate, NetGenConfig};
pub use network::{Cpt, DiscreteBn};
pub use repo::{load_domain, Domain};
pub use sampler::forward_sample;
