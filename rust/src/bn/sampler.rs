//! Forward (ancestral) sampling: draw complete-data datasets from a
//! `DiscreteBn` — the process the paper used to create its 11×5000-row
//! OpenML datasets from each bnlearn network.

use crate::bn::DiscreteBn;
use crate::data::Dataset;
use crate::rng::Rng;

/// Sample `rows` complete instances with the given seed.
pub fn forward_sample(bn: &DiscreteBn, rows: usize, seed: u64) -> Dataset {
    let n = bn.n();
    let order = bn.dag.topological_order().expect("BN structure must be acyclic");
    let mut rng = Rng::new(seed);
    let mut cols: Vec<Vec<u8>> = vec![vec![0u8; rows]; n];
    let mut states = vec![0u8; n];
    for t in 0..rows {
        for &v in &order {
            let cfg = bn.parent_config(v, &states, &bn.cards);
            let s = rng.categorical(bn.cpts[v].row(cfg));
            states[v] = s as u8;
            cols[v][t] = s as u8;
        }
    }
    Dataset::new(bn.names.clone(), bn.cards.clone(), cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn marginals_converge_to_cpts() {
        let bn = tiny_bn();
        let d = forward_sample(&bn, 40_000, 7);
        // P(a=0) = 0.7
        let p_a0 = d.col(0).iter().filter(|&&s| s == 0).count() as f64 / 40_000.0;
        assert!((p_a0 - 0.7).abs() < 0.01, "p_a0={p_a0}");
        // P(b=0) = 0.7*0.9 + 0.3*0.2 = 0.69
        let p_b0 = d.col(1).iter().filter(|&&s| s == 0).count() as f64 / 40_000.0;
        assert!((p_b0 - 0.69).abs() < 0.01, "p_b0={p_b0}");
        // Conditional: P(b=0 | a=0) = 0.9
        let (mut n_a0, mut n_b0a0) = (0usize, 0usize);
        for t in 0..d.n_rows() {
            if d.col(0)[t] == 0 {
                n_a0 += 1;
                if d.col(1)[t] == 0 {
                    n_b0a0 += 1;
                }
            }
        }
        assert!((n_b0a0 as f64 / n_a0 as f64 - 0.9).abs() < 0.01);
    }

    #[test]
    fn deterministic_per_seed() {
        let bn = tiny_bn();
        let a = forward_sample(&bn, 100, 3);
        let b = forward_sample(&bn, 100, 3);
        let c = forward_sample(&bn, 100, 4);
        assert_eq!(a.col(0), b.col(0));
        assert_eq!(a.col(1), b.col(1));
        assert_ne!(a.col(0), c.col(0));
    }
}
