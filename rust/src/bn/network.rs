//! Discrete Bayesian network: DAG + conditional probability tables.
//!
//! Ground-truth networks (generated analogs or parsed BIF files) are
//! instances of this type; the sampler draws datasets from it and the
//! metrics compare learned structures against its DAG.

use crate::graph::Dag;

/// CPT of one variable: `table[cfg * r + k] = P(X = k | pa-config cfg)`.
/// Parent configurations are mixed-radix encoded over `parents` in
/// ascending variable order, first parent = least-significant digit.
#[derive(Clone, Debug)]
pub struct Cpt {
    /// Parent variable indices, ascending.
    pub parents: Vec<usize>,
    /// Flattened `(q, r)` probability table, rows sum to 1.
    pub table: Vec<f64>,
    /// Child cardinality.
    pub r: usize,
}

impl Cpt {
    /// Number of parent configurations.
    pub fn q(&self) -> usize {
        self.table.len() / self.r
    }

    /// Distribution row for a parent configuration.
    pub fn row(&self, cfg: usize) -> &[f64] {
        &self.table[cfg * self.r..(cfg + 1) * self.r]
    }
}

/// Discrete Bayesian network.
#[derive(Clone)]
pub struct DiscreteBn {
    /// Structure.
    pub dag: Dag,
    /// Variable names.
    pub names: Vec<String>,
    /// Cardinalities.
    pub cards: Vec<u32>,
    /// One CPT per variable (aligned with node indices).
    pub cpts: Vec<Cpt>,
}

impl DiscreteBn {
    /// Number of variables.
    pub fn n(&self) -> usize {
        self.dag.n()
    }

    /// Total number of free parameters: Σ q_i (r_i - 1).
    pub fn parameter_count(&self) -> usize {
        self.cpts.iter().map(|c| c.q() * (c.r - 1)).sum()
    }

    /// Mixed-radix parent configuration of row `t` in `states`.
    pub fn parent_config(&self, v: usize, states: &[u8], cards: &[u32]) -> usize {
        let mut cfg = 0usize;
        let mut stride = 1usize;
        for &p in &self.cpts[v].parents {
            cfg += stride * states[p] as usize;
            stride *= cards[p] as usize;
        }
        cfg
    }

    /// Log-likelihood of one complete instance (states indexed by
    /// variable).
    pub fn log_likelihood_row(&self, states: &[u8]) -> f64 {
        let mut ll = 0.0;
        for v in 0..self.n() {
            let cfg = self.parent_config(v, states, &self.cards);
            let p = self.cpts[v].row(cfg)[states[v] as usize];
            ll += p.max(1e-300).ln();
        }
        ll
    }

    /// Structural sanity: CPT parents match the DAG, rows normalized.
    pub fn validate(&self) -> Result<(), String> {
        for v in 0..self.n() {
            let mut pa: Vec<usize> = self.dag.parents(v).iter().collect();
            pa.sort_unstable();
            if pa != self.cpts[v].parents {
                return Err(format!("node {v}: CPT parents {:?} != DAG {:?}", self.cpts[v].parents, pa));
            }
            let q: usize = pa.iter().map(|&p| self.cards[p] as usize).product();
            if self.cpts[v].q() != q {
                return Err(format!("node {v}: q mismatch"));
            }
            for cfg in 0..q {
                let s: f64 = self.cpts[v].row(cfg).iter().sum();
                if (s - 1.0).abs() > 1e-6 {
                    return Err(format!("node {v} cfg {cfg}: row sums to {s}"));
                }
            }
        }
        if !self.dag.is_acyclic() {
            return Err("cyclic structure".into());
        }
        Ok(())
    }
}

/// Two-node test network `a -> b` (shared across module tests).
#[cfg(test)]
pub(crate) fn tiny_bn() -> DiscreteBn {
    let dag = Dag::from_edges(2, &[(0, 1)]);
    DiscreteBn {
        dag,
        names: vec!["a".into(), "b".into()],
        cards: vec![2, 2],
        cpts: vec![
            Cpt { parents: vec![], table: vec![0.7, 0.3], r: 2 },
            Cpt { parents: vec![0], table: vec![0.9, 0.1, 0.2, 0.8], r: 2 },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_and_counts_params() {
        let bn = tiny_bn();
        bn.validate().unwrap();
        assert_eq!(bn.parameter_count(), 1 + 2);
    }

    #[test]
    fn loglik_of_row() {
        let bn = tiny_bn();
        // P(a=0) * P(b=1 | a=0) = 0.7 * 0.1
        let ll = bn.log_likelihood_row(&[0, 1]);
        assert!((ll - (0.7f64 * 0.1).ln()).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_bad_rows() {
        let mut bn = tiny_bn();
        bn.cpts[0].table = vec![0.5, 0.2];
        assert!(bn.validate().is_err());
    }
}
