//! The paper's system contribution: the directed-ring distributed
//! learning coordinator (Algorithm 1) plus run telemetry.

pub mod ring;
pub mod telemetry;

pub use ring::{cges, insert_limit, PartitionSource, RingConfig, RingResult};
pub use telemetry::{RoundRecord, Telemetry};
