//! The paper's system contribution: the directed-ring distributed
//! learning coordinator (Algorithm 1) as a message-passing runtime —
//! actor-style workers over a pluggable [`transport`] — plus run
//! telemetry.

pub mod fault;
pub mod ring;
pub mod telemetry;
pub mod transport;

pub use fault::{
    ChaosTransport, FaultAction, FaultEvent, FaultPlan, FaultPolicy, FaultStats, FaultSummary,
    RingFault,
};
pub use ring::{
    cges, insert_limit, run_ring, BundleEmit, PartitionSource, RingConfig, RingMode,
    RingObsHub, RingOutcome, RingResult, RingRunOptions, WorkerObsCtx,
};
pub use telemetry::{RoundRecord, Telemetry, WorkerTimeline};
pub use transport::{
    ChannelTransport, ModelMsg, ObsPayload, RingLink, RingMessage, RingRx, RingToken,
    RingTransport, RingTx, RoundProbe, WireTransport,
};
