//! The cGES ring coordinator — Algorithm 1 of the paper.
//!
//! Stage 1 (edge partitioning): pairwise BDeu similarities — from the
//! AOT XLA artifact when available, the threaded Rust fallback
//! otherwise — feed the hierarchical clustering and the balanced edge
//! assignment (`partition`).
//!
//! Stage 2 (ring learning): k workers, one per edge subset E_i,
//! synchronous rounds. In round t worker i fuses its own model
//! G_i^{t-1} with its predecessor's G_{i-1}^{t-1} (`fusion`), then runs
//! GES restricted to E_i, optionally capped at l = (10/k)·√n inserts
//! (cGES-L). All workers share one concurrent score cache; candidate
//! scoring inside each worker is threaded so the whole machine stays at
//! `threads` busy cores (the paper's 8).
//!
//! Convergence: the round's best BDeu must beat the best seen so far,
//! else the learning stage stops (Algorithm 1 lines 11-16).
//!
//! Stage 3 (fine tuning): one unrestricted GES from the ring's best
//! model — this run is what transfers GES's theoretical guarantees to
//! cGES.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::telemetry::{RoundRecord, Telemetry};
use crate::data::Dataset;
use crate::fusion::fuse;
use crate::graph::Dag;
use crate::learn::{ges, EdgeMask, GesConfig, RingWorker};
use crate::partition::partition_edges;
use crate::score::{BdeuScorer, PairwiseScores, ScoreCache};
use crate::util::Timer;

/// Where stage 1 gets its pairwise similarities.
#[derive(Clone, Debug, Default)]
pub enum PartitionSource {
    /// Load + execute the AOT artifact from this directory; fall back
    /// to Rust (with a warning) if no config fits.
    Artifacts(PathBuf),
    /// Always use the threaded Rust implementation.
    #[default]
    RustFallback,
}

/// Ring configuration.
#[derive(Clone)]
pub struct RingConfig {
    /// Number of ring processes / edge subsets (paper: 2, 4, 8).
    pub k: usize,
    /// cGES-L: cap FES inserts per round at (10/k)·√n.
    pub limit_inserts: bool,
    /// BDeu equivalent sample size.
    pub ess: f64,
    /// Total scoring threads, shared across workers (paper: 8).
    pub threads: usize,
    /// Safety cap on rounds (the paper iterates to convergence).
    pub max_rounds: usize,
    /// Stage-1 similarity source.
    pub partition_source: PartitionSource,
    /// Run the stage-3 unrestricted GES.
    pub fine_tune: bool,
    /// Optional hard max-parents cap passed to the learners.
    pub max_parents: Option<usize>,
}

impl Default for RingConfig {
    fn default() -> Self {
        RingConfig {
            k: 4,
            limit_inserts: true,
            ess: 10.0,
            threads: crate::util::num_threads(),
            max_rounds: 50,
            partition_source: PartitionSource::RustFallback,
            fine_tune: true,
            max_parents: None,
        }
    }
}

/// Ring outcome.
pub struct RingResult {
    /// Final structure (after fine tuning if enabled).
    pub dag: Dag,
    /// Its BDeu score.
    pub score: f64,
    /// Learning-stage rounds executed.
    pub rounds: usize,
    /// Telemetry (per-round records, stage times, cache stats).
    pub telemetry: Telemetry,
}

/// The cGES-L insert limit l = (10/k)·√n.
pub fn insert_limit(k: usize, n: usize) -> usize {
    ((10.0 / k as f64) * (n as f64).sqrt()).ceil() as usize
}

/// Compute stage-1 similarities, preferring the artifact path.
fn stage1_similarity(
    data: &Arc<Dataset>,
    cfg: &RingConfig,
) -> (PairwiseScores, String) {
    match &cfg.partition_source {
        PartitionSource::Artifacts(dir) => {
            match crate::runtime::SimilarityRuntime::load(dir) {
                Ok(rt) if rt.supports(data) => match rt.pairwise(data, cfg.ess) {
                    Ok(s) => return (s, format!("xla:{}", rt.platform())),
                    Err(e) => eprintln!("warning: artifact execution failed ({e}); falling back to Rust"),
                },
                Ok(_) => eprintln!(
                    "warning: no artifact config fits n={} m={} r={}; falling back to Rust",
                    data.n_vars(),
                    data.n_rows(),
                    data.max_card()
                ),
                Err(e) => eprintln!("warning: artifact load failed ({e}); falling back to Rust"),
            }
            (crate::score::pairwise_similarity(data, cfg.ess, cfg.threads), "rust-fallback".into())
        }
        PartitionSource::RustFallback => {
            (crate::score::pairwise_similarity(data, cfg.ess, cfg.threads), "rust-fallback".into())
        }
    }
}

/// Run cGES on a dataset.
pub fn cges(data: Arc<Dataset>, cfg: &RingConfig) -> Result<RingResult> {
    assert!(cfg.k >= 1, "ring needs at least one process");
    let n = data.n_vars();
    let mut telemetry = Telemetry::default();

    // ---- Stage 1: edge partitioning -------------------------------
    let t = Timer::start();
    let (pairwise, source) = stage1_similarity(&data, cfg);
    let masks: Vec<Arc<EdgeMask>> =
        partition_edges(&pairwise.s, cfg.k).into_iter().map(Arc::new).collect();
    let seed = Arc::new(pairwise.s);
    telemetry.partition_secs = t.secs();
    telemetry.partition_source = source;

    // Shared score cache across every worker and stage.
    let cache = Arc::new(ScoreCache::new());
    let scorer = BdeuScorer::with_cache(data.clone(), cfg.ess, cache.clone());

    let limit = cfg.limit_inserts.then(|| insert_limit(cfg.k, n));
    let worker_threads = (cfg.threads / cfg.k).max(1);

    // ---- Stage 2: ring learning -----------------------------------
    // Workers keep their search state (candidate heaps, version
    // stamps) across rounds: a round only re-evaluates pairs the
    // fusion actually changed (see learn::ges::RingWorker — the §Perf
    // optimization that makes the ring competitive with heap-GES).
    let t = Timer::start();
    let mut workers: Vec<RingWorker> = (0..cfg.k)
        .map(|i| {
            let ges_cfg = GesConfig {
                threads: worker_threads,
                insert_limit: limit,
                mask: Some(masks[i].clone()),
                max_parents: cfg.max_parents,
                seed: Some(seed.clone()),
                iterate_until_stable: false,
                forward_empty_t: false,
            };
            RingWorker::new(scorer.clone(), ges_cfg)
        })
        .collect();
    let mut models: Vec<Dag> = vec![Dag::new(n); cfg.k];
    let mut best_score = f64::NEG_INFINITY;
    let mut best_dag = Dag::new(n);
    let mut rounds = 0usize;

    'rounds: for round in 0..cfg.max_rounds {
        rounds = round + 1;
        // Jacobi-synchronous ring step: worker i consumes its own model
        // and predecessor (i-1)'s model from the previous round.
        let prev = models.clone();
        let results: Vec<(Dag, RoundRecord)> = std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .iter_mut()
                .enumerate()
                .map(|(i, worker)| {
                    let scorer = scorer.clone();
                    let own = &prev[i];
                    let pred = &prev[(i + cfg.k - 1) % cfg.k];
                    s.spawn(move || {
                        // Fusion (skipped in round 0: nothing learned yet).
                        let ft = Timer::start();
                        if round > 0 {
                            let (fused, _sigma) = fuse(&[own, pred]);
                            worker.absorb(&fused);
                        }
                        let fusion_secs = ft.secs();

                        // Constrained GES resuming the persistent state.
                        let gt = Timer::start();
                        let (inserts, deletes) = worker.step(limit);
                        let dag = worker.dag();
                        let rec = RoundRecord {
                            round,
                            worker: i,
                            fusion_secs,
                            ges_secs: gt.secs(),
                            score: scorer.score_dag(&dag),
                            edges: dag.edge_count(),
                            inserts,
                            deletes,
                        };
                        (dag, rec)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("ring worker panicked")).collect()
        });

        // Convergence check (Algorithm 1, lines 11-16).
        let mut improved = false;
        for (i, (dag, rec)) in results.into_iter().enumerate() {
            if rec.score > best_score {
                best_score = rec.score;
                best_dag = dag.clone();
                improved = true;
            }
            telemetry.records.push(rec);
            models[i] = dag;
        }
        if !improved {
            break 'rounds;
        }
    }
    telemetry.learning_secs = t.secs();

    // ---- Stage 3: fine tuning --------------------------------------
    let t = Timer::start();
    let (dag, score) = if cfg.fine_tune {
        let ges_cfg = GesConfig {
            threads: cfg.threads,
            insert_limit: None,
            mask: None,
            max_parents: cfg.max_parents,
            seed: None,
            iterate_until_stable: false,
            forward_empty_t: false,
        };
        let r = ges(&scorer, &best_dag, &ges_cfg);
        (r.dag, r.score)
    } else {
        (best_dag, best_score)
    };
    telemetry.fine_tune_secs = t.secs();

    let (hits, misses) = cache.stats();
    telemetry.cache_hits = hits;
    telemetry.cache_misses = misses;

    Ok(RingResult { dag, score, rounds, telemetry })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{forward_sample, generate, NetGenConfig};
    use crate::learn::GesConfig;

    fn workload(nodes: usize, edges: usize, seed: u64) -> (crate::bn::DiscreteBn, Arc<Dataset>) {
        let bn = generate(&NetGenConfig { nodes, edges, ..Default::default() }, seed);
        let data = Arc::new(forward_sample(&bn, 1500, seed + 1));
        (bn, data)
    }

    #[test]
    fn cges_beats_empty_and_converges() {
        let (_bn, data) = workload(20, 28, 41);
        let cfg = RingConfig { k: 2, threads: 4, ..Default::default() };
        let r = cges(data.clone(), &cfg).unwrap();
        let sc = BdeuScorer::new(data, cfg.ess);
        assert!(r.score > sc.score_dag(&Dag::new(20)));
        assert!(r.rounds >= 1 && r.rounds < cfg.max_rounds);
        assert!(!r.telemetry.records.is_empty());
        let (h, _m) = (r.telemetry.cache_hits, r.telemetry.cache_misses);
        assert!(h > 0, "workers must share the cache");
    }

    #[test]
    fn cges_k1_close_to_plain_ges() {
        let (_bn, data) = workload(14, 18, 7);
        let cfg = RingConfig {
            k: 1,
            limit_inserts: false,
            threads: 2,
            ..Default::default()
        };
        let ring = cges(data.clone(), &cfg).unwrap();
        let sc = BdeuScorer::new(data, cfg.ess);
        let plain = ges(&sc, &Dag::new(14), &GesConfig { threads: 2, ..Default::default() });
        assert!(
            (ring.score - plain.score).abs() < 1e-6,
            "k=1 unlimited ring = GES: {} vs {}",
            ring.score,
            plain.score
        );
    }

    #[test]
    fn limit_policy_applies() {
        assert_eq!(insert_limit(4, 400), 50);
        assert_eq!(insert_limit(2, 100), 50);
        let (_bn, data) = workload(16, 24, 3);
        let cfg = RingConfig { k: 4, limit_inserts: true, threads: 4, fine_tune: false, ..Default::default() };
        let r = cges(data, &cfg).unwrap();
        let l = insert_limit(4, 16);
        for rec in &r.telemetry.records {
            assert!(rec.inserts <= l, "round {} worker {} inserted {}", rec.round, rec.worker, rec.inserts);
        }
    }

    #[test]
    fn fine_tune_only_improves() {
        let (_bn, data) = workload(18, 26, 11);
        let base = RingConfig { k: 2, threads: 4, fine_tune: false, ..Default::default() };
        let no_ft = cges(data.clone(), &base).unwrap();
        let with_ft = cges(data, &RingConfig { fine_tune: true, ..base }).unwrap();
        assert!(with_ft.score >= no_ft.score - 1e-9);
    }
}
