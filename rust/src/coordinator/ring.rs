//! The cGES ring coordinator — Algorithm 1 of the paper, as a
//! message-passing runtime.
//!
//! Stage 1 (edge partitioning): pairwise BDeu similarities — from the
//! AOT XLA artifact when available, the threaded Rust fallback
//! otherwise — feed the hierarchical clustering and the balanced edge
//! assignment (`partition`).
//!
//! Stage 2 (ring learning): k long-lived workers, one per edge subset
//! E_i, connected in a directed ring through a
//! [`RingTransport`](crate::coordinator::transport). Each worker owns
//! its [`RingWorker`] search state for the whole run: it receives its
//! predecessor's round-(t−1) model, fuses it with its own (`fusion`),
//! runs GES restricted to E_i — optionally capped at l = (10/k)·√n
//! inserts (cGES-L) — and sends the result to its successor. No global
//! barrier: worker i can be at round t+2 while worker j is still at
//! round t (the paper's true dataflow, which the previous
//! Jacobi-synchronous implementation serialized).
//!
//! Convergence: a token circulates the ring carrying the best-seen
//! BDeu per round (see `transport::RoundProbe`). The ring head applies
//! the paper's rule — stop when a round fails to improve the best
//! score seen so far (Algorithm 1 lines 11–16) — and a `Stop` marker
//! then makes one circuit so every link drains. The coordinator also
//! folds the workers' event stream and raises a stop flag as soon as
//! the deciding round completes, bounding speculative work to ~1 round
//! instead of the k-round token latency.
//!
//! Determinism: per-worker dataflow is identical in every mode (same
//! fusion inputs, same search steps), and the stop round is a pure
//! function of the per-round scores, so the pipelined runtime returns
//! the *same* `(dag, score)` as [`RingMode::Deterministic`] — the
//! barrier-synchronous reference scheduler kept for paper-comparable
//! (Table 2) runs. Pipelining only changes wall-clock and how many
//! speculative hops past the stop round get computed (they are
//! recorded in telemetry but never affect the result).
//!
//! Stage 3 (fine tuning): one unrestricted GES from the ring's best
//! model — this run is what transfers GES's theoretical guarantees to
//! cGES.
//!
//! Bundle emission ([`RingRunOptions`]`::emit`): ring workers can
//! additionally fit CPTs on their own data each round and ship the
//! result as a self-contained [`Bundle`] — structure, parameters and
//! calibrated jointree potentials — alongside the structure (gated by
//! the `ship_bundles` wire capability flag so potential-less peers
//! keep receiving byte-identical legacy frames), with the coordinator
//! keeping the winning round's bundle ([`RingOutcome::best_bundle`]).
//! That path is for rings whose coordinator holds no data — the
//! federated example's per-shard sites are the canonical user.
//! [`cges`], whose workers all score one shared dataset, instead fits
//! and calibrates the final model once ([`RingConfig::emit_bundle`] →
//! [`RingResult::bundle`]) — identical bytes, none of the in-loop
//! fitting cost.
//!
//! Distributed observability ([`RingRunOptions`]`::obs`): with a
//! [`RingObsHub`] installed, each worker keeps its own [`obs::Tracer`]
//! and [`obs::Registry`], clock-aligns with its ring predecessor
//! before any round traffic (NTP-style over wire links, exact epoch
//! arithmetic in-process), and piggybacks span batches + metric deltas
//! on its round messages. Shipments hop toward the ring head, rebased
//! onto each holder's clock per link, and the head relays them to the
//! coordinator, which merges every worker's metrics under a
//! `worker<k>.` prefix and files every span — mapped onto the
//! coordinator's clock — into one trace with one lane per worker.
//! Same capability contract as bundles: with the hub absent, frames
//! stay byte-identical to the legacy format.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::fault::{
    panic_message, recv_with_policy, ChaosTransport, FaultPlan, FaultPolicy, FaultStats,
    FaultSummary, RingFault,
};
use crate::coordinator::telemetry::{RoundRecord, Telemetry};
use crate::coordinator::transport::{
    ChannelTransport, ModelMsg, ObsPayload, RingLink, RingMessage, RingRx, RingToken,
    RingTransport, RingTx, RoundProbe, WireTransport,
};
use crate::data::Dataset;
use crate::graph::Dag;
use crate::learn::{EdgeMask, GesConfig, RingWorker};
use crate::model::{Bundle, BundleMeta};
use crate::obs;
use crate::partition::partition_edges;
use crate::score::{BdeuScorer, CountConfig, CountMode, PairwiseScores, ScoreCache};
use crate::util::Timer;

/// Where stage 1 gets its pairwise similarities.
#[derive(Clone, Debug, Default)]
pub enum PartitionSource {
    /// Load + execute the AOT artifact from this directory; fall back
    /// to Rust (with a warning) if no config fits.
    Artifacts(PathBuf),
    /// Always use the threaded Rust implementation.
    #[default]
    RustFallback,
}

/// How the stage-2 ring executes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RingMode {
    /// Barrier-synchronous reference scheduler: all workers step in
    /// lock-step, no speculation. Same `(dag, score)` as the pipelined
    /// modes; kept for reproducing the paper's Table 2 exactly and for
    /// debugging.
    Deterministic,
    /// Actor threads over in-process mpsc channels (the default).
    #[default]
    Channel,
    /// Actor threads over loopback TCP: every model crosses a real
    /// byte boundary through the wire codec. Same results, measurable
    /// `codec_secs` — and the proof that the ring is remotable.
    Tcp,
}

impl RingMode {
    /// Parse a CLI name (`sync`/`deterministic`, `channel`, `tcp`/`wire`).
    pub fn parse(s: &str) -> Option<RingMode> {
        match s {
            "sync" | "deterministic" => Some(RingMode::Deterministic),
            "channel" | "mpsc" => Some(RingMode::Channel),
            "tcp" | "wire" => Some(RingMode::Tcp),
            _ => None,
        }
    }

    /// Telemetry name.
    pub fn name(self) -> &'static str {
        match self {
            RingMode::Deterministic => "deterministic",
            RingMode::Channel => "channel",
            RingMode::Tcp => "tcp",
        }
    }
}

/// Ring configuration.
#[derive(Clone)]
pub struct RingConfig {
    /// Number of ring processes / edge subsets (paper: 2, 4, 8).
    pub k: usize,
    /// cGES-L: cap FES inserts per round at (10/k)·√n.
    pub limit_inserts: bool,
    /// BDeu equivalent sample size.
    pub ess: f64,
    /// Total scoring threads, shared across workers (paper: 8).
    pub threads: usize,
    /// Safety cap on rounds (the paper iterates to convergence).
    pub max_rounds: usize,
    /// Stage-1 similarity source.
    pub partition_source: PartitionSource,
    /// Run the stage-3 unrestricted GES.
    pub fine_tune: bool,
    /// Optional hard max-parents cap passed to the learners.
    pub max_parents: Option<usize>,
    /// Stage-2 execution mode / transport.
    pub mode: RingMode,
    /// Emit a self-contained model [`Bundle`] for the final structure
    /// (fitted CPTs + calibrated jointree potentials): one fit +
    /// compile + calibrate at the end of the run. Opt in for runs
    /// that end in serving.
    pub emit_bundle: bool,
    /// Equivalent sample size for the bundle's CPT fit (the CLI's
    /// `fit --ess` default).
    pub bundle_ess: f64,
    /// Counting engine for the shared scorer: `Packed` (word-parallel
    /// fast paths) or `Reference` (scalar oracle — bit-identical
    /// scores, for pinning and perf baselines).
    pub count_mode: CountMode,
    /// Metrics registry to bind the run's live counters and export
    /// stage/hop metrics into (`None` skips all registration).
    pub registry: Option<obs::Registry>,
    /// Span tracer threaded through the coordinator and every ring
    /// worker; disabled by default (one atomic probe per span site).
    pub tracer: obs::Tracer,
    /// Ring-wide distributed-observability capability: give each
    /// worker its own clock domain, clock-align the links, and ship
    /// spans + metric deltas on the ring's round messages, merged live
    /// into `tracer` (one lane per worker) and `registry` (worker
    /// series under `worker<k>.`). Changes the wire format (obs frame
    /// tags) but never the learned result. Ignored in
    /// [`RingMode::Deterministic`], which has no ring messages.
    pub distributed_obs: bool,
    /// Fault tolerance knobs (recv deadline, straggler skip, decode
    /// retries, ring healing). The default is inert: fault-free runs
    /// are byte/bit-identical with or without it.
    pub fault_policy: FaultPolicy,
    /// Scripted fault injection for the pipelined transports (the
    /// `learn --fault-plan` debug flag). `None` or an empty plan is a
    /// pure pass-through.
    pub fault_plan: Option<FaultPlan>,
}

impl Default for RingConfig {
    fn default() -> Self {
        RingConfig {
            k: 4,
            limit_inserts: true,
            ess: 10.0,
            threads: crate::util::num_threads(),
            max_rounds: 50,
            partition_source: PartitionSource::RustFallback,
            fine_tune: true,
            max_parents: None,
            mode: RingMode::default(),
            emit_bundle: false,
            bundle_ess: 1.0,
            count_mode: CountMode::Packed,
            registry: None,
            tracer: obs::Tracer::disabled(),
            distributed_obs: false,
            fault_policy: FaultPolicy::default(),
            fault_plan: None,
        }
    }
}

/// Ring outcome.
pub struct RingResult {
    /// Final structure (after fine tuning if enabled).
    pub dag: Dag,
    /// Its BDeu score.
    pub score: f64,
    /// Learning-stage rounds counted toward convergence.
    pub rounds: usize,
    /// Telemetry (per-hop records, worker timelines, stage times,
    /// cache stats).
    pub telemetry: Telemetry,
    /// The final model as a self-contained artifact, when
    /// [`RingConfig::emit_bundle`] is on: `dag` + CPTs fitted at
    /// [`RingConfig::bundle_ess`] + calibrated potentials (when the
    /// jointree fits the budget).
    pub bundle: Option<Bundle>,
}

/// The cGES-L insert limit l = (10/k)·√n.
pub fn insert_limit(k: usize, n: usize) -> usize {
    ((10.0 / k as f64) * (n as f64).sqrt()).ceil() as usize
}

/// Compute stage-1 similarities, preferring the artifact path. Every
/// miss (load failure, no fitting config, execution failure) warns and
/// falls through to the single Rust-fallback path at the bottom.
fn stage1_similarity(data: &Arc<Dataset>, cfg: &RingConfig) -> (PairwiseScores, String) {
    if let PartitionSource::Artifacts(dir) = &cfg.partition_source {
        match crate::runtime::SimilarityRuntime::load(dir) {
            Ok(rt) if rt.supports(data) => match rt.pairwise(data, cfg.ess) {
                Ok(s) => return (s, format!("xla:{}", rt.platform())),
                Err(e) => {
                    eprintln!("warning: artifact execution failed ({e}); falling back to Rust")
                }
            },
            Ok(_) => eprintln!(
                "warning: no artifact config fits n={} m={} r={}; falling back to Rust",
                data.n_vars(),
                data.n_rows(),
                data.max_card()
            ),
            Err(e) => eprintln!("warning: artifact load failed ({e}); falling back to Rust"),
        }
    }
    (crate::score::pairwise_similarity(data, cfg.ess, cfg.threads), "rust-fallback".into())
}

// =====================================================================
// The generic ring runtime
// =====================================================================

/// Per-round bundle emission parameters for [`run_ring`].
#[derive(Clone, Copy, Debug)]
pub struct BundleEmit {
    /// Equivalent sample size for the per-round CPT fit (each worker
    /// fits against its own scorer's data, so federated rings
    /// parameterize on their private shards).
    pub ess: f64,
    /// Max clique state space to calibrate within; past it bundles
    /// ship without potentials (consumers cold-start).
    pub budget: u64,
}

impl Default for BundleEmit {
    fn default() -> Self {
        BundleEmit { ess: 1.0, budget: crate::infer::EngineConfig::default().budget }
    }
}

/// One ring worker's private observability context inside a
/// [`RingObsHub`]: its own registry and its own tracer (with its own
/// epoch — each worker is a clock domain, exactly as if it ran in a
/// separate process).
#[derive(Debug)]
pub struct WorkerObsCtx {
    /// The worker's private metric store; shipped as deltas and merged
    /// into the hub's registry under `worker<k>.`.
    pub registry: obs::Registry,
    /// The worker's span clock and sink; enabled iff the coordinator's
    /// tracer is.
    pub tracer: obs::Tracer,
}

/// The ring's distributed-observability capability: per-worker clock
/// domains plus the coordinator-side merge targets. Install one via
/// [`RingRunOptions::obs`] (or [`RingConfig::distributed_obs`]) to
/// turn on obs frames, clock alignment, and live merging.
#[derive(Clone, Debug)]
pub struct RingObsHub {
    coordinator: obs::Tracer,
    merged: obs::Registry,
    workers: Arc<Vec<WorkerObsCtx>>,
}

impl RingObsHub {
    /// Hub for a `k`-ring merging into `coordinator`'s trace and
    /// `merged`. Worker tracers record iff `coordinator` does.
    pub fn new(k: usize, coordinator: obs::Tracer, merged: obs::Registry) -> RingObsHub {
        let workers = (0..k)
            .map(|_| WorkerObsCtx {
                registry: obs::Registry::new(),
                tracer: obs::Tracer::new(coordinator.enabled()),
            })
            .collect();
        RingObsHub { coordinator, merged, workers: Arc::new(workers) }
    }

    /// Worker `i`'s private obs context.
    pub fn worker(&self, i: usize) -> &WorkerObsCtx {
        &self.workers[i]
    }

    /// The registry every worker's metric deltas merge into.
    pub fn merged_registry(&self) -> &obs::Registry {
        &self.merged
    }

    /// The tracer every worker's spans merge into (the coordinator's).
    pub fn coordinator_tracer(&self) -> &obs::Tracer {
        &self.coordinator
    }

    /// Merge one shipment the coordinator received from `holder`:
    /// spans (on `holder`'s clock) are mapped onto the coordinator's
    /// clock by the exact in-process epoch offset and filed in the
    /// origin worker's lane; metrics land under `worker<origin>.`.
    pub fn absorb(&self, holder: usize, payload: &ObsPayload) {
        if !payload.spans.is_empty() {
            let off = self.workers[holder].tracer.offset_to(&self.coordinator);
            let mut th = self.coordinator.handle(payload.origin);
            for s in &payload.spans {
                th.add(&s.name, s.cat, s.start_ns.saturating_add_signed(off), s.dur_ns, &s.args);
            }
            th.flush();
        }
        if !payload.metrics.is_empty() {
            self.merged.absorb_prefixed(&format!("worker{}.", payload.origin), &payload.metrics);
        }
    }
}

/// Options for [`run_ring`] (what the runtime needs beyond the workers
/// themselves — each [`RingWorker`] already owns its scorer, mask and
/// cGES-L insert cap through its `GesConfig`).
#[derive(Clone, Debug)]
pub struct RingRunOptions {
    /// Hard cap on rounds.
    pub max_rounds: usize,
    /// Scheduler / transport.
    pub mode: RingMode,
    /// Fit + calibrate a [`Bundle`] for every round that improves a
    /// worker's own best score (other rounds can never be adopted as
    /// [`RingOutcome::best_bundle`], so they skip the fitting cost)
    /// and report it with the event stream. `None` (the default) is
    /// the pre-bundle behavior.
    pub emit: Option<BundleEmit>,
    /// Bundle wire capability: also attach the emitted bundles to the
    /// [`ModelMsg`]s crossing the ring, so successors (and remote
    /// peers) receive self-contained models. Requires every peer to
    /// understand the bundle frame tag — leave off when older peers
    /// share the ring; frames are then byte-identical to the legacy
    /// format. No-op unless `emit` is set.
    pub ship_bundles: bool,
    /// Span tracer: each worker emits wait/codec/fuse/ges/send spans
    /// into its own lane when enabled. The default disabled tracer
    /// costs one atomic probe per span site.
    pub tracer: obs::Tracer,
    /// Distributed-observability capability: when set, each worker
    /// records into its own hub context (ignoring `tracer`),
    /// clock-aligns its inbound link, and ships spans + metric deltas
    /// on its round messages (`TAG_MODEL_OBS` frames — every peer must
    /// understand them, the same ring-wide contract as
    /// `ship_bundles`). `None` (the default) leaves frames
    /// byte-identical to the legacy format. Ignored by the
    /// deterministic scheduler, whose barrier workers already share
    /// the coordinator's tracer directly.
    pub obs: Option<RingObsHub>,
    /// Fault tolerance: per-round recv deadline (straggler skip),
    /// decode retry budget, and ring healing on worker death. The
    /// default is inert — fault-free runs behave identically with or
    /// without it.
    pub policy: FaultPolicy,
    /// Scripted fault injection: wraps the pipelined transports in a
    /// [`ChaosTransport`] applying the plan's actions at each worker's
    /// numbered send hops. `None` (or an empty plan) leaves the
    /// transport untouched. Ignored by the deterministic scheduler,
    /// which has no transport.
    pub plan: Option<FaultPlan>,
}

impl Default for RingRunOptions {
    fn default() -> Self {
        RingRunOptions {
            max_rounds: 50,
            mode: RingMode::default(),
            emit: None,
            ship_bundles: false,
            tracer: obs::Tracer::disabled(),
            obs: None,
            policy: FaultPolicy::default(),
            plan: None,
        }
    }
}

/// What a ring run produced.
pub struct RingOutcome {
    /// Best model over all counted rounds (paper's G_best).
    pub best_dag: Dag,
    /// Its BDeu score.
    pub best_score: f64,
    /// Rounds counted toward convergence: the first non-improving
    /// round is included, speculative hops past it are not.
    pub rounds: usize,
    /// Each worker's model at the last counted round.
    pub models: Vec<Dag>,
    /// Every hop record, including speculative ones, sorted by
    /// (round, worker).
    pub records: Vec<RoundRecord>,
    /// The bundle shipped with the best counted model, when
    /// [`RingRunOptions::emit`] was set (absent if that worker's fit
    /// failed or emission was off).
    pub best_bundle: Option<Bundle>,
    /// Fault events over the whole run (all zero in a clean run):
    /// stragglers skipped, frames retried, workers healed around.
    pub faults: FaultSummary,
}

/// Fit + calibrate one worker's current model into a shippable bundle
/// (the per-hop emission behind [`RingRunOptions::emit`]). Fit
/// failures (e.g. a family past the CPT cell cap) skip emission
/// rather than failing the round; calibration degrades to a
/// potential-less bundle past the budget.
fn emit_worker_bundle(
    worker: &RingWorker,
    dag: &Dag,
    score: f64,
    round: usize,
    emit: &BundleEmit,
) -> Option<Bundle> {
    let bn = crate::bn::fit(dag, worker.scorer().data(), emit.ess).ok()?;
    let meta = BundleMeta {
        producer: "ring-worker".into(),
        rounds: (round + 1) as u32,
        score,
        ess: emit.ess,
    };
    Some(Bundle::calibrated_within(bn, meta, emit.budget))
}

/// Run a ring of pre-built workers to convergence. This is the
/// runtime under [`cges`], exposed so other ring topologies (e.g. the
/// federated example, where every worker scores against a private
/// shard) can reuse it.
pub fn run_ring(workers: Vec<RingWorker>, opts: &RingRunOptions) -> Result<RingOutcome> {
    assert!(!workers.is_empty(), "ring needs at least one worker");
    match opts.mode {
        RingMode::Deterministic => run_deterministic(workers, opts),
        RingMode::Channel => run_pipelined(workers, &ChannelTransport, opts),
        RingMode::Tcp => run_pipelined(workers, &WireTransport, opts),
    }
}

/// Barrier-synchronous reference scheduler: one scoped thread per
/// worker per round, a convergence test at the barrier. Dataflow is
/// identical to the pipelined runtime (worker i always fuses its own
/// round-(t−1) model with its predecessor's round-(t−1) model), so the
/// outcome is too.
fn run_deterministic(mut workers: Vec<RingWorker>, opts: &RingRunOptions) -> Result<RingOutcome> {
    let k = workers.len();
    let n = workers[0].n();
    let mut records: Vec<RoundRecord> = Vec::new();
    let mut models: Vec<Dag> = vec![Dag::new(n); k];
    let mut best_score = f64::NEG_INFINITY;
    let mut best_dag = Dag::new(n);
    let mut best_bundle: Option<Bundle> = None;
    let mut rounds = 0usize;
    let emit = opts.emit;
    let tracer = &opts.tracer;
    // Per-worker running best, for the same emission gate as the
    // pipelined worker loop (a self-non-improving round's bundle can
    // never be adopted).
    let mut own_best = vec![f64::NEG_INFINITY; k];

    'rounds: for round in 0..opts.max_rounds {
        rounds = round + 1;
        let prev = models.clone();
        let joined: Vec<std::thread::Result<(Dag, RoundRecord, Option<Bundle>)>> =
            std::thread::scope(|s| {
            let handles: Vec<_> = workers
                .iter_mut()
                .zip(own_best.iter_mut())
                .enumerate()
                .map(|(i, (worker, own_best))| {
                    let pred = &prev[(i + k - 1) % k];
                    s.spawn(move || {
                        let mut th = tracer.handle(i as u32);
                        let t_f = th.start();
                        let ft = Timer::start();
                        if round > 0 {
                            worker.absorb_fused(pred);
                        }
                        let fusion_secs = ft.secs();
                        th.end_args(t_f, "fuse", "ring", &[("round", round as f64)]);

                        let t_g = th.start();
                        let gt = Timer::start();
                        let (inserts, deletes) = worker.step();
                        let ges_secs = gt.secs();
                        th.end_args(
                            t_g,
                            "ges",
                            "ring",
                            &[
                                ("round", round as f64),
                                ("inserts", inserts as f64),
                                ("deletes", deletes as f64),
                            ],
                        );
                        let dag = worker.dag();
                        let score = worker.score_of(&dag);
                        let improved_own = *own_best < score;
                        if improved_own {
                            *own_best = score;
                        }
                        let bundle = if improved_own {
                            emit.as_ref()
                                .and_then(|e| emit_worker_bundle(worker, &dag, score, round, e))
                        } else {
                            None
                        };
                        let rec = RoundRecord {
                            round,
                            worker: i,
                            fusion_secs,
                            ges_secs,
                            wait_secs: 0.0,
                            codec_secs: 0.0,
                            score,
                            edges: dag.edge_count(),
                            inserts,
                            deletes,
                        };
                        (dag, rec, bundle)
                    })
                })
                .collect();
            // Join without unwrapping: a worker panic is surfaced as a
            // typed fault below instead of poisoning the coordinator.
            handles.into_iter().map(|h| h.join()).collect()
        });
        let mut results: Vec<(Dag, RoundRecord, Option<Bundle>)> = Vec::with_capacity(k);
        for (i, res) in joined.into_iter().enumerate() {
            match res {
                Ok(r) => results.push(r),
                Err(payload) => {
                    let detail = panic_message(payload.as_ref());
                    obs::log::error(format_args!(
                        "ring worker {i} panicked in deterministic mode ({detail}); \
                         the barrier scheduler cannot heal — failing the run"
                    ));
                    return Err(RingFault::WorkerPanicked { worker: i, detail }.into());
                }
            }
        }

        // Convergence check (Algorithm 1, lines 11-16).
        let mut improved = false;
        for (i, (dag, rec, bundle)) in results.into_iter().enumerate() {
            if rec.score > best_score {
                best_score = rec.score;
                best_dag = dag.clone();
                best_bundle = bundle;
                improved = true;
            }
            records.push(rec);
            models[i] = dag;
        }
        if !improved {
            break 'rounds;
        }
    }
    // The barrier scheduler has no transport and no healing: a clean
    // run by construction (panics error out above).
    let faults = FaultSummary::default();
    Ok(RingOutcome { best_dag, best_score, rounds, models, records, best_bundle, faults })
}

/// What flows from the worker threads to the coordinator's fold.
enum RingEvent {
    /// One completed hop: its record, model, and optional bundle.
    Hop(RoundRecord, Dag, Option<Bundle>),
    /// An observability shipment that reached the coordinator, either
    /// relayed by the ring head mid-run or flushed directly by a
    /// worker at teardown. `holder` is the worker whose clock the
    /// payload's spans are on.
    Obs { holder: usize, payload: ObsPayload },
    /// A worker's body panicked and was caught at the worker boundary.
    /// Sent exactly once per worker, after all of its `Hop` events
    /// (same mpsc sender, FIFO per sender), carrying the candidate
    /// subset the coordinator may redistribute.
    WorkerDead { worker: usize, mask: Option<Arc<EdgeMask>>, detail: String },
}

/// Coordinator → worker side-channel commands (polled between rounds).
enum HealCmd {
    /// Ring healing: union a dead worker's candidate-edge subset into
    /// the receiver's own, so the dead worker's pairs stay covered.
    Widen(Arc<EdgeMask>),
}

/// Actor runtime: one long-lived thread per worker, connected through
/// the transport; the calling thread folds the event stream.
fn run_pipelined(
    workers: Vec<RingWorker>,
    transport: &dyn RingTransport,
    opts: &RingRunOptions,
) -> Result<RingOutcome> {
    let k = workers.len();
    let n = workers[0].n();
    // Scripted fault injection: interpose the chaos wrapper on each
    // worker's send side. An absent or empty plan keeps the inner
    // transport untouched (frames stay byte-identical).
    let chaos;
    let transport: &dyn RingTransport = match &opts.plan {
        Some(plan) if !plan.is_empty() => {
            chaos = ChaosTransport::new(transport, plan.clone());
            &chaos
        }
        _ => transport,
    };
    let links = transport.connect(k)?;
    let stop = AtomicBool::new(false);
    let faults = FaultStats::default();
    let (events_tx, events_rx) = mpsc::channel::<RingEvent>();
    // Healing side channels: the coordinator redistributes a dead
    // worker's candidate subset to a live heir through its own queue.
    let mut heal_txs: Vec<mpsc::Sender<HealCmd>> = Vec::with_capacity(k);
    let mut heal_rxs: Vec<mpsc::Receiver<HealCmd>> = Vec::with_capacity(k);
    for _ in 0..k {
        let (htx, hrx) = mpsc::channel::<HealCmd>();
        heal_txs.push(htx);
        heal_rxs.push(hrx);
    }
    let opts = opts.clone();

    let outcome = std::thread::scope(|s| {
        for (i, ((worker, link), heal_rx)) in
            workers.into_iter().zip(links).zip(heal_rxs).enumerate()
        {
            let events = events_tx.clone();
            let stop = &stop;
            let faults = &faults;
            let wopts = opts.clone();
            s.spawn(move || worker_loop(i, k, worker, link, events, stop, &wopts, heal_rx, faults));
        }
        drop(events_tx);
        collect(k, n, &opts, &stop, events_rx, &heal_txs, &faults)
    });
    // Snapshot after the scope joins every worker thread, so late
    // teardown events (relay exits, link failures) are counted too.
    let mut outcome = outcome?;
    outcome.faults = faults.snapshot();
    Ok(outcome)
}

/// Send `Stop` (unless the peer's already arrived) and drain the
/// inbound link so no writer is left blocked mid-frame.
fn stop_and_drain(tx: &mut dyn RingTx, rx: &mut dyn RingRx) {
    let _ = tx.send(RingMessage::Stop);
    loop {
        match rx.recv() {
            Ok((RingMessage::Stop, _)) | Err(_) => break,
            Ok(_) => {} // discard late speculative models
        }
    }
}

/// One worker's in-loop obs state (present iff the run has a
/// [`RingObsHub`]).
struct WorkerObsState {
    /// The worker's private clock domain (same handles as
    /// `hub.worker(i)`).
    tracer: obs::Tracer,
    registry: obs::Registry,
    /// Ship-state of `registry`: each round ships only what changed.
    cursor: obs::RegistryCursor,
    /// Payloads received from the predecessor, already rebased onto
    /// this worker's clock, awaiting the next outbound message.
    relay: Vec<ObsPayload>,
    /// Offset mapping predecessor-clock timestamps onto this worker's
    /// clock (measured over wire links, exact in-process).
    link_offset_ns: i64,
    /// Per-hop stage metrics, recorded into `registry`.
    wait_ns: obs::Hist,
    fusion_ns: obs::Hist,
    ges_ns: obs::Hist,
    codec_ns: obs::Hist,
    hops: obs::Counter,
}

impl WorkerObsState {
    fn new(i: usize, hub: &RingObsHub, link_offset_ns: i64) -> WorkerObsState {
        let ctx = hub.worker(i);
        WorkerObsState {
            tracer: ctx.tracer.clone(),
            registry: ctx.registry.clone(),
            cursor: obs::RegistryCursor::default(),
            relay: Vec::new(),
            link_offset_ns,
            wait_ns: ctx.registry.hist("ring.wait_ns"),
            fusion_ns: ctx.registry.hist("ring.fusion_ns"),
            ges_ns: ctx.registry.hist("ring.ges_ns"),
            codec_ns: ctx.registry.hist("ring.codec_ns"),
            hops: ctx.registry.counter("ring.hops"),
        }
    }

    /// Everything new since the last shipment, as one payload (may be
    /// empty when the round produced no spans or metric changes).
    fn own_payload(&mut self, i: usize, th: &mut obs::TraceHandle) -> ObsPayload {
        th.flush();
        ObsPayload {
            origin: i as u32,
            spans: self.tracer.take_spans(),
            metrics: self.registry.delta_since(&mut self.cursor),
        }
    }
}

/// Clock-align one worker's link pair before any round traffic: answer
/// the successor's pings on the outbound link while measuring the
/// predecessor on the inbound one (every worker does both at once, so
/// the ring-wide handshake cannot deadlock). In-process links skip the
/// wire handshake and use exact tracer-epoch arithmetic; a transport
/// error falls back to 0 — the ring is tearing down anyway.
fn clock_align(
    i: usize,
    k: usize,
    hub: &RingObsHub,
    tx: &mut dyn RingTx,
    rx: &mut dyn RingRx,
) -> i64 {
    let own = hub.worker(i).tracer.clone();
    let answer_clock = own.clone();
    let measured = std::thread::scope(|s| {
        let answerer = s.spawn(move || {
            let mut now = || answer_clock.now_ns();
            tx.answer_clock_sync(&mut now)
        });
        let mut now = || own.now_ns();
        let measured = rx.measure_clock_sync(&mut now);
        let _ = answerer.join();
        measured
    });
    match measured {
        Ok(Some(off)) => off.offset_ns,
        Ok(None) => {
            let pred = (i + k - 1) % k;
            hub.worker(pred).tracer.offset_to(&hub.worker(i).tracer)
        }
        Err(_) => 0,
    }
}

/// Teardown flush: hand any relayed payloads plus this worker's own
/// tail (spans still buffered, metric changes since the last shipment)
/// straight to the coordinator's event stream, covering every loop
/// exit path — convergence, stop flag, peer-gone.
fn flush_worker_obs(
    i: usize,
    st: &mut WorkerObsState,
    th: &mut obs::TraceHandle,
    events: &mpsc::Sender<RingEvent>,
) {
    for payload in std::mem::take(&mut st.relay) {
        let _ = events.send(RingEvent::Obs { holder: i, payload });
    }
    let own = st.own_payload(i, th);
    if !own.is_empty() {
        let _ = events.send(RingEvent::Obs { holder: i, payload: own });
    }
}

/// The actor body: receive, fuse, learn, send — plus token folding and
/// shutdown. Errors from the transport mean the runtime is tearing
/// down; the loop exits quietly and the coordinator already has every
/// record that matters. A panic inside the round loop is caught here —
/// the worker boundary — reported as a [`RingEvent::WorkerDead`], and
/// (with healing on) the thread lives on as a pass-through relay so
/// the ring stays connected.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    i: usize,
    k: usize,
    worker: RingWorker,
    link: RingLink,
    events: mpsc::Sender<RingEvent>,
    stop: &AtomicBool,
    opts: &RingRunOptions,
    heal: mpsc::Receiver<HealCmd>,
    faults: &FaultStats,
) {
    let RingLink { mut tx, mut rx } = link;
    let mut obs_state = opts.obs.as_ref().map(|hub| {
        let off = clock_align(i, k, hub, tx.as_mut(), rx.as_mut());
        WorkerObsState::new(i, hub, off)
    });
    // This worker's trace lane: its private clock domain when the obs
    // capability is on, the run-wide tracer otherwise.
    let mut th = match &obs_state {
        Some(st) => st.tracer.handle(i as u32),
        None => opts.tracer.handle(i as u32),
    };
    // Stashed before the body can panic: the panic consumes the
    // worker, but its candidate subset must survive the crash so the
    // coordinator can redistribute it.
    let mask = worker.mask();
    let body = catch_unwind(AssertUnwindSafe(|| {
        run_worker_rounds(
            i,
            k,
            worker,
            tx.as_mut(),
            rx.as_mut(),
            &events,
            stop,
            opts,
            &mut th,
            obs_state.as_mut(),
            &heal,
            faults,
        )
    }));
    if let Err(payload) = body {
        let detail = panic_message(payload.as_ref());
        faults.deaths.fetch_add(1, Ordering::Relaxed);
        obs::log::warn(format_args!("ring worker {i} died: {detail}"));
        let _ = events.send(RingEvent::WorkerDead { worker: i, mask, detail });
        if opts.policy.heal {
            let off = obs_state.as_ref().map(|st| st.link_offset_ns).unwrap_or(0);
            relay_loop(tx.as_mut(), rx.as_mut(), stop, off);
        }
    }
    if let Some(st) = obs_state.as_mut() {
        flush_worker_obs(i, st, &mut th, &events);
    }
}

/// A healed worker's replacement body: a pure pass-through relay.
/// Forwards every predecessor message to the successor — advancing
/// token probes by one hop without folding any score, and rebasing
/// relayed obs shipments by the link offset this worker measured — so
/// the dataflow is exactly a ring re-linked around the dead worker,
/// without re-dialing any transport. Polls the stop flag so shutdown
/// completes even when both neighbors are idle.
fn relay_loop(tx: &mut dyn RingTx, rx: &mut dyn RingRx, stop: &AtomicBool, link_offset_ns: i64) {
    const RELAY_POLL: Duration = Duration::from_millis(25);
    let mut sent_stop = false;
    loop {
        if stop.load(Ordering::Acquire) && !sent_stop {
            sent_stop = true;
            if tx.send(RingMessage::Stop).is_err() {
                return;
            }
        }
        match rx.recv_deadline(Some(RELAY_POLL), Duration::from_secs(30)) {
            Ok((RingMessage::Stop, _)) => {
                // Forward so the circuit completes (unless this relay
                // already injected its own Stop), then exit.
                if !sent_stop {
                    let _ = tx.send(RingMessage::Stop);
                }
                return;
            }
            Ok((RingMessage::Model(mut m), _)) => {
                for p in &mut m.token.probes {
                    p.hops += 1; // a visited hop that folds no score
                }
                for payload in &mut m.obs {
                    for s in &mut payload.spans {
                        s.start_ns = s.start_ns.saturating_add_signed(link_offset_ns);
                    }
                }
                if tx.send(RingMessage::Model(m)).is_err() {
                    return;
                }
            }
            Err(RingFault::Timeout { .. }) => {} // idle poll slice; re-check the stop flag
            Err(_) => return,
        }
    }
}

/// The round loop of [`worker_loop`], split out so obs teardown runs
/// after *every* exit path.
#[allow(clippy::too_many_arguments)]
fn run_worker_rounds(
    i: usize,
    k: usize,
    mut worker: RingWorker,
    tx: &mut dyn RingTx,
    rx: &mut dyn RingRx,
    events: &mpsc::Sender<RingEvent>,
    stop: &AtomicBool,
    opts: &RingRunOptions,
    th: &mut obs::TraceHandle,
    mut obs_state: Option<&mut WorkerObsState>,
    heal: &mpsc::Receiver<HealCmd>,
    faults: &FaultStats,
) {
    let max_rounds = opts.max_rounds;
    // My score per round (what token probes fold in).
    let mut history: Vec<f64> = Vec::new();
    // Probes received last hop, to forward with the next send.
    let mut pending: Vec<RoundProbe> = Vec::new();
    // Ring head only: best score over completed (token-confirmed) rounds.
    let mut head_best = f64::NEG_INFINITY;
    // Straggler bookkeeping: rounds skipped minus late messages since
    // drained (the inbound backlog the catch-up drain may consume),
    // and the last accepted (from, round) — the duplicate filter.
    let mut lag = 0usize;
    let mut last_seen: Option<(usize, usize)> = None;

    for round in 0..max_rounds {
        if stop.load(Ordering::Acquire) {
            stop_and_drain(tx, rx);
            return;
        }
        // Ring healing: adopt any candidate subset the coordinator
        // redistributed from a dead worker.
        while let Ok(HealCmd::Widen(extra)) = heal.try_recv() {
            obs::log::warn(format_args!(
                "ring worker {i}: adopted {} candidate pairs from a dead worker",
                extra.len()
            ));
            worker.widen_mask(&extra);
        }

        let mut wait_secs = 0.0;
        let mut codec_secs = 0.0;
        let mut fusion_secs = 0.0;
        if round > 0 {
            let t_recv = th.start();
            // The freshest predecessor model this round — the one to
            // fuse. Earlier messages drained from a recovered
            // straggler's backlog still get their probes folded and
            // their obs shipments relayed; only the model itself is
            // superseded.
            let mut fuse_dag: Option<Dag> = None;
            let mut stop_seen = false;
            let mut teardown = false;
            // One mandatory receive, plus — after earlier skipped
            // rounds — a non-blocking catch-up drain so the backlog
            // shrinks instead of growing without bound.
            let mut extra_budget = lag;
            loop {
                let result = if fuse_dag.is_none() {
                    recv_with_policy(rx, &opts.policy, faults, i)
                } else if extra_budget > 0 {
                    rx.recv_deadline(Some(Duration::ZERO), opts.policy.stall_timeout)
                } else {
                    break;
                };
                match result {
                    Ok((msg, timing)) => {
                        wait_secs += timing.wait_secs;
                        codec_secs += timing.codec_secs;
                        match msg {
                            RingMessage::Stop => {
                                stop_seen = true;
                                break;
                            }
                            RingMessage::Model(mut m) => {
                                if last_seen == Some((m.from, m.round)) {
                                    // A duplicated frame (chaos `dup`):
                                    // this hop is already folded in.
                                    faults.duplicates.fetch_add(1, Ordering::Relaxed);
                                    obs::log::warn(format_args!(
                                        "ring worker {i}: discarded duplicate frame \
                                         (worker {} round {})",
                                        m.from, m.round
                                    ));
                                    continue;
                                }
                                last_seen = Some((m.from, m.round));
                                if fuse_dag.is_some() {
                                    extra_budget -= 1;
                                    lag -= 1;
                                }
                                if let Some(st) = obs_state.as_deref_mut() {
                                    // Rebase the shipment onto this
                                    // worker's clock and move it one hop
                                    // closer to the head — which hands it
                                    // straight to the coordinator.
                                    for mut payload in std::mem::take(&mut m.obs) {
                                        for s in &mut payload.spans {
                                            s.start_ns = s
                                                .start_ns
                                                .saturating_add_signed(st.link_offset_ns);
                                        }
                                        if i == 0 {
                                            let _ =
                                                events.send(RingEvent::Obs { holder: 0, payload });
                                        } else {
                                            st.relay.push(payload);
                                        }
                                    }
                                }
                                if i == 0 {
                                    // Probes have completed the circuit:
                                    // apply the paper's convergence rule
                                    // in round order.
                                    for p in &m.token.probes {
                                        debug_assert_eq!(p.hops, k, "probe returned early");
                                        if p.best > head_best {
                                            head_best = p.best;
                                        } else {
                                            stop_and_drain(tx, rx);
                                            return;
                                        }
                                    }
                                } else {
                                    for p in &mut m.token.probes {
                                        if let Some(&s) = history.get(p.round) {
                                            if s > p.best {
                                                p.best = s;
                                            }
                                        }
                                        p.hops += 1;
                                    }
                                    pending.append(&mut m.token.probes);
                                }
                                fuse_dag = Some(m.dag);
                            }
                        }
                    }
                    Err(RingFault::Timeout { after }) => {
                        if fuse_dag.is_none() {
                            // Straggler policy: the bounded per-round
                            // wait expired — skip the predecessor's
                            // contribution and step on our own model.
                            faults.timeouts.fetch_add(1, Ordering::Relaxed);
                            faults.skips.fetch_add(1, Ordering::Relaxed);
                            lag += 1;
                            wait_secs += after.as_secs_f64();
                            obs::log::warn(format_args!(
                                "ring worker {i}: predecessor missed the round-{round} \
                                 deadline ({:.0}ms); skipping its model this round",
                                after.as_secs_f64() * 1e3
                            ));
                            if let Some(t0) = t_recv {
                                th.add(
                                    "skip",
                                    "ring",
                                    t0,
                                    obs::secs_to_ns(after.as_secs_f64()),
                                    &[("round", round as f64)],
                                );
                            }
                        }
                        break; // (a drain timeout just means: backlog empty)
                    }
                    Err(fault) => {
                        // Peer gone (or a decode fault past the retry
                        // budget): the inbound link is unusable. Quiet
                        // when the run is already stopping — that is
                        // the normal teardown race, not a fault.
                        if !stop.load(Ordering::Acquire) {
                            if matches!(fault, RingFault::PeerGone { .. }) {
                                faults.peer_gone.fetch_add(1, Ordering::Relaxed);
                            }
                            obs::log::warn(format_args!(
                                "ring worker {i}: inbound link failed ({fault}); \
                                 leaving the ring"
                            ));
                        }
                        teardown = true;
                        break;
                    }
                }
            }
            if fuse_dag.is_some() || stop_seen {
                if let Some(t0) = t_recv {
                    // Split the recv interval into the transport's own
                    // blocked-wait and decode measurements.
                    let wait_ns = obs::secs_to_ns(wait_secs);
                    let round_arg = [("round", round as f64)];
                    th.add("wait", "ring", t0, wait_ns, &round_arg);
                    th.add("codec", "ring", t0 + wait_ns, obs::secs_to_ns(codec_secs), &round_arg);
                }
            }
            if stop_seen {
                // Forward once so the circuit completes, then exit:
                // the predecessor sends nothing after Stop.
                let _ = tx.send(RingMessage::Stop);
                return;
            }
            if teardown {
                return;
            }
            if let Some(dag) = &fuse_dag {
                let t_f = th.start();
                let ft = Timer::start();
                worker.absorb_fused(dag);
                fusion_secs = ft.secs();
                th.end_args(t_f, "fuse", "ring", &[("round", round as f64)]);
            }
        }

        let t_g = th.start();
        let gt = Timer::start();
        let (inserts, deletes) = worker.step();
        let ges_secs = gt.secs();
        th.end_args(
            t_g,
            "ges",
            "ring",
            &[("round", round as f64), ("inserts", inserts as f64), ("deletes", deletes as f64)],
        );
        let dag = worker.dag();
        let score = worker.score_of(&dag);
        // Fit + calibrate this round's model into a shippable bundle
        // when emission is on (each worker against its own data) —
        // but only on rounds that improve this worker's own best: the
        // coordinator adopts a bundle only when its score beats the
        // global running best, which a self-non-improving round never
        // can, so fitting one would be pure waste.
        let improved_own =
            history.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b)) < score;
        history.push(score);
        let bundle = if improved_own {
            opts.emit.as_ref().and_then(|e| emit_worker_bundle(&worker, &dag, score, round, e))
        } else {
            None
        };

        let mut probes = std::mem::take(&mut pending);
        let mut self_converged = false;
        if i == 0 {
            let own = RoundProbe { round, best: score, hops: 1 };
            if k == 1 {
                // Self-ring: the probe is complete at creation.
                if own.best > head_best {
                    head_best = own.best;
                } else {
                    self_converged = true;
                }
            } else {
                probes.push(own);
            }
        }

        // Obs capability: drain the relayed payloads plus everything
        // this worker produced since its last shipment. The head
        // delivers directly to the coordinator instead of sending its
        // own data the long way around the ring.
        let mut obs_for_wire: Vec<ObsPayload> = Vec::new();
        if let Some(st) = obs_state.as_deref_mut() {
            let own = st.own_payload(i, th);
            if i == 0 {
                if !own.is_empty() {
                    let _ = events.send(RingEvent::Obs { holder: 0, payload: own });
                }
            } else {
                obs_for_wire = std::mem::take(&mut st.relay);
                if !own.is_empty() {
                    obs_for_wire.push(own);
                }
            }
        }

        // Hand the model to the successor first (unless this is the
        // self-ring's non-improving round, which nobody consumes) so
        // the hop's record includes the serialization cost.
        let mut peer_gone = false;
        if !self_converged {
            let msg = RingMessage::Model(ModelMsg {
                from: i,
                round,
                score,
                dag: dag.clone(),
                token: RingToken { probes },
                // The wire capability: bundles ride the ring only when
                // every peer negotiated the bundle-frame tag.
                bundle: if opts.ship_bundles { bundle.clone() } else { None },
                obs: obs_for_wire,
            });
            let t_s = th.start();
            match tx.send(msg) {
                Ok(secs) => codec_secs += secs,
                Err(fault) => {
                    // Successor gone: tear down — quietly when the run
                    // is already stopping (the normal shutdown race).
                    if !stop.load(Ordering::Acquire) {
                        faults.peer_gone.fetch_add(1, Ordering::Relaxed);
                        obs::log::warn(format_args!(
                            "ring worker {i}: outbound link failed ({fault}); leaving the ring"
                        ));
                    }
                    peer_gone = true;
                }
            }
            th.end_args(t_s, "send", "ring", &[("round", round as f64)]);
        }

        // The coordinator needs the record (and model) even for the
        // non-improving round — it is counted, per Algorithm 1.
        let rec = RoundRecord {
            round,
            worker: i,
            fusion_secs,
            ges_secs,
            wait_secs,
            codec_secs,
            score,
            edges: dag.edge_count(),
            inserts,
            deletes,
        };
        if let Some(st) = obs_state.as_deref_mut() {
            // Recorded after this round's shipment was built, so the
            // hop's metrics ride the *next* message (or the teardown
            // flush) — totals are exact either way.
            st.hops.inc();
            st.wait_ns.record(obs::secs_to_ns(rec.wait_secs));
            st.fusion_ns.record(obs::secs_to_ns(rec.fusion_secs));
            st.ges_ns.record(obs::secs_to_ns(rec.ges_secs));
            st.codec_ns.record(obs::secs_to_ns(rec.codec_secs));
        }
        let _ = events.send(RingEvent::Hop(rec, dag, bundle));

        if self_converged {
            stop_and_drain(tx, rx);
            return;
        }
        if peer_gone {
            return;
        }
    }
}

/// Fold the workers' event stream: count rounds in order, apply the
/// convergence rule as soon as a round completes, raise the stop flag,
/// and keep the best model — the same strict-improvement scan, in the
/// same (round, worker) order, as the deterministic scheduler.
///
/// Fault tolerance: a [`RingEvent::WorkerDead`] marks its worker's
/// future round slots as satisfied (the ring runs on with k−1
/// contributors), redistributes the dead worker's candidate subset to
/// the next live worker, and logs the healing exactly once per death.
/// With [`FaultPolicy::heal`] off, the first death fails the run with
/// a typed [`RingFault::WorkerPanicked`] after the stream drains.
fn collect(
    k: usize,
    n: usize,
    opts: &RingRunOptions,
    stop: &AtomicBool,
    events: mpsc::Receiver<RingEvent>,
    heal_txs: &[mpsc::Sender<HealCmd>],
    faults: &FaultStats,
) -> Result<RingOutcome> {
    use std::collections::BTreeMap;

    let max_rounds = opts.max_rounds;
    let obs = opts.obs.as_ref();
    let mut buffer: BTreeMap<usize, Vec<Option<(RoundRecord, Dag, Option<Bundle>)>>> =
        BTreeMap::new();
    let mut records: Vec<RoundRecord> = Vec::new();
    let mut next_round = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    let mut best_dag = Dag::new(n);
    let mut best_bundle: Option<Bundle> = None;
    let mut models: Vec<Dag> = vec![Dag::new(n); k];
    let mut rounds = 0usize;
    let mut decided = false;
    let mut dead: Vec<bool> = vec![false; k];
    let mut first_death: Option<(usize, String)> = None;

    while let Ok(event) = events.recv() {
        match event {
            RingEvent::Obs { holder, payload } => {
                if let Some(hub) = obs {
                    hub.absorb(holder, &payload);
                }
                continue;
            }
            RingEvent::WorkerDead { worker, mask, detail } => {
                if dead[worker] {
                    continue; // defensive: one death event per worker
                }
                dead[worker] = true;
                if first_death.is_none() {
                    first_death = Some((worker, detail.clone()));
                }
                if opts.policy.heal {
                    faults.healed.fetch_add(1, Ordering::Relaxed);
                    // The dead worker's own thread relays messages past
                    // it (predecessor re-linked to successor); here we
                    // redistribute its candidate subset to the next
                    // live worker so its pairs stay covered.
                    let heir = (1..k).map(|d| (worker + d) % k).find(|&j| !dead[j]);
                    match (heir, mask) {
                        (Some(j), Some(m)) => {
                            let pairs = m.len();
                            let _ = heal_txs[j].send(HealCmd::Widen(m));
                            obs::log::warn(format_args!(
                                "ring healed: worker {worker} died ({detail}); re-linked \
                                 its neighbors and redistributed {pairs} candidate pairs \
                                 to worker {j}"
                            ));
                        }
                        (Some(j), None) => {
                            obs::log::warn(format_args!(
                                "ring healed: worker {worker} died ({detail}); re-linked \
                                 its neighbors (worker {j} is unrestricted — nothing to \
                                 redistribute)"
                            ));
                        }
                        (None, _) => {
                            obs::log::warn(format_args!(
                                "ring worker {worker} died ({detail}); no live workers \
                                 remain to heal around"
                            ));
                        }
                    }
                } else {
                    obs::log::error(format_args!(
                        "ring worker {worker} died ({detail}); healing is disabled — \
                         failing the run"
                    ));
                    stop.store(true, Ordering::Release);
                }
                // Fall through: rounds the dead worker will never
                // report may be complete now.
            }
            RingEvent::Hop(rec, dag, bundle) => {
                records.push(rec.clone());
                let slots =
                    buffer.entry(rec.round).or_insert_with(|| (0..k).map(|_| None).collect());
                slots[rec.worker] = Some((rec, dag, bundle));
            }
        }

        while !decided {
            // A round is complete when every live worker reported it; a
            // dead worker's slot is vacuously satisfied (its hops all
            // precede its death event on the same FIFO sender, so a
            // slot still empty here can never fill).
            let complete = buffer
                .get(&next_round)
                .map(|s| s.iter().enumerate().all(|(w, x)| x.is_some() || dead[w]))
                .unwrap_or(false);
            if !complete {
                break;
            }
            let slots = buffer.remove(&next_round).expect("checked above");
            rounds = next_round + 1;
            let mut improved = false;
            for (w, entry) in slots.into_iter().enumerate() {
                // A dead worker's missing slot keeps its last model.
                let Some((rec, dag, bundle)) = entry else { continue };
                if rec.score > best_score {
                    best_score = rec.score;
                    best_dag = dag.clone();
                    best_bundle = bundle;
                    improved = true;
                }
                models[w] = dag;
            }
            next_round += 1;
            if !improved || rounds == max_rounds {
                decided = true;
                stop.store(true, Ordering::Release);
            }
        }
    }
    if let Some((worker, detail)) = first_death {
        if !opts.policy.heal {
            return Err(RingFault::WorkerPanicked { worker, detail }.into());
        }
    }
    records.sort_by_key(|r| (r.round, r.worker));
    // `faults` is re-snapshotted by `run_pipelined` after every worker
    // thread joins; this interim copy keeps the struct total.
    let faults = faults.snapshot();
    Ok(RingOutcome { best_dag, best_score, rounds, models, records, best_bundle, faults })
}

/// Run cGES on a dataset.
pub fn cges(data: Arc<Dataset>, cfg: &RingConfig) -> Result<RingResult> {
    assert!(cfg.k >= 1, "ring needs at least one process");
    let n = data.n_vars();
    let mut telemetry = Telemetry::default();
    // Coordinator-stage spans get their own lane above the workers'.
    let mut th = cfg.tracer.handle(obs::COORDINATOR_TID);

    // ---- Stage 1: edge partitioning -------------------------------
    let t_stage = th.start();
    let t = Timer::start();
    let (pairwise, source) = stage1_similarity(&data, cfg);
    let masks: Vec<Arc<EdgeMask>> =
        partition_edges(&pairwise.s, cfg.k).into_iter().map(Arc::new).collect();
    let seed = Arc::new(pairwise.s);
    telemetry.partition_secs = t.secs();
    telemetry.partition_source = source;
    th.end(t_stage, "partition", "stage");

    // Shared score cache and counting engine across every worker and
    // stage (the packed columns are built once here).
    let cache = Arc::new(ScoreCache::new());
    let scorer = BdeuScorer::with_parts(
        data.clone(),
        cfg.ess,
        cache.clone(),
        CountConfig { mode: cfg.count_mode, ..Default::default() },
    );
    if let Some(reg) = &cfg.registry {
        // Snapshots read the run's live cache / counting-path counters.
        scorer.bind_obs(reg);
    }

    let limit = cfg.limit_inserts.then(|| insert_limit(cfg.k, n));
    let worker_threads = (cfg.threads / cfg.k).max(1);

    // ---- Stage 2: ring learning -----------------------------------
    // Workers keep their search state (candidate heaps, version
    // stamps) across rounds: a round only re-evaluates pairs the
    // fusion actually changed (see learn::ges::RingWorker — the §Perf
    // optimization that makes the ring competitive with heap-GES).
    let t = Timer::start();
    let workers: Vec<RingWorker> = (0..cfg.k)
        .map(|i| {
            let ges_cfg = GesConfig {
                threads: worker_threads,
                insert_limit: limit,
                mask: Some(masks[i].clone()),
                max_parents: cfg.max_parents,
                seed: Some(seed.clone()),
                iterate_until_stable: false,
                forward_empty_t: false,
            };
            RingWorker::new(scorer.clone(), ges_cfg)
        })
        .collect();
    // Per-round bundle emission stays off here: every cges worker
    // scores the same full dataset, so the coordinator can fit and
    // calibrate the final model once at the end for identical bytes —
    // k × rounds of in-loop fits would buy nothing. `run_ring` callers
    // whose coordinator holds no data (the federated example's
    // per-shard sites) are the ones that set `emit`/`ship_bundles`.
    // Distributed obs merges into the run's own tracer and registry
    // (a throwaway registry when none was configured — the spans still
    // land in the trace).
    let obs_hub = (cfg.distributed_obs && cfg.mode != RingMode::Deterministic).then(|| {
        RingObsHub::new(
            cfg.k,
            cfg.tracer.clone(),
            cfg.registry.clone().unwrap_or_default(),
        )
    });
    let t_stage = th.start();
    let outcome = run_ring(
        workers,
        &RingRunOptions {
            max_rounds: cfg.max_rounds,
            mode: cfg.mode,
            tracer: cfg.tracer.clone(),
            obs: obs_hub,
            policy: cfg.fault_policy,
            plan: cfg.fault_plan.clone(),
            ..Default::default()
        },
    )?;
    telemetry.learning_secs = t.secs();
    th.end_args(t_stage, "learning", "stage", &[("rounds", outcome.rounds as f64)]);
    telemetry.records = outcome.records;
    telemetry.transport = cfg.mode.name().into();
    telemetry.converged_rounds = outcome.rounds;
    telemetry.faults = outcome.faults;

    // ---- Stage 3: fine tuning --------------------------------------
    let t_stage = th.start();
    let t = Timer::start();
    let (dag, score) = if cfg.fine_tune {
        let ges_cfg = GesConfig {
            threads: cfg.threads,
            insert_limit: None,
            mask: None,
            max_parents: cfg.max_parents,
            seed: None,
            iterate_until_stable: false,
            forward_empty_t: false,
        };
        let r = crate::learn::ges(&scorer, &outcome.best_dag, &ges_cfg);
        telemetry.fes_evaluations = r.fes_evaluations;
        telemetry.bes_evaluations = r.bes_evaluations;
        (r.dag, r.score)
    } else {
        (outcome.best_dag, outcome.best_score)
    };
    telemetry.fine_tune_secs = t.secs();
    th.end(t_stage, "fine_tune", "stage");

    // ---- Bundle emission -------------------------------------------
    // One fit + calibrate over the final structure: the artifact that
    // serving warm-starts from. A fit failure (e.g. a family past the
    // CPT cell cap) degrades to no bundle with a warning — it must
    // never discard the completed learning run.
    let bundle = if cfg.emit_bundle {
        let t_stage = th.start();
        let meta = BundleMeta {
            producer: format!("cges k={} [{}]", cfg.k, cfg.mode.name()),
            rounds: outcome.rounds as u32,
            score,
            ess: cfg.bundle_ess,
        };
        let b = match Bundle::fit_calibrated(&dag, &data, BundleEmit::default().budget, meta) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("warning: bundle emission failed ({e:#}); returning the structure only");
                None
            }
        };
        th.end(t_stage, "bundle", "stage");
        b
    } else {
        None
    };

    let (hits, misses) = cache.stats();
    telemetry.cache_hits = hits;
    telemetry.cache_misses = misses;
    let cs = scorer.count_stats();
    telemetry.count_popcount = cs.popcount;
    telemetry.count_blocked = cs.blocked;
    telemetry.count_dense = cs.dense;
    telemetry.count_sparse = cs.sparse;
    telemetry.count_derived = cs.derived;
    telemetry.table_hits = cs.table_hits;
    telemetry.table_misses = cs.table_misses;

    if let Some(reg) = &cfg.registry {
        // Ring-specific metrics (per-hop histograms, stage gauges);
        // cache / counting counters are already live via `bind_obs`.
        telemetry.export_metrics(reg);
    }
    // Make worker spans visible to `tracer.chrome_json()` callers.
    th.flush();

    Ok(RingResult { dag, score, rounds: outcome.rounds, telemetry, bundle })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{forward_sample, generate, NetGenConfig};
    use crate::learn::{ges, GesConfig};

    fn workload(nodes: usize, edges: usize, seed: u64) -> (crate::bn::DiscreteBn, Arc<Dataset>) {
        let bn = generate(&NetGenConfig { nodes, edges, ..Default::default() }, seed);
        let data = Arc::new(forward_sample(&bn, 1500, seed + 1));
        (bn, data)
    }

    #[test]
    fn cges_beats_empty_and_converges() {
        let (_bn, data) = workload(20, 28, 41);
        let cfg = RingConfig { k: 2, threads: 4, ..Default::default() };
        let r = cges(data.clone(), &cfg).unwrap();
        let sc = BdeuScorer::new(data, cfg.ess);
        assert!(r.score > sc.score_dag(&Dag::new(20)));
        assert!(r.rounds >= 1 && r.rounds < cfg.max_rounds);
        assert!(!r.telemetry.records.is_empty());
        let (h, _m) = (r.telemetry.cache_hits, r.telemetry.cache_misses);
        assert!(h > 0, "workers must share the cache");
    }

    #[test]
    fn cges_k1_close_to_plain_ges() {
        let (_bn, data) = workload(14, 18, 7);
        let cfg = RingConfig {
            k: 1,
            limit_inserts: false,
            threads: 2,
            ..Default::default()
        };
        let ring = cges(data.clone(), &cfg).unwrap();
        let sc = BdeuScorer::new(data, cfg.ess);
        let plain = ges(&sc, &Dag::new(14), &GesConfig { threads: 2, ..Default::default() });
        assert!(
            (ring.score - plain.score).abs() < 1e-6,
            "k=1 unlimited ring = GES: {} vs {}",
            ring.score,
            plain.score
        );
    }

    #[test]
    fn limit_policy_applies() {
        assert_eq!(insert_limit(4, 400), 50);
        assert_eq!(insert_limit(2, 100), 50);
        let (_bn, data) = workload(16, 24, 3);
        let cfg = RingConfig { k: 4, limit_inserts: true, threads: 4, fine_tune: false, ..Default::default() };
        let r = cges(data, &cfg).unwrap();
        let l = insert_limit(4, 16);
        for rec in &r.telemetry.records {
            assert!(rec.inserts <= l, "round {} worker {} inserted {}", rec.round, rec.worker, rec.inserts);
        }
    }

    #[test]
    fn insert_limit_matches_paper_formula() {
        // l = ceil((10/k)·√n), spot-checked against hand computation.
        for (k, n, expected) in [
            (1usize, 100usize, 100usize), // 10·10
            (2, 100, 50),                 // 5·10
            (4, 400, 50),                 // 2.5·20
            (8, 1000, 40),                // 1.25·31.62… → ceil(39.53)
            (8, 724, 34),                 // link-sized: 1.25·26.90… → ceil(33.63)
            (4, 1, 3),                    // tiny n still positive: ceil(2.5)
        ] {
            assert_eq!(insert_limit(k, n), expected, "l({k}, {n})");
        }
    }

    #[test]
    fn fine_tune_only_improves() {
        let (_bn, data) = workload(18, 26, 11);
        let base = RingConfig { k: 2, threads: 4, fine_tune: false, ..Default::default() };
        let no_ft = cges(data.clone(), &base).unwrap();
        let with_ft = cges(data, &RingConfig { fine_tune: true, ..base }).unwrap();
        assert!(with_ft.score >= no_ft.score - 1e-9);
    }

    #[test]
    fn counted_rounds_are_complete_and_speculation_is_bounded() {
        let (_bn, data) = workload(18, 24, 29);
        let k = 3;
        let cfg = RingConfig { k, threads: 3, fine_tune: false, ..Default::default() };
        let r = cges(data, &cfg).unwrap();
        // Every counted round has exactly k records.
        for round in 0..r.rounds {
            let cnt = r.telemetry.records.iter().filter(|rec| rec.round == round).count();
            assert_eq!(cnt, k, "round {round} incomplete");
        }
        // Speculative hops exist only past the stop round and are
        // bounded by the token circuit length.
        let max_round = r.telemetry.records.iter().map(|rec| rec.round).max().unwrap();
        assert!(max_round < r.rounds + 2 * k, "unbounded speculation: {max_round} vs {}", r.rounds);
    }

    #[test]
    fn bundle_emission_preserves_results_and_warm_serves() {
        let (_bn, data) = workload(16, 22, 13);
        let base = RingConfig { k: 2, threads: 4, ..Default::default() };
        let plain = cges(data.clone(), &base).unwrap();
        assert!(plain.bundle.is_none(), "emission is opt-in");

        let bundled = cges(data.clone(), &RingConfig { emit_bundle: true, ..base }).unwrap();
        assert_eq!(plain.dag.edges(), bundled.dag.edges());
        assert!((plain.score - bundled.score).abs() < 1e-9);
        assert_eq!(plain.rounds, bundled.rounds);

        let bundle = bundled.bundle.expect("emit_bundle produces an artifact");
        assert_eq!(bundle.bn.dag.edges(), bundled.dag.edges());
        assert!(bundle.has_potentials(), "small jointree must calibrate");
        assert_eq!(bundle.meta.rounds as usize, bundled.rounds);

        // The artifact warm-serves bit-identically to a cold compile
        // of the same network, with zero collect-message
        // recomputation on the first evidence-free query.
        let warm = crate::engine::CompiledModel::from_bundle(&bundle).unwrap();
        assert!(warm.is_warm_started());
        let cold = crate::engine::CompiledModel::compile(&bundle.bn).unwrap();
        let (mut ws, mut cs) = (warm.new_scratch(), cold.new_scratch());
        let a = warm.marginals(&mut ws, &[]).unwrap();
        let b = cold.marginals(&mut cs, &[]).unwrap();
        assert_eq!(ws.collect_recomputes(), 0, "warm start must skip the collect sweep");
        assert_eq!(a.log_evidence.to_bits(), b.log_evidence.to_bits());
        for v in 0..16 {
            for (x, y) in a.marginal(v).iter().zip(b.marginal(v)) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn ring_bundle_shipping_interops_with_capability_off() {
        // The wire capability, end to end over both transports: with
        // `ship_bundles` on, every hop carries a bundle frame (tag 2);
        // with it off (or emission off entirely — the legacy peers
        // case) frames are byte-identical to the pre-bundle format.
        // All variants must converge to the same structures.
        let (_bn, data) = workload(14, 18, 21);
        let run = |mode: RingMode, emit: Option<BundleEmit>, ship: bool, obs: Option<RingObsHub>| {
            let scorer = BdeuScorer::new(data.clone(), 10.0);
            let workers: Vec<RingWorker> = (0..2)
                .map(|_| {
                    RingWorker::new(
                        scorer.clone(),
                        GesConfig { threads: 2, ..Default::default() },
                    )
                })
                .collect();
            run_ring(
                workers,
                &RingRunOptions {
                    max_rounds: 8,
                    mode,
                    emit,
                    ship_bundles: ship,
                    obs,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let legacy = run(RingMode::Channel, None, false, None);
        let variants = [
            (None, false),
            (Some(BundleEmit::default()), false),
            (Some(BundleEmit::default()), true),
        ];
        for mode in [RingMode::Channel, RingMode::Tcp] {
            for (emit, ship) in variants {
                let got = run(mode, emit, ship, None);
                assert_eq!(
                    got.best_dag.edges(),
                    legacy.best_dag.edges(),
                    "{} emit={} ship={ship}",
                    mode.name(),
                    emit.is_some()
                );
                assert!((got.best_score - legacy.best_score).abs() < 1e-9);
                assert_eq!(got.rounds, legacy.rounds);
                assert_eq!(got.best_bundle.is_some(), emit.is_some());
                if let Some(b) = &got.best_bundle {
                    assert_eq!(b.bn.dag.edges(), got.best_dag.edges());
                }
            }
        }

        // The obs capability composes the same way: structures, scores
        // and rounds are bit-identical to the legacy run, and the hub
        // additionally merges every worker's series and spans.
        for mode in [RingMode::Channel, RingMode::Tcp] {
            let tracer = obs::Tracer::new(true);
            let merged = obs::Registry::new();
            let hub = RingObsHub::new(2, tracer.clone(), merged.clone());
            let got = run(mode, None, false, Some(hub));
            assert_eq!(
                got.best_dag.edges(),
                legacy.best_dag.edges(),
                "{} obs-on must not change the result",
                mode.name()
            );
            assert!((got.best_score - legacy.best_score).abs() < 1e-9);
            assert_eq!(got.rounds, legacy.rounds);
            for w in 0..2 {
                let hops = merged
                    .counter_value(&format!("worker{w}.ring.hops"))
                    .unwrap_or(0);
                assert!(hops >= 1, "{}: worker{w} shipped no hop metrics", mode.name());
            }
            let json = tracer.chrome_json();
            assert!(!json.is_empty(), "{}: no merged spans", mode.name());
            crate::infer::json::Json::parse(&json).expect("merged trace parses");
        }
    }

    #[test]
    fn ring_heals_and_logs_exactly_once_per_dead_worker() {
        // A scripted kill at worker 1's second send: the panic is
        // caught at the worker boundary, the ring re-links around the
        // dead worker (its thread relays), and the run completes on
        // k−1 contributors. The healing warn fires exactly once.
        let (_bn, data) = workload(16, 22, 17);
        let scorer = BdeuScorer::new(data, 10.0);
        let workers: Vec<RingWorker> = (0..3)
            .map(|_| {
                RingWorker::new(scorer.clone(), GesConfig { threads: 2, ..Default::default() })
            })
            .collect();
        obs::log::capture_start();
        let out = run_ring(
            workers,
            &RingRunOptions {
                max_rounds: 6,
                mode: RingMode::Channel,
                policy: FaultPolicy {
                    recv_timeout: Some(Duration::from_secs(5)),
                    ..Default::default()
                },
                plan: Some(FaultPlan::parse("kill:w1@1").unwrap()),
                ..Default::default()
            },
        )
        .unwrap();
        let lines = obs::log::capture_take();
        let heals = lines.iter().filter(|l| l.contains("ring healed: worker 1")).count();
        assert_eq!(heals, 1, "healing must log exactly once per dead worker: {lines:#?}");
        assert_eq!(out.faults.deaths, 1);
        assert_eq!(out.faults.healed, 1);
        assert!(out.best_score.is_finite());
        assert!(out.rounds >= 1);
        // The healed run still returns a usable structure with records
        // from every worker that lived.
        assert!(out.records.iter().any(|r| r.worker == 0));
        assert!(out.records.iter().any(|r| r.worker == 2));
    }

    // Cross-mode result equality (deterministic vs channel vs tcp) is
    // covered once, end-to-end, by
    // `ring_transports_and_deterministic_mode_agree` in
    // tests/pipeline.rs — the acceptance gate for this runtime.
}
