//! Pluggable message transport for the ring runtime.
//!
//! Algorithm 1 of the paper is a *directed ring*: processor i receives
//! a model from its predecessor, fuses, learns on its edge subset, and
//! sends the result to its successor. This module is the communication
//! substrate of that ring, abstracted so the same worker loop can run
//! over different media:
//!
//! * [`ChannelTransport`] — in-process `std::sync::mpsc` channels, the
//!   default. Messages move by value; zero serialization cost.
//! * [`WireTransport`] — length-prefixed binary frames over loopback
//!   TCP sockets. Every model crosses a real byte boundary through the
//!   [`graph::codec`](crate::graph::codec) wire format, proving the
//!   abstraction is remotable: pointing the connector at remote
//!   addresses instead of `127.0.0.1` is a deployment change, not a
//!   code change (the direction FedGES takes for federated structure
//!   learning).
//!
//! # Topology
//!
//! [`RingTransport::connect`]`(k)` materializes the k directed links
//! of the ring and hands worker i a [`RingLink`]: a sender to its
//! successor (link i) and a receiver from its predecessor (link
//! (i−1) mod k). Exactly one message per round flows on each link, so
//! FIFO order per link is the only delivery guarantee the runtime
//! needs — precisely what both mpsc channels and TCP streams provide.
//!
//! # Messages and the convergence token
//!
//! A [`RingMessage`] is either a [`ModelMsg`] — the learned [`Dag`]
//! plus its BDeu score for one round — or `Stop`, the shutdown marker
//! that circulates once around the ring so every link drains cleanly.
//!
//! Termination detection replaces the old global barrier test with a
//! circulating token ([`RingToken`]): the ring head (worker 0) attaches
//! a [`RoundProbe`] carrying its round-r score to its round-r message;
//! every worker folds its own round-r score into the probe (a running
//! max of best-seen BDeu) and forwards it with its next message. After
//! k hops the probe returns to the head carrying the exact global best
//! score of round r, and the head applies the paper's convergence rule
//! (Algorithm 1 lines 11–16: stop when a round fails to improve the
//! best score seen so far) without ever stopping the pipeline.
//!
//! # Timing
//!
//! Send returns its serialization seconds and receive reports
//! (blocked-wait, decode) seconds separately, feeding the per-hop
//! worker timelines in [`telemetry`](crate::coordinator::telemetry).

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::codec::{
    decode_dag, encode_dag, put_f64, put_u32, take_f64, take_u32, take_u8,
};
use crate::graph::Dag;
use crate::model::{decode_bundle, encode_bundle, Bundle};
use crate::util::{ensure_frame_len, Timer};

/// One probe of the convergence token: the best BDeu score seen for
/// `round` across the `hops` workers it has visited so far.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundProbe {
    /// Ring round this probe measures.
    pub round: usize,
    /// Max BDeu score over the visited workers' round-`round` models.
    pub best: f64,
    /// Workers folded in so far (complete when `hops == k`).
    pub hops: usize,
}

/// The circulating convergence token (piggybacked on model messages).
#[derive(Clone, Debug, Default)]
pub struct RingToken {
    /// In-flight probes; in steady state exactly one per message.
    pub probes: Vec<RoundProbe>,
}

/// A model handoff from one ring worker to its successor.
#[derive(Clone, Debug)]
pub struct ModelMsg {
    /// Sending worker index.
    pub from: usize,
    /// Ring round the model belongs to.
    pub round: usize,
    /// BDeu score of `dag` (as computed by the sender).
    pub score: f64,
    /// The learned model.
    pub dag: Dag,
    /// Convergence-token probes riding along.
    pub token: RingToken,
    /// Optional self-contained model bundle (fitted CPTs + calibrated
    /// jointree potentials) riding alongside the structure. Gated by
    /// the ring's bundle capability
    /// ([`RingRunOptions::ship_bundles`](crate::coordinator::RingRunOptions)):
    /// with the capability off a message encodes to exactly the legacy
    /// `TAG_MODEL` frame, so potential-less peers interop unchanged;
    /// with it on the frame uses a new tag an old peer would cleanly
    /// refuse — which is why the flag must only be enabled ring-wide.
    pub bundle: Option<Bundle>,
}

/// What flows on a ring link.
#[derive(Clone, Debug)]
pub enum RingMessage {
    /// A round's learned model.
    Model(ModelMsg),
    /// Shutdown marker: the sender is done; forward once and drain.
    Stop,
}

/// Timing breakdown of one receive.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecvTiming {
    /// Seconds blocked waiting for the message to arrive.
    pub wait_secs: f64,
    /// Seconds spent reading + decoding the payload (wire only).
    pub codec_secs: f64,
}

/// Sending half of a ring link (worker i → worker (i+1) mod k).
pub trait RingTx: Send {
    /// Send one message (by value — channels move it, wires encode
    /// it); returns serialization seconds (0 for moves). An error
    /// means the peer is gone — callers treat it as shutdown.
    fn send(&mut self, msg: RingMessage) -> Result<f64>;
}

/// Receiving half of a ring link (worker (i−1) mod k → worker i).
pub trait RingRx: Send {
    /// Block for the next message. An error means the peer closed the
    /// link without a `Stop` — callers treat it as shutdown.
    fn recv(&mut self) -> Result<(RingMessage, RecvTiming)>;
}

/// Both endpoints owned by one worker.
pub struct RingLink {
    /// To the successor.
    pub tx: Box<dyn RingTx>,
    /// From the predecessor.
    pub rx: Box<dyn RingRx>,
}

/// A way to materialize the k directed links of a ring. (Telemetry
/// naming comes from `RingMode::name` — the single source — so the
/// trait stays a pure connector.)
pub trait RingTransport {
    /// Build the ring: element i of the result is worker i's link pair
    /// (tx to successor, rx from predecessor).
    fn connect(&self, k: usize) -> Result<Vec<RingLink>>;
}

// ---------------------------------------------------------------------
// Channel transport (in-process, the default)
// ---------------------------------------------------------------------

/// In-process transport over unbounded `mpsc` channels.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChannelTransport;

struct ChannelTx {
    sender: mpsc::Sender<RingMessage>,
}

struct ChannelRx {
    receiver: mpsc::Receiver<RingMessage>,
}

impl RingTx for ChannelTx {
    fn send(&mut self, msg: RingMessage) -> Result<f64> {
        self.sender.send(msg).map_err(|_| anyhow!("ring successor hung up"))?;
        Ok(0.0)
    }
}

impl RingRx for ChannelRx {
    fn recv(&mut self) -> Result<(RingMessage, RecvTiming)> {
        let t = Timer::start();
        let msg = self
            .receiver
            .recv()
            .map_err(|_| anyhow!("ring predecessor hung up"))?;
        Ok((msg, RecvTiming { wait_secs: t.secs(), codec_secs: 0.0 }))
    }
}

impl RingTransport for ChannelTransport {
    fn connect(&self, k: usize) -> Result<Vec<RingLink>> {
        assert!(k >= 1, "ring needs at least one worker");
        let mut txs: Vec<Option<mpsc::Sender<RingMessage>>> = Vec::with_capacity(k);
        let mut rxs: Vec<Option<mpsc::Receiver<RingMessage>>> = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = mpsc::channel();
            txs.push(Some(tx));
            rxs.push(Some(rx));
        }
        Ok((0..k)
            .map(|i| RingLink {
                tx: Box::new(ChannelTx { sender: txs[i].take().expect("tx taken once") }),
                rx: Box::new(ChannelRx {
                    receiver: rxs[(i + k - 1) % k].take().expect("rx taken once"),
                }),
            })
            .collect())
    }
}

// ---------------------------------------------------------------------
// Wire transport (length-prefixed binary frames over TCP)
// ---------------------------------------------------------------------

/// Hard cap on a single frame; a learned BN is O(n) edges, so even
/// genome-scale rings stay far below this. Guards against corrupt
/// length prefixes allocating unbounded buffers.
const MAX_FRAME_BYTES: u32 = 64 << 20;

const TAG_MODEL: u8 = 0;
const TAG_STOP: u8 = 1;
/// A model frame that additionally carries a bundle payload. Emitted
/// only when the ring's bundle capability is on; peers without the
/// capability never see (and would refuse) this tag.
const TAG_MODEL_BUNDLE: u8 = 2;

/// Encode a [`RingMessage`] to its wire form (appended to `buf`).
/// Bundle-less model messages encode byte-identically to the
/// pre-bundle format.
pub fn encode_message(msg: &RingMessage, buf: &mut Vec<u8>) {
    match msg {
        RingMessage::Stop => buf.push(TAG_STOP),
        RingMessage::Model(m) => {
            buf.push(if m.bundle.is_some() { TAG_MODEL_BUNDLE } else { TAG_MODEL });
            put_u32(buf, m.from as u32);
            put_u32(buf, m.round as u32);
            put_f64(buf, m.score);
            put_u32(buf, m.token.probes.len() as u32);
            for p in &m.token.probes {
                put_u32(buf, p.round as u32);
                put_u32(buf, p.hops as u32);
                put_f64(buf, p.best);
            }
            encode_dag(&m.dag, buf);
            if let Some(b) = &m.bundle {
                encode_bundle(b, buf);
            }
        }
    }
}

/// Decode a full [`RingMessage`] frame.
pub fn decode_message(bytes: &[u8]) -> Result<RingMessage> {
    let mut cursor = bytes;
    let tag = take_u8(&mut cursor)?;
    let msg = match tag {
        TAG_STOP => RingMessage::Stop,
        TAG_MODEL | TAG_MODEL_BUNDLE => {
            let from = take_u32(&mut cursor)? as usize;
            let round = take_u32(&mut cursor)? as usize;
            let score = take_f64(&mut cursor)?;
            let n_probes = take_u32(&mut cursor)? as usize;
            // Each probe encodes to 16 bytes; a count the remaining
            // payload cannot hold is corrupt — reject before
            // allocating for it.
            if n_probes > cursor.len() / 16 {
                bail!("probe count {n_probes} exceeds remaining frame ({} bytes)", cursor.len());
            }
            let mut probes = Vec::with_capacity(n_probes);
            for _ in 0..n_probes {
                let round = take_u32(&mut cursor)? as usize;
                let hops = take_u32(&mut cursor)? as usize;
                let best = take_f64(&mut cursor)?;
                probes.push(RoundProbe { round, best, hops });
            }
            let dag = decode_dag(&mut cursor)?;
            let bundle = if tag == TAG_MODEL_BUNDLE {
                Some(decode_bundle(&mut cursor)?)
            } else {
                None
            };
            RingMessage::Model(ModelMsg {
                from,
                round,
                score,
                dag,
                token: RingToken { probes },
                bundle,
            })
        }
        other => bail!("unknown message tag {other}"),
    };
    if !cursor.is_empty() {
        bail!("{} trailing bytes after message frame", cursor.len());
    }
    Ok(msg)
}

/// TCP-loopback transport: every hop serializes through the wire codec.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireTransport;

struct WireTx {
    stream: BufWriter<TcpStream>,
    scratch: Vec<u8>,
    /// Oversized-bundle degrade already reported on this link.
    warned_oversize: bool,
}

struct WireRx {
    stream: BufReader<TcpStream>,
}

impl RingTx for WireTx {
    fn send(&mut self, msg: RingMessage) -> Result<f64> {
        // Only serialization counts as codec time; blocking in the
        // socket writes is communication, not encoding, and must not
        // masquerade as codec cost in the worker timelines.
        let t = Timer::start();
        self.scratch.clear();
        encode_message(&msg, &mut self.scratch);
        let mut codec_secs = t.secs();

        // A bundle payload is advisory: when it alone pushes the frame
        // past the cap, ship the structure without it instead of
        // erroring — the worker loop reads a send error as "peer gone"
        // and would silently tear the ring down mid-run. The re-encode
        // never copies the oversized bundle itself (the borrowed
        // message is encoded with its bundle slot emptied).
        if self.scratch.len() > MAX_FRAME_BYTES as usize {
            if let RingMessage::Model(m) = &msg {
                if m.bundle.is_some() {
                    if !self.warned_oversize {
                        self.warned_oversize = true;
                        eprintln!(
                            "warning: ring bundle payload inflates the frame to {} bytes \
                             (cap {MAX_FRAME_BYTES}); shipping structures without bundles \
                             on this link",
                            self.scratch.len()
                        );
                    }
                    let t = Timer::start();
                    let slim = ModelMsg {
                        from: m.from,
                        round: m.round,
                        score: m.score,
                        dag: m.dag.clone(),
                        token: m.token.clone(),
                        bundle: None,
                    };
                    self.scratch.clear();
                    encode_message(&RingMessage::Model(slim), &mut self.scratch);
                    codec_secs += t.secs();
                }
            }
        }

        let len = u32::try_from(self.scratch.len()).context("frame too large for u32 prefix")?;
        ensure_frame_len("outgoing", len, MAX_FRAME_BYTES)?;
        self.stream.write_all(&len.to_le_bytes()).context("write frame length")?;
        self.stream.write_all(&self.scratch).context("write frame payload")?;
        self.stream.flush().context("flush frame")?;
        Ok(codec_secs)
    }
}

impl RingRx for WireRx {
    fn recv(&mut self) -> Result<(RingMessage, RecvTiming)> {
        // All socket I/O (length prefix *and* payload) is wait;
        // only the in-memory decode is codec.
        let t = Timer::start();
        let mut len_bytes = [0u8; 4];
        self.stream.read_exact(&mut len_bytes).context("read frame length")?;
        let len = u32::from_le_bytes(len_bytes);
        ensure_frame_len("incoming", len, MAX_FRAME_BYTES)?;
        let mut payload = vec![0u8; len as usize];
        self.stream.read_exact(&mut payload).context("read frame payload")?;
        let wait_secs = t.secs();

        let t = Timer::start();
        let msg = decode_message(&payload)?;
        Ok((msg, RecvTiming { wait_secs, codec_secs: t.secs() }))
    }
}

impl RingTransport for WireTransport {
    fn connect(&self, k: usize) -> Result<Vec<RingLink>> {
        assert!(k >= 1, "ring needs at least one worker");
        // One listener per directed link i → (i+1) mod k. Bind all
        // first, then connect+accept pairwise: loopback connects
        // complete against the listen backlog, so a single thread can
        // wire the whole ring.
        let listeners: Vec<TcpListener> = (0..k)
            .map(|_| TcpListener::bind(("127.0.0.1", 0)).context("bind ring listener"))
            .collect::<Result<_>>()?;
        let mut out_streams: Vec<Option<TcpStream>> = Vec::with_capacity(k);
        let mut in_streams: Vec<Option<TcpStream>> = Vec::with_capacity(k);
        for listener in &listeners {
            let addr = listener.local_addr().context("listener addr")?;
            let out = TcpStream::connect(addr).context("connect ring link")?;
            out.set_nodelay(true).context("set nodelay")?;
            let (inc, _) = listener.accept().context("accept ring link")?;
            inc.set_nodelay(true).context("set nodelay")?;
            out_streams.push(Some(out));
            in_streams.push(Some(inc));
        }
        Ok((0..k)
            .map(|i| RingLink {
                tx: Box::new(WireTx {
                    stream: BufWriter::new(out_streams[i].take().expect("out taken once")),
                    scratch: Vec::new(),
                    warned_oversize: false,
                }),
                rx: Box::new(WireRx {
                    stream: BufReader::new(
                        in_streams[(i + k - 1) % k].take().expect("in taken once"),
                    ),
                }),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_msg() -> RingMessage {
        RingMessage::Model(ModelMsg {
            from: 2,
            round: 7,
            score: -1234.5678,
            dag: Dag::from_edges(5, &[(0, 1), (1, 2), (0, 3), (3, 4)]),
            token: RingToken {
                probes: vec![
                    RoundProbe { round: 6, best: -1300.25, hops: 3 },
                    RoundProbe { round: 7, best: -1234.5678, hops: 1 },
                ],
            },
            bundle: None,
        })
    }

    fn bundled_msg() -> RingMessage {
        use crate::model::BundleMeta;
        let bn = crate::bn::network::tiny_bn();
        let meta = BundleMeta { producer: "ring".into(), rounds: 7, score: -12.0, ess: 1.0 };
        let bundle = Bundle::calibrated_within(bn.clone(), meta, u64::MAX);
        RingMessage::Model(ModelMsg {
            from: 1,
            round: 7,
            score: -12.0,
            dag: bn.dag,
            token: RingToken { probes: vec![RoundProbe { round: 7, best: -12.0, hops: 1 }] },
            bundle: Some(bundle),
        })
    }

    fn assert_msgs_equal(a: &RingMessage, b: &RingMessage) {
        match (a, b) {
            (RingMessage::Stop, RingMessage::Stop) => {}
            (RingMessage::Model(x), RingMessage::Model(y)) => {
                assert_eq!(x.from, y.from);
                assert_eq!(x.round, y.round);
                assert_eq!(x.score, y.score);
                assert_eq!(x.dag.edges(), y.dag.edges());
                assert_eq!(x.token.probes, y.token.probes);
                assert_eq!(x.bundle.is_some(), y.bundle.is_some());
                if let (Some(p), Some(q)) = (&x.bundle, &y.bundle) {
                    assert_eq!(p.bn.names, q.bn.names);
                    assert_eq!(p.bn.dag.edges(), q.bn.dag.edges());
                    assert_eq!(p.has_potentials(), q.has_potentials());
                    if let (Some(pp), Some(qp)) = (&p.potentials, &q.potentials) {
                        assert_eq!(pp.fingerprint, qp.fingerprint);
                        for (m1, m2) in pp.messages.iter().zip(&qp.messages) {
                            for (u, v) in m1.iter().zip(m2) {
                                assert_eq!(u.to_bits(), v.to_bits());
                            }
                        }
                    }
                }
            }
            _ => panic!("message variants differ"),
        }
    }

    #[test]
    fn message_codec_roundtrip() {
        for msg in [model_msg(), bundled_msg(), RingMessage::Stop] {
            let mut buf = Vec::new();
            encode_message(&msg, &mut buf);
            let back = decode_message(&buf).unwrap();
            assert_msgs_equal(&msg, &back);
        }
    }

    #[test]
    fn bundle_less_frames_stay_byte_identical_to_legacy() {
        // Capability off = the sender attaches no bundle, and the
        // resulting frame must be exactly the legacy TAG_MODEL layout
        // (old peers keep interoperating byte-for-byte).
        let mut buf = Vec::new();
        encode_message(&model_msg(), &mut buf);
        assert_eq!(buf[0], TAG_MODEL);
        let mut bundled = Vec::new();
        encode_message(&bundled_msg(), &mut bundled);
        assert_eq!(bundled[0], TAG_MODEL_BUNDLE);
        // Stripping the bundle restores the legacy tag.
        let RingMessage::Model(mut m) = bundled_msg() else { unreachable!() };
        m.bundle = None;
        let mut stripped = Vec::new();
        encode_message(&RingMessage::Model(m), &mut stripped);
        assert_eq!(stripped[0], TAG_MODEL);
    }

    #[test]
    fn message_codec_rejects_garbage() {
        assert!(decode_message(&[]).is_err());
        assert!(decode_message(&[42]).is_err());
        let mut buf = Vec::new();
        encode_message(&model_msg(), &mut buf);
        buf.push(0); // trailing byte
        assert!(decode_message(&buf).is_err());
        assert!(decode_message(&buf[..buf.len() - 3]).is_err());
    }

    /// Pass a message all the way around a k-ring and check it arrives
    /// intact — the same relay on both transports.
    fn relay_roundtrip(transport: &dyn RingTransport) {
        let k = 3;
        let links = transport.connect(k).unwrap();
        let results = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for (i, link) in links.into_iter().enumerate() {
                let RingLink { mut tx, mut rx } = link;
                let results = &results;
                s.spawn(move || {
                    if i == 0 {
                        tx.send(model_msg()).unwrap();
                        let (msg, _) = rx.recv().unwrap();
                        results.lock().unwrap().push(msg);
                    } else {
                        let (msg, timing) = rx.recv().unwrap();
                        assert!(timing.wait_secs >= 0.0);
                        tx.send(msg).unwrap();
                    }
                });
            }
        });
        let got = results.into_inner().unwrap();
        assert_eq!(got.len(), 1);
        assert_msgs_equal(&got[0], &model_msg());
    }

    #[test]
    fn channel_relay_roundtrip() {
        relay_roundtrip(&ChannelTransport);
    }

    #[test]
    fn tcp_relay_roundtrip() {
        relay_roundtrip(&WireTransport);
    }

    #[test]
    fn single_worker_self_loop() {
        for transport in [&ChannelTransport as &dyn RingTransport, &WireTransport as &dyn RingTransport] {
            let mut links = transport.connect(1).unwrap();
            let RingLink { mut tx, mut rx } = links.pop().unwrap();
            tx.send(model_msg()).unwrap();
            tx.send(RingMessage::Stop).unwrap();
            let (first, _) = rx.recv().unwrap();
            assert_msgs_equal(&first, &model_msg());
            let (second, _) = rx.recv().unwrap();
            assert!(matches!(second, RingMessage::Stop));
        }
    }
}
