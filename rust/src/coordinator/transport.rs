//! Pluggable message transport for the ring runtime.
//!
//! Algorithm 1 of the paper is a *directed ring*: processor i receives
//! a model from its predecessor, fuses, learns on its edge subset, and
//! sends the result to its successor. This module is the communication
//! substrate of that ring, abstracted so the same worker loop can run
//! over different media:
//!
//! * [`ChannelTransport`] — in-process `std::sync::mpsc` channels, the
//!   default. Messages move by value; zero serialization cost.
//! * [`WireTransport`] — length-prefixed binary frames over loopback
//!   TCP sockets. Every model crosses a real byte boundary through the
//!   [`graph::codec`](crate::graph::codec) wire format, proving the
//!   abstraction is remotable: pointing the connector at remote
//!   addresses instead of `127.0.0.1` is a deployment change, not a
//!   code change (the direction FedGES takes for federated structure
//!   learning).
//!
//! # Topology
//!
//! [`RingTransport::connect`]`(k)` materializes the k directed links
//! of the ring and hands worker i a [`RingLink`]: a sender to its
//! successor (link i) and a receiver from its predecessor (link
//! (i−1) mod k). Exactly one message per round flows on each link, so
//! FIFO order per link is the only delivery guarantee the runtime
//! needs — precisely what both mpsc channels and TCP streams provide.
//!
//! # Messages and the convergence token
//!
//! A [`RingMessage`] is either a [`ModelMsg`] — the learned [`Dag`]
//! plus its BDeu score for one round — or `Stop`, the shutdown marker
//! that circulates once around the ring so every link drains cleanly.
//!
//! Termination detection replaces the old global barrier test with a
//! circulating token ([`RingToken`]): the ring head (worker 0) attaches
//! a [`RoundProbe`] carrying its round-r score to its round-r message;
//! every worker folds its own round-r score into the probe (a running
//! max of best-seen BDeu) and forwards it with its next message. After
//! k hops the probe returns to the head carrying the exact global best
//! score of round r, and the head applies the paper's convergence rule
//! (Algorithm 1 lines 11–16: stop when a round fails to improve the
//! best score seen so far) without ever stopping the pipeline.
//!
//! # Timing
//!
//! Send returns its serialization seconds and receive reports
//! (blocked-wait, decode) seconds separately, feeding the per-hop
//! worker timelines in [`telemetry`](crate::coordinator::telemetry).

use std::io::{BufReader, BufWriter, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::graph::codec::{
    decode_dag, encode_dag, put_f64, put_str, put_u32, put_u64, take_f64, take_str, take_u32,
    take_u64, take_u8,
};
use crate::graph::Dag;
use crate::model::{decode_bundle, encode_bundle, Bundle};
use crate::obs::log;
use crate::obs::sync::{answer_pings, measure_offset, ClockOffset, ReadWritePair, SYNC_ROUNDS};
use crate::obs::{HistDelta, RegistryDelta, SpanRec};
use crate::util::Timer;

use super::fault::RingFault;

/// One probe of the convergence token: the best BDeu score seen for
/// `round` across the `hops` workers it has visited so far.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundProbe {
    /// Ring round this probe measures.
    pub round: usize,
    /// Max BDeu score over the visited workers' round-`round` models.
    pub best: f64,
    /// Workers folded in so far (complete when `hops == k`).
    pub hops: usize,
}

/// The circulating convergence token (piggybacked on model messages).
#[derive(Clone, Debug, Default)]
pub struct RingToken {
    /// In-flight probes; in steady state exactly one per message.
    pub probes: Vec<RoundProbe>,
}

/// A model handoff from one ring worker to its successor.
#[derive(Clone, Debug)]
pub struct ModelMsg {
    /// Sending worker index.
    pub from: usize,
    /// Ring round the model belongs to.
    pub round: usize,
    /// BDeu score of `dag` (as computed by the sender).
    pub score: f64,
    /// The learned model.
    pub dag: Dag,
    /// Convergence-token probes riding along.
    pub token: RingToken,
    /// Optional self-contained model bundle (fitted CPTs + calibrated
    /// jointree potentials) riding alongside the structure. Gated by
    /// the ring's bundle capability
    /// ([`RingRunOptions::ship_bundles`](crate::coordinator::RingRunOptions)):
    /// with the capability off a message encodes to exactly the legacy
    /// `TAG_MODEL` frame, so potential-less peers interop unchanged;
    /// with it on the frame uses a new tag an old peer would cleanly
    /// refuse — which is why the flag must only be enabled ring-wide.
    pub bundle: Option<Bundle>,
    /// Observability shipments riding this hop, gated by the ring's
    /// obs capability
    /// ([`RingRunOptions::obs`](crate::coordinator::RingRunOptions))
    /// with the same contract as `bundle`: an empty list encodes to
    /// exactly the legacy frame, a non-empty one to a new tag. Each
    /// payload's spans are on the clock of the *last holder*, rebased
    /// by the measured link offset at every wire hop.
    pub obs: Vec<ObsPayload>,
}

/// One worker's observability shipment: the spans and metric deltas
/// accumulated since its previous round message, riding the ring hop
/// by hop toward the head (worker 0), which relays them to the
/// coordinator for merging.
#[derive(Clone, Debug, Default)]
pub struct ObsPayload {
    /// Worker whose data this is — its lane in the merged trace and
    /// its `worker<k>.` prefix in the merged registry.
    pub origin: u32,
    /// Completed spans; timestamps are on the current holder's clock
    /// (each wire hop rebases them with its link's [`ClockOffset`]).
    pub spans: Vec<SpanRec>,
    /// Metric changes since the origin's previous shipment.
    pub metrics: RegistryDelta,
}

impl ObsPayload {
    /// True when there is nothing to ship.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.metrics.is_empty()
    }
}

/// What flows on a ring link.
#[derive(Clone, Debug)]
pub enum RingMessage {
    /// A round's learned model.
    Model(ModelMsg),
    /// Shutdown marker: the sender is done; forward once and drain.
    Stop,
}

/// Timing breakdown of one receive.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecvTiming {
    /// Seconds blocked waiting for the message to arrive.
    pub wait_secs: f64,
    /// Seconds spent reading + decoding the payload (wire only).
    pub codec_secs: f64,
}

/// Sending half of a ring link (worker i → worker (i+1) mod k).
pub trait RingTx: Send {
    /// Send one message (by value — channels move it, wires encode
    /// it); returns serialization seconds (0 for moves). Errors are
    /// typed [`RingFault`]s — [`RingFault::PeerGone`] means the
    /// successor closed the link, [`RingFault::Oversize`] that the
    /// frame can't fit the wire cap.
    fn send(&mut self, msg: RingMessage) -> Result<f64, RingFault>;

    /// Fault-injection hook: send a deliberately mangled copy of
    /// `msg`. Wire links flip payload bytes so the receiver sees a
    /// framed-but-corrupt message ([`RingFault::Decode`]); in-process
    /// links move values and have no bytes to flip, so the default
    /// degrades to a drop (the closest observable effect: the frame
    /// is lost either way).
    fn send_corrupt(&mut self, msg: RingMessage) -> Result<f64, RingFault> {
        let _ = msg;
        log::warn(format_args!(
            "ring chaos: corrupt injection degrades to a drop on an in-process link"
        ));
        Ok(0.0)
    }

    /// Obs capability: answer the successor's clock-sync pings on this
    /// link's back-channel (wire links are full-duplex TCP), stamping
    /// replies with `now_ns` — the sender's tracer clock. In-process
    /// links share the host clock and need no handshake, so the
    /// default is a no-op. Must run concurrently with the successor's
    /// [`RingRx::measure_clock_sync`], before any round traffic.
    fn answer_clock_sync(&mut self, _now_ns: &mut dyn FnMut() -> u64) -> Result<()> {
        Ok(())
    }
}

/// Receiving half of a ring link (worker (i−1) mod k → worker i).
pub trait RingRx: Send {
    /// Block for the next message. Errors are typed [`RingFault`]s:
    /// [`RingFault::PeerGone`] when the peer closed the link without a
    /// `Stop`, [`RingFault::Decode`] for a corrupt-but-framed payload
    /// (the link stays synchronized; receiving again is safe).
    fn recv(&mut self) -> Result<(RingMessage, RecvTiming), RingFault>;

    /// Receive with a bounded wait. `deadline: None` is exactly
    /// [`RingRx::recv`] (the default implementation). With
    /// `Some(d)`, a frame whose first byte hasn't arrived within `d`
    /// returns [`RingFault::Timeout`] with the link still framed; a
    /// frame that *started* but stalls longer than `stall` returns
    /// [`RingFault::PeerGone`] (a half-read frame can't be resynced).
    fn recv_deadline(
        &mut self,
        deadline: Option<Duration>,
        stall: Duration,
    ) -> Result<(RingMessage, RecvTiming), RingFault> {
        let _ = stall;
        let _ = deadline;
        self.recv()
    }

    /// Obs capability: measure the predecessor's clock offset with a
    /// few NTP-style ping round-trips ([`crate::obs::sync`]), reading
    /// the local tracer clock through `now_ns`. `Ok(None)` means the
    /// link shares the caller's process and no measured offset is
    /// needed (the default, kept by in-process transports).
    fn measure_clock_sync(
        &mut self,
        _now_ns: &mut dyn FnMut() -> u64,
    ) -> Result<Option<ClockOffset>> {
        Ok(None)
    }
}

/// Both endpoints owned by one worker.
pub struct RingLink {
    /// To the successor.
    pub tx: Box<dyn RingTx>,
    /// From the predecessor.
    pub rx: Box<dyn RingRx>,
}

/// A way to materialize the k directed links of a ring. (Telemetry
/// naming comes from `RingMode::name` — the single source — so the
/// trait stays a pure connector.)
pub trait RingTransport {
    /// Build the ring: element i of the result is worker i's link pair
    /// (tx to successor, rx from predecessor).
    fn connect(&self, k: usize) -> Result<Vec<RingLink>>;
}

// ---------------------------------------------------------------------
// Channel transport (in-process, the default)
// ---------------------------------------------------------------------

/// In-process transport over unbounded `mpsc` channels.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChannelTransport;

struct ChannelTx {
    sender: mpsc::Sender<RingMessage>,
}

struct ChannelRx {
    receiver: mpsc::Receiver<RingMessage>,
}

impl RingTx for ChannelTx {
    fn send(&mut self, msg: RingMessage) -> Result<f64, RingFault> {
        self.sender
            .send(msg)
            .map_err(|_| RingFault::PeerGone { detail: "ring successor hung up".into() })?;
        Ok(0.0)
    }
}

impl RingRx for ChannelRx {
    fn recv(&mut self) -> Result<(RingMessage, RecvTiming), RingFault> {
        let t = Timer::start();
        let msg = self
            .receiver
            .recv()
            .map_err(|_| RingFault::PeerGone { detail: "ring predecessor hung up".into() })?;
        Ok((msg, RecvTiming { wait_secs: t.secs(), codec_secs: 0.0 }))
    }

    fn recv_deadline(
        &mut self,
        deadline: Option<Duration>,
        _stall: Duration,
    ) -> Result<(RingMessage, RecvTiming), RingFault> {
        let Some(d) = deadline else { return self.recv() };
        let t = Timer::start();
        match self.receiver.recv_timeout(d) {
            Ok(msg) => Ok((msg, RecvTiming { wait_secs: t.secs(), codec_secs: 0.0 })),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(RingFault::Timeout { after: d }),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(RingFault::PeerGone { detail: "ring predecessor hung up".into() })
            }
        }
    }
}

impl RingTransport for ChannelTransport {
    fn connect(&self, k: usize) -> Result<Vec<RingLink>> {
        assert!(k >= 1, "ring needs at least one worker");
        let mut txs: Vec<Option<mpsc::Sender<RingMessage>>> = Vec::with_capacity(k);
        let mut rxs: Vec<Option<mpsc::Receiver<RingMessage>>> = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = mpsc::channel();
            txs.push(Some(tx));
            rxs.push(Some(rx));
        }
        Ok((0..k)
            .map(|i| RingLink {
                tx: Box::new(ChannelTx { sender: txs[i].take().expect("tx taken once") }),
                rx: Box::new(ChannelRx {
                    receiver: rxs[(i + k - 1) % k].take().expect("rx taken once"),
                }),
            })
            .collect())
    }
}

// ---------------------------------------------------------------------
// Wire transport (length-prefixed binary frames over TCP)
// ---------------------------------------------------------------------

/// Hard cap on a single frame; a learned BN is O(n) edges, so even
/// genome-scale rings stay far below this. Guards against corrupt
/// length prefixes allocating unbounded buffers.
const MAX_FRAME_BYTES: u32 = 64 << 20;

const TAG_MODEL: u8 = 0;
const TAG_STOP: u8 = 1;
/// A model frame that additionally carries a bundle payload. Emitted
/// only when the ring's bundle capability is on; peers without the
/// capability never see (and would refuse) this tag.
const TAG_MODEL_BUNDLE: u8 = 2;
/// A model frame that additionally carries obs payloads (and, for
/// `TAG_MODEL_BUNDLE_OBS`, a bundle too). Same capability contract:
/// emitted only when the ring's obs capability is on, so legacy peers
/// never see these tags.
const TAG_MODEL_OBS: u8 = 3;
const TAG_MODEL_BUNDLE_OBS: u8 = 4;

/// Span categories and argument keys cross the wire as text, but
/// [`SpanRec`] holds `&'static str`s; decoding interns the crate's own
/// instrumentation names and degrades anything else to a generic
/// label. Lossy only for names the crate never emits.
fn intern_cat(s: &str) -> &'static str {
    match s {
        "ring" => "ring",
        "stage" => "stage",
        "serve" => "serve",
        "jointree" => "jointree",
        "proc" => "proc",
        "test" => "test",
        _ => "remote",
    }
}

fn intern_arg(s: &str) -> &'static str {
    match s {
        "round" => "round",
        "rounds" => "rounds",
        "inserts" => "inserts",
        "deletes" => "deletes",
        "score" => "score",
        "i" => "i",
        _ => "arg",
    }
}

fn encode_obs_section(payloads: &[ObsPayload], buf: &mut Vec<u8>) {
    put_u32(buf, payloads.len() as u32);
    for p in payloads {
        put_u32(buf, p.origin);
        put_u32(buf, p.spans.len() as u32);
        for s in &p.spans {
            put_str(buf, &s.name);
            put_str(buf, s.cat);
            put_u32(buf, s.tid);
            put_u64(buf, s.start_ns);
            put_u64(buf, s.dur_ns);
            put_u32(buf, s.args.len() as u32);
            for (k, v) in &s.args {
                put_str(buf, k);
                put_f64(buf, *v);
            }
        }
        let m = &p.metrics;
        put_u32(buf, m.counters.len() as u32);
        for (k, v) in &m.counters {
            put_str(buf, k);
            put_u64(buf, *v);
        }
        put_u32(buf, m.gauges.len() as u32);
        for (k, v) in &m.gauges {
            put_str(buf, k);
            put_f64(buf, *v);
        }
        put_u32(buf, m.hists.len() as u32);
        for (k, d) in &m.hists {
            put_str(buf, k);
            put_u32(buf, d.buckets.len() as u32);
            for &(idx, n) in &d.buckets {
                buf.push(idx);
                put_u64(buf, n);
            }
            put_u64(buf, d.sum);
            put_u64(buf, d.count);
            put_u64(buf, d.max);
            put_u64(buf, d.min);
        }
    }
}

/// Read a `u32` element count and reject values the remaining payload
/// can't possibly hold (`min_bytes` per element) before allocating.
fn guarded_count(cursor: &mut &[u8], min_bytes: usize, what: &str) -> Result<usize> {
    let n = take_u32(cursor)? as usize;
    if n > cursor.len() / min_bytes.max(1) {
        bail!("{what} count {n} exceeds remaining frame ({} bytes)", cursor.len());
    }
    Ok(n)
}

fn decode_obs_section(cursor: &mut &[u8]) -> Result<Vec<ObsPayload>> {
    let n_payloads = guarded_count(cursor, 32, "obs payload")?;
    let mut payloads = Vec::with_capacity(n_payloads);
    for _ in 0..n_payloads {
        let origin = take_u32(cursor)?;
        let n_spans = guarded_count(cursor, 32, "span")?;
        let mut spans = Vec::with_capacity(n_spans);
        for _ in 0..n_spans {
            let name = take_str(cursor)?;
            let cat = intern_cat(&take_str(cursor)?);
            let tid = take_u32(cursor)?;
            let start_ns = take_u64(cursor)?;
            let dur_ns = take_u64(cursor)?;
            let n_args = guarded_count(cursor, 12, "span arg")?;
            let mut args = Vec::with_capacity(n_args);
            for _ in 0..n_args {
                let key = intern_arg(&take_str(cursor)?);
                args.push((key, take_f64(cursor)?));
            }
            spans.push(SpanRec { name, cat, tid, start_ns, dur_ns, args });
        }
        let mut metrics = RegistryDelta::default();
        let n_counters = guarded_count(cursor, 12, "counter")?;
        for _ in 0..n_counters {
            let name = take_str(cursor)?;
            metrics.counters.push((name, take_u64(cursor)?));
        }
        let n_gauges = guarded_count(cursor, 12, "gauge")?;
        for _ in 0..n_gauges {
            let name = take_str(cursor)?;
            metrics.gauges.push((name, take_f64(cursor)?));
        }
        let n_hists = guarded_count(cursor, 40, "histogram")?;
        for _ in 0..n_hists {
            let name = take_str(cursor)?;
            let n_buckets = guarded_count(cursor, 9, "bucket")?;
            let mut buckets = Vec::with_capacity(n_buckets);
            for _ in 0..n_buckets {
                let idx = take_u8(cursor)?;
                buckets.push((idx, take_u64(cursor)?));
            }
            let sum = take_u64(cursor)?;
            let count = take_u64(cursor)?;
            let max = take_u64(cursor)?;
            let min = take_u64(cursor)?;
            metrics.hists.push((name, HistDelta { buckets, sum, count, max, min }));
        }
        payloads.push(ObsPayload { origin, spans, metrics });
    }
    Ok(payloads)
}

/// Encode a [`RingMessage`] to its wire form (appended to `buf`).
/// Bundle-less, obs-less model messages encode byte-identically to the
/// original pre-capability format.
pub fn encode_message(msg: &RingMessage, buf: &mut Vec<u8>) {
    match msg {
        RingMessage::Stop => buf.push(TAG_STOP),
        RingMessage::Model(m) => {
            buf.push(match (m.bundle.is_some(), !m.obs.is_empty()) {
                (false, false) => TAG_MODEL,
                (true, false) => TAG_MODEL_BUNDLE,
                (false, true) => TAG_MODEL_OBS,
                (true, true) => TAG_MODEL_BUNDLE_OBS,
            });
            put_u32(buf, m.from as u32);
            put_u32(buf, m.round as u32);
            put_f64(buf, m.score);
            put_u32(buf, m.token.probes.len() as u32);
            for p in &m.token.probes {
                put_u32(buf, p.round as u32);
                put_u32(buf, p.hops as u32);
                put_f64(buf, p.best);
            }
            encode_dag(&m.dag, buf);
            if let Some(b) = &m.bundle {
                encode_bundle(b, buf);
            }
            if !m.obs.is_empty() {
                encode_obs_section(&m.obs, buf);
            }
        }
    }
}

/// Decode a full [`RingMessage`] frame.
pub fn decode_message(bytes: &[u8]) -> Result<RingMessage> {
    let mut cursor = bytes;
    let tag = take_u8(&mut cursor)?;
    let msg = match tag {
        TAG_STOP => RingMessage::Stop,
        TAG_MODEL | TAG_MODEL_BUNDLE | TAG_MODEL_OBS | TAG_MODEL_BUNDLE_OBS => {
            let from = take_u32(&mut cursor)? as usize;
            let round = take_u32(&mut cursor)? as usize;
            let score = take_f64(&mut cursor)?;
            // Each probe encodes to 16 bytes; a count the remaining
            // payload cannot hold is corrupt — reject before
            // allocating for it.
            let n_probes = guarded_count(&mut cursor, 16, "probe")?;
            let mut probes = Vec::with_capacity(n_probes);
            for _ in 0..n_probes {
                let round = take_u32(&mut cursor)? as usize;
                let hops = take_u32(&mut cursor)? as usize;
                let best = take_f64(&mut cursor)?;
                probes.push(RoundProbe { round, best, hops });
            }
            let dag = decode_dag(&mut cursor)?;
            let bundle = if tag == TAG_MODEL_BUNDLE || tag == TAG_MODEL_BUNDLE_OBS {
                Some(decode_bundle(&mut cursor)?)
            } else {
                None
            };
            let obs = if tag == TAG_MODEL_OBS || tag == TAG_MODEL_BUNDLE_OBS {
                decode_obs_section(&mut cursor)?
            } else {
                Vec::new()
            };
            RingMessage::Model(ModelMsg {
                from,
                round,
                score,
                dag,
                token: RingToken { probes },
                bundle,
                obs,
            })
        }
        other => bail!("unknown message tag {other}"),
    };
    if !cursor.is_empty() {
        bail!("{} trailing bytes after message frame", cursor.len());
    }
    Ok(msg)
}

/// TCP-loopback transport: every hop serializes through the wire codec.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireTransport;

struct WireTx {
    stream: BufWriter<TcpStream>,
    scratch: Vec<u8>,
    /// Oversized-bundle degrade already reported on this link.
    warned_oversize: bool,
}

struct WireRx {
    stream: BufReader<TcpStream>,
}

impl WireTx {
    /// Write the scratch buffer as one length-prefixed frame.
    fn flush_scratch(&mut self) -> Result<(), RingFault> {
        let len = u32::try_from(self.scratch.len()).map_err(|_| RingFault::Oversize {
            len: self.scratch.len() as u64,
            cap: MAX_FRAME_BYTES as u64,
        })?;
        if len > MAX_FRAME_BYTES {
            return Err(RingFault::Oversize { len: len as u64, cap: MAX_FRAME_BYTES as u64 });
        }
        let gone = |what: &str| {
            let what = what.to_string();
            move |e: std::io::Error| RingFault::PeerGone { detail: format!("{what}: {e}") }
        };
        self.stream.write_all(&len.to_le_bytes()).map_err(gone("write frame length"))?;
        self.stream.write_all(&self.scratch).map_err(gone("write frame payload"))?;
        self.stream.flush().map_err(gone("flush frame"))?;
        Ok(())
    }
}

impl RingTx for WireTx {
    fn send(&mut self, msg: RingMessage) -> Result<f64, RingFault> {
        // Only serialization counts as codec time; blocking in the
        // socket writes is communication, not encoding, and must not
        // masquerade as codec cost in the worker timelines.
        let t = Timer::start();
        self.scratch.clear();
        encode_message(&msg, &mut self.scratch);
        let mut codec_secs = t.secs();

        // Bundle and obs payloads are advisory: when they push the
        // frame past the cap, ship the bare structure instead of
        // erroring — the worker loop reads a send error as "peer gone"
        // and would silently tear the ring down mid-run. The re-encode
        // never copies the oversized payloads themselves (the borrowed
        // message is encoded with both capability slots emptied).
        if self.scratch.len() > MAX_FRAME_BYTES as usize {
            if let RingMessage::Model(m) = &msg {
                if m.bundle.is_some() || !m.obs.is_empty() {
                    if !self.warned_oversize {
                        self.warned_oversize = true;
                        eprintln!(
                            "warning: ring capability payloads inflate the frame to {} bytes \
                             (cap {MAX_FRAME_BYTES}); shipping bare structures \
                             on this link",
                            self.scratch.len()
                        );
                    }
                    let t = Timer::start();
                    let slim = ModelMsg {
                        from: m.from,
                        round: m.round,
                        score: m.score,
                        dag: m.dag.clone(),
                        token: m.token.clone(),
                        bundle: None,
                        obs: Vec::new(),
                    };
                    self.scratch.clear();
                    encode_message(&RingMessage::Model(slim), &mut self.scratch);
                    codec_secs += t.secs();
                }
            }
        }

        self.flush_scratch()?;
        Ok(codec_secs)
    }

    fn send_corrupt(&mut self, msg: RingMessage) -> Result<f64, RingFault> {
        // Chaos-only path: encode, then mangle the payload while
        // keeping the length prefix consistent with what is written —
        // the receiver sees a well-framed but undecodable message and
        // the link stays synchronized. Truncating the tail plus
        // flipping a middle byte reliably trips the codec's validation
        // (`message_codec_rejects_garbage` pins truncated frames as
        // undecodable).
        let t = Timer::start();
        self.scratch.clear();
        encode_message(&msg, &mut self.scratch);
        let codec_secs = t.secs();
        if self.scratch.len() > 4 {
            let mid = self.scratch.len() / 2;
            self.scratch[mid] ^= 0xFF;
            self.scratch.truncate(self.scratch.len() - 3);
        }
        self.flush_scratch()?;
        Ok(codec_secs)
    }

    fn answer_clock_sync(&mut self, now_ns: &mut dyn FnMut() -> u64) -> Result<()> {
        // The link's TCP stream is full-duplex: the successor pings us
        // on the direction we normally only write. Run before any
        // frames, so the writer buffer is empty — flush to be safe.
        self.stream.flush().context("flush before clock sync")?;
        answer_pings(self.stream.get_mut(), now_ns, SYNC_ROUNDS)
    }
}

/// Poll slice while a deadline-armed read waits for bytes.
const WIRE_POLL: Duration = Duration::from_millis(20);

impl WireRx {
    /// Read one length-prefixed frame, blocking indefinitely.
    fn read_frame_blocking(&mut self) -> Result<Vec<u8>, RingFault> {
        let mut len_bytes = [0u8; 4];
        self.stream
            .read_exact(&mut len_bytes)
            .map_err(|e| RingFault::PeerGone { detail: format!("read frame length: {e}") })?;
        let len = u32::from_le_bytes(len_bytes);
        if len > MAX_FRAME_BYTES {
            return Err(RingFault::Oversize { len: len as u64, cap: MAX_FRAME_BYTES as u64 });
        }
        let mut payload = vec![0u8; len as usize];
        self.stream
            .read_exact(&mut payload)
            .map_err(|e| RingFault::PeerGone { detail: format!("read frame payload: {e}") })?;
        Ok(payload)
    }

    /// Read one frame under a first-byte `deadline` and a mid-frame
    /// `stall` grace, polling the socket in short slices. A deadline
    /// expiry with zero bytes consumed leaves the link framed
    /// ([`RingFault::Timeout`]); a frame that started but stalls is
    /// unrecoverable ([`RingFault::PeerGone`]).
    fn read_frame_deadline(
        &mut self,
        deadline: Duration,
        stall: Duration,
    ) -> Result<Vec<u8>, RingFault> {
        let start = Instant::now();
        let poll = WIRE_POLL.min(deadline.max(Duration::from_millis(1)));
        self.stream
            .get_ref()
            .set_read_timeout(Some(poll))
            .map_err(|e| RingFault::PeerGone { detail: format!("arm read timeout: {e}") })?;
        let out = self.read_frame_polled(start, deadline, stall);
        // Restore the blocking socket for plain `recv` and clock sync.
        let _ = self.stream.get_ref().set_read_timeout(None);
        out
    }

    fn read_frame_polled(
        &mut self,
        start: Instant,
        deadline: Duration,
        stall: Duration,
    ) -> Result<Vec<u8>, RingFault> {
        let mut len_bytes = [0u8; 4];
        let mut got = 0usize;
        let mut frame_started: Option<Instant> = None;
        while got < len_bytes.len() {
            match self.stream.read(&mut len_bytes[got..]) {
                Ok(0) => {
                    return Err(RingFault::PeerGone {
                        detail: "ring peer closed the link".into(),
                    })
                }
                Ok(n) => {
                    got += n;
                    frame_started.get_or_insert_with(Instant::now);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    match frame_started {
                        None if start.elapsed() >= deadline => {
                            return Err(RingFault::Timeout { after: deadline })
                        }
                        Some(t0) if t0.elapsed() >= stall => {
                            return Err(RingFault::PeerGone {
                                detail: "ring peer stalled mid-frame".into(),
                            })
                        }
                        _ => {}
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(RingFault::PeerGone { detail: format!("read frame length: {e}") })
                }
            }
        }
        let len = u32::from_le_bytes(len_bytes);
        if len > MAX_FRAME_BYTES {
            return Err(RingFault::Oversize { len: len as u64, cap: MAX_FRAME_BYTES as u64 });
        }
        let t0 = frame_started.unwrap_or_else(Instant::now);
        let mut payload = vec![0u8; len as usize];
        let mut got = 0usize;
        while got < payload.len() {
            match self.stream.read(&mut payload[got..]) {
                Ok(0) => {
                    return Err(RingFault::PeerGone {
                        detail: "ring peer closed the link mid-frame".into(),
                    })
                }
                Ok(n) => got += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    if t0.elapsed() >= stall {
                        return Err(RingFault::PeerGone {
                            detail: "ring peer stalled mid-frame".into(),
                        });
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(RingFault::PeerGone { detail: format!("read frame payload: {e}") })
                }
            }
        }
        Ok(payload)
    }

    fn recv_inner(
        &mut self,
        deadline: Option<Duration>,
        stall: Duration,
    ) -> Result<(RingMessage, RecvTiming), RingFault> {
        // All socket I/O (length prefix *and* payload) is wait;
        // only the in-memory decode is codec.
        let t = Timer::start();
        let payload = match deadline {
            None => self.read_frame_blocking()?,
            Some(d) => self.read_frame_deadline(d, stall)?,
        };
        let wait_secs = t.secs();

        let t = Timer::start();
        match decode_message(&payload) {
            Ok(msg) => Ok((msg, RecvTiming { wait_secs, codec_secs: t.secs() })),
            Err(e) => Err(RingFault::Decode { detail: format!("{e:#}") }),
        }
    }
}

impl RingRx for WireRx {
    fn recv(&mut self) -> Result<(RingMessage, RecvTiming), RingFault> {
        self.recv_inner(None, Duration::MAX)
    }

    fn recv_deadline(
        &mut self,
        deadline: Option<Duration>,
        stall: Duration,
    ) -> Result<(RingMessage, RecvTiming), RingFault> {
        self.recv_inner(deadline, stall)
    }

    fn measure_clock_sync(
        &mut self,
        now_ns: &mut dyn FnMut() -> u64,
    ) -> Result<Option<ClockOffset>> {
        // Ping the predecessor over this link's back-channel. Reads go
        // through the BufReader (any prefetched bytes stay available
        // to later `recv`s); writes go through a second OS handle to
        // the same socket.
        let mut tx_half = self
            .stream
            .get_ref()
            .try_clone()
            .context("clone ring socket for clock sync")?;
        let mut pair = ReadWritePair { r: &mut self.stream, w: &mut tx_half };
        Ok(Some(measure_offset(&mut pair, now_ns, SYNC_ROUNDS)?))
    }
}

impl RingTransport for WireTransport {
    fn connect(&self, k: usize) -> Result<Vec<RingLink>> {
        assert!(k >= 1, "ring needs at least one worker");
        // One listener per directed link i → (i+1) mod k. Bind all
        // first, then connect+accept pairwise: loopback connects
        // complete against the listen backlog, so a single thread can
        // wire the whole ring.
        let listeners: Vec<TcpListener> = (0..k)
            .map(|_| TcpListener::bind(("127.0.0.1", 0)).context("bind ring listener"))
            .collect::<Result<_>>()?;
        let mut out_streams: Vec<Option<TcpStream>> = Vec::with_capacity(k);
        let mut in_streams: Vec<Option<TcpStream>> = Vec::with_capacity(k);
        for listener in &listeners {
            let addr = listener.local_addr().context("listener addr")?;
            let out = TcpStream::connect(addr).context("connect ring link")?;
            out.set_nodelay(true).context("set nodelay")?;
            let (inc, _) = listener.accept().context("accept ring link")?;
            inc.set_nodelay(true).context("set nodelay")?;
            out_streams.push(Some(out));
            in_streams.push(Some(inc));
        }
        Ok((0..k)
            .map(|i| RingLink {
                tx: Box::new(WireTx {
                    stream: BufWriter::new(out_streams[i].take().expect("out taken once")),
                    scratch: Vec::new(),
                    warned_oversize: false,
                }),
                rx: Box::new(WireRx {
                    stream: BufReader::new(
                        in_streams[(i + k - 1) % k].take().expect("in taken once"),
                    ),
                }),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_msg() -> RingMessage {
        RingMessage::Model(ModelMsg {
            from: 2,
            round: 7,
            score: -1234.5678,
            dag: Dag::from_edges(5, &[(0, 1), (1, 2), (0, 3), (3, 4)]),
            token: RingToken {
                probes: vec![
                    RoundProbe { round: 6, best: -1300.25, hops: 3 },
                    RoundProbe { round: 7, best: -1234.5678, hops: 1 },
                ],
            },
            bundle: None,
            obs: Vec::new(),
        })
    }

    fn obs_payload(origin: u32) -> ObsPayload {
        ObsPayload {
            origin,
            spans: vec![
                SpanRec {
                    name: "ges".into(),
                    cat: "ring",
                    tid: origin,
                    start_ns: 1_000,
                    dur_ns: 500,
                    args: vec![("round", 3.0), ("score", -12.5)],
                },
                SpanRec {
                    name: "wait".into(),
                    cat: "ring",
                    tid: origin,
                    start_ns: 1_500,
                    dur_ns: 80,
                    args: vec![],
                },
            ],
            metrics: RegistryDelta {
                counters: vec![("ring.hops".into(), 2)],
                gauges: vec![("load".into(), 0.25)],
                hists: vec![(
                    "ring.wait_ns".into(),
                    HistDelta {
                        buckets: vec![(7, 1), (10, 3)],
                        sum: 4_242,
                        count: 4,
                        max: 900,
                        min: 64,
                    },
                )],
            },
        }
    }

    fn obs_msg() -> RingMessage {
        let RingMessage::Model(mut m) = model_msg() else { unreachable!() };
        m.obs = vec![obs_payload(2), obs_payload(0)];
        RingMessage::Model(m)
    }

    fn bundled_msg() -> RingMessage {
        use crate::model::BundleMeta;
        let bn = crate::bn::network::tiny_bn();
        let meta = BundleMeta { producer: "ring".into(), rounds: 7, score: -12.0, ess: 1.0 };
        let bundle = Bundle::calibrated_within(bn.clone(), meta, u64::MAX);
        RingMessage::Model(ModelMsg {
            from: 1,
            round: 7,
            score: -12.0,
            dag: bn.dag,
            token: RingToken { probes: vec![RoundProbe { round: 7, best: -12.0, hops: 1 }] },
            bundle: Some(bundle),
            obs: Vec::new(),
        })
    }

    fn assert_msgs_equal(a: &RingMessage, b: &RingMessage) {
        match (a, b) {
            (RingMessage::Stop, RingMessage::Stop) => {}
            (RingMessage::Model(x), RingMessage::Model(y)) => {
                assert_eq!(x.from, y.from);
                assert_eq!(x.round, y.round);
                assert_eq!(x.score, y.score);
                assert_eq!(x.dag.edges(), y.dag.edges());
                assert_eq!(x.token.probes, y.token.probes);
                assert_eq!(x.obs.len(), y.obs.len());
                for (p, q) in x.obs.iter().zip(&y.obs) {
                    assert_eq!(p.origin, q.origin);
                    assert_eq!(p.spans, q.spans);
                    assert_eq!(p.metrics.counters, q.metrics.counters);
                    assert_eq!(p.metrics.gauges, q.metrics.gauges);
                    assert_eq!(p.metrics.hists, q.metrics.hists);
                }
                assert_eq!(x.bundle.is_some(), y.bundle.is_some());
                if let (Some(p), Some(q)) = (&x.bundle, &y.bundle) {
                    assert_eq!(p.bn.names, q.bn.names);
                    assert_eq!(p.bn.dag.edges(), q.bn.dag.edges());
                    assert_eq!(p.has_potentials(), q.has_potentials());
                    if let (Some(pp), Some(qp)) = (&p.potentials, &q.potentials) {
                        assert_eq!(pp.fingerprint, qp.fingerprint);
                        for (m1, m2) in pp.messages.iter().zip(&qp.messages) {
                            for (u, v) in m1.iter().zip(m2) {
                                assert_eq!(u.to_bits(), v.to_bits());
                            }
                        }
                    }
                }
            }
            _ => panic!("message variants differ"),
        }
    }

    #[test]
    fn message_codec_roundtrip() {
        let both = {
            let RingMessage::Model(mut m) = bundled_msg() else { unreachable!() };
            m.obs = vec![obs_payload(1)];
            RingMessage::Model(m)
        };
        for msg in [model_msg(), bundled_msg(), obs_msg(), both, RingMessage::Stop] {
            let mut buf = Vec::new();
            encode_message(&msg, &mut buf);
            let back = decode_message(&buf).unwrap();
            assert_msgs_equal(&msg, &back);
        }
    }

    #[test]
    fn unknown_span_names_intern_to_generic_labels() {
        let RingMessage::Model(mut m) = model_msg() else { unreachable!() };
        m.obs = vec![ObsPayload {
            origin: 1,
            spans: vec![SpanRec {
                name: "x".into(),
                cat: "test",
                tid: 1,
                start_ns: 0,
                dur_ns: 1,
                args: vec![("round", 1.0)],
            }],
            metrics: RegistryDelta::default(),
        }];
        let mut buf = Vec::new();
        encode_message(&RingMessage::Model(m), &mut buf);
        // Corrupting nothing: a known cat survives; an alien cat would
        // come back as "remote" — simulate by checking the intern fns.
        assert_eq!(intern_cat("ring"), "ring");
        assert_eq!(intern_cat("alien"), "remote");
        assert_eq!(intern_arg("score"), "score");
        assert_eq!(intern_arg("alien"), "arg");
        let back = decode_message(&buf).unwrap();
        let RingMessage::Model(b) = back else { unreachable!() };
        assert_eq!(b.obs[0].spans[0].cat, "test");
    }

    #[test]
    fn bundle_less_frames_stay_byte_identical_to_legacy() {
        // Capability off = the sender attaches no bundle and no obs
        // payloads, and the resulting frame must be exactly the legacy
        // TAG_MODEL layout (old peers keep interoperating
        // byte-for-byte).
        let mut buf = Vec::new();
        encode_message(&model_msg(), &mut buf);
        assert_eq!(buf[0], TAG_MODEL);
        let mut bundled = Vec::new();
        encode_message(&bundled_msg(), &mut bundled);
        assert_eq!(bundled[0], TAG_MODEL_BUNDLE);
        let mut with_obs = Vec::new();
        encode_message(&obs_msg(), &mut with_obs);
        assert_eq!(with_obs[0], TAG_MODEL_OBS);
        // Stripping the capability payloads restores the legacy frame
        // byte-for-byte, not just the tag.
        let RingMessage::Model(mut m) = bundled_msg() else { unreachable!() };
        m.bundle = None;
        let mut stripped = Vec::new();
        encode_message(&RingMessage::Model(m), &mut stripped);
        assert_eq!(stripped[0], TAG_MODEL);
        let RingMessage::Model(mut m) = obs_msg() else { unreachable!() };
        m.obs.clear();
        let mut obs_stripped = Vec::new();
        encode_message(&RingMessage::Model(m), &mut obs_stripped);
        assert_eq!(obs_stripped, buf, "obs-less frame must match legacy bytes exactly");
    }

    #[test]
    fn message_codec_rejects_garbage() {
        assert!(decode_message(&[]).is_err());
        assert!(decode_message(&[42]).is_err());
        for msg in [model_msg(), obs_msg()] {
            let mut buf = Vec::new();
            encode_message(&msg, &mut buf);
            buf.push(0); // trailing byte
            assert!(decode_message(&buf).is_err());
            assert!(decode_message(&buf[..buf.len() - 3]).is_err());
        }
    }

    #[test]
    fn wire_clock_sync_measures_offset_between_link_peers() {
        // One directed link of a 2-ring: worker 1's rx initiates, the
        // predecessor's tx answers. Fixed fake clocks make the offset
        // deterministic up to RTT.
        let links = WireTransport.connect(2).unwrap();
        let mut it = links.into_iter();
        let mut w0 = it.next().unwrap();
        let mut w1 = it.next().unwrap();
        const SKEW_NS: u64 = 2_000_000_000;
        let epoch = std::time::Instant::now();
        std::thread::scope(|s| {
            s.spawn(move || {
                // worker 0 answers on its tx link with its own clock
                let mut now = || epoch.elapsed().as_nanos() as u64;
                w0.tx.answer_clock_sync(&mut now).expect("answer");
            });
            let mut now = || epoch.elapsed().as_nanos() as u64 + SKEW_NS;
            let off = w1
                .rx
                .measure_clock_sync(&mut now)
                .expect("measure")
                .expect("wire links report a measured offset");
            let err = (off.offset_ns - SKEW_NS as i64).unsigned_abs();
            assert!(err <= off.rtt_ns / 2 + 1, "offset {off:?} vs skew {SKEW_NS}");
        });
        // Channel links report None (shared clock).
        let links = ChannelTransport.connect(2).unwrap();
        let mut link = links.into_iter().next().unwrap();
        let mut now = || 0u64;
        assert!(link.rx.measure_clock_sync(&mut now).unwrap().is_none());
        assert!(link.tx.answer_clock_sync(&mut now).is_ok());
    }

    /// Pass a message all the way around a k-ring and check it arrives
    /// intact — the same relay on both transports.
    fn relay_roundtrip(transport: &dyn RingTransport) {
        let k = 3;
        let links = transport.connect(k).unwrap();
        let results = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for (i, link) in links.into_iter().enumerate() {
                let RingLink { mut tx, mut rx } = link;
                let results = &results;
                s.spawn(move || {
                    if i == 0 {
                        tx.send(model_msg()).unwrap();
                        let (msg, _) = rx.recv().unwrap();
                        results.lock().unwrap().push(msg);
                    } else {
                        let (msg, timing) = rx.recv().unwrap();
                        assert!(timing.wait_secs >= 0.0);
                        tx.send(msg).unwrap();
                    }
                });
            }
        });
        let got = results.into_inner().unwrap();
        assert_eq!(got.len(), 1);
        assert_msgs_equal(&got[0], &model_msg());
    }

    #[test]
    fn channel_relay_roundtrip() {
        relay_roundtrip(&ChannelTransport);
    }

    #[test]
    fn tcp_relay_roundtrip() {
        relay_roundtrip(&WireTransport);
    }

    #[test]
    fn single_worker_self_loop() {
        for transport in [&ChannelTransport as &dyn RingTransport, &WireTransport as &dyn RingTransport] {
            let mut links = transport.connect(1).unwrap();
            let RingLink { mut tx, mut rx } = links.pop().unwrap();
            tx.send(model_msg()).unwrap();
            tx.send(RingMessage::Stop).unwrap();
            let (first, _) = rx.recv().unwrap();
            assert_msgs_equal(&first, &model_msg());
            let (second, _) = rx.recv().unwrap();
            assert!(matches!(second, RingMessage::Stop));
        }
    }

    #[test]
    fn recv_deadline_times_out_then_still_delivers() {
        // Both transports: an expired deadline is a typed Timeout that
        // leaves the link framed — the next message arrives intact.
        for transport in
            [&ChannelTransport as &dyn RingTransport, &WireTransport as &dyn RingTransport]
        {
            let mut links = transport.connect(1).unwrap();
            let RingLink { mut tx, mut rx } = links.pop().unwrap();
            let d = Duration::from_millis(60);
            let err = rx.recv_deadline(Some(d), Duration::from_secs(5)).unwrap_err();
            assert!(matches!(err, RingFault::Timeout { .. }), "{err}");
            tx.send(model_msg()).unwrap();
            let (msg, _) = rx
                .recv_deadline(Some(Duration::from_secs(5)), Duration::from_secs(5))
                .unwrap();
            assert_msgs_equal(&msg, &model_msg());
        }
    }

    #[test]
    fn wire_corrupt_send_is_a_typed_decode_fault() {
        // One directed wire link: a mangled frame surfaces as Decode
        // (not PeerGone) and the link stays synchronized for the next
        // clean frame.
        let links = WireTransport.connect(2).unwrap();
        let mut it = links.into_iter();
        let mut w0 = it.next().unwrap();
        let mut w1 = it.next().unwrap();
        w0.tx.send_corrupt(model_msg()).unwrap();
        w0.tx.send(model_msg()).unwrap();
        let err = w1.rx.recv().unwrap_err();
        assert!(matches!(err, RingFault::Decode { .. }), "{err}");
        let (msg, _) = w1.rx.recv().unwrap();
        assert_msgs_equal(&msg, &model_msg());
    }

    #[test]
    fn wire_mid_frame_stall_is_peer_gone() {
        // A frame that starts arriving but stalls past the grace is
        // unrecoverable: the reader cannot resynchronize mid-frame.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut rx = WireRx { stream: BufReader::new(server) };
        client.write_all(&[7u8, 0]).unwrap(); // 2 of 4 prefix bytes, then silence
        client.flush().unwrap();
        let err = rx
            .recv_deadline(Some(Duration::from_millis(500)), Duration::from_millis(120))
            .unwrap_err();
        assert!(matches!(err, RingFault::PeerGone { .. }), "{err}");
    }

    #[test]
    fn wire_oversize_prefix_is_a_typed_fault() {
        // A corrupt length prefix above the cap is rejected before any
        // allocation, as Oversize.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut rx = WireRx { stream: BufReader::new(server) };
        client.write_all(&(MAX_FRAME_BYTES + 1).to_le_bytes()).unwrap();
        client.flush().unwrap();
        let err = rx.recv().unwrap_err();
        assert!(matches!(err, RingFault::Oversize { .. }), "{err}");
    }
}
