//! Fault taxonomy, fault policy, and the fault-injection harness for
//! the ring runtime.
//!
//! The paper's ring is a synchronous pipeline: one slow or dead
//! processor stalls every round forever. This module is the toolbox
//! the runtime uses to do better, in three parts:
//!
//! * **[`RingFault`]** — a typed error taxonomy for everything that
//!   can go wrong on a ring link (timeout, corrupt frame, peer gone,
//!   oversize frame, worker panic), replacing the ad-hoc `anyhow!`
//!   tears the transports used to produce. The worker loop matches on
//!   the variant to pick a policy: skip the round, retry the link, or
//!   heal the ring.
//! * **[`FaultPolicy`] + [`FaultStats`]** — the knobs (per-round recv
//!   deadline, mid-frame stall grace, bounded decode retries with
//!   exponential backoff, healing on/off) and the shared counters
//!   every fault event increments (exported as `ring.faults.*`).
//!   The default policy is *inert*: no deadline, healing passive —
//!   absent faults, frames and learned structures are byte/bit
//!   identical to a policy-less run.
//! * **[`FaultPlan`] + [`ChaosTransport`]** — a scripted
//!   fault-injection harness. The plan is parsed from a tiny grammar
//!   (`kill:w2@1,delay:w1@2:50ms,...`) and the chaos transport wraps
//!   any [`RingTransport`], applying the scripted actions at each
//!   worker's numbered *send hops* (hop h = the send that ends round
//!   h). Tests and the `learn --fault-plan` debug flag drive it; an
//!   empty plan is a pure pass-through.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::obs::log;

use super::transport::{RecvTiming, RingLink, RingMessage, RingRx, RingTransport, RingTx};

// ---------------------------------------------------------------------
// Typed fault taxonomy
// ---------------------------------------------------------------------

/// Everything that can go wrong on a ring link, typed so callers can
/// choose a policy per failure mode instead of tearing down on any
/// error string.
#[derive(Clone, Debug, PartialEq)]
pub enum RingFault {
    /// No frame arrived within the configured per-round deadline. The
    /// link is still synchronized (no partial frame was consumed);
    /// receiving later is safe. Policy: skip the round (straggler).
    Timeout {
        /// The deadline that expired.
        after: Duration,
    },
    /// A complete frame arrived but failed validation/decoding. The
    /// frame is consumed and the link remains framed (length prefixes
    /// still line up). Policy: bounded retry, then surface.
    Decode {
        /// What the codec rejected.
        detail: String,
    },
    /// The peer closed the link, reset the connection, or stalled
    /// mid-frame past the stall grace. Policy: treat the neighbor as
    /// gone (shutdown or heal).
    PeerGone {
        /// What the transport observed.
        detail: String,
    },
    /// A frame exceeded the wire cap — either an incoming length
    /// prefix above the cap (likely corruption) or an outgoing frame
    /// too large to ship.
    Oversize {
        /// Claimed/actual frame length in bytes.
        len: u64,
        /// The cap it exceeded.
        cap: u64,
    },
    /// A ring worker's body panicked; the panic was caught at the
    /// worker boundary instead of poisoning the coordinator.
    WorkerPanicked {
        /// Ring index of the panicked worker.
        worker: usize,
        /// The panic payload, stringified.
        detail: String,
    },
}

impl std::fmt::Display for RingFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RingFault::Timeout { after } => {
                write!(f, "ring recv deadline expired after {:.0}ms", after.as_secs_f64() * 1e3)
            }
            RingFault::Decode { detail } => write!(f, "corrupt ring frame: {detail}"),
            RingFault::PeerGone { detail } => write!(f, "ring peer gone: {detail}"),
            RingFault::Oversize { len, cap } => {
                write!(f, "ring frame of {len} bytes exceeds cap of {cap} bytes")
            }
            RingFault::WorkerPanicked { worker, detail } => {
                write!(f, "ring worker {worker} panicked: {detail}")
            }
        }
    }
}

impl std::error::Error for RingFault {}

/// Stringify a caught panic payload (the `Box<dyn Any>` from
/// `catch_unwind`/`join`) for a [`RingFault::WorkerPanicked`].
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

// ---------------------------------------------------------------------
// Fault policy + stats
// ---------------------------------------------------------------------

/// How a ring run reacts to faults. The default is inert: blocking
/// receives, generous stall grace, two decode retries, healing on —
/// none of which changes behavior in a fault-free run.
#[derive(Clone, Copy, Debug)]
pub struct FaultPolicy {
    /// Bounded per-round wait for the predecessor's model. `None`
    /// (default) blocks forever — the legacy synchronous behavior.
    /// `Some(d)` arms the straggler policy: after `d` the round is
    /// skipped and the worker steps on its own model.
    pub recv_timeout: Option<Duration>,
    /// Grace for a frame that started arriving but stalled mid-bytes.
    /// Past this the link is declared [`RingFault::PeerGone`] (a
    /// half-written frame can never be resynchronized).
    pub stall_timeout: Duration,
    /// Bounded retries after a [`RingFault::Decode`] before the fault
    /// is surfaced. Each retry waits for the *next* frame on the link
    /// (the corrupt one is consumed and unrecoverable).
    pub max_retries: u32,
    /// Base delay between decode retries; doubles per attempt.
    pub backoff: Duration,
    /// Catch worker panics and heal the ring (dead worker's thread
    /// becomes a pass-through relay, its edge subset is redistributed)
    /// instead of failing the run.
    pub heal: bool,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            recv_timeout: None,
            stall_timeout: Duration::from_secs(30),
            max_retries: 2,
            backoff: Duration::from_millis(1),
            heal: true,
        }
    }
}

/// Shared fault-event counters, incremented by the worker loops and
/// the transports; snapshotted into [`FaultSummary`] for telemetry and
/// exported as `ring.faults.*`.
#[derive(Debug, Default)]
pub struct FaultStats {
    /// Recv deadlines that expired (straggler detections).
    pub timeouts: AtomicU64,
    /// Rounds a worker stepped without its predecessor's fresh model.
    pub skips: AtomicU64,
    /// Decode retries consumed.
    pub retries: AtomicU64,
    /// Corrupt frames seen.
    pub decode: AtomicU64,
    /// Duplicated frames discarded.
    pub duplicates: AtomicU64,
    /// Links declared dead (close/reset/mid-frame stall).
    pub peer_gone: AtomicU64,
    /// Worker panics caught at the worker boundary.
    pub deaths: AtomicU64,
    /// Dead workers the coordinator healed around.
    pub healed: AtomicU64,
}

impl FaultStats {
    /// Plain-integer snapshot for telemetry.
    pub fn snapshot(&self) -> FaultSummary {
        FaultSummary {
            timeouts: self.timeouts.load(Ordering::Relaxed),
            skips: self.skips.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            decode: self.decode.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            peer_gone: self.peer_gone.load(Ordering::Relaxed),
            deaths: self.deaths.load(Ordering::Relaxed),
            healed: self.healed.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`FaultStats`], carried in telemetry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSummary {
    /// See [`FaultStats::timeouts`].
    pub timeouts: u64,
    /// See [`FaultStats::skips`].
    pub skips: u64,
    /// See [`FaultStats::retries`].
    pub retries: u64,
    /// See [`FaultStats::decode`].
    pub decode: u64,
    /// See [`FaultStats::duplicates`].
    pub duplicates: u64,
    /// See [`FaultStats::peer_gone`].
    pub peer_gone: u64,
    /// See [`FaultStats::deaths`].
    pub deaths: u64,
    /// See [`FaultStats::healed`].
    pub healed: u64,
}

impl FaultSummary {
    /// Did any fault event occur?
    pub fn any(&self) -> bool {
        *self != FaultSummary::default()
    }
}

/// Receive with the policy's deadline, retrying corrupt frames up to
/// `policy.max_retries` times with exponential backoff. Non-decode
/// faults pass straight through. Shared by the worker loop and the
/// fault tests.
pub fn recv_with_policy(
    rx: &mut dyn RingRx,
    policy: &FaultPolicy,
    stats: &FaultStats,
    who: usize,
) -> Result<(RingMessage, RecvTiming), RingFault> {
    let mut attempt = 0u32;
    loop {
        match rx.recv_deadline(policy.recv_timeout, policy.stall_timeout) {
            Err(RingFault::Decode { detail }) => {
                stats.decode.fetch_add(1, Ordering::Relaxed);
                if attempt >= policy.max_retries {
                    return Err(RingFault::Decode { detail });
                }
                attempt += 1;
                stats.retries.fetch_add(1, Ordering::Relaxed);
                log::warn(format_args!(
                    "ring worker {who}: corrupt frame from predecessor ({detail}); \
                     retrying ({attempt}/{})",
                    policy.max_retries
                ));
                let backoff = policy.backoff.saturating_mul(1u32 << (attempt - 1).min(16));
                if !backoff.is_zero() {
                    std::thread::sleep(backoff);
                }
            }
            other => return other,
        }
    }
}

// ---------------------------------------------------------------------
// Fault-injection harness
// ---------------------------------------------------------------------

/// One scripted fault action.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic the worker at the send site (caught and healed by the
    /// runtime when [`FaultPolicy::heal`] is on).
    Kill,
    /// Swallow the frame — the successor never sees this round.
    Drop,
    /// Sleep before sending — makes the worker a straggler.
    Delay(Duration),
    /// Flip a payload byte in flight (wire links; in-process links
    /// degrade to a drop, since a moved message has no bytes to flip).
    Corrupt,
    /// Send the frame twice — the successor must deduplicate.
    Duplicate,
}

/// A scripted fault at one worker's numbered send hop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Ring index of the worker whose send misbehaves.
    pub worker: usize,
    /// Which of that worker's model sends (0-based; hop h ends round
    /// h) the action fires on.
    pub hop: usize,
    /// What happens.
    pub action: FaultAction,
}

/// A parsed fault-injection script.
///
/// Grammar (comma-separated entries):
///
/// ```text
/// <action>:w<worker>@<hop>[:<param>]
/// action := kill | drop | delay | corrupt | dup
/// param  := duration for delay: "50ms", "2s", or bare millis
/// ```
///
/// Example: `kill:w2@1,delay:w1@2:50ms,corrupt:w3@0,dup:w3@0`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The scripted events.
    pub events: Vec<FaultEvent>,
}

fn parse_duration(text: &str) -> Result<Duration> {
    let t = text.trim();
    if let Some(ms) = t.strip_suffix("ms") {
        let v: u64 = ms.trim().parse().with_context(|| format!("bad millis '{t}'"))?;
        return Ok(Duration::from_millis(v));
    }
    if let Some(s) = t.strip_suffix('s') {
        let v: f64 = s.trim().parse().with_context(|| format!("bad seconds '{t}'"))?;
        if !(v.is_finite() && v >= 0.0) {
            bail!("bad seconds '{t}'");
        }
        return Ok(Duration::from_secs_f64(v));
    }
    let v: u64 = t.parse().with_context(|| format!("bad duration '{t}' (want e.g. 50ms)"))?;
    Ok(Duration::from_millis(v))
}

impl FaultPlan {
    /// Parse the `--fault-plan` grammar. An empty/blank spec is the
    /// empty plan (pure pass-through).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let mut parts = entry.splitn(3, ':');
            let action = parts.next().unwrap_or("").trim().to_ascii_lowercase();
            let site = parts
                .next()
                .with_context(|| format!("fault entry '{entry}' missing ':w<worker>@<hop>'"))?
                .trim();
            let param = parts.next();
            let rest = site
                .strip_prefix('w')
                .with_context(|| format!("fault site '{site}' must look like w<worker>@<hop>"))?;
            let (w, h) = rest
                .split_once('@')
                .with_context(|| format!("fault site '{site}' must look like w<worker>@<hop>"))?;
            let worker: usize =
                w.trim().parse().with_context(|| format!("bad worker index '{w}'"))?;
            let hop: usize = h.trim().parse().with_context(|| format!("bad hop index '{h}'"))?;
            let action = match action.as_str() {
                "kill" => FaultAction::Kill,
                "drop" => FaultAction::Drop,
                "delay" => FaultAction::Delay(parse_duration(
                    param.with_context(|| format!("delay entry '{entry}' needs a duration"))?,
                )?),
                "corrupt" => FaultAction::Corrupt,
                "dup" | "duplicate" => FaultAction::Duplicate,
                other => bail!("unknown fault action '{other}' (want kill|drop|delay|corrupt|dup)"),
            };
            events.push(FaultEvent { worker, hop, action });
        }
        Ok(FaultPlan { events })
    }

    /// True when no events are scripted.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The (hop, action) script for one worker's send side.
    fn for_worker(&self, worker: usize) -> Vec<(usize, FaultAction)> {
        self.events
            .iter()
            .filter(|e| e.worker == worker)
            .map(|e| (e.hop, e.action.clone()))
            .collect()
    }
}

/// Chaos wrapper over any [`RingTransport`]: connects the inner ring,
/// then interposes on each worker's send side to apply its scripted
/// [`FaultPlan`] actions. With an empty plan every send passes through
/// untouched (frames stay byte-identical).
pub struct ChaosTransport<'a> {
    inner: &'a dyn RingTransport,
    plan: FaultPlan,
}

impl<'a> ChaosTransport<'a> {
    /// Wrap `inner` with the scripted `plan`.
    pub fn new(inner: &'a dyn RingTransport, plan: FaultPlan) -> Self {
        ChaosTransport { inner, plan }
    }
}

impl RingTransport for ChaosTransport<'_> {
    fn connect(&self, k: usize) -> Result<Vec<RingLink>> {
        let links = self.inner.connect(k)?;
        Ok(links
            .into_iter()
            .enumerate()
            .map(|(i, link)| RingLink {
                tx: Box::new(ChaosTx {
                    inner: link.tx,
                    worker: i,
                    hop: 0,
                    script: self.plan.for_worker(i),
                }),
                rx: link.rx,
            })
            .collect())
    }
}

struct ChaosTx {
    inner: Box<dyn RingTx>,
    worker: usize,
    /// Model sends completed so far (hop counter; `Stop` doesn't count).
    hop: usize,
    script: Vec<(usize, FaultAction)>,
}

impl RingTx for ChaosTx {
    fn send(&mut self, msg: RingMessage) -> Result<f64, RingFault> {
        if matches!(msg, RingMessage::Stop) {
            return self.inner.send(msg);
        }
        let hop = self.hop;
        self.hop += 1;
        let actions: Vec<FaultAction> =
            self.script.iter().filter(|(h, _)| *h == hop).map(|(_, a)| a.clone()).collect();
        if actions.iter().any(|a| *a == FaultAction::Kill) {
            panic!("fault-plan kill: worker {} at hop {hop}", self.worker);
        }
        for a in &actions {
            if let FaultAction::Delay(d) = a {
                std::thread::sleep(*d);
            }
        }
        if actions.iter().any(|a| *a == FaultAction::Drop) {
            return Ok(0.0);
        }
        let duplicate = actions.iter().any(|a| *a == FaultAction::Duplicate);
        if duplicate {
            self.inner.send(msg.clone())?;
        }
        if actions.iter().any(|a| *a == FaultAction::Corrupt) {
            return self.inner.send_corrupt(msg);
        }
        self.inner.send(msg)
    }

    fn send_corrupt(&mut self, msg: RingMessage) -> Result<f64, RingFault> {
        self.inner.send_corrupt(msg)
    }

    fn answer_clock_sync(&mut self, now_ns: &mut dyn FnMut() -> u64) -> Result<()> {
        self.inner.answer_clock_sync(now_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::ChannelTransport;
    use crate::graph::Dag;

    fn tiny_model(round: usize) -> RingMessage {
        RingMessage::Model(super::super::transport::ModelMsg {
            from: 0,
            round,
            score: -1.0,
            dag: Dag::new(2),
            token: Default::default(),
            bundle: None,
            obs: Vec::new(),
        })
    }

    #[test]
    fn fault_plan_grammar_round_trips() {
        let plan = FaultPlan::parse("kill:w2@1, delay:w1@2:50ms, drop:w0@0, corrupt:w3@2, dup:w3@2")
            .unwrap();
        assert_eq!(plan.events.len(), 5);
        assert_eq!(
            plan.events[0],
            FaultEvent { worker: 2, hop: 1, action: FaultAction::Kill }
        );
        assert_eq!(
            plan.events[1],
            FaultEvent { worker: 1, hop: 2, action: FaultAction::Delay(Duration::from_millis(50)) }
        );
        assert_eq!(plan.events[2].action, FaultAction::Drop);
        assert_eq!(plan.events[3].action, FaultAction::Corrupt);
        assert_eq!(plan.events[4].action, FaultAction::Duplicate);
        // Alternate duration spellings.
        let plan = FaultPlan::parse("delay:w0@0:2s").unwrap();
        assert_eq!(plan.events[0].action, FaultAction::Delay(Duration::from_secs(2)));
        let plan = FaultPlan::parse("delay:w0@0:75").unwrap();
        assert_eq!(plan.events[0].action, FaultAction::Delay(Duration::from_millis(75)));
        // Empty and blank specs are the empty plan.
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  , ").unwrap().is_empty());
        // Garbage is rejected with the offending fragment named.
        for bad in ["boom:w0@0", "kill", "kill:x0@0", "kill:w0", "delay:w0@0", "kill:w@0"] {
            assert!(FaultPlan::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn chaos_drop_and_duplicate_shape_the_stream() {
        // k=1 self-loop: the worker's tx feeds its own rx.
        let plan = FaultPlan::parse("drop:w0@0,dup:w0@1").unwrap();
        let chaos = ChaosTransport::new(&ChannelTransport, plan);
        let mut links = chaos.connect(1).unwrap();
        let RingLink { mut tx, mut rx } = links.pop().unwrap();
        tx.send(tiny_model(0)).unwrap(); // dropped
        tx.send(tiny_model(1)).unwrap(); // duplicated
        tx.send(RingMessage::Stop).unwrap();
        let (m1, _) = rx.recv().unwrap();
        let (m2, _) = rx.recv().unwrap();
        let (m3, _) = rx.recv().unwrap();
        match (&m1, &m2) {
            (RingMessage::Model(a), RingMessage::Model(b)) => {
                assert_eq!(a.round, 1);
                assert_eq!(b.round, 1);
            }
            _ => panic!("expected the duplicated round-1 model twice"),
        }
        assert!(matches!(m3, RingMessage::Stop));
    }

    #[test]
    fn chaos_kill_panics_at_the_scripted_hop() {
        let plan = FaultPlan::parse("kill:w0@1").unwrap();
        let chaos = ChaosTransport::new(&ChannelTransport, plan);
        let mut links = chaos.connect(1).unwrap();
        let RingLink { mut tx, mut rx } = links.pop().unwrap();
        tx.send(tiny_model(0)).unwrap(); // hop 0: clean
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = tx.send(tiny_model(1)); // hop 1: kill
        }));
        let payload = caught.expect_err("scripted kill must panic");
        let msg = panic_message(payload.as_ref());
        assert!(msg.contains("kill") && msg.contains("hop 1"), "{msg}");
        let (m, _) = rx.recv().unwrap();
        assert!(matches!(m, RingMessage::Model(_)));
    }

    #[test]
    fn empty_plan_is_pass_through() {
        let chaos = ChaosTransport::new(&ChannelTransport, FaultPlan::default());
        let mut links = chaos.connect(1).unwrap();
        let RingLink { mut tx, mut rx } = links.pop().unwrap();
        tx.send(tiny_model(0)).unwrap();
        tx.send(RingMessage::Stop).unwrap();
        assert!(matches!(rx.recv().unwrap().0, RingMessage::Model(_)));
        assert!(matches!(rx.recv().unwrap().0, RingMessage::Stop));
    }

    #[test]
    fn fault_summary_any_and_snapshot() {
        let stats = FaultStats::default();
        assert!(!stats.snapshot().any());
        stats.skips.fetch_add(2, Ordering::Relaxed);
        stats.healed.fetch_add(1, Ordering::Relaxed);
        let s = stats.snapshot();
        assert!(s.any());
        assert_eq!(s.skips, 2);
        assert_eq!(s.healed, 1);
    }

    #[test]
    fn panic_message_extracts_strs_and_strings() {
        let p: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_message(p.as_ref()), "boom");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("dynamic boom"));
        assert_eq!(panic_message(p.as_ref()), "dynamic boom");
        let p: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(p.as_ref()), "worker panicked");
    }
}
