//! Run telemetry: per-hop, per-worker records of the ring — the data
//! behind the paper's Table 2c and our convergence-trace "figure".
//!
//! With the message-passing runtime each worker produces one
//! [`RoundRecord`] per hop, now including the time it spent *blocked*
//! on its predecessor (`wait_secs`) and in the wire codec
//! (`codec_secs`) — the numbers that distinguish a compute-bound ring
//! from a communication-bound one. [`Telemetry::timelines`] regroups
//! the flat record stream into one [`WorkerTimeline`] per worker, the
//! actor-centric view of the same data.

use std::io::Write;
use std::path::Path;

use crate::coordinator::fault::FaultSummary;
use crate::obs;
use crate::obs::trace::{spans_to_chrome_json, SpanRec};

/// One worker's activity in one ring hop (= one round of its loop).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub worker: usize,
    /// Seconds fusing the predecessor's model into the search state.
    pub fusion_secs: f64,
    /// Seconds in the constrained GES step.
    pub ges_secs: f64,
    /// Seconds blocked waiting on the predecessor's message
    /// (0 in deterministic mode, where a barrier replaces the wait).
    pub wait_secs: f64,
    /// Seconds serializing/deserializing models (wire transport only).
    pub codec_secs: f64,
    pub score: f64,
    pub edges: usize,
    pub inserts: usize,
    pub deletes: usize,
}

/// One worker's whole run, hop by hop, with per-activity totals.
#[derive(Debug, Clone)]
pub struct WorkerTimeline {
    pub worker: usize,
    /// This worker's records in round order.
    pub hops: Vec<RoundRecord>,
    pub fusion_secs: f64,
    pub ges_secs: f64,
    pub wait_secs: f64,
    pub codec_secs: f64,
}

/// Full run telemetry.
#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    pub records: Vec<RoundRecord>,
    /// (hits, computed) of the shared score cache at the end.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Stage wall-times.
    pub partition_secs: f64,
    pub learning_secs: f64,
    pub fine_tune_secs: f64,
    /// Partition source ("xla:<config>" or "rust-fallback").
    pub partition_source: String,
    /// Ring execution mode ("deterministic", "channel", "tcp").
    pub transport: String,
    /// Rounds the learning stage counted toward convergence; records
    /// with `round >= converged_rounds` are speculative pipeline work
    /// past the stop round (also emitted in the TSV `#summary` line so
    /// trace readers can split counted from speculative hops).
    pub converged_rounds: usize,
    /// Counting-core path counters (from the scorer's `Counter`):
    /// families counted via popcount planes / row-block tiling /
    /// scalar dense / hashed sparse, plus histograms derived by
    /// marginalizing a cached superset table and the contingency-table
    /// cache hit/miss split.
    pub count_popcount: u64,
    pub count_blocked: u64,
    pub count_dense: u64,
    pub count_sparse: u64,
    pub count_derived: u64,
    pub table_hits: u64,
    pub table_misses: u64,
    /// Stage-3 (fine-tune) GES operator evaluations, forward and
    /// backward (0 when fine tuning is off).
    pub fes_evaluations: u64,
    pub bes_evaluations: u64,
    /// Fault events over the learning stage (all zero in a clean run):
    /// straggler skips, frame retries, healed worker deaths.
    pub faults: FaultSummary,
}

impl Telemetry {
    /// Best score observed per round (the convergence trace).
    pub fn round_best_scores(&self) -> Vec<(usize, f64)> {
        let mut out: Vec<(usize, f64)> = Vec::new();
        for r in &self.records {
            match out.iter_mut().find(|(round, _)| *round == r.round) {
                Some((_, best)) => {
                    if r.score > *best {
                        *best = r.score;
                    }
                }
                None => out.push((r.round, r.score)),
            }
        }
        out.sort_by_key(|&(round, _)| round);
        out
    }

    /// Per-worker timelines: each worker's hops in round order plus
    /// fusion/learn/wait/codec totals.
    pub fn timelines(&self) -> Vec<WorkerTimeline> {
        let n_workers = self.records.iter().map(|r| r.worker + 1).max().unwrap_or(0);
        let mut out: Vec<WorkerTimeline> = (0..n_workers)
            .map(|worker| WorkerTimeline {
                worker,
                hops: Vec::new(),
                fusion_secs: 0.0,
                ges_secs: 0.0,
                wait_secs: 0.0,
                codec_secs: 0.0,
            })
            .collect();
        for r in &self.records {
            let t = &mut out[r.worker];
            t.fusion_secs += r.fusion_secs;
            t.ges_secs += r.ges_secs;
            t.wait_secs += r.wait_secs;
            t.codec_secs += r.codec_secs;
            t.hops.push(r.clone());
        }
        for t in &mut out {
            t.hops.sort_by_key(|h| h.round);
        }
        out
    }

    /// Export the run's metrics into a registry: per-hop activity
    /// histograms (`ring.*_ns`), stage wall-time gauges, round/record
    /// counters and the fine-tune evaluation counts. Cache and
    /// counting-path counters are *not* exported here — they reach a
    /// registry live, through `bind_obs` on the scorer — so calling
    /// this never double-counts them.
    pub fn export_metrics(&self, reg: &obs::Registry) {
        let wait = reg.hist("ring.wait_ns");
        let fuse = reg.hist("ring.fusion_ns");
        let ges = reg.hist("ring.ges_ns");
        let codec = reg.hist("ring.codec_ns");
        for r in &self.records {
            wait.record_secs(r.wait_secs);
            fuse.record_secs(r.fusion_secs);
            ges.record_secs(r.ges_secs);
            codec.record_secs(r.codec_secs);
        }
        reg.counter("ring.hops").add(self.records.len() as u64);
        reg.counter("ring.converged_rounds").add(self.converged_rounds as u64);
        reg.gauge("ring.partition_secs").set(self.partition_secs);
        reg.gauge("ring.learning_secs").set(self.learning_secs);
        reg.gauge("ring.fine_tune_secs").set(self.fine_tune_secs);
        reg.counter("ges.fes_evaluations").add(self.fes_evaluations);
        reg.counter("ges.bes_evaluations").add(self.bes_evaluations);
        // Fault taxonomy: always exported (zeros included), so a clean
        // run's series pin "no faults" rather than being absent.
        reg.counter("ring.faults.timeouts").add(self.faults.timeouts);
        reg.counter("ring.faults.skips").add(self.faults.skips);
        reg.counter("ring.faults.retries").add(self.faults.retries);
        reg.counter("ring.faults.decode").add(self.faults.decode);
        reg.counter("ring.faults.duplicates").add(self.faults.duplicates);
        reg.counter("ring.faults.peer_gone").add(self.faults.peer_gone);
        reg.counter("ring.faults.deaths").add(self.faults.deaths);
        reg.counter("ring.faults.healed").add(self.faults.healed);
    }

    /// The run as trace spans: one lane per worker, each hop rendered
    /// as its wait → fuse → ges → codec activity in sequence. Spans are
    /// placed on a per-lane relative clock (each lane starts at 0), so
    /// lanes show each worker's own activity profile rather than
    /// cross-worker alignment — for wall-clock-aligned spans, run with
    /// a live [`obs::Tracer`] instead.
    pub fn to_spans(&self) -> Vec<SpanRec> {
        let mut spans = Vec::new();
        for t in self.timelines() {
            let mut cursor = 0u64;
            for h in &t.hops {
                for (name, secs) in [
                    ("wait", h.wait_secs),
                    ("fuse", h.fusion_secs),
                    ("ges", h.ges_secs),
                    ("codec", h.codec_secs),
                ] {
                    let dur = obs::secs_to_ns(secs);
                    if dur == 0 {
                        continue;
                    }
                    let mut args = vec![("round", h.round as f64)];
                    if name == "ges" {
                        args.push(("score", h.score));
                        args.push(("inserts", h.inserts as f64));
                        args.push(("deletes", h.deletes as f64));
                    }
                    spans.push(SpanRec {
                        name: name.to_string(),
                        cat: "ring",
                        tid: t.worker as u32,
                        start_ns: cursor,
                        dur_ns: dur,
                        args,
                    });
                    cursor += dur;
                }
            }
        }
        spans
    }

    /// Sibling of [`Telemetry::write_tsv`]: the same records as Chrome
    /// trace-event JSON (Perfetto-loadable), via [`Telemetry::to_spans`].
    pub fn write_trace(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, spans_to_chrome_json(&self.to_spans()))
    }

    /// Dump as TSV (one row per record plus `#worker` timeline
    /// summaries and a `#summary` trailer).
    pub fn write_tsv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(
            f,
            "round\tworker\tfusion_secs\tges_secs\twait_secs\tcodec_secs\tscore\tedges\tinserts\tdeletes"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{}\t{}\t{:.4}\t{:.4}\t{:.4}\t{:.4}\t{:.4}\t{}\t{}\t{}",
                r.round,
                r.worker,
                r.fusion_secs,
                r.ges_secs,
                r.wait_secs,
                r.codec_secs,
                r.score,
                r.edges,
                r.inserts,
                r.deletes
            )?;
        }
        for t in self.timelines() {
            writeln!(
                f,
                "#worker {}\thops={}\tfusion={:.3}s\tges={:.3}s\twait={:.3}s\tcodec={:.3}s",
                t.worker,
                t.hops.len(),
                t.fusion_secs,
                t.ges_secs,
                t.wait_secs,
                t.codec_secs
            )?;
        }
        writeln!(
            f,
            "#summary\ttransport={}\tcounted_rounds={}\tpartition={:.3}s ({})\tlearning={:.3}s\tfine_tune={:.3}s\tcache_hits={}\tcache_misses={}\tcounts=popcount:{}/blocked:{}/dense:{}/sparse:{}/derived:{}\ttables={}h/{}m\tevals=fes:{}/bes:{}\tfaults=skips:{}/retries:{}/deaths:{}/healed:{}",
            if self.transport.is_empty() { "-" } else { &self.transport },
            self.converged_rounds,
            self.partition_secs,
            self.partition_source,
            self.learning_secs,
            self.fine_tune_secs,
            self.cache_hits,
            self.cache_misses,
            self.count_popcount,
            self.count_blocked,
            self.count_dense,
            self.count_sparse,
            self.count_derived,
            self.table_hits,
            self.table_misses,
            self.fes_evaluations,
            self.bes_evaluations,
            self.faults.skips,
            self.faults.retries,
            self.faults.deaths,
            self.faults.healed
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, worker: usize, score: f64) -> RoundRecord {
        RoundRecord {
            round,
            worker,
            fusion_secs: 0.01,
            ges_secs: 0.1,
            wait_secs: 0.02,
            codec_secs: 0.001,
            score,
            edges: round + 1,
            inserts: 1,
            deletes: 0,
        }
    }

    #[test]
    fn round_best_scores_tracks_max() {
        let t = Telemetry {
            records: vec![rec(0, 0, -10.0), rec(0, 1, -8.0), rec(1, 0, -7.0)],
            ..Default::default()
        };
        assert_eq!(t.round_best_scores(), vec![(0, -8.0), (1, -7.0)]);
    }

    #[test]
    fn timelines_group_and_total() {
        let t = Telemetry {
            // Deliberately out of round order for worker 1.
            records: vec![rec(0, 0, -10.0), rec(1, 1, -6.0), rec(0, 1, -8.0), rec(1, 0, -7.0)],
            ..Default::default()
        };
        let tl = t.timelines();
        assert_eq!(tl.len(), 2);
        for w in &tl {
            assert_eq!(w.hops.len(), 2);
            assert_eq!(w.hops[0].round, 0);
            assert_eq!(w.hops[1].round, 1);
            assert!((w.fusion_secs - 0.02).abs() < 1e-12);
            assert!((w.wait_secs - 0.04).abs() < 1e-12);
            assert!((w.codec_secs - 0.002).abs() < 1e-12);
        }
    }

    #[test]
    fn tsv_has_records_timelines_and_summary() {
        let t = Telemetry {
            records: vec![rec(0, 0, -1.0), rec(0, 1, -2.0)],
            partition_source: "rust-fallback".into(),
            transport: "channel".into(),
            ..Default::default()
        };
        let tmp = std::env::temp_dir().join("cges_telemetry.tsv");
        t.write_tsv(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        assert!(text.starts_with("round\t"));
        assert!(text.contains("wait_secs"));
        assert!(text.contains("#worker 0"));
        assert!(text.contains("#worker 1"));
        assert!(text.contains("#summary"));
        assert!(text.contains("transport=channel"));
        assert!(text.contains("counts=popcount:"));
        assert!(text.contains("evals=fes:"));
        assert!(text.contains("faults=skips:0/retries:0/deaths:0/healed:0"));
        // header + 2 records + 2 worker lines + summary
        assert_eq!(text.lines().count(), 6);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn write_trace_emits_parseable_chrome_events() {
        use crate::infer::json::Json;
        let t = Telemetry {
            records: vec![rec(0, 0, -1.0), rec(1, 0, -0.5), rec(0, 1, -2.0)],
            ..Default::default()
        };
        let spans = t.to_spans();
        // wait/fuse/ges/codec per hop, all non-zero in `rec`
        assert_eq!(spans.len(), 3 * 4);
        let tmp = std::env::temp_dir().join("cges_telemetry.trace.json");
        t.write_trace(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        let doc = Json::parse(&text).expect("trace must be valid JSON");
        let events = doc.as_array().expect("event array");
        // one B and one E per span
        assert_eq!(events.len(), 2 * spans.len());
        assert!(events.iter().any(|e| e.get("name").and_then(Json::as_str) == Some("ges")));
        // both workers get a lane
        for tid in [0.0, 1.0] {
            assert!(events.iter().any(|e| e.get("tid").and_then(Json::as_f64) == Some(tid)));
        }
    }

    #[test]
    fn export_metrics_fills_registry() {
        let t = Telemetry {
            records: vec![rec(0, 0, -1.0), rec(0, 1, -2.0)],
            converged_rounds: 1,
            partition_secs: 0.5,
            fes_evaluations: 12,
            bes_evaluations: 3,
            faults: FaultSummary { skips: 2, healed: 1, ..Default::default() },
            ..Default::default()
        };
        let reg = crate::obs::Registry::new();
        t.export_metrics(&reg);
        assert_eq!(reg.counter_value("ring.hops"), Some(2));
        assert_eq!(reg.counter_value("ring.faults.skips"), Some(2));
        assert_eq!(reg.counter_value("ring.faults.healed"), Some(1));
        assert_eq!(reg.counter_value("ring.faults.deaths"), Some(0));
        assert_eq!(reg.counter_value("ring.converged_rounds"), Some(1));
        assert_eq!(reg.counter_value("ges.fes_evaluations"), Some(12));
        assert_eq!(reg.counter_value("ges.bes_evaluations"), Some(3));
        assert_eq!(reg.gauge("ring.partition_secs").get(), 0.5);
        // each record contributed one sample per activity histogram
        assert_eq!(reg.hist("ring.ges_ns").inner().count(), 2);
        assert_eq!(reg.hist("ring.wait_ns").inner().count(), 2);
        // 0.1s ges in `rec` → 1e8 ns, bracketed by the p50 bounds
        let (lo, hi) = reg.hist("ring.ges_ns").inner().quantile_bounds(0.5);
        assert!(lo <= 100_000_000 && 100_000_000 <= hi);
    }
}
