//! Run telemetry: per-round, per-worker records of the ring — the data
//! behind the paper's Table 2c and our convergence-trace "figure".

use std::io::Write;
use std::path::Path;

/// One worker's activity in one ring round.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub worker: usize,
    pub fusion_secs: f64,
    pub ges_secs: f64,
    pub score: f64,
    pub edges: usize,
    pub inserts: usize,
    pub deletes: usize,
}

/// Full run telemetry.
#[derive(Debug, Default, Clone)]
pub struct Telemetry {
    pub records: Vec<RoundRecord>,
    /// (hits, computed) of the shared score cache at the end.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Stage wall-times.
    pub partition_secs: f64,
    pub learning_secs: f64,
    pub fine_tune_secs: f64,
    /// Partition source ("xla:<config>" or "rust-fallback").
    pub partition_source: String,
}

impl Telemetry {
    /// Best score observed per round (the convergence trace).
    pub fn round_best_scores(&self) -> Vec<(usize, f64)> {
        let mut out: Vec<(usize, f64)> = Vec::new();
        for r in &self.records {
            match out.iter_mut().find(|(round, _)| *round == r.round) {
                Some((_, best)) => {
                    if r.score > *best {
                        *best = r.score;
                    }
                }
                None => out.push((r.round, r.score)),
            }
        }
        out.sort_by_key(|&(round, _)| round);
        out
    }

    /// Dump as TSV (one row per record plus a `#summary` trailer).
    pub fn write_tsv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "round\tworker\tfusion_secs\tges_secs\tscore\tedges\tinserts\tdeletes")?;
        for r in &self.records {
            writeln!(
                f,
                "{}\t{}\t{:.4}\t{:.4}\t{:.4}\t{}\t{}\t{}",
                r.round, r.worker, r.fusion_secs, r.ges_secs, r.score, r.edges, r.inserts, r.deletes
            )?;
        }
        writeln!(
            f,
            "#summary\tpartition={:.3}s ({})\tlearning={:.3}s\tfine_tune={:.3}s\tcache_hits={}\tcache_misses={}",
            self.partition_secs,
            self.partition_source,
            self.learning_secs,
            self.fine_tune_secs,
            self.cache_hits,
            self.cache_misses
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_best_scores_tracks_max() {
        let t = Telemetry {
            records: vec![
                RoundRecord { round: 0, worker: 0, fusion_secs: 0.0, ges_secs: 0.1, score: -10.0, edges: 1, inserts: 1, deletes: 0 },
                RoundRecord { round: 0, worker: 1, fusion_secs: 0.0, ges_secs: 0.1, score: -8.0, edges: 2, inserts: 2, deletes: 0 },
                RoundRecord { round: 1, worker: 0, fusion_secs: 0.1, ges_secs: 0.1, score: -7.0, edges: 3, inserts: 1, deletes: 0 },
            ],
            ..Default::default()
        };
        assert_eq!(t.round_best_scores(), vec![(0, -8.0), (1, -7.0)]);
    }

    #[test]
    fn tsv_roundtrip_lines() {
        let t = Telemetry {
            records: vec![RoundRecord { round: 0, worker: 0, fusion_secs: 0.0, ges_secs: 0.5, score: -1.0, edges: 4, inserts: 4, deletes: 1 }],
            partition_source: "rust-fallback".into(),
            ..Default::default()
        };
        let tmp = std::env::temp_dir().join("cges_telemetry.tsv");
        t.write_tsv(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        assert!(text.starts_with("round\t"));
        assert!(text.contains("#summary"));
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_file(&tmp).ok();
    }
}
