//! Minimal CLI argument parser (no `clap` in the offline registry).
//!
//! Supports `--flag`, `--key value`, and positional arguments, with
//! typed getters and an unknown-option check. Each `main.rs` subcommand
//! declares its options against this.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed argument bag.
pub struct Args {
    positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse an argv slice. `flag_names` lists boolean flags (which
    /// consume no value); everything else starting with `--` takes one.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if flag_names.contains(&name) {
                    flags.push(name.to_string());
                } else {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("option --{name} expects a value"))?;
                    options.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, options, flags })
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// Number of positional arguments.
    pub fn n_pos(&self) -> usize {
        self.positional.len()
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Required string option.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    /// Typed option with default. An empty value (`--threads ""`) is
    /// reported as such, naming the flag — `"".parse::<String>()`
    /// would otherwise succeed silently and numeric types would emit
    /// the unhelpful `cannot parse ''`.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some("") => Err(anyhow!("option --{key} has an empty value")),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: cannot parse '{v}'")),
        }
    }

    /// Boolean flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Error on unknown options (call after reading all known keys).
    pub fn check_known(&self, known_opts: &[&str], known_flags: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known_opts.contains(&k.as_str()) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&argv(&["learn", "--k", "4", "--full", "data.csv"]), &["full"]).unwrap();
        assert_eq!(a.pos(0), Some("learn"));
        assert_eq!(a.pos(1), Some("data.csv"));
        assert_eq!(a.get_parse::<usize>("k", 2).unwrap(), 4);
        assert!(a.flag("full"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["--k"]), &[]).is_err());
    }

    #[test]
    fn defaults_and_require() {
        let a = Args::parse(&argv(&[]), &[]).unwrap();
        assert_eq!(a.get_parse::<f64>("ess", 10.0).unwrap(), 10.0);
        assert!(a.require("data").is_err());
    }

    #[test]
    fn empty_option_value_names_the_flag() {
        let a = Args::parse(&argv(&["--threads", ""]), &[]).unwrap();
        let e = a.get_parse::<usize>("threads", 4).unwrap_err();
        assert_eq!(format!("{e}"), "option --threads has an empty value");
        // Same wording for types where "" would otherwise parse.
        let e = a.get_parse::<String>("threads", "x".into()).unwrap_err();
        assert_eq!(format!("{e}"), "option --threads has an empty value");
        // Absent keys still fall back to the default.
        assert_eq!(a.get_parse::<usize>("batch", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_detection() {
        let a = Args::parse(&argv(&["--bogus", "1"]), &[]).unwrap();
        assert!(a.check_known(&["k"], &[]).is_err());
        let b = Args::parse(&argv(&["--k", "1"]), &[]).unwrap();
        assert!(b.check_known(&["k"], &[]).is_ok());
    }
}
