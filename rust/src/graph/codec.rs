//! Binary wire codec for graph structures.
//!
//! The ring runtime's [`WireTransport`](crate::coordinator::transport)
//! moves learned models between processors as bytes, so the [`Dag`]
//! needs a stable serialized form. The format is deliberately dumb —
//! little-endian, fixed-width, self-validating — because the payloads
//! are small (a learned BN has O(n) edges) and the codec must be easy
//! to reimplement in another language for cross-machine rings:
//!
//! ```text
//! u8   version            (currently 1)
//! u32  n                  node count
//! u32  e                  edge count
//! e ×  (u32 u32)          directed edges (parent, child)
//! ```
//!
//! [`decode_dag`] validates everything it reads: version, node bounds,
//! self-loops, duplicate edges, the DAG edge-count bound n·(n−1)/2 and
//! — because downstream fusion/learning assume acyclicity — that the
//! decoded graph is in fact acyclic. A corrupt or adversarial frame
//! yields an error, never a poisoned search state.

use anyhow::{bail, Result};

use crate::graph::Dag;

/// Current wire-format version byte.
pub const DAG_CODEC_VERSION: u8 = 1;

/// Append a `u32` in little-endian order.
#[inline]
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u64` in little-endian order.
#[inline]
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append an `f64` as its little-endian IEEE-754 bits.
#[inline]
pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Read one byte, advancing the cursor.
#[inline]
pub fn take_u8(input: &mut &[u8]) -> Result<u8> {
    let Some((&b, rest)) = input.split_first() else {
        bail!("truncated frame: expected u8");
    };
    *input = rest;
    Ok(b)
}

/// Read a little-endian `u32`, advancing the cursor.
#[inline]
pub fn take_u32(input: &mut &[u8]) -> Result<u32> {
    if input.len() < 4 {
        bail!("truncated frame: expected u32, {} bytes left", input.len());
    }
    let (head, rest) = input.split_at(4);
    *input = rest;
    Ok(u32::from_le_bytes(head.try_into().expect("4-byte slice")))
}

/// Read a little-endian `u64`, advancing the cursor.
#[inline]
pub fn take_u64(input: &mut &[u8]) -> Result<u64> {
    if input.len() < 8 {
        bail!("truncated frame: expected u64, {} bytes left", input.len());
    }
    let (head, rest) = input.split_at(8);
    *input = rest;
    Ok(u64::from_le_bytes(head.try_into().expect("8-byte slice")))
}

/// Append a length-prefixed UTF-8 string (`u32` length + bytes).
#[inline]
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Read a string written by [`put_str`], advancing the cursor. The
/// declared length is checked against the remaining bytes before any
/// allocation, so a corrupt length can't balloon memory.
pub fn take_str(input: &mut &[u8]) -> Result<String> {
    let len = take_u32(input)? as usize;
    if input.len() < len {
        bail!("truncated frame: string of {len} bytes, {} left", input.len());
    }
    let (head, rest) = input.split_at(len);
    *input = rest;
    match std::str::from_utf8(head) {
        Ok(s) => Ok(s.to_string()),
        Err(_) => bail!("invalid utf-8 in wire string"),
    }
}

/// Read a little-endian `f64`, advancing the cursor.
#[inline]
pub fn take_f64(input: &mut &[u8]) -> Result<f64> {
    if input.len() < 8 {
        bail!("truncated frame: expected f64, {} bytes left", input.len());
    }
    let (head, rest) = input.split_at(8);
    *input = rest;
    Ok(f64::from_le_bytes(head.try_into().expect("8-byte slice")))
}

/// Append the wire encoding of a DAG to `buf`.
pub fn encode_dag(dag: &Dag, buf: &mut Vec<u8>) {
    buf.push(DAG_CODEC_VERSION);
    put_u32(buf, dag.n() as u32);
    let edges = dag.edges();
    put_u32(buf, edges.len() as u32);
    for (u, v) in edges {
        put_u32(buf, u as u32);
        put_u32(buf, v as u32);
    }
}

/// Wire encoding of a DAG as an owned buffer.
pub fn dag_to_bytes(dag: &Dag) -> Vec<u8> {
    let mut buf = Vec::with_capacity(9 + 8 * dag.edge_count());
    encode_dag(dag, &mut buf);
    buf
}

/// Decode a DAG from the front of `input`, advancing the cursor past
/// it (frames can therefore be concatenated). Fully validating.
pub fn decode_dag(input: &mut &[u8]) -> Result<Dag> {
    let version = take_u8(input)?;
    if version != DAG_CODEC_VERSION {
        bail!("unsupported dag codec version {version} (expected {DAG_CODEC_VERSION})");
    }
    let n = take_u32(input)? as usize;
    let e = take_u32(input)? as usize;
    let max_edges = n.saturating_mul(n.saturating_sub(1)) / 2;
    if e > max_edges {
        bail!("edge count {e} exceeds DAG bound {max_edges} for n={n}");
    }
    let mut dag = Dag::new(n);
    for i in 0..e {
        let u = take_u32(input)? as usize;
        let v = take_u32(input)? as usize;
        if u >= n || v >= n {
            bail!("edge {i}: node ({u}, {v}) out of range for n={n}");
        }
        if u == v {
            bail!("edge {i}: self-loop on node {u}");
        }
        if dag.has_edge(u, v) {
            bail!("edge {i}: duplicate edge {u} -> {v}");
        }
        dag.add_edge(u, v);
    }
    if !dag.is_acyclic() {
        bail!("decoded graph contains a directed cycle");
    }
    Ok(dag)
}

/// Decode a DAG from an exact buffer (trailing bytes are an error).
pub fn dag_from_bytes(bytes: &[u8]) -> Result<Dag> {
    let mut cursor = bytes;
    let dag = decode_dag(&mut cursor)?;
    if !cursor.is_empty() {
        bail!("{} trailing bytes after dag frame", cursor.len());
    }
    Ok(dag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_edges() {
        let dag = Dag::from_edges(6, &[(0, 1), (1, 2), (0, 3), (3, 4), (2, 5)]);
        let bytes = dag_to_bytes(&dag);
        let back = dag_from_bytes(&bytes).unwrap();
        assert_eq!(back.n(), 6);
        assert_eq!(back.edges(), dag.edges());
    }

    #[test]
    fn empty_graph_roundtrips() {
        let dag = Dag::new(0);
        let back = dag_from_bytes(&dag_to_bytes(&dag)).unwrap();
        assert_eq!(back.n(), 0);
        assert_eq!(back.edge_count(), 0);
    }

    #[test]
    fn frames_concatenate() {
        let a = Dag::from_edges(3, &[(0, 1)]);
        let b = Dag::from_edges(4, &[(1, 2), (2, 3)]);
        let mut buf = Vec::new();
        encode_dag(&a, &mut buf);
        encode_dag(&b, &mut buf);
        let mut cursor = buf.as_slice();
        let a2 = decode_dag(&mut cursor).unwrap();
        let b2 = decode_dag(&mut cursor).unwrap();
        assert!(cursor.is_empty());
        assert_eq!(a2.edges(), a.edges());
        assert_eq!(b2.edges(), b.edges());
    }

    #[test]
    fn rejects_corrupt_frames() {
        let dag = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let bytes = dag_to_bytes(&dag);

        // Truncation.
        assert!(dag_from_bytes(&bytes[..bytes.len() - 2]).is_err());
        // Bad version.
        let mut bad = bytes.clone();
        bad[0] = 99;
        assert!(dag_from_bytes(&bad).is_err());
        // Out-of-range node id.
        let mut oob = bytes.clone();
        let last_edge = bytes.len() - 8;
        oob[last_edge..last_edge + 4].copy_from_slice(&7u32.to_le_bytes());
        assert!(dag_from_bytes(&oob).is_err());
        // Trailing garbage.
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(dag_from_bytes(&trailing).is_err());
    }

    #[test]
    fn rejects_cycles_and_duplicates() {
        // Hand-build a frame with a 2-cycle 0 -> 1 -> 0.
        let mut buf = vec![DAG_CODEC_VERSION];
        put_u32(&mut buf, 3); // n
        put_u32(&mut buf, 2); // e
        put_u32(&mut buf, 0);
        put_u32(&mut buf, 1);
        put_u32(&mut buf, 1);
        put_u32(&mut buf, 0);
        assert!(dag_from_bytes(&buf).is_err());

        // Duplicate edge.
        let mut dup = vec![DAG_CODEC_VERSION];
        put_u32(&mut dup, 3);
        put_u32(&mut dup, 2);
        for _ in 0..2 {
            put_u32(&mut dup, 0);
            put_u32(&mut dup, 1);
        }
        assert!(dag_from_bytes(&dup).is_err());
    }

    #[test]
    fn string_helper_roundtrips_and_rejects_bad_frames() {
        let mut buf = Vec::new();
        put_str(&mut buf, "ring.wait_ns");
        put_str(&mut buf, "");
        put_str(&mut buf, "π≈3.14159");
        let mut cursor = buf.as_slice();
        assert_eq!(take_str(&mut cursor).unwrap(), "ring.wait_ns");
        assert_eq!(take_str(&mut cursor).unwrap(), "");
        assert_eq!(take_str(&mut cursor).unwrap(), "π≈3.14159");
        assert!(cursor.is_empty());

        // Over-long declared length must fail, not allocate.
        let mut bogus = Vec::new();
        put_u32(&mut bogus, u32::MAX);
        assert!(take_str(&mut bogus.as_slice()).is_err());

        // Invalid UTF-8 must fail cleanly.
        let mut bad = Vec::new();
        put_u32(&mut bad, 2);
        bad.extend_from_slice(&[0xFF, 0xFE]);
        assert!(take_str(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn scalar_helpers_roundtrip() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_f64(&mut buf, -1234.5678e-9);
        let mut cursor = buf.as_slice();
        assert_eq!(take_u32(&mut cursor).unwrap(), 0xDEAD_BEEF);
        assert_eq!(take_f64(&mut cursor).unwrap(), -1234.5678e-9);
        assert!(cursor.is_empty());
        assert!(take_u32(&mut cursor).is_err());
    }
}
