//! Moralization: the undirected graph obtained by "marrying" every
//! node's parents and dropping directions. The paper's SMHD metric
//! (structural *moral* Hamming distance) compares moral graphs, so this
//! is the evaluation substrate.

use crate::graph::Dag;
use crate::util::BitSet;

/// Symmetric adjacency rows of the moral graph of `g`.
pub fn moral_graph(g: &Dag) -> Vec<BitSet> {
    let n = g.n();
    let mut adj = vec![BitSet::new(n); n];
    for (u, v) in g.edges() {
        adj[u].insert(v);
        adj[v].insert(u);
    }
    // Marry parents pairwise.
    for v in 0..n {
        let pa: Vec<usize> = g.parents(v).iter().collect();
        for (i, &a) in pa.iter().enumerate() {
            for &b in &pa[i + 1..] {
                adj[a].insert(b);
                adj[b].insert(a);
            }
        }
    }
    adj
}

/// Number of edges in a symmetric adjacency structure.
pub fn undirected_edge_count(adj: &[BitSet]) -> usize {
    adj.iter().map(|r| r.count()).sum::<usize>() / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marries_parents() {
        // 0 -> 2 <- 1: moral graph is the triangle {0-1, 0-2, 1-2}.
        let g = Dag::from_edges(3, &[(0, 2), (1, 2)]);
        let m = moral_graph(&g);
        assert!(m[0].contains(1) && m[1].contains(0));
        assert!(m[0].contains(2) && m[1].contains(2));
        assert_eq!(undirected_edge_count(&m), 3);
    }

    #[test]
    fn chain_unchanged() {
        let g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let m = moral_graph(&g);
        assert!(!m[0].contains(2));
        assert_eq!(undirected_edge_count(&m), 2);
    }
}
