//! Directed acyclic graph with bitset adjacency rows.
//!
//! The DAG is the common currency between the learners (GES search
//! state extensions), the fusion stage (σ-consistent minimal I-maps),
//! the generators (ground-truth networks) and the metrics (moral
//! graphs). Parent/children sets are `BitSet` rows so the hot set
//! operations (ancestor closures, clique tests, parent unions) are
//! word-parallel.

use crate::util::BitSet;

/// Directed graph (acyclicity enforced by callers via `is_acyclic` /
/// `try_add_edge`; all learner code paths only create acyclic graphs).
#[derive(Clone, PartialEq, Eq)]
pub struct Dag {
    n: usize,
    parents: Vec<BitSet>,
    children: Vec<BitSet>,
}

impl Dag {
    /// Empty graph over `n` nodes.
    pub fn new(n: usize) -> Self {
        Dag {
            n,
            parents: vec![BitSet::new(n); n],
            children: vec![BitSet::new(n); n],
        }
    }

    /// Build from directed edges; panics on out-of-range nodes.
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut g = Dag::new(n);
        for &(u, v) in edges {
            g.add_edge(u, v);
        }
        g
    }

    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Add edge `u -> v` (idempotent).
    #[inline]
    pub fn add_edge(&mut self, u: usize, v: usize) {
        debug_assert!(u != v);
        self.parents[v].insert(u);
        self.children[u].insert(v);
    }

    /// Remove edge `u -> v` if present.
    #[inline]
    pub fn remove_edge(&mut self, u: usize, v: usize) {
        self.parents[v].remove(u);
        self.children[u].remove(v);
    }

    /// True iff `u -> v` exists.
    #[inline]
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.parents[v].contains(u)
    }

    /// True iff `u -> v` or `v -> u`.
    #[inline]
    pub fn adjacent(&self, u: usize, v: usize) -> bool {
        self.has_edge(u, v) || self.has_edge(v, u)
    }

    /// Parent set of `v`.
    #[inline]
    pub fn parents(&self, v: usize) -> &BitSet {
        &self.parents[v]
    }

    /// Children set of `u`.
    #[inline]
    pub fn children(&self, u: usize) -> &BitSet {
        &self.children[u]
    }

    /// Total directed edge count.
    pub fn edge_count(&self) -> usize {
        self.parents.iter().map(|p| p.count()).sum()
    }

    /// All edges as `(u, v)` pairs.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.edge_count());
        for v in 0..self.n {
            for u in self.parents[v].iter() {
                out.push((u, v));
            }
        }
        out
    }

    /// Kahn topological order; `None` if the graph has a cycle.
    pub fn topological_order(&self) -> Option<Vec<usize>> {
        let mut indeg: Vec<usize> = (0..self.n).map(|v| self.parents[v].count()).collect();
        let mut queue: Vec<usize> = (0..self.n).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(self.n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for c in self.children[u].iter() {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        (order.len() == self.n).then_some(order)
    }

    /// True iff acyclic.
    pub fn is_acyclic(&self) -> bool {
        self.topological_order().is_some()
    }

    /// Would adding `u -> v` keep the graph acyclic? (i.e. no directed
    /// path `v ⇝ u` exists.)
    pub fn can_add_edge(&self, u: usize, v: usize) -> bool {
        u != v && !self.has_directed_path(v, u)
    }

    /// BFS directed reachability `from ⇝ to`.
    pub fn has_directed_path(&self, from: usize, to: usize) -> bool {
        if from == to {
            return true;
        }
        let mut seen = BitSet::new(self.n);
        seen.insert(from);
        let mut stack = vec![from];
        while let Some(u) = stack.pop() {
            for c in self.children[u].iter() {
                if c == to {
                    return true;
                }
                if !seen.contains(c) {
                    seen.insert(c);
                    stack.push(c);
                }
            }
        }
        false
    }

    /// Ancestor set of `v` (excluding `v`).
    pub fn ancestors(&self, v: usize) -> BitSet {
        let mut anc = BitSet::new(self.n);
        let mut stack: Vec<usize> = self.parents[v].iter().collect();
        while let Some(u) = stack.pop() {
            if !anc.contains(u) {
                anc.insert(u);
                stack.extend(self.parents[u].iter());
            }
        }
        anc
    }

    /// Descendant set of `v` (excluding `v`).
    pub fn descendants(&self, v: usize) -> BitSet {
        let mut des = BitSet::new(self.n);
        let mut stack: Vec<usize> = self.children[v].iter().collect();
        while let Some(u) = stack.pop() {
            if !des.contains(u) {
                des.insert(u);
                stack.extend(self.children[u].iter());
            }
        }
        des
    }

    /// Maximum in-degree (max parents per node).
    pub fn max_in_degree(&self) -> usize {
        (0..self.n).map(|v| self.parents[v].count()).max().unwrap_or(0)
    }

    /// Undirected skeleton as symmetric adjacency bitset rows.
    pub fn skeleton(&self) -> Vec<BitSet> {
        let mut adj = vec![BitSet::new(self.n); self.n];
        for (u, v) in self.edges() {
            adj[u].insert(v);
            adj[v].insert(u);
        }
        adj
    }

    /// V-structures `(a, c, b)` with `a -> c <- b`, a/b non-adjacent, a < b.
    pub fn v_structures(&self) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::new();
        for c in 0..self.n {
            let pa: Vec<usize> = self.parents[c].iter().collect();
            for (i, &a) in pa.iter().enumerate() {
                for &b in &pa[i + 1..] {
                    if !self.adjacent(a, b) {
                        out.push((a, c, b));
                    }
                }
            }
        }
        out
    }
}

impl std::fmt::Debug for Dag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Dag(n={}, edges={:?})", self.n, self.edges())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_roundtrip() {
        let g = Dag::from_edges(4, &[(0, 1), (1, 2), (0, 3)]);
        assert!(g.has_edge(0, 1) && !g.has_edge(1, 0));
        assert!(g.adjacent(1, 0));
        assert_eq!(g.edge_count(), 3);
        let mut e = g.edges();
        e.sort_unstable();
        assert_eq!(e, vec![(0, 1), (0, 3), (1, 2)]);
    }

    #[test]
    fn topo_and_cycles() {
        let g = Dag::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(g.topological_order(), Some(vec![0, 1, 2, 3]));
        assert!(g.is_acyclic());
        let mut c = g.clone();
        c.add_edge(3, 0);
        assert!(!c.is_acyclic());
        assert!(g.can_add_edge(0, 3));
        assert!(!g.can_add_edge(3, 0));
    }

    #[test]
    fn ancestors_descendants() {
        let g = Dag::from_edges(5, &[(0, 1), (1, 2), (3, 2), (2, 4)]);
        assert_eq!(g.ancestors(4).to_vec(), vec![0, 1, 2, 3]);
        assert_eq!(g.descendants(0).to_vec(), vec![1, 2, 4]);
        assert!(g.has_directed_path(0, 4));
        assert!(!g.has_directed_path(4, 0));
    }

    #[test]
    fn v_structures_found() {
        // 0 -> 2 <- 1 is a v-structure (0, 1 non-adjacent).
        let g = Dag::from_edges(4, &[(0, 2), (1, 2)]);
        assert_eq!(g.v_structures(), vec![(0, 2, 1)]);
        // Marrying the parents destroys it.
        let shielded = Dag::from_edges(4, &[(0, 2), (1, 2), (0, 1)]);
        assert!(shielded.v_structures().is_empty());
    }
}
