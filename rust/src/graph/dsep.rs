//! d-separation via the Bayes-ball / reachability algorithm.
//!
//! Used by the test-suite to validate generators and the fusion stage
//! (an I-map must not claim independences the inputs reject), not on
//! the learning hot path.

use crate::graph::Dag;
use crate::util::BitSet;

/// True iff `x` and `y` are d-separated by the conditioning set `z` in
/// DAG `g` (reachability formulation over ancestral moral subgraph is
/// equivalent; we implement the classic ball-passing walk).
pub fn d_separated(g: &Dag, x: usize, y: usize, z: &BitSet) -> bool {
    !d_connected(g, x, y, z)
}

/// True iff an active path connects `x` and `y` given `z`.
pub fn d_connected(g: &Dag, x: usize, y: usize, z: &BitSet) -> bool {
    if x == y {
        return true;
    }
    let n = g.n();
    // Ancestors of z (for collider activation).
    let mut anc_z = z.clone();
    let mut stack: Vec<usize> = z.iter().collect();
    while let Some(v) = stack.pop() {
        for p in g.parents(v).iter() {
            if !anc_z.contains(p) {
                anc_z.insert(p);
                stack.push(p);
            }
        }
    }

    // Ball-passing: states (node, direction) with direction = came from
    // child (up=true) or from parent (up=false).
    let mut visited_up = BitSet::new(n);
    let mut visited_down = BitSet::new(n);
    // Start from x as if arriving from a child (can go anywhere).
    let mut queue: Vec<(usize, bool)> = vec![(x, true)];
    visited_up.insert(x);
    while let Some((v, up)) = queue.pop() {
        if v == y {
            return true;
        }
        let in_z = z.contains(v);
        if up {
            // Arrived from a child: if v not in z, pass to parents
            // (up) and children (down).
            if !in_z {
                for p in g.parents(v).iter() {
                    if !visited_up.contains(p) {
                        visited_up.insert(p);
                        queue.push((p, true));
                    }
                }
                for c in g.children(v).iter() {
                    if !visited_down.contains(c) {
                        visited_down.insert(c);
                        queue.push((c, false));
                    }
                }
            }
        } else {
            // Arrived from a parent.
            if !in_z {
                // Chain: continue to children.
                for c in g.children(v).iter() {
                    if !visited_down.contains(c) {
                        visited_down.insert(c);
                        queue.push((c, false));
                    }
                }
            }
            // Collider: bounce to parents iff v activates (in An(z) ∪ z).
            if anc_z.contains(v) || in_z {
                for p in g.parents(v).iter() {
                    if !visited_up.contains(p) {
                        visited_up.insert(p);
                        queue.push((p, true));
                    }
                }
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(n: usize, items: &[usize]) -> BitSet {
        BitSet::from_iter(n, items.iter().copied())
    }

    #[test]
    fn chain_blocked_by_middle() {
        let g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        assert!(d_connected(&g, 0, 2, &set(3, &[])));
        assert!(d_separated(&g, 0, 2, &set(3, &[1])));
    }

    #[test]
    fn fork_blocked_by_root() {
        let g = Dag::from_edges(3, &[(1, 0), (1, 2)]);
        assert!(d_connected(&g, 0, 2, &set(3, &[])));
        assert!(d_separated(&g, 0, 2, &set(3, &[1])));
    }

    #[test]
    fn collider_activates_on_conditioning() {
        let g = Dag::from_edges(3, &[(0, 1), (2, 1)]);
        assert!(d_separated(&g, 0, 2, &set(3, &[])));
        assert!(d_connected(&g, 0, 2, &set(3, &[1])));
    }

    #[test]
    fn collider_activates_via_descendant() {
        // 0 -> 1 <- 2, 1 -> 3: conditioning on descendant 3 activates.
        let g = Dag::from_edges(4, &[(0, 1), (2, 1), (1, 3)]);
        assert!(d_separated(&g, 0, 2, &set(4, &[])));
        assert!(d_connected(&g, 0, 2, &set(4, &[3])));
    }

    #[test]
    fn markov_condition_holds() {
        // In 0 -> 1 -> 2 -> 3: node 3 ⫫ {0,1} | parent 2.
        let g = Dag::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert!(d_separated(&g, 3, 0, &set(4, &[2])));
        assert!(d_separated(&g, 3, 1, &set(4, &[2])));
    }
}
