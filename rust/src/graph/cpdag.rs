//! DAG ↔ CPDAG conversions (Chickering 1995/2002) and the
//! PDAG-consistent-extension algorithm (Dor & Tarsi 1992).
//!
//! GES searches the space of equivalence classes: after applying an
//! Insert/Delete to a CPDAG the result is a PDAG, which is extended to
//! a consistent DAG (`pdag_to_dag`) and re-completed (`dag_to_cpdag`).
//! These two routines dominate operator-application cost and are the
//! reason the search state lives in bitset adjacency.

use crate::graph::{Dag, Pdag};
use crate::util::BitSet;

/// Chickering's ORDER-EDGES + LABEL-EDGES: convert a DAG to the
/// completed PDAG (CPDAG) of its Markov equivalence class. Compelled
/// edges stay directed; reversible edges become undirected.
pub fn dag_to_cpdag(g: &Dag) -> Pdag {
    let n = g.n();
    let order = g.topological_order().expect("dag_to_cpdag: input has a cycle");
    let mut rank = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        rank[v] = i;
    }

    // ORDER-EDGES: edges sorted by (rank(y), -rank(x)) for x -> y gives
    // exactly Chickering's total order.
    let mut edges: Vec<(usize, usize)> = g.edges();
    edges.sort_by_key(|&(x, y)| (rank[y], std::cmp::Reverse(rank[x])));
    let m = edges.len();
    let mut edge_id = std::collections::HashMap::with_capacity(m);
    for (i, &e) in edges.iter().enumerate() {
        edge_id.insert(e, i);
    }

    // 0 = unknown, 1 = compelled, 2 = reversible
    let mut label = vec![0u8; m];

    for idx in 0..m {
        if label[idx] != 0 {
            continue;
        }
        let (x, y) = edges[idx];
        let mut done = false;
        // Step: for every w -> x labeled compelled.
        let w_parents: Vec<usize> = g.parents(x).iter().collect();
        for w in w_parents {
            let wx = edge_id[&(w, x)];
            if label[wx] != 1 {
                continue;
            }
            if !g.has_edge(w, y) {
                // Label x -> y and every edge incident into y compelled.
                for u in g.parents(y).iter() {
                    label[edge_id[&(u, y)]] = 1;
                }
                done = true;
                break;
            } else {
                label[edge_id[&(w, y)]] = 1;
            }
        }
        if done {
            continue;
        }
        // If there is z -> y with z != x and z not a parent of x.
        let exists_z = g
            .parents(y)
            .iter()
            .any(|z| z != x && !g.has_edge(z, x));
        if exists_z {
            label[idx] = 1;
            for u in g.parents(y).iter() {
                let e = edge_id[&(u, y)];
                if label[e] == 0 {
                    label[e] = 1;
                }
            }
        } else {
            label[idx] = 2;
            for u in g.parents(y).iter() {
                let e = edge_id[&(u, y)];
                if label[e] == 0 {
                    label[e] = 2;
                }
            }
        }
    }

    let mut out = Pdag::new(n);
    for (i, &(x, y)) in edges.iter().enumerate() {
        match label[i] {
            1 => out.add_directed(x, y),
            2 => out.add_undirected(x, y),
            _ => unreachable!("unlabeled edge after LABEL-EDGES"),
        }
    }
    out
}

/// Dor & Tarsi consistent extension: orient the undirected edges of a
/// PDAG into a DAG with the same skeleton, the same directed edges and
/// no new v-structures. Returns `None` if no consistent extension
/// exists.
pub fn pdag_to_dag(p: &Pdag) -> Option<Dag> {
    let n = p.n();
    let mut work = p.clone();
    let mut out = Dag::new(n);
    // Copy directed edges up front; orientation decisions add the rest.
    for (u, v) in p.directed_edges() {
        out.add_edge(u, v);
    }

    let mut removed = BitSet::new(n);
    let mut remaining = n;
    while remaining > 0 {
        // Find a node x that (a) has no outgoing directed edges, and
        // (b) every undirected neighbor of x is adjacent to every other
        // node adjacent to x.
        let mut found = None;
        'outer: for x in 0..n {
            if removed.contains(x) || !work.children(x).is_empty() {
                continue;
            }
            let nbrs = work.neighbors(x).clone();
            if !nbrs.is_empty() {
                let adjx = work.adjacents(x);
                for u in nbrs.iter() {
                    for w in adjx.iter() {
                        if w != u && !work.adjacent(u, w) {
                            continue 'outer;
                        }
                    }
                }
            }
            found = Some(x);
            break;
        }
        let x = found?;
        // Orient all undirected edges incident to x toward x.
        for u in work.neighbors(x).clone().iter() {
            out.add_edge(u, x);
        }
        // Remove x from the working graph.
        for u in work.adjacents(x).iter() {
            work.remove_between(u, x);
        }
        removed.insert(x);
        remaining -= 1;
    }
    debug_assert!(out.is_acyclic());
    Some(out)
}

/// Convenience: complete a PDAG (extend to DAG, then re-complete).
/// Returns `None` when the PDAG admits no consistent extension.
pub fn complete_pdag(p: &Pdag) -> Option<Pdag> {
    pdag_to_dag(p).map(|d| dag_to_cpdag(&d))
}

/// Markov equivalence check via the graphical characterization:
/// same skeleton and same v-structures (Verma & Pearl).
pub fn markov_equivalent(a: &Dag, b: &Dag) -> bool {
    if a.n() != b.n() {
        return false;
    }
    if a.skeleton() != b.skeleton() {
        return false;
    }
    let mut va = a.v_structures();
    let mut vb = b.v_structures();
    // canonicalize (a, c, b) with a < b
    for v in va.iter_mut().chain(vb.iter_mut()) {
        if v.0 > v.2 {
            *v = (v.2, v.1, v.0);
        }
    }
    va.sort_unstable();
    vb.sort_unstable();
    va == vb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_is_fully_reversible() {
        // 0 -> 1 -> 2 has no v-structure: CPDAG is 0 - 1 - 2.
        let g = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let c = dag_to_cpdag(&g);
        assert_eq!(c.edge_counts(), (0, 2));
        assert!(c.has_undirected(0, 1) && c.has_undirected(1, 2));
    }

    #[test]
    fn collider_is_compelled() {
        // 0 -> 2 <- 1: both edges compelled.
        let g = Dag::from_edges(3, &[(0, 2), (1, 2)]);
        let c = dag_to_cpdag(&g);
        assert_eq!(c.edge_counts(), (2, 0));
        assert!(c.has_directed(0, 2) && c.has_directed(1, 2));
    }

    #[test]
    fn collider_tail_compelled_downstream() {
        // 0 -> 2 <- 1, 2 -> 3: edge 2 -> 3 is compelled (else new
        // v-structure at 2... actually reversing would create 3 -> 2
        // colliding with 0 -> 2, changing the class).
        let g = Dag::from_edges(4, &[(0, 2), (1, 2), (2, 3)]);
        let c = dag_to_cpdag(&g);
        assert_eq!(c.edge_counts(), (3, 0));
        assert!(c.has_directed(2, 3));
    }

    #[test]
    fn extension_roundtrip_equivalent() {
        let g = Dag::from_edges(5, &[(0, 1), (1, 2), (3, 2), (3, 4), (0, 4)]);
        let c = dag_to_cpdag(&g);
        let d = pdag_to_dag(&c).expect("CPDAG must be extendable");
        assert!(markov_equivalent(&g, &d));
    }

    #[test]
    fn inextensible_pdag() {
        // Square with all sides undirected plus a collider constraint
        // that cannot be satisfied: 1 -> 0, 2 -> 0 directed and 1 - 2
        // undirected with 1, 2 non-adjacent to anything else... the
        // classic minimal example: a - b, a - c, b -> d, c -> d, with
        // b, c non-adjacent and a non-adjacent d.
        let mut p = Pdag::new(4);
        p.add_undirected(0, 1);
        p.add_undirected(0, 2);
        p.add_directed(1, 3);
        p.add_directed(2, 3);
        // Extending must orient 0-1 and 0-2 without creating a new
        // v-structure at 0: impossible orientations exist... this PDAG
        // IS extendable (orient 0 -> 1, 0 -> 2). Check it succeeds:
        assert!(pdag_to_dag(&p).is_some());
        // A truly inextensible PDAG: the chordless undirected 4-cycle.
        // Every acyclic orientation gives some node two non-adjacent
        // parents (a new v-structure), so no consistent extension.
        let mut q = Pdag::new(4);
        q.add_undirected(0, 1);
        q.add_undirected(1, 2);
        q.add_undirected(2, 3);
        q.add_undirected(3, 0);
        assert!(pdag_to_dag(&q).is_none());
    }

    #[test]
    fn markov_equivalence_basics() {
        let a = Dag::from_edges(3, &[(0, 1), (1, 2)]);
        let b = Dag::from_edges(3, &[(1, 0), (1, 2)]);
        let c = Dag::from_edges(3, &[(0, 1), (2, 1)]);
        assert!(markov_equivalent(&a, &b));
        assert!(!markov_equivalent(&a, &c)); // collider differs
    }
}
