//! Graph substrate: DAGs, PDAGs/CPDAGs, conversions, moralization and
//! d-separation — everything the learners, the fusion stage and the
//! metrics build on.

pub mod codec;
pub mod cpdag;
pub mod dag;
pub mod dsep;
pub mod moral;
pub mod pdag;

pub use codec::{dag_from_bytes, dag_to_bytes, decode_dag, encode_dag};
pub use cpdag::{complete_pdag, dag_to_cpdag, markov_equivalent, pdag_to_dag};
pub use dag::Dag;
pub use dsep::{d_connected, d_separated};
pub use moral::{moral_graph, undirected_edge_count};
pub use pdag::Pdag;
