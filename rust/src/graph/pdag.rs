//! Partially directed graph (PDAG): the GES search state.
//!
//! A PDAG holds directed edges (`u -> v`) and undirected edges
//! (`u - v`). CPDAGs (completed PDAGs, i.e. equivalence classes) are
//! represented with this type; `graph::cpdag` provides the DAG↔CPDAG
//! conversions and the consistent-extension algorithm.

use crate::graph::Dag;
use crate::util::BitSet;

/// Mixed graph with directed and undirected edges.
#[derive(Clone, PartialEq, Eq)]
pub struct Pdag {
    n: usize,
    /// Directed: parents[v] = {u : u -> v}.
    parents: Vec<BitSet>,
    /// Directed: children[u] = {v : u -> v}.
    children: Vec<BitSet>,
    /// Undirected, symmetric: und[u] = {v : u - v}.
    und: Vec<BitSet>,
}

impl Pdag {
    /// Empty PDAG over `n` nodes.
    pub fn new(n: usize) -> Self {
        Pdag {
            n,
            parents: vec![BitSet::new(n); n],
            children: vec![BitSet::new(n); n],
            und: vec![BitSet::new(n); n],
        }
    }

    /// View a DAG as a PDAG (all edges directed).
    pub fn from_dag(d: &Dag) -> Self {
        let mut g = Pdag::new(d.n());
        for (u, v) in d.edges() {
            g.add_directed(u, v);
        }
        g
    }

    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Add `u -> v`.
    #[inline]
    pub fn add_directed(&mut self, u: usize, v: usize) {
        debug_assert!(u != v);
        self.parents[v].insert(u);
        self.children[u].insert(v);
    }

    /// Add `u - v`.
    #[inline]
    pub fn add_undirected(&mut self, u: usize, v: usize) {
        debug_assert!(u != v);
        self.und[u].insert(v);
        self.und[v].insert(u);
    }

    /// Remove any edge (directed either way or undirected) between u, v.
    pub fn remove_between(&mut self, u: usize, v: usize) {
        self.parents[v].remove(u);
        self.children[u].remove(v);
        self.parents[u].remove(v);
        self.children[v].remove(u);
        self.und[u].remove(v);
        self.und[v].remove(u);
    }

    /// Turn `u - v` into `u -> v` (no-op if not undirected-adjacent).
    pub fn orient(&mut self, u: usize, v: usize) {
        if self.und[u].contains(v) {
            self.und[u].remove(v);
            self.und[v].remove(u);
            self.add_directed(u, v);
        }
    }

    /// True iff `u -> v`.
    #[inline]
    pub fn has_directed(&self, u: usize, v: usize) -> bool {
        self.parents[v].contains(u)
    }

    /// True iff `u - v`.
    #[inline]
    pub fn has_undirected(&self, u: usize, v: usize) -> bool {
        self.und[u].contains(v)
    }

    /// True iff any edge connects u and v.
    #[inline]
    pub fn adjacent(&self, u: usize, v: usize) -> bool {
        self.has_directed(u, v) || self.has_directed(v, u) || self.has_undirected(u, v)
    }

    /// Directed parents of `v`.
    #[inline]
    pub fn parents(&self, v: usize) -> &BitSet {
        &self.parents[v]
    }

    /// Directed children of `u`.
    #[inline]
    pub fn children(&self, u: usize) -> &BitSet {
        &self.children[u]
    }

    /// Undirected neighbors of `v`.
    #[inline]
    pub fn neighbors(&self, v: usize) -> &BitSet {
        &self.und[v]
    }

    /// All nodes connected to `v` by any edge.
    pub fn adjacents(&self, v: usize) -> BitSet {
        let mut a = self.parents[v].clone();
        a.union_with(&self.children[v]);
        a.union_with(&self.und[v]);
        a
    }

    /// NA(y, x): undirected neighbors of `y` that are adjacent to `x`
    /// (Chickering's `NA_{y,x}`, the core of Insert/Delete validity).
    pub fn na(&self, y: usize, x: usize) -> BitSet {
        let mut s = self.und[y].clone();
        s.intersect_with(&self.adjacents(x));
        s
    }

    /// True iff every pair in `set` is adjacent (∅ and singletons are
    /// cliques).
    pub fn is_clique(&self, set: &BitSet) -> bool {
        let members: Vec<usize> = set.iter().collect();
        for (i, &u) in members.iter().enumerate() {
            for &v in &members[i + 1..] {
                if !self.adjacent(u, v) {
                    return false;
                }
            }
        }
        true
    }

    /// True iff a semi-directed path (following `-` or `->` edges)
    /// exists from `from` to `to` avoiding all nodes in `block`.
    pub fn has_semi_directed_path(&self, from: usize, to: usize, block: &BitSet) -> bool {
        if from == to {
            return true;
        }
        if block.contains(to) {
            return false;
        }
        let mut seen = BitSet::new(self.n);
        seen.insert(from);
        let mut stack = vec![from];
        while let Some(u) = stack.pop() {
            let mut succ = self.children[u].clone();
            succ.union_with(&self.und[u]);
            for w in succ.iter() {
                if w == to {
                    return true;
                }
                if !seen.contains(w) && !block.contains(w) {
                    seen.insert(w);
                    stack.push(w);
                }
            }
        }
        false
    }

    /// Counts `(directed, undirected)` edges.
    pub fn edge_counts(&self) -> (usize, usize) {
        let d = self.parents.iter().map(|p| p.count()).sum();
        let u = self.und.iter().map(|p| p.count()).sum::<usize>() / 2;
        (d, u)
    }

    /// Total number of edges (undirected counted once).
    pub fn total_edges(&self) -> usize {
        let (d, u) = self.edge_counts();
        d + u
    }

    /// Undirected skeleton adjacency rows.
    pub fn skeleton(&self) -> Vec<BitSet> {
        let mut adj = vec![BitSet::new(self.n); self.n];
        for v in 0..self.n {
            for u in self.parents[v].iter() {
                adj[u].insert(v);
                adj[v].insert(u);
            }
            adj[v].union_with(&self.und[v]);
        }
        adj
    }

    /// Directed edges list.
    pub fn directed_edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for v in 0..self.n {
            for u in self.parents[v].iter() {
                out.push((u, v));
            }
        }
        out
    }

    /// Undirected edges list with `u < v`.
    pub fn undirected_edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for u in 0..self.n {
            for v in self.und[u].iter() {
                if u < v {
                    out.push((u, v));
                }
            }
        }
        out
    }
}

impl std::fmt::Debug for Pdag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Pdag(n={}, directed={:?}, undirected={:?})",
            self.n,
            self.directed_edges(),
            self.undirected_edges()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_kinds() {
        let mut g = Pdag::new(4);
        g.add_directed(0, 1);
        g.add_undirected(1, 2);
        assert!(g.has_directed(0, 1) && !g.has_directed(1, 0));
        assert!(g.has_undirected(2, 1));
        assert!(g.adjacent(1, 2) && g.adjacent(0, 1) && !g.adjacent(0, 2));
        assert_eq!(g.edge_counts(), (1, 1));
        g.orient(1, 2);
        assert!(g.has_directed(1, 2) && !g.has_undirected(1, 2));
        g.remove_between(0, 1);
        assert!(!g.adjacent(0, 1));
    }

    #[test]
    fn na_and_clique() {
        let mut g = Pdag::new(5);
        // y=0 with undirected neighbors 1, 2; x=4 adjacent to 1 only.
        g.add_undirected(0, 1);
        g.add_undirected(0, 2);
        g.add_directed(4, 1);
        assert_eq!(g.na(0, 4).to_vec(), vec![1]);
        let mut s = BitSet::new(5);
        s.insert(1);
        s.insert(2);
        assert!(!g.is_clique(&s));
        g.add_undirected(1, 2);
        assert!(g.is_clique(&s));
        assert!(g.is_clique(&BitSet::new(5)));
    }

    #[test]
    fn semi_directed_paths() {
        let mut g = Pdag::new(5);
        g.add_directed(0, 1);
        g.add_undirected(1, 2);
        g.add_directed(2, 3);
        assert!(g.has_semi_directed_path(0, 3, &BitSet::new(5)));
        // Can't traverse a directed edge backwards.
        assert!(!g.has_semi_directed_path(3, 0, &BitSet::new(5)));
        // Blocking the middle node cuts the path.
        let block = BitSet::from_iter(5, [1]);
        assert!(!g.has_semi_directed_path(0, 3, &block));
    }
}
