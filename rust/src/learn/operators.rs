//! GES operators on equivalence classes (Chickering 2002):
//! Insert(X, Y, T) and Delete(X, Y, H) — validity tests, score deltas
//! and CPDAG application.
//!
//! Validity (Theorems 15/17 of Chickering 2002):
//! * Insert(X,Y,T): X, Y non-adjacent; T ⊆ neighbors(Y) \ adj(X);
//!   NA_{Y,X} ∪ T is a clique; every semi-directed Y→X path is blocked
//!   by NA_{Y,X} ∪ T.
//!   Δ = s(Y, NA ∪ T ∪ Pa(Y) ∪ {X}) − s(Y, NA ∪ T ∪ Pa(Y)).
//! * Delete(X,Y,H): X→Y or X−Y; H ⊆ NA_{Y,X}; NA_{Y,X} \ H is a clique.
//!   Δ = s(Y, (NA\H) ∪ Pa(Y) \ {X}) − s(Y, (NA\H) ∪ Pa(Y)).
//!
//! After application the PDAG is re-completed by the caller
//! (`graph::complete_pdag`).

use crate::graph::Pdag;
use crate::score::BdeuScorer;
use crate::util::BitSet;

/// Largest NA/T candidate pool enumerated exhaustively; beyond this a
/// greedy forward pass is used. 2^6 = 64 subsets bounds the per-pair
/// work on dense fused subgraphs (unlimited cGES grows those — the
/// paper's stated motivation for cGES-L) while sparse regions are
/// unaffected.
const EXHAUSTIVE_LIMIT: usize = 6;

/// Widest family (parents incl. X) a candidate evaluation will score.
/// With 2-5k rows, families beyond this width have q >> m and are never
/// competitive under BDeu; scoring them costs a fresh sparse count per
/// T-subset, which blew up unlimited-cGES benches (§Perf).
const MAX_EVAL_WIDTH: usize = 8;

/// A scored, applicable operator.
#[derive(Clone, Debug)]
pub struct Operator {
    /// Insert = true, Delete = false.
    pub is_insert: bool,
    pub x: usize,
    pub y: usize,
    /// T (insert) or H (delete) node set.
    pub set: Vec<usize>,
    /// Score delta of applying the operator.
    pub delta: f64,
}

/// Score delta of Insert(x, y, t_set) on `g`. Both family scores come
/// from one [`BdeuScorer::local_pair`] probe, so the cold case counts
/// the superset table once and marginalizes the base out of it.
pub fn insert_delta(scorer: &BdeuScorer, g: &Pdag, x: usize, y: usize, t: &BitSet) -> f64 {
    let mut base: Vec<usize> = g.na(y, x).union(t).union(g.parents(y)).to_vec();
    base.retain(|&v| v != x);
    let (with_x, without_x) = scorer.local_pair(y, &base, x);
    with_x - without_x
}

/// Score delta of Delete(x, y, h_set) on `g` — the same fused probe as
/// [`insert_delta`], with the sign flipped.
pub fn delete_delta(scorer: &BdeuScorer, g: &Pdag, x: usize, y: usize, h: &BitSet) -> f64 {
    let mut na_minus_h = g.na(y, x);
    na_minus_h.difference_with(h);
    let mut base: Vec<usize> = na_minus_h.union(g.parents(y)).to_vec();
    base.retain(|&v| v != x);
    let (with_x, without_x) = scorer.local_pair(y, &base, x);
    without_x - with_x
}

/// Insert validity (Chickering Thm 15).
pub fn valid_insert(g: &Pdag, x: usize, y: usize, t: &BitSet) -> bool {
    valid_insert_opt(g, x, y, t, true)
}

/// Insert validity with an optional path check. The clique condition is
/// cheap and always verified; the semi-directed-path BFS (the §Perf
/// profile's second-largest cost) may be skipped for heap *estimates* —
/// the search re-validates every candidate exactly before applying it,
/// so a skipped check can only cost a wasted pop, never a wrong apply.
pub fn valid_insert_opt(g: &Pdag, x: usize, y: usize, t: &BitSet, check_path: bool) -> bool {
    debug_assert!(!g.adjacent(x, y));
    let na_t = g.na(y, x).union(t);
    if !g.is_clique(&na_t) {
        return false;
    }
    // Every semi-directed path from Y to X must pass through NA ∪ T:
    // equivalently no such path exists once NA ∪ T is blocked.
    !check_path || !g.has_semi_directed_path(y, x, &na_t)
}

/// Delete validity (Chickering Thm 17).
pub fn valid_delete(g: &Pdag, x: usize, y: usize, h: &BitSet) -> bool {
    debug_assert!(g.has_directed(x, y) || g.has_undirected(x, y));
    let mut na_minus_h = g.na(y, x);
    na_minus_h.difference_with(h);
    g.is_clique(&na_minus_h)
}

/// Best valid Insert(x, y, ·) by exhaustive / greedy T search.
/// Returns `None` when no valid positive-candidate structure exists
/// (all deltas are still reported; caller filters on `delta > 0`).
pub fn best_insert(
    scorer: &BdeuScorer,
    g: &Pdag,
    x: usize,
    y: usize,
    max_parents: Option<usize>,
) -> Option<Operator> {
    best_insert_opt(scorer, g, x, y, max_parents, true)
}

/// [`best_insert`] with the path check optionally deferred (see
/// [`valid_insert_opt`]).
pub fn best_insert_opt(
    scorer: &BdeuScorer,
    g: &Pdag,
    x: usize,
    y: usize,
    max_parents: Option<usize>,
    check_path: bool,
) -> Option<Operator> {
    if g.adjacent(x, y) {
        return None;
    }
    let n = g.n();
    // T pool: neighbors of Y not adjacent to X.
    let mut pool = g.neighbors(y).clone();
    pool.difference_with(&g.adjacents(x));
    pool.remove(x);
    let pool_vec: Vec<usize> = pool.iter().collect();

    if let Some(cap) = max_parents {
        // Even T = ∅ implies |Pa ∪ NA| + 1 parents for Y in the DAG view.
        let lower = g.parents(y).count() + 1;
        if lower > cap {
            return None;
        }
    }

    let mut best: Option<(f64, BitSet)> = None;
    let mut consider = |t: &BitSet, scorer: &BdeuScorer| {
        if !valid_insert_opt(g, x, y, t, check_path) {
            return;
        }
        let width = g.na(y, x).union(t).union(g.parents(y)).count() + 1;
        if width > max_parents.unwrap_or(MAX_EVAL_WIDTH).min(MAX_EVAL_WIDTH) {
            return;
        }
        let d = insert_delta(scorer, g, x, y, t);
        if best.as_ref().map(|(bd, _)| d > *bd).unwrap_or(true) {
            best = Some((d, t.clone()));
        }
    };

    if pool_vec.len() <= EXHAUSTIVE_LIMIT {
        // All subsets of the pool.
        let k = pool_vec.len();
        for bits in 0..(1u32 << k) {
            let mut t = BitSet::new(n);
            for (i, &v) in pool_vec.iter().enumerate() {
                if bits >> i & 1 == 1 {
                    t.insert(v);
                }
            }
            consider(&t, scorer);
        }
    } else {
        // Greedy grow from ∅.
        let mut t = BitSet::new(n);
        consider(&t, scorer);
        loop {
            let mut improved = false;
            let current_best = best.as_ref().map(|(d, _)| *d).unwrap_or(f64::NEG_INFINITY);
            let mut best_add: Option<(f64, usize)> = None;
            for &v in &pool_vec {
                if t.contains(v) {
                    continue;
                }
                let mut t2 = t.clone();
                t2.insert(v);
                if !valid_insert_opt(g, x, y, &t2, check_path) {
                    continue;
                }
                let d = insert_delta(scorer, g, x, y, &t2);
                if d > current_best && best_add.map(|(bd, _)| d > bd).unwrap_or(true) {
                    best_add = Some((d, v));
                }
            }
            if let Some((d, v)) = best_add {
                t.insert(v);
                best = Some((d, t.clone()));
                improved = true;
            }
            if !improved {
                break;
            }
        }
    }

    best.map(|(delta, t)| Operator { is_insert: true, x, y, set: t.to_vec(), delta })
}

/// Insert restricted to T = ∅ (fGES's forward heuristic — skips the
/// T-subset search entirely; validity still fully checked).
pub fn best_insert_empty_t(
    scorer: &BdeuScorer,
    g: &Pdag,
    x: usize,
    y: usize,
    max_parents: Option<usize>,
) -> Option<Operator> {
    if g.adjacent(x, y) {
        return None;
    }
    let t = BitSet::new(g.n());
    if !valid_insert(g, x, y, &t) {
        return None;
    }
    if let Some(cap) = max_parents {
        if g.na(y, x).union(g.parents(y)).count() + 1 > cap {
            return None;
        }
    }
    let delta = insert_delta(scorer, g, x, y, &t);
    Some(Operator { is_insert: true, x, y, set: Vec::new(), delta })
}

/// Best valid Delete(x, y, ·) by exhaustive / greedy H search.
pub fn best_delete(scorer: &BdeuScorer, g: &Pdag, x: usize, y: usize) -> Option<Operator> {
    if !(g.has_directed(x, y) || g.has_undirected(x, y)) {
        return None;
    }
    let n = g.n();
    let pool_vec: Vec<usize> = g.na(y, x).iter().collect();

    let mut best: Option<(f64, BitSet)> = None;
    let mut consider = |h: &BitSet, scorer: &BdeuScorer| {
        if !valid_delete(g, x, y, h) {
            return;
        }
        let d = delete_delta(scorer, g, x, y, h);
        if best.as_ref().map(|(bd, _)| d > *bd).unwrap_or(true) {
            best = Some((d, h.clone()));
        }
    };

    if pool_vec.len() <= EXHAUSTIVE_LIMIT {
        let k = pool_vec.len();
        for bits in 0..(1u32 << k) {
            let mut h = BitSet::new(n);
            for (i, &v) in pool_vec.iter().enumerate() {
                if bits >> i & 1 == 1 {
                    h.insert(v);
                }
            }
            consider(&h, scorer);
        }
    } else {
        let mut h = BitSet::new(n);
        consider(&h, scorer);
        loop {
            let current_best = best.as_ref().map(|(d, _)| *d).unwrap_or(f64::NEG_INFINITY);
            let mut best_add: Option<(f64, usize)> = None;
            for &v in &pool_vec {
                if h.contains(v) {
                    continue;
                }
                let mut h2 = h.clone();
                h2.insert(v);
                if !valid_delete(g, x, y, &h2) {
                    continue;
                }
                let d = delete_delta(scorer, g, x, y, &h2);
                if d > current_best && best_add.map(|(bd, _)| d > bd).unwrap_or(true) {
                    best_add = Some((d, v));
                }
            }
            match best_add {
                Some((d, v)) => {
                    h.insert(v);
                    best = Some((d, h.clone()));
                }
                None => break,
            }
        }
    }

    best.map(|(delta, h)| Operator { is_insert: false, x, y, set: h.to_vec(), delta })
}

/// Apply an operator to the PDAG (caller re-completes afterwards).
pub fn apply(g: &mut Pdag, op: &Operator) {
    if op.is_insert {
        g.add_directed(op.x, op.y);
        for &t in &op.set {
            g.orient(t, op.y);
        }
    } else {
        g.remove_between(op.x, op.y);
        for &h in &op.set {
            g.orient(op.y, h);
            if g.has_undirected(op.x, h) {
                g.orient(op.x, h);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::graph::{complete_pdag, Dag, Pdag};
    use crate::rng::Rng;
    use std::sync::Arc;

    fn chain_data() -> Arc<Dataset> {
        // X0 -> X1 -> X2, strong links, 1000 rows.
        let mut rng = Rng::new(17);
        let m = 1000;
        let mut c0 = vec![0u8; m];
        let mut c1 = vec![0u8; m];
        let mut c2 = vec![0u8; m];
        for t in 0..m {
            c0[t] = rng.bool(0.5) as u8;
            c1[t] = if rng.bool(0.9) { c0[t] } else { 1 - c0[t] };
            c2[t] = if rng.bool(0.9) { c1[t] } else { 1 - c1[t] };
        }
        Arc::new(Dataset::unnamed(vec![2, 2, 2], vec![c0, c1, c2]))
    }

    #[test]
    fn insert_delta_on_empty_graph_is_pair_gain() {
        let d = chain_data();
        let sc = BdeuScorer::new(d, 10.0);
        let g = Pdag::new(3);
        let t = BitSet::new(3);
        let delta = insert_delta(&sc, &g, 0, 1, &t);
        let expect = sc.local(1, &[0]) - sc.local(1, &[]);
        assert!((delta - expect).abs() < 1e-12);
        assert!(delta > 0.0);
    }

    #[test]
    fn valid_insert_respects_paths() {
        // CPDAG 0 -> 1 -> 2 (directed): inserting 2 -> ... back to 0
        // must be blocked (semi-directed path 0 ⇝ 2 exists).
        let mut g = Pdag::new(3);
        g.add_directed(0, 1);
        g.add_directed(1, 2);
        let t = BitSet::new(3);
        // Insert(x=2, y=0): semi-directed path y=0 ⇝ x=2 exists -> invalid
        // (a 2 -> 0 edge would close a cycle in every consistent DAG).
        assert!(!valid_insert(&g, 2, 0, &t));
        // Insert(x=0, y=2): no path 2 ⇝ 0 -> valid.
        assert!(valid_insert(&g, 0, 2, &t));
    }

    #[test]
    fn apply_insert_then_complete() {
        let d = chain_data();
        let sc = BdeuScorer::new(d, 10.0);
        let mut g = Pdag::new(3);
        let op = best_insert(&sc, &g, 0, 1, None).unwrap();
        assert!(op.delta > 0.0);
        apply(&mut g, &op);
        let c = complete_pdag(&g).unwrap();
        // Single edge: reversible, so undirected in the CPDAG.
        assert!(c.has_undirected(0, 1));
    }

    #[test]
    fn delete_undoes_insert_delta() {
        let d = chain_data();
        let sc = BdeuScorer::new(d.clone(), 10.0);
        // Graph with undirected 0 - 1 (CPDAG of 0 -> 1).
        let dag = Dag::from_edges(3, &[(0, 1)]);
        let g = crate::graph::dag_to_cpdag(&dag);
        let op = best_delete(&sc, &g, 0, 1).unwrap();
        // Deleting the (true) edge must lose score.
        assert!(op.delta < 0.0);
        let ins = insert_delta(&sc, &Pdag::new(3), 0, 1, &BitSet::new(3));
        assert!((op.delta + ins).abs() < 1e-9);
    }

    #[test]
    fn best_insert_skips_adjacent() {
        let d = chain_data();
        let sc = BdeuScorer::new(d, 10.0);
        let mut g = Pdag::new(3);
        g.add_undirected(0, 1);
        assert!(best_insert(&sc, &g, 0, 1, None).is_none());
    }

    #[test]
    fn max_parents_cap_respected() {
        let d = chain_data();
        let sc = BdeuScorer::new(d, 10.0);
        let mut g = Pdag::new(3);
        g.add_directed(0, 2);
        g.add_directed(1, 2);
        // Cap of 2 parents: inserting a third parent into 2 is refused.
        assert!(best_insert(&sc, &g, 1, 0, Some(2)).is_some());
        let mut g3 = Pdag::new(3);
        g3.add_directed(0, 1);
        assert!(best_insert(&sc, &g3, 2, 1, Some(1)).is_none());
    }
}
