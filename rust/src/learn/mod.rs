//! Structure learners: GES (paper's parallel variant), fGES baseline,
//! the Chickering operator machinery, and edge-mask restrictions.

pub mod fges;
pub mod ges;
pub mod mask;
pub mod operators;

pub use fges::{fges, FgesConfig};
pub use ges::{ges, GesConfig, GesResult, RingWorker};
pub use mask::EdgeMask;
pub use operators::Operator;
