//! Parallel GES (Greedy Equivalence Search) over CPDAGs.
//!
//! The variant follows the paper's control algorithm (Alonso-Barba et
//! al. 2013): a totally greedy FES (apply the single best valid Insert,
//! re-score affected candidates, repeat), then a standard BES, with the
//! candidate scoring distributed across threads (the paper's "checking
//! phase ... carried out in a distributed manner by using the available
//! threads").
//!
//! Candidate management is a max-heap with version stamps and
//! recompute-on-pop (the Tetrad approach):
//! * every node carries a version bumped whenever its parent or
//!   neighbor set changes (operator application + re-completion);
//! * a popped candidate whose endpoints are stale is recomputed and
//!   re-pushed;
//! * a popped fresh candidate is recomputed once before application —
//!   this re-checks the (graph-global) path validity condition that
//!   version stamps cannot capture.
//!
//! cGES hooks: an [`EdgeMask`] restricts the candidate pairs to one
//! partition subset E_i, `insert_limit` implements the cGES-L cap
//! l = (10/k)·√n, and `seed` lets the coordinator inject the AOT
//! artifact's pairwise similarity matrix as the initial FES frontier
//! (exact deltas for the empty graph, a free first sweep).

use std::cmp::Ordering as CmpOrd;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::graph::{complete_pdag, dag_to_cpdag, pdag_to_dag, Dag, Pdag};
use crate::learn::mask::EdgeMask;
use crate::learn::operators::{apply, best_delete, best_insert_empty_t, best_insert_opt, Operator};
use crate::score::BdeuScorer;
use crate::util::par::par_map_index;
use crate::util::BitSet;

/// Minimum improvement treated as progress (guards float noise; the
/// paper's convergence test is a plain ≥ comparison on BDeu).
const EPS: f64 = 1e-9;

/// GES configuration.
#[derive(Clone)]
pub struct GesConfig {
    /// Scoring threads (the paper uses 8).
    pub threads: usize,
    /// FES insertion cap — cGES-L's l = (10/k)·√n. `None` = unlimited.
    pub insert_limit: Option<usize>,
    /// Candidate-pair restriction (cGES partition subset E_i).
    pub mask: Option<Arc<EdgeMask>>,
    /// Optional hard cap on parents per node.
    pub max_parents: Option<usize>,
    /// Pairwise similarity seed (from the XLA artifact or the Rust
    /// fallback): S[y][x] = exact Insert(x, y, ∅) delta on the empty
    /// graph.
    pub seed: Option<Arc<Vec<Vec<f64>>>>,
    /// Re-run FES+BES until neither applies an operator.
    pub iterate_until_stable: bool,
    /// fGES mode (Ramsey et al. 2017): forward phase considers only
    /// T = ∅ inserts — the speed/quality trade the paper observes.
    pub forward_empty_t: bool,
}

impl Default for GesConfig {
    fn default() -> Self {
        GesConfig {
            threads: crate::util::num_threads(),
            insert_limit: None,
            mask: None,
            max_parents: None,
            seed: None,
            iterate_until_stable: false,
            forward_empty_t: false,
        }
    }
}

/// Search outcome.
pub struct GesResult {
    /// A DAG from the final equivalence class.
    pub dag: Dag,
    /// The final CPDAG.
    pub cpdag: Pdag,
    /// BDeu score of `dag`.
    pub score: f64,
    /// Applied insert / delete counts.
    pub inserts: usize,
    pub deletes: usize,
    /// Candidate evaluations performed (telemetry).
    pub evaluations: u64,
    /// Evaluations split by phase (`evaluations` = FES + BES), so
    /// counting-core speedups are attributable to the phase that
    /// spends them.
    pub fes_evaluations: u64,
    pub bes_evaluations: u64,
}

impl GesResult {
    /// Export the search's evaluation counters into an observability
    /// registry under `ges.*` (same names the ring coordinator uses in
    /// [`crate::coordinator::Telemetry::export_metrics`]), so a
    /// single-machine `ges`/`fges` run and a ring run produce
    /// comparable metric snapshots.
    pub fn export_obs(&self, reg: &crate::obs::Registry) {
        reg.counter("ges.evaluations").add(self.evaluations);
        reg.counter("ges.fes_evaluations").add(self.fes_evaluations);
        reg.counter("ges.bes_evaluations").add(self.bes_evaluations);
    }
}

#[derive(Clone, Debug)]
struct Cand {
    delta: f64,
    x: usize,
    y: usize,
    vx: u64,
    vy: u64,
    /// Exact (recomputed) vs seeded estimate.
    exact: bool,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.delta == other.delta
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrd> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> CmpOrd {
        self.delta.partial_cmp(&other.delta).unwrap_or(CmpOrd::Equal)
    }
}

/// Shared search machinery for the two phases. Owns its scorer clone
/// (the score cache is shared through `Arc`) so it can persist across
/// ring rounds inside a [`RingWorker`].
struct Search {
    scorer: BdeuScorer,
    cfg: GesConfig,
    cpdag: Pdag,
    version: Vec<u64>,
    evaluations: u64,
    /// Per-phase split of `evaluations` (FES / BES attribution).
    fes_evaluations: u64,
    bes_evaluations: u64,
    /// Persistent candidate heaps (insert / delete). Stale entries are
    /// version-checked on pop; entries for untouched pairs stay valid
    /// across rounds — the incremental-ring optimization (§Perf).
    fwd: BinaryHeap<Cand>,
    bwd: BinaryHeap<Cand>,
    fwd_seeded: bool,
    bwd_seeded: bool,
    /// Nodes whose incident candidates are outdated for a phase (the
    /// *other* phase's applies and ring fusions mark these; they are
    /// drained in one batched incident evaluation when the phase
    /// starts, instead of per-apply — cheaper and just as complete).
    dirty_fwd: Vec<usize>,
    dirty_bwd: Vec<usize>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    Forward,
    Backward,
}

impl Search {
    fn n(&self) -> usize {
        self.cpdag.n()
    }

    /// Record `n` candidate evaluations against `phase`.
    fn note_eval(&mut self, phase: Phase, n: u64) {
        self.evaluations += n;
        match phase {
            Phase::Forward => self.fes_evaluations += n,
            Phase::Backward => self.bes_evaluations += n,
        }
    }

    fn allowed(&self, x: usize, y: usize) -> bool {
        self.cfg.mask.as_ref().map(|m| m.allowed(x, y)).unwrap_or(true)
    }

    /// Best operator for an unordered pair under a phase. With
    /// `exact = false` the (expensive, graph-global) path-validity BFS
    /// is skipped — fine for heap estimates, which are re-validated
    /// exactly at pop time before any application.
    fn best_for_pair(&self, x: usize, y: usize, phase: Phase, exact: bool) -> Option<Operator> {
        match phase {
            Phase::Forward => {
                let f = |s: &BdeuScorer, g: &Pdag, x: usize, y: usize, mp: Option<usize>| {
                    if self.cfg.forward_empty_t {
                        best_insert_empty_t(s, g, x, y, mp)
                    } else {
                        best_insert_opt(s, g, x, y, mp, exact)
                    }
                };
                let a = f(&self.scorer, &self.cpdag, x, y, self.cfg.max_parents);
                let b = f(&self.scorer, &self.cpdag, y, x, self.cfg.max_parents);
                match (a, b) {
                    (Some(a), Some(b)) => Some(if a.delta >= b.delta { a } else { b }),
                    (a, b) => a.or(b),
                }
            }
            Phase::Backward => {
                let a = best_delete(&self.scorer, &self.cpdag, x, y);
                let b = best_delete(&self.scorer, &self.cpdag, y, x);
                match (a, b) {
                    (Some(a), Some(b)) => Some(if a.delta >= b.delta { a } else { b }),
                    (a, b) => a.or(b),
                }
            }
        }
    }

    /// Candidate pair applicability for a phase.
    fn applicable(&self, x: usize, y: usize, phase: Phase) -> bool {
        match phase {
            Phase::Forward => !self.cpdag.adjacent(x, y) && self.allowed(x, y),
            // Deletions are always allowed ("addition and deletion ...
            // restrained to E_i" — an edge inside the graph can only be
            // there if its pair was permitted, so masking deletes too
            // only matters for fused-in edges; the paper prunes those
            // during the constrained GES run, so we do NOT mask deletes).
            Phase::Backward => {
                self.cpdag.has_directed(x, y)
                    || self.cpdag.has_directed(y, x)
                    || self.cpdag.has_undirected(x, y)
            }
        }
    }

    /// Parallel evaluation of a set of unordered pairs; pushes positive
    /// candidates into the phase's heap.
    fn evaluate_pairs(&mut self, pairs: &[(usize, usize)], phase: Phase) {
        let results = par_map_index(pairs.len(), self.cfg.threads, |i| {
            let (x, y) = pairs[i];
            // Estimates only: path validity deferred to pop time.
            self.best_for_pair(x, y, phase, false).map(|op| (op.delta, op.x, op.y))
        });
        self.note_eval(phase, pairs.len() as u64);
        let version = &self.version;
        let cands = results.into_iter().flatten().filter(|(d, _, _)| *d > EPS).map(
            |(delta, x, y)| Cand { delta, x, y, vx: version[x], vy: version[y], exact: true },
        );
        match phase {
            Phase::Forward => self.fwd.extend(cands),
            Phase::Backward => self.bwd.extend(cands),
        }
    }

    /// All applicable unordered pairs for a phase.
    fn frontier(&self, phase: Phase) -> Vec<(usize, usize)> {
        let n = self.n();
        let mut pairs = Vec::new();
        match phase {
            Phase::Forward => {
                for x in 0..n {
                    if let Some(mask) = &self.cfg.mask {
                        for y in mask.partners(x).iter() {
                            if x < y && !self.cpdag.adjacent(x, y) {
                                pairs.push((x, y));
                            }
                        }
                    } else {
                        for y in (x + 1)..n {
                            if !self.cpdag.adjacent(x, y) {
                                pairs.push((x, y));
                            }
                        }
                    }
                }
            }
            Phase::Backward => {
                for x in 0..n {
                    for y in self.cpdag.adjacents(x).iter() {
                        if x < y {
                            pairs.push((x, y));
                        }
                    }
                }
            }
        }
        pairs
    }

    /// Apply an operator, re-complete, bump versions of changed nodes,
    /// and return them. `None` (with state untouched) if the PDAG
    /// became inconsistent (operator raced a stale validity — skip it).
    fn apply_and_refresh(&mut self, op: &Operator) -> Option<Vec<usize>> {
        let mut pdag = self.cpdag.clone();
        apply(&mut pdag, op);
        let completed = complete_pdag(&pdag)?;
        let n = self.n();
        let mut changed = Vec::new();
        for v in 0..n {
            if completed.parents(v) != self.cpdag.parents(v)
                || completed.neighbors(v) != self.cpdag.neighbors(v)
            {
                changed.push(v);
                self.version[v] += 1;
            }
        }
        self.cpdag = completed;
        Some(changed)
    }

    /// Pairs incident to any changed node, applicable under `phase`.
    fn incident_pairs(&self, changed: &[usize], phase: Phase) -> Vec<(usize, usize)> {
        let n = self.n();
        let mut mark = BitSet::new(n);
        for &c in changed {
            mark.insert(c);
        }
        let mut pairs = Vec::new();
        for &c in changed {
            for w in 0..n {
                if w == c || (mark.contains(w) && w < c) {
                    continue; // dedupe pairs with both ends changed
                }
                let (x, y) = if c < w { (c, w) } else { (w, c) };
                if self.applicable(x, y, phase) {
                    pairs.push((x, y));
                }
            }
        }
        pairs
    }

    /// Populate a phase's heap: the similarity seed when starting from
    /// the empty graph (exact ∅-graph deltas for free), the evaluated
    /// full frontier otherwise.
    fn seed_phase(&mut self, phase: Phase) {
        let seeded = phase == Phase::Forward
            && self.cfg.seed.is_some()
            && self.cpdag.total_edges() == 0;
        if seeded {
            let seed = self.cfg.seed.clone().unwrap();
            let n = self.n();
            for x in 0..n {
                let iter: Box<dyn Iterator<Item = usize>> = if let Some(m) = &self.cfg.mask {
                    Box::new(m.partners(x).iter().filter(move |&y| y > x))
                } else {
                    Box::new((x + 1)..n)
                };
                for y in iter {
                    let d = seed[y][x].max(seed[x][y]);
                    if d > EPS {
                        self.fwd.push(Cand { delta: d, x, y, vx: 0, vy: 0, exact: false });
                    }
                }
            }
        } else {
            let frontier = self.frontier(phase);
            self.evaluate_pairs(&frontier, phase);
        }
        match phase {
            Phase::Forward => self.fwd_seeded = true,
            Phase::Backward => self.bwd_seeded = true,
        }
    }

    fn pop(&mut self, phase: Phase) -> Option<Cand> {
        match phase {
            Phase::Forward => self.fwd.pop(),
            Phase::Backward => self.bwd.pop(),
        }
    }

    fn push(&mut self, phase: Phase, cand: Cand) {
        match phase {
            Phase::Forward => self.fwd.push(cand),
            Phase::Backward => self.bwd.push(cand),
        }
    }

    /// One greedy phase (FES or BES) over the persistent heaps.
    /// Returns number of applied ops.
    fn run_phase(&mut self, phase: Phase, limit: Option<usize>) -> usize {
        let seeded = match phase {
            Phase::Forward => self.fwd_seeded,
            Phase::Backward => self.bwd_seeded,
        };
        if !seeded {
            self.seed_phase(phase);
            match phase {
                Phase::Forward => self.dirty_fwd.clear(),
                Phase::Backward => self.dirty_bwd.clear(),
            }
        } else {
            // Batched catch-up on nodes touched by the other phase or
            // by ring fusion since this heap was last current.
            let mut dirty = match phase {
                Phase::Forward => std::mem::take(&mut self.dirty_fwd),
                Phase::Backward => std::mem::take(&mut self.dirty_bwd),
            };
            dirty.sort_unstable();
            dirty.dedup();
            if !dirty.is_empty() {
                let pairs = self.incident_pairs(&dirty, phase);
                self.evaluate_pairs(&pairs, phase);
            }
        }

        let mut applied = 0usize;
        let mut deferred: Vec<Cand> = Vec::new(); // positive leftovers past the limit
        while let Some(cand) = self.pop(phase) {
            if cand.delta <= EPS {
                break;
            }
            if let Some(lim) = limit {
                if applied >= lim {
                    deferred.push(cand); // keep for the next round
                    break;
                }
            }
            let fresh =
                cand.vx == self.version[cand.x] && cand.vy == self.version[cand.y];
            if !fresh || !cand.exact {
                // Stale or seeded estimate: recompute and re-push.
                if self.applicable(cand.x, cand.y, phase) {
                    if let Some(op) = self.best_for_pair(cand.x, cand.y, phase, false) {
                        self.note_eval(phase, 1);
                        if op.delta > EPS {
                            let c = Cand {
                                delta: op.delta,
                                x: cand.x,
                                y: cand.y,
                                vx: self.version[cand.x],
                                vy: self.version[cand.y],
                                exact: true,
                            };
                            self.push(phase, c);
                        }
                    }
                }
                continue;
            }
            // Fresh: recompute once — revalidates the path condition
            // and gives the operator to apply.
            if !self.applicable(cand.x, cand.y, phase) {
                continue;
            }
            let Some(op) = self.best_for_pair(cand.x, cand.y, phase, true) else {
                continue;
            };
            self.note_eval(phase, 1);
            if op.delta <= EPS {
                continue;
            }
            if (op.delta - cand.delta).abs() > 1e-9 {
                // Value moved (path-check correction or stale base):
                // reorder with the exact value.
                let c = Cand {
                    delta: op.delta,
                    x: cand.x,
                    y: cand.y,
                    vx: self.version[cand.x],
                    vy: self.version[cand.y],
                    exact: true,
                };
                self.push(phase, c);
                continue;
            }
            // Apply.
            let Some(changed) = self.apply_and_refresh(&op) else {
                continue; // inconsistent extension: drop candidate
            };
            applied += 1;
            // Refresh candidates incident to the change for the active
            // phase now; mark them dirty for the other phase (drained
            // in a single batch when that phase next runs).
            let pairs = self.incident_pairs(&changed, phase);
            self.evaluate_pairs(&pairs, phase);
            match phase {
                Phase::Forward => self.dirty_bwd.extend_from_slice(&changed),
                Phase::Backward => self.dirty_fwd.extend_from_slice(&changed),
            }
        }
        for c in deferred {
            self.push(phase, c);
        }
        applied
    }

    /// Replace the search graph (ring fusion result): bump versions of
    /// every node whose parents/neighbors changed and re-evaluate only
    /// the incident pairs — entries for untouched pairs in the
    /// persistent heaps remain valid.
    fn absorb_graph(&mut self, new_dag: &Dag) {
        let completed = if new_dag.edge_count() == 0 {
            Pdag::new(new_dag.n())
        } else {
            dag_to_cpdag(new_dag)
        };
        let n = self.n();
        let mut changed = Vec::new();
        for v in 0..n {
            if completed.parents(v) != self.cpdag.parents(v)
                || completed.neighbors(v) != self.cpdag.neighbors(v)
            {
                changed.push(v);
                self.version[v] += 1;
            }
        }
        self.cpdag = completed;
        self.dirty_fwd.extend_from_slice(&changed);
        self.dirty_bwd.extend_from_slice(&changed);
    }
}

/// Persistent per-process search state for the ring coordinator: keeps
/// the candidate heaps, version stamps and CPDAG alive across rounds so
/// each round only re-evaluates pairs the fusion actually touched —
/// instead of re-scanning the worker's whole E_i frontier (§Perf: this
/// cut ring learning time ~an order of magnitude at n ≥ 400).
pub struct RingWorker {
    search: Search,
}

impl RingWorker {
    /// New worker over an empty graph.
    pub fn new(scorer: BdeuScorer, cfg: GesConfig) -> RingWorker {
        let n = scorer.data().n_vars();
        RingWorker {
            search: Search {
                scorer,
                cfg,
                cpdag: Pdag::new(n),
                version: vec![0; n],
                evaluations: 0,
                fes_evaluations: 0,
                bes_evaluations: 0,
                fwd: BinaryHeap::new(),
                bwd: BinaryHeap::new(),
                fwd_seeded: false,
                bwd_seeded: false,
                dirty_fwd: Vec::new(),
                dirty_bwd: Vec::new(),
            },
        }
    }

    /// Number of variables this worker searches over.
    pub fn n(&self) -> usize {
        self.search.n()
    }

    /// Absorb the fusion result as the new search state.
    pub fn absorb(&mut self, fused: &Dag) {
        self.search.absorb_graph(fused);
    }

    /// Ring-hop fusion: fuse the predecessor's model with this
    /// worker's own current model (the paper's 2-argument fusion that
    /// keeps structures sparse) and absorb the result. This is the
    /// receive half of the actor lifecycle — the coordinator's runtime
    /// calls it with whatever the transport delivered.
    pub fn absorb_fused(&mut self, pred: &Dag) {
        let own = self.dag();
        let (fused, _sigma) = crate::fusion::fuse(&[&own, pred]);
        self.search.absorb_graph(&fused);
    }

    /// One round: FES (capped at the worker's own
    /// `GesConfig::insert_limit`, the single source of the cGES-L
    /// knob) + BES. Returns `(inserts, deletes)`.
    pub fn step(&mut self) -> (usize, usize) {
        let limit = self.search.cfg.insert_limit;
        let i = self.search.run_phase(Phase::Forward, limit);
        let d = self.search.run_phase(Phase::Backward, None);
        (i, d)
    }

    /// Current model as a DAG.
    pub fn dag(&self) -> Dag {
        pdag_to_dag(&self.search.cpdag).expect("worker CPDAG must be extendable")
    }

    /// BDeu score of an already-extracted model (through the worker's
    /// own scorer, so ring workers sharing a cache also share the
    /// work) — takes the `dag()` the caller just materialized instead
    /// of extending the CPDAG a second time.
    pub fn score_of(&self, dag: &Dag) -> f64 {
        self.search.scorer.score_dag(dag)
    }

    /// Candidate evaluations so far (telemetry).
    pub fn evaluations(&self) -> u64 {
        self.search.evaluations
    }

    /// This worker's edge-subset restriction (`None` = unrestricted).
    /// The ring runtime stashes it at spawn so a healed ring can hand
    /// the subset to a surviving worker if this one dies.
    pub fn mask(&self) -> Option<Arc<EdgeMask>> {
        self.search.cfg.mask.clone()
    }

    /// Ring healing: adopt a dead worker's candidate pairs by widening
    /// this worker's mask with `extra`, then mark the whole forward
    /// frontier dirty so the newly-allowed pairs get evaluated. An
    /// unrestricted worker (no mask) already covers every pair — no-op.
    /// The backward phase is unmasked by design (deletes of existing
    /// edges are always legal), so only the forward frontier re-seeds.
    pub fn widen_mask(&mut self, extra: &EdgeMask) {
        let merged = match self.search.cfg.mask.take() {
            Some(own) => {
                let mut m = (*own).clone();
                m.merge(extra);
                m
            }
            None => return,
        };
        self.search.cfg.mask = Some(Arc::new(merged));
        let n = self.search.n();
        self.search.dirty_fwd.extend(0..n);
    }

    /// The scorer (and through it the dataset) this worker learns
    /// against — what the ring's bundle-emitting path fits CPTs with,
    /// so a federated worker parameterizes on its own shard.
    pub fn scorer(&self) -> &BdeuScorer {
        &self.search.scorer
    }
}

/// Run GES from an initial DAG.
pub fn ges(scorer: &BdeuScorer, init: &Dag, cfg: &GesConfig) -> GesResult {
    let cpdag = if init.edge_count() == 0 {
        Pdag::new(init.n())
    } else {
        dag_to_cpdag(init)
    };
    let mut search = Search {
        scorer: scorer.clone(),
        cfg: cfg.clone(),
        cpdag,
        version: vec![0; init.n()],
        evaluations: 0,
        fes_evaluations: 0,
        bes_evaluations: 0,
        fwd: BinaryHeap::new(),
        bwd: BinaryHeap::new(),
        fwd_seeded: false,
        bwd_seeded: false,
        dirty_fwd: Vec::new(),
        dirty_bwd: Vec::new(),
    };

    let mut inserts = 0;
    let mut deletes = 0;
    loop {
        let i = search.run_phase(Phase::Forward, cfg.insert_limit);
        let d = search.run_phase(Phase::Backward, None);
        inserts += i;
        deletes += d;
        if !cfg.iterate_until_stable || (i == 0 && d == 0) {
            break;
        }
    }

    let dag = pdag_to_dag(&search.cpdag).expect("final CPDAG must be extendable");
    let score = scorer.score_dag(&dag);
    GesResult {
        dag,
        cpdag: search.cpdag,
        score,
        inserts,
        deletes,
        evaluations: search.evaluations,
        fes_evaluations: search.fes_evaluations,
        bes_evaluations: search.bes_evaluations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{forward_sample, generate, NetGenConfig};
    use crate::data::Dataset;
    use crate::graph::markov_equivalent;
    use std::sync::Arc;

    fn learn(data: Arc<Dataset>, cfg: &GesConfig) -> (GesResult, BdeuScorer) {
        let sc = BdeuScorer::new(data, 10.0);
        let n = sc.data().n_vars();
        let r = ges(&sc, &Dag::new(n), cfg);
        (r, sc)
    }

    #[test]
    fn recovers_chain_class() {
        // Ground truth 0 -> 1 -> 2; GES should recover the equivalence
        // class (chain skeleton, no collider).
        let bn = generate(
            &NetGenConfig { nodes: 3, edges: 2, max_parents: 1, locality: 0, ..Default::default() },
            21,
        );
        let data = Arc::new(forward_sample(&bn, 4000, 1));
        let (r, _) = learn(data, &GesConfig::default());
        assert!(markov_equivalent(&r.dag, &bn.dag) || r.dag.skeleton() == bn.dag.skeleton());
    }

    #[test]
    fn improves_over_empty_and_bes_prunes() {
        let bn = generate(&NetGenConfig { nodes: 12, edges: 16, ..Default::default() }, 3);
        let data = Arc::new(forward_sample(&bn, 2000, 5));
        let (r, sc) = learn(data, &GesConfig::default());
        let empty = sc.score_dag(&Dag::new(12));
        assert!(r.score > empty, "GES must beat the empty graph");
        assert!(r.inserts > 0);
    }

    #[test]
    fn mask_restricts_edges() {
        let bn = generate(&NetGenConfig { nodes: 10, edges: 14, ..Default::default() }, 8);
        let data = Arc::new(forward_sample(&bn, 1500, 2));
        // Only pairs within {0..4} and within {5..9} allowed.
        let mut mask = EdgeMask::new(10);
        for a in 0..5 {
            for b in (a + 1)..5 {
                mask.allow(a, b);
                mask.allow(a + 5, b + 5);
            }
        }
        let cfg = GesConfig { mask: Some(Arc::new(mask.clone())), ..Default::default() };
        let (r, _) = learn(data, &cfg);
        for (u, v) in r.dag.edges() {
            assert!(mask.allowed(u, v), "edge ({u},{v}) outside mask");
        }
    }

    #[test]
    fn insert_limit_caps_edges() {
        let bn = generate(&NetGenConfig { nodes: 12, edges: 20, ..Default::default() }, 4);
        let data = Arc::new(forward_sample(&bn, 1500, 3));
        let cfg = GesConfig { insert_limit: Some(3), ..Default::default() };
        let (r, _) = learn(data, &cfg);
        assert!(r.inserts <= 3);
        assert!(r.dag.edge_count() <= 3);
    }

    #[test]
    fn seeded_matches_unseeded() {
        let bn = generate(&NetGenConfig { nodes: 10, edges: 13, ..Default::default() }, 6);
        let data = Arc::new(forward_sample(&bn, 1200, 9));
        let sc1 = BdeuScorer::new(data.clone(), 10.0);
        let plain = ges(&sc1, &Dag::new(10), &GesConfig::default());

        let pw = crate::score::pairwise_similarity(&data, 10.0, 2);
        let sc2 = BdeuScorer::new(data, 10.0);
        let seeded = ges(
            &sc2,
            &Dag::new(10),
            &GesConfig { seed: Some(Arc::new(pw.s.clone())), ..Default::default() },
        );
        assert!((plain.score - seeded.score).abs() < 1e-6, "{} vs {}", plain.score, seeded.score);
    }

    #[test]
    fn incident_pairs_touch_only_changed_nodes() {
        // The frontier recomputation after an applied operator must be
        // bounded by pairs incident to version-bumped endpoints — not
        // the full O(n²) sweep.
        let data = Arc::new(forward_sample(
            &generate(&NetGenConfig { nodes: 8, edges: 10, ..Default::default() }, 2),
            300,
            4,
        ));
        let n = 8;
        let search = Search {
            scorer: BdeuScorer::new(data, 10.0),
            cfg: GesConfig::default(),
            cpdag: Pdag::new(n),
            version: vec![0; n],
            evaluations: 0,
            fes_evaluations: 0,
            bes_evaluations: 0,
            fwd: BinaryHeap::new(),
            bwd: BinaryHeap::new(),
            fwd_seeded: false,
            bwd_seeded: false,
            dirty_fwd: Vec::new(),
            dirty_bwd: Vec::new(),
        };
        let changed = [2usize, 5];
        let pairs = search.incident_pairs(&changed, Phase::Forward);
        // Every pair touches a changed node; no duplicates.
        for &(x, y) in &pairs {
            assert!(x < y);
            assert!(
                changed.contains(&x) || changed.contains(&y),
                "pair ({x},{y}) touches no changed node"
            );
        }
        let mut uniq = pairs.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), pairs.len(), "duplicate incident pairs");
        // On an empty graph every incident pair is applicable:
        // (n-1) pairs touching node 2 plus (n-2) more touching node 5.
        assert_eq!(pairs.len(), (n - 1) + (n - 2));
    }

    #[test]
    fn evaluations_split_by_phase() {
        let bn = generate(&NetGenConfig { nodes: 10, edges: 14, ..Default::default() }, 5);
        let data = Arc::new(forward_sample(&bn, 1200, 7));
        let (r, _) = learn(data, &GesConfig::default());
        assert_eq!(r.evaluations, r.fes_evaluations + r.bes_evaluations);
        assert!(r.fes_evaluations > 0);
    }

    #[test]
    fn starting_from_truth_stays_near_truth() {
        let bn = generate(&NetGenConfig { nodes: 12, edges: 16, ..Default::default() }, 13);
        let data = Arc::new(forward_sample(&bn, 3000, 11));
        let sc = BdeuScorer::new(data, 10.0);
        let from_truth = ges(&sc, &bn.dag, &GesConfig::default());
        let from_empty = ges(&sc, &Dag::new(12), &GesConfig::default());
        // Warm start can only do at least as well as the score of truth.
        assert!(from_truth.score >= sc.score_dag(&bn.dag) - 1e-9);
        // Both runs should land in the same ballpark.
        assert!((from_truth.score - from_empty.score).abs() / from_empty.score.abs() < 0.05);
    }
}
