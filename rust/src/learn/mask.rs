//! Edge mask: the per-process search-space restriction of cGES.
//!
//! Stage 1 partitions the O(n²) candidate edges into k disjoint subsets
//! E_1..E_k; each ring process may only Insert/Delete pairs inside its
//! mask. GES treats candidate adjacencies symmetrically (equivalence-
//! class search), so masks hold *unordered* pairs — assigning X→Y and
//! Y→X to one subset, exactly what the paper's balancing does.

use crate::util::BitSet;

/// Symmetric set of allowed variable pairs.
#[derive(Clone)]
pub struct EdgeMask {
    rows: Vec<BitSet>,
    count: usize,
}

impl EdgeMask {
    /// Empty mask over `n` variables.
    pub fn new(n: usize) -> Self {
        EdgeMask { rows: vec![BitSet::new(n); n], count: 0 }
    }

    /// Mask allowing every pair.
    pub fn full(n: usize) -> Self {
        let mut m = EdgeMask::new(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    m.rows[i].insert(j);
                }
            }
        }
        m.count = n * (n - 1) / 2;
        m
    }

    /// Number of variables.
    pub fn n(&self) -> usize {
        self.rows.len()
    }

    /// Allow the unordered pair {x, y}.
    pub fn allow(&mut self, x: usize, y: usize) {
        debug_assert!(x != y);
        if !self.rows[x].contains(y) {
            self.rows[x].insert(y);
            self.rows[y].insert(x);
            self.count += 1;
        }
    }

    /// True iff the pair {x, y} is in the mask.
    #[inline]
    pub fn allowed(&self, x: usize, y: usize) -> bool {
        self.rows[x].contains(y)
    }

    /// Row view: all partners allowed with `x`.
    pub fn partners(&self, x: usize) -> &BitSet {
        &self.rows[x]
    }

    /// Union `other` into this mask (ring healing: the heir adopts a
    /// dead worker's candidate pairs). Idempotent — pairs already
    /// present are left alone, so the count stays exact.
    pub fn merge(&mut self, other: &EdgeMask) {
        debug_assert_eq!(self.n(), other.n());
        for x in 0..self.rows.len() {
            for y in other.rows[x].iter() {
                if x < y {
                    self.allow(x, y);
                }
            }
        }
    }

    /// Number of unordered pairs in the mask.
    pub fn len(&self) -> usize {
        self.count
    }

    /// True iff no pair is allowed.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_and_query_symmetric() {
        let mut m = EdgeMask::new(5);
        assert!(!m.allowed(0, 1));
        m.allow(0, 1);
        assert!(m.allowed(0, 1) && m.allowed(1, 0));
        assert_eq!(m.len(), 1);
        m.allow(1, 0); // idempotent
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn merge_unions_pairs_idempotently() {
        let mut a = EdgeMask::new(5);
        a.allow(0, 1);
        a.allow(2, 3);
        let mut b = EdgeMask::new(5);
        b.allow(2, 3); // overlap
        b.allow(1, 4);
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert!(a.allowed(0, 1) && a.allowed(2, 3) && a.allowed(4, 1));
        // Merging again changes nothing.
        a.merge(&b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn full_mask_counts() {
        let m = EdgeMask::full(6);
        assert_eq!(m.len(), 15);
        for i in 0..6 {
            assert!(!m.allowed(i, i));
            assert_eq!(m.partners(i).count(), 5);
        }
    }
}
