//! fGES baseline (Ramsey, Glymour, Sanchez-Romero, Glymour 2017):
//! "A million variables and more".
//!
//! fGES trades exhaustiveness for speed relative to GES:
//! * the forward phase evaluates only T = ∅ inserts (the original
//!   "arrows" are single-edge hypotheses; the full T-subset search of
//!   Chickering's Insert is skipped);
//! * candidate arrows are kept in a priority queue and only arrows
//!   incident to changed nodes are re-scored (our shared heap engine
//!   already works this way);
//! * the initial all-pairs effect scan is embarrassingly parallel —
//!   here it is either threaded in Rust or read straight from the AOT
//!   pairwise-similarity artifact.
//!
//! The paper's experiments show exactly the trade this produces:
//! fastest on easy domains, subpar BDeu/SMHD on pigs and link, and a
//! blow-up on munin — shapes our benches reproduce.

use std::sync::Arc;

use crate::graph::Dag;
use crate::learn::ges::{ges, GesConfig, GesResult};
use crate::score::BdeuScorer;

/// fGES configuration (subset of [`GesConfig`]).
#[derive(Clone)]
pub struct FgesConfig {
    /// Scoring threads.
    pub threads: usize,
    /// Optional cap on parents per node.
    pub max_parents: Option<usize>,
    /// Pairwise similarity seed (artifact or Rust fallback).
    pub seed: Option<Arc<Vec<Vec<f64>>>>,
}

impl Default for FgesConfig {
    fn default() -> Self {
        FgesConfig { threads: crate::util::num_threads(), max_parents: None, seed: None }
    }
}

/// Run fGES from an initial DAG.
pub fn fges(scorer: &BdeuScorer, init: &Dag, cfg: &FgesConfig) -> GesResult {
    let ges_cfg = GesConfig {
        threads: cfg.threads,
        insert_limit: None,
        mask: None,
        max_parents: cfg.max_parents,
        seed: cfg.seed.clone(),
        iterate_until_stable: false,
        forward_empty_t: true,
    };
    ges(scorer, init, &ges_cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{forward_sample, generate, NetGenConfig};
    use crate::graph::Dag;
    use std::sync::Arc;

    #[test]
    fn fges_learns_and_is_no_better_than_ges() {
        let bn = generate(&NetGenConfig { nodes: 14, edges: 20, ..Default::default() }, 31);
        let data = Arc::new(forward_sample(&bn, 2000, 7));
        let sc = BdeuScorer::new(data.clone(), 10.0);
        let f = fges(&sc, &Dag::new(14), &FgesConfig::default());
        let sc2 = BdeuScorer::new(data, 10.0);
        let g = ges(&sc2, &Dag::new(14), &Default::default());
        let empty = sc.score_dag(&Dag::new(14));
        assert!(f.score > empty);
        // GES with full T-search can only match or beat fGES.
        assert!(g.score >= f.score - 1e-9, "ges {} < fges {}", g.score, f.score);
    }

    #[test]
    fn fges_seed_path_consistent() {
        let bn = generate(&NetGenConfig { nodes: 10, edges: 12, ..Default::default() }, 5);
        let data = Arc::new(forward_sample(&bn, 1500, 2));
        let pw = crate::score::pairwise_similarity(&data, 10.0, 2);
        let sc = BdeuScorer::new(data.clone(), 10.0);
        let seeded = fges(
            &sc,
            &Dag::new(10),
            &FgesConfig { seed: Some(Arc::new(pw.s.clone())), ..Default::default() },
        );
        let sc2 = BdeuScorer::new(data, 10.0);
        let plain = fges(&sc2, &Dag::new(10), &FgesConfig::default());
        assert!((seeded.score - plain.score).abs() < 1e-6);
    }
}
