//! fGES baseline (Ramsey, Glymour, Sanchez-Romero, Glymour 2017):
//! "A million variables and more".
//!
//! fGES trades exhaustiveness for speed relative to GES:
//! * the forward phase evaluates only T = ∅ inserts (the original
//!   "arrows" are single-edge hypotheses; the full T-subset search of
//!   Chickering's Insert is skipped);
//! * candidate arrows are kept in a priority queue and only arrows
//!   incident to changed nodes are re-scored (our shared heap engine
//!   already works this way);
//! * the initial all-pairs effect scan is embarrassingly parallel —
//!   here it is either threaded in Rust or read straight from the AOT
//!   pairwise-similarity artifact.
//!
//! The paper's experiments show exactly the trade this produces:
//! fastest on easy domains, subpar BDeu/SMHD on pigs and link, and a
//! blow-up on munin — shapes our benches reproduce.

use std::sync::Arc;

use crate::graph::Dag;
use crate::learn::ges::{ges, GesConfig, GesResult};
use crate::score::BdeuScorer;

/// fGES configuration (subset of [`GesConfig`]).
#[derive(Clone)]
pub struct FgesConfig {
    /// Scoring threads.
    pub threads: usize,
    /// Optional cap on parents per node.
    pub max_parents: Option<usize>,
    /// Pairwise similarity seed (artifact or Rust fallback).
    pub seed: Option<Arc<Vec<Vec<f64>>>>,
}

impl Default for FgesConfig {
    fn default() -> Self {
        FgesConfig { threads: crate::util::num_threads(), max_parents: None, seed: None }
    }
}

/// Run fGES from an initial DAG.
pub fn fges(scorer: &BdeuScorer, init: &Dag, cfg: &FgesConfig) -> GesResult {
    let ges_cfg = GesConfig {
        threads: cfg.threads,
        insert_limit: None,
        mask: None,
        max_parents: cfg.max_parents,
        seed: cfg.seed.clone(),
        iterate_until_stable: false,
        forward_empty_t: true,
    };
    ges(scorer, init, &ges_cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::{forward_sample, generate, NetGenConfig};
    use crate::graph::Dag;
    use std::sync::Arc;

    #[test]
    fn fges_learns_and_is_no_better_than_ges() {
        let bn = generate(&NetGenConfig { nodes: 14, edges: 20, ..Default::default() }, 31);
        let data = Arc::new(forward_sample(&bn, 2000, 7));
        let sc = BdeuScorer::new(data.clone(), 10.0);
        let f = fges(&sc, &Dag::new(14), &FgesConfig::default());
        let sc2 = BdeuScorer::new(data, 10.0);
        let g = ges(&sc2, &Dag::new(14), &Default::default());
        let empty = sc.score_dag(&Dag::new(14));
        assert!(f.score > empty);
        // GES with full T-search can only match or beat fGES.
        assert!(g.score >= f.score - 1e-9, "ges {} < fges {}", g.score, f.score);
    }

    #[test]
    fn frontier_recompute_is_incident_bounded() {
        // fGES's scaling claim rests on re-scoring only candidates
        // incident to version-bumped endpoints after each apply. A
        // full-rescan strategy would evaluate ~C(n,2) pairs per applied
        // operator; the incident frontier touches at most the changed
        // nodes' rows of the pair matrix. Bound the total accordingly.
        let n = 20usize;
        let bn = generate(&NetGenConfig { nodes: n, edges: 28, ..Default::default() }, 11);
        let data = Arc::new(forward_sample(&bn, 1500, 9));
        let sc = BdeuScorer::new(data, 10.0);
        let r = fges(&sc, &Dag::new(n), &FgesConfig::default());
        let all_pairs = (n * (n - 1) / 2) as u64;
        assert!(r.inserts > 0, "test needs applied operators to be meaningful");
        // Per-phase split must reconcile and both phases must have run.
        assert_eq!(r.evaluations, r.fes_evaluations + r.bes_evaluations);
        assert!(r.fes_evaluations >= all_pairs, "initial FES sweep scans all pairs");
        // A full-rescan strategy costs at least one all-pairs sweep per
        // applied operator on top of the initial one; the incident
        // frontier must land strictly inside that floor.
        let applies = (r.inserts + r.deletes) as u64;
        let full_rescan_floor = (applies + 1) * all_pairs;
        assert!(
            r.evaluations < full_rescan_floor,
            "evaluations {} ≥ full-rescan floor {} ({} applies): frontier is not incident-bounded",
            r.evaluations,
            full_rescan_floor,
            applies
        );
    }

    #[test]
    fn fges_result_exports_obs_counters() {
        // A local fges run should land in a metrics registry under the
        // same `ges.*` names the ring coordinator exports, with the
        // scorer's cache/count counters live alongside via bind_obs.
        let bn = generate(&NetGenConfig { nodes: 12, edges: 16, ..Default::default() }, 13);
        let data = Arc::new(forward_sample(&bn, 1200, 3));
        let sc = BdeuScorer::new(data, 10.0);
        let reg = crate::obs::Registry::new();
        sc.bind_obs(&reg);
        let r = fges(&sc, &Dag::new(12), &FgesConfig::default());
        r.export_obs(&reg);
        assert_eq!(reg.counter_value("ges.evaluations"), Some(r.evaluations));
        assert_eq!(
            reg.counter_value("ges.fes_evaluations").unwrap()
                + reg.counter_value("ges.bes_evaluations").unwrap(),
            r.evaluations
        );
        // The scorer counters were registered as live views: the run
        // above must have produced cache traffic without any re-export.
        let hits = reg.counter_value("score_cache.hits").unwrap_or(0);
        let misses = reg.counter_value("score_cache.misses").unwrap_or(0);
        assert!(hits + misses > 0, "bound scorer counters saw no traffic");
    }

    #[test]
    fn fges_seed_path_consistent() {
        let bn = generate(&NetGenConfig { nodes: 10, edges: 12, ..Default::default() }, 5);
        let data = Arc::new(forward_sample(&bn, 1500, 2));
        let pw = crate::score::pairwise_similarity(&data, 10.0, 2);
        let sc = BdeuScorer::new(data.clone(), 10.0);
        let seeded = fges(
            &sc,
            &Dag::new(10),
            &FgesConfig { seed: Some(Arc::new(pw.s.clone())), ..Default::default() },
        );
        let sc2 = BdeuScorer::new(data, 10.0);
        let plain = fges(&sc2, &Dag::new(10), &FgesConfig::default());
        assert!((seeded.score - plain.score).abs() < 1e-6);
    }
}
