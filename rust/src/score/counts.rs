//! Contingency-table counting: the measured hot path of every learner.
//!
//! `family_counts` computes the `N_ijk` frequencies for a (child,
//! parent-set) family. Two strategies, picked by the dense table size
//! `q·r`:
//!   * dense radix accumulation into a `Vec<u32>` when `q·r` fits a
//!     sane budget — one multiply-add per parent per row, fully
//!     branchless, streaming column-major data;
//!   * hashed sparse accumulation otherwise (large parent sets only
//!     materialize the configurations that occur, ≤ n_rows of them).

use std::collections::HashMap;

use crate::data::Dataset;

/// Max dense table cells before switching to the sparse counter
/// (8M cells = 32 MB of u32; reached only by pathological parent sets).
const DENSE_LIMIT: u64 = 8 << 20;

/// Counts for one family: per observed parent configuration `j`, the
/// child-state histogram `n[j*r..(j+1)*r]`.
pub struct FamilyCounts {
    /// Child cardinality.
    pub r: usize,
    /// Histograms: flat `(config, child_state)`; *dense* tables include
    /// all-zero configs, *sparse* only observed ones — both score
    /// identically under BDeu because zero-count configs contribute 0.
    pub table: CountsTable,
}

/// Dense or sparse count storage.
pub enum CountsTable {
    /// `counts[j * r + k]`, `q * r` cells.
    Dense(Vec<u32>),
    /// config-index -> child histogram of length `r`.
    Sparse(HashMap<u64, Vec<u32>>),
}

/// Compute family counts of `child` given `parents` over `data`.
///
/// `parents` must not contain `child`; order does not matter for the
/// score but determines the (internal) configuration encoding.
pub fn family_counts(data: &Dataset, child: usize, parents: &[usize]) -> FamilyCounts {
    let r = data.card(child) as usize;
    let m = data.n_rows();
    // Configuration strides: mixed-radix encoding over parent states.
    let mut q: u64 = 1;
    let mut strides = Vec::with_capacity(parents.len());
    for &p in parents {
        strides.push(q);
        q = q.saturating_mul(data.card(p) as u64);
    }

    let child_col = data.col(child);
    if q * r as u64 <= DENSE_LIMIT {
        let mut counts = vec![0u32; (q as usize) * r];
        match parents.len() {
            0 => {
                for t in 0..m {
                    counts[child_col[t] as usize] += 1;
                }
            }
            1 => {
                // Specialized single-parent loop: the dominant call
                // shape in GES (pairwise deltas) — keep it branch-free.
                let p0 = data.col(parents[0]);
                for t in 0..m {
                    counts[p0[t] as usize * r + child_col[t] as usize] += 1;
                }
            }
            _ => {
                let pcols: Vec<&[u8]> = parents.iter().map(|&p| data.col(p)).collect();
                for t in 0..m {
                    let mut cfg = 0u64;
                    for (s, col) in strides.iter().zip(&pcols) {
                        cfg += s * col[t] as u64;
                    }
                    counts[cfg as usize * r + child_col[t] as usize] += 1;
                }
            }
        }
        FamilyCounts { r, table: CountsTable::Dense(counts) }
    } else {
        let pcols: Vec<&[u8]> = parents.iter().map(|&p| data.col(p)).collect();
        let mut map: HashMap<u64, Vec<u32>> = HashMap::new();
        for t in 0..m {
            let mut cfg = 0u64;
            for (s, col) in strides.iter().zip(&pcols) {
                cfg += s * col[t] as u64;
            }
            map.entry(cfg).or_insert_with(|| vec![0u32; r])[child_col[t] as usize] += 1;
        }
        FamilyCounts { r, table: CountsTable::Sparse(map) }
    }
}

impl FamilyCounts {
    /// Iterate parent-configuration histograms (observed configs only
    /// for sparse tables; dense tables include empty configs, which
    /// score 0 under BDeu).
    pub fn for_each_config<F: FnMut(&[u32])>(&self, mut f: F) {
        match &self.table {
            CountsTable::Dense(v) => {
                for chunk in v.chunks_exact(self.r) {
                    f(chunk);
                }
            }
            CountsTable::Sparse(m) => {
                for hist in m.values() {
                    f(hist);
                }
            }
        }
    }

    /// Total instance count (sanity checks).
    pub fn total(&self) -> u64 {
        let mut t = 0u64;
        self.for_each_config(|h| t += h.iter().map(|&x| x as u64).sum::<u64>());
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        // X0 (card 2), X1 (card 3), X2 (card 2)
        Dataset::unnamed(
            vec![2, 3, 2],
            vec![
                vec![0, 0, 1, 1, 0, 1],
                vec![0, 1, 2, 0, 1, 1],
                vec![0, 0, 1, 1, 1, 0],
            ],
        )
    }

    #[test]
    fn no_parent_counts() {
        let d = toy();
        let fc = family_counts(&d, 0, &[]);
        match &fc.table {
            CountsTable::Dense(v) => assert_eq!(v, &vec![3, 3]),
            _ => panic!("expected dense"),
        }
        assert_eq!(fc.total(), 6);
    }

    #[test]
    fn one_parent_counts() {
        let d = toy();
        let fc = family_counts(&d, 0, &[1]);
        // configs of X1 (0,1,2) x states of X0: rows (0,0),(0,1),(1,2),(1,0),(0,1),(1,1)
        // X1=0: X0 in {0, 1} -> [1,1]; X1=1: {0,0,1} -> [2,1]; X1=2: {1} -> [0,1]
        match &fc.table {
            CountsTable::Dense(v) => assert_eq!(v, &vec![1, 1, 2, 1, 0, 1]),
            _ => panic!("expected dense"),
        }
    }

    #[test]
    fn two_parent_total_preserved() {
        let d = toy();
        let fc = family_counts(&d, 0, &[1, 2]);
        assert_eq!(fc.total(), 6);
        let mut nconfigs = 0;
        fc.for_each_config(|_| nconfigs += 1);
        assert_eq!(nconfigs, 6); // q = 3 * 2 dense configs
    }

    #[test]
    fn sparse_matches_dense_totals() {
        // Force sparse by a synthetic huge-q family: craft via many
        // parents over the toy data is impossible (q small), so check
        // the sparse path directly through a low DENSE_LIMIT simulation:
        // emulate by calling with enough parents to overflow is not
        // feasible here; instead assert the encoding invariants on the
        // dense path (sparse path is exercised in integration tests on
        // wide networks).
        let d = toy();
        let fc = family_counts(&d, 2, &[0, 1]);
        assert_eq!(fc.total(), d.n_rows() as u64);
    }
}
