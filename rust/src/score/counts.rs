//! Contingency-table counting: the measured hot path of every learner.
//!
//! Two layers:
//!
//! * [`family_counts`] / [`family_counts_with_limit`] — the **retained
//!   scalar reference**: per-row radix accumulation straight off the
//!   raw `u8` columns. Every fast path below is pinned bit-identical
//!   to it (counts are exact integers, so "bit-identical" is simply
//!   "equal tables" — and equal tables make the downstream BDeu sums
//!   `to_bits`-equal).
//! * [`Counter`] — the word-parallel engine every [`BdeuScorer`]
//!   (see `score::bdeu`) counts through. It picks per family between
//!   a **popcount path** (AND of precomputed state bit-planes from
//!   [`PackedData`], 64 rows per instruction — the zero/one/two-parent
//!   shapes that dominate GES pairwise deltas), a **row-block tiled
//!   path** (per-thread partial tables over `util::par`, reduced by
//!   integer addition — order-independent, hence deterministic),
//!   a scalar **packed-decode path**, and the reference's hashed
//!   sparse/wide fallbacks for huge parent sets.
//!
//! Table-size arithmetic is fully checked: a parent set whose mixed-
//! radix `q` overflows `u64` goes to the [`CountsTable::Wide`] counter
//! (tuple keys — `q` itself is meaningless there), and a `q` that fits
//! but whose `q·r` cell count overflows or exceeds the dense limit goes
//! to [`CountsTable::Sparse`]. Both sparse forms iterate their configs
//! in sorted order so sparse scores are `to_bits`-equal to dense ones.
//!
//! [`BdeuScorer`]: crate::score::BdeuScorer

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::data::{Dataset, PackedData};
use crate::obs;
use crate::util::par::par_map_index;

/// Max dense table cells before switching to the sparse counter
/// (8M cells = 32 MB of u32; reached only by pathological parent sets).
const DENSE_LIMIT: u64 = 8 << 20;

/// The popcount path touches `cells · words` plane words where the
/// scalar path touches `m` rows (plus decode). Engage it while
/// `cells · words ≤ POPCOUNT_ADVANTAGE · m`, i.e. while each of the
/// up-to-64-way word-parallel AND+popcounts replaces at least
/// `64 / POPCOUNT_ADVANTAGE` scalar scatter-increments.
const POPCOUNT_ADVANTAGE: u64 = 4;

/// Widest dense table the row-block tiled path will replicate per
/// thread (64K u32 = 256 KB of partials per worker).
const BLOCKED_MAX_CELLS: u64 = 1 << 16;

/// Widest dense table kept in the [`Counter`]'s contingency-table
/// cache (the count-reuse layer marginalizes these instead of
/// re-streaming data).
const TABLE_CACHE_MAX_CELLS: usize = 4096;

/// Table-cache entry cap; the cache is cleared wholesale when full
/// (families are re-countable, so eviction needs no bookkeeping).
const TABLE_CACHE_MAX_ENTRIES: usize = 8192;

/// Counts for one family: per observed parent configuration `j`, the
/// child-state histogram `n[j*r..(j+1)*r]`.
pub struct FamilyCounts {
    /// Child cardinality.
    pub r: usize,
    /// Histograms: flat `(config, child_state)`; *dense* tables include
    /// all-zero configs, *sparse* only observed ones — both score
    /// identically under BDeu because zero-count configs contribute 0.
    pub table: CountsTable,
}

/// Dense or sparse count storage.
pub enum CountsTable {
    /// `counts[j * r + k]`, `q * r` cells.
    Dense(Vec<u32>),
    /// `(config index, child histogram)`, sorted ascending by config —
    /// the same iteration order as the dense table's non-empty configs,
    /// which is what makes sparse BDeu sums `to_bits`-equal to dense.
    Sparse(Vec<(u64, Vec<u32>)>),
    /// `(parent state tuple, child histogram)` for parent sets whose
    /// mixed-radix `q` overflows `u64`; tuples are in `parents` order
    /// and sorted lexicographically (deterministic iteration).
    Wide(Vec<(Box<[u8]>, Vec<u32>)>),
}

/// Compute family counts of `child` given `parents` over `data` — the
/// scalar reference counter (see module docs).
///
/// `parents` must not contain `child`; order does not matter for the
/// score but determines the (internal) configuration encoding.
pub fn family_counts(data: &Dataset, child: usize, parents: &[usize]) -> FamilyCounts {
    family_counts_with_limit(data, child, parents, DENSE_LIMIT)
}

/// [`family_counts`] with an injectable dense-table cell limit, so
/// tests can force the sparse path on small families and pin it
/// against the dense one.
pub fn family_counts_with_limit(
    data: &Dataset,
    child: usize,
    parents: &[usize],
    dense_limit: u64,
) -> FamilyCounts {
    let r = data.card(child) as usize;
    // Configuration strides: mixed-radix encoding over parent states.
    // All products are checked — saturation must route to a hashed
    // counter, never alias distinct configs in a wrapped-size table.
    let mut q: u64 = 1;
    let mut strides = Vec::with_capacity(parents.len());
    for &p in parents {
        strides.push(q);
        match q.checked_mul(data.card(p) as u64) {
            Some(next) => q = next,
            None => return wide_counts(data, child, parents),
        }
    }
    match q.checked_mul(r as u64) {
        Some(cells) if cells <= dense_limit => {
            let counts = dense_scalar(data, child, parents, &strides, (q as usize) * r);
            FamilyCounts { r, table: CountsTable::Dense(counts) }
        }
        _ => sparse_counts(data, child, parents, &strides),
    }
}

/// Dense per-row radix accumulation off the raw byte columns.
fn dense_scalar(
    data: &Dataset,
    child: usize,
    parents: &[usize],
    strides: &[u64],
    cells: usize,
) -> Vec<u32> {
    let m = data.n_rows();
    let r = data.card(child) as usize;
    let child_col = data.col(child);
    let mut counts = vec![0u32; cells];
    match parents.len() {
        0 => {
            for t in 0..m {
                counts[child_col[t] as usize] += 1;
            }
        }
        1 => {
            // Specialized single-parent loop: the dominant call
            // shape in GES (pairwise deltas) — keep it branch-free.
            let p0 = data.col(parents[0]);
            for t in 0..m {
                counts[p0[t] as usize * r + child_col[t] as usize] += 1;
            }
        }
        _ => {
            let pcols: Vec<&[u8]> = parents.iter().map(|&p| data.col(p)).collect();
            for t in 0..m {
                let mut cfg = 0u64;
                for (s, col) in strides.iter().zip(&pcols) {
                    cfg += s * col[t] as u64;
                }
                counts[cfg as usize * r + child_col[t] as usize] += 1;
            }
        }
    }
    counts
}

/// Hashed sparse counter (config fits `u64`, table would not): only
/// observed configurations materialize, sorted ascending afterwards.
fn sparse_counts(
    data: &Dataset,
    child: usize,
    parents: &[usize],
    strides: &[u64],
) -> FamilyCounts {
    let m = data.n_rows();
    let r = data.card(child) as usize;
    let child_col = data.col(child);
    let pcols: Vec<&[u8]> = parents.iter().map(|&p| data.col(p)).collect();
    let mut map: HashMap<u64, Vec<u32>> = HashMap::new();
    for t in 0..m {
        let mut cfg = 0u64;
        for (s, col) in strides.iter().zip(&pcols) {
            cfg += s * col[t] as u64;
        }
        map.entry(cfg).or_insert_with(|| vec![0u32; r])[child_col[t] as usize] += 1;
    }
    let mut entries: Vec<(u64, Vec<u32>)> = map.into_iter().collect();
    entries.sort_unstable_by_key(|&(cfg, _)| cfg);
    FamilyCounts { r, table: CountsTable::Sparse(entries) }
}

/// Tuple-keyed counter for parent sets whose `q` overflows `u64`: the
/// key is the raw parent-state tuple (one byte per parent, in
/// `parents` order), sorted lexicographically afterwards.
fn wide_counts(data: &Dataset, child: usize, parents: &[usize]) -> FamilyCounts {
    let m = data.n_rows();
    let r = data.card(child) as usize;
    let child_col = data.col(child);
    let pcols: Vec<&[u8]> = parents.iter().map(|&p| data.col(p)).collect();
    let mut map: HashMap<Box<[u8]>, Vec<u32>> = HashMap::new();
    let mut key = vec![0u8; parents.len()];
    for t in 0..m {
        for (slot, col) in key.iter_mut().zip(&pcols) {
            *slot = col[t];
        }
        // Probe by slice (Box<[u8]>: Borrow<[u8]>) so only the first
        // occurrence of a tuple allocates a key.
        match map.get_mut(key.as_slice()) {
            Some(hist) => hist[child_col[t] as usize] += 1,
            None => {
                let mut hist = vec![0u32; r];
                hist[child_col[t] as usize] += 1;
                map.insert(key.clone().into_boxed_slice(), hist);
            }
        }
    }
    let mut entries: Vec<(Box<[u8]>, Vec<u32>)> = map.into_iter().collect();
    entries.sort_unstable_by(|a, b| a.0.cmp(&b.0));
    FamilyCounts { r, table: CountsTable::Wide(entries) }
}

impl FamilyCounts {
    /// Iterate parent-configuration histograms (observed configs only
    /// for sparse tables; dense tables include empty configs, which
    /// score 0 under BDeu). Sparse/wide iteration is in sorted config
    /// order — the same order as the dense table's non-empty configs.
    pub fn for_each_config<F: FnMut(&[u32])>(&self, mut f: F) {
        match &self.table {
            CountsTable::Dense(v) => {
                for chunk in v.chunks_exact(self.r) {
                    f(chunk);
                }
            }
            CountsTable::Sparse(entries) => {
                for (_, hist) in entries {
                    f(hist);
                }
            }
            CountsTable::Wide(entries) => {
                for (_, hist) in entries {
                    f(hist);
                }
            }
        }
    }

    /// Total instance count (sanity checks).
    pub fn total(&self) -> u64 {
        let mut t = 0u64;
        self.for_each_config(|h| t += h.iter().map(|&x| x as u64).sum::<u64>());
        t
    }
}

// =====================================================================
// The word-parallel counting engine.
// =====================================================================

/// Which counting implementation a [`Counter`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CountMode {
    /// Packed fast paths (popcount / row-block tiled / packed-decode);
    /// results are identical to `Reference` by construction.
    Packed,
    /// Delegate every family to the scalar reference counter — the
    /// pinning oracle and perf baseline.
    Reference,
}

/// [`Counter`] configuration. Thresholds are injectable so tests can
/// force each path on small data.
#[derive(Clone, Debug)]
pub struct CountConfig {
    pub mode: CountMode,
    /// Max dense-table cells before the sparse counter takes over.
    pub dense_limit: u64,
    /// Popcount-path gate: max dense cells (the triple loop over
    /// plane pairs is quadratic in cells) — combined with the
    /// [`POPCOUNT_ADVANTAGE`] work-ratio test.
    pub popcount_max_cells: u64,
    /// Minimum rows before the row-block tiled parallel path engages
    /// (below it, thread spawn costs more than the count).
    pub par_rows: usize,
    /// Workers for the row-block tiled path.
    pub par_threads: usize,
}

impl Default for CountConfig {
    fn default() -> Self {
        CountConfig {
            mode: CountMode::Packed,
            dense_limit: DENSE_LIMIT,
            popcount_max_cells: 256,
            par_rows: 1 << 16,
            par_threads: crate::util::num_threads().min(8),
        }
    }
}

impl CountConfig {
    /// Reference-mode config (scalar counter for every family).
    pub fn reference() -> Self {
        CountConfig { mode: CountMode::Reference, ..Default::default() }
    }
}

/// Families counted per strategy plus count-reuse stats — atomic
/// [`obs::Counter`]s so concurrent scoring threads tick them lock-free
/// and a metrics registry can adopt the live handles.
#[derive(Default)]
pub struct CountStats {
    popcount: obs::Counter,
    blocked: obs::Counter,
    dense: obs::Counter,
    sparse: obs::Counter,
    derived: obs::Counter,
    table_hits: obs::Counter,
    table_misses: obs::Counter,
}

impl CountStats {
    /// Register the live path counters under `counts.*`.
    pub fn bind_obs(&self, reg: &obs::Registry) {
        reg.register_counter("counts.popcount", &self.popcount);
        reg.register_counter("counts.blocked", &self.blocked);
        reg.register_counter("counts.dense", &self.dense);
        reg.register_counter("counts.sparse", &self.sparse);
        reg.register_counter("counts.derived", &self.derived);
        reg.register_counter("counts.table_hits", &self.table_hits);
        reg.register_counter("counts.table_misses", &self.table_misses);
    }
}

/// Plain-integer snapshot of [`CountStats`] (telemetry / benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct CountSnapshot {
    /// Families counted via bit-plane popcounts.
    pub popcount: u64,
    /// Families counted via row-block tiled partial tables.
    pub blocked: u64,
    /// Families counted via the scalar dense path (packed decode in
    /// `Packed` mode, raw bytes in `Reference` mode).
    pub dense: u64,
    /// Families counted via a hashed (sparse or wide) counter.
    pub sparse: u64,
    /// Subset-family histograms derived by marginalizing a cached
    /// superset table instead of re-streaming data.
    pub derived: u64,
    /// Contingency-table cache hits / misses (count-reuse layer).
    pub table_hits: u64,
    pub table_misses: u64,
}

/// Table-cache key: `(child, sorted parents)`.
type TableKey = (u32, Vec<u32>);

/// The counting engine one scorer (and all its clones) shares: the
/// packed view of the dataset, the path-selection config, stats, and
/// the small dense contingency-table cache behind the count-reuse
/// layer.
pub struct Counter {
    data: Arc<Dataset>,
    packed: PackedData,
    cfg: CountConfig,
    stats: CountStats,
    tables: Mutex<HashMap<TableKey, Arc<Vec<u32>>>>,
}

impl Counter {
    /// Pack `data` and build an engine with `cfg`.
    pub fn new(data: Arc<Dataset>, cfg: CountConfig) -> Counter {
        let packed = PackedData::pack(&data);
        Counter { data, packed, cfg, stats: CountStats::default(), tables: Mutex::new(HashMap::new()) }
    }

    /// The engine's configuration.
    pub fn config(&self) -> &CountConfig {
        &self.cfg
    }

    /// The dataset this engine counts over.
    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// Current path/reuse counters — a thin view over the same
    /// [`obs`] counters that [`Counter::bind_obs`] registers.
    pub fn stats(&self) -> CountSnapshot {
        CountSnapshot {
            popcount: self.stats.popcount.get(),
            blocked: self.stats.blocked.get(),
            dense: self.stats.dense.get(),
            sparse: self.stats.sparse.get(),
            derived: self.stats.derived.get(),
            table_hits: self.stats.table_hits.get(),
            table_misses: self.stats.table_misses.get(),
        }
    }

    /// Register this engine's live path counters with a registry.
    pub fn bind_obs(&self, reg: &obs::Registry) {
        self.stats.bind_obs(reg);
    }

    /// Dense-table cell count of the family, `None` when the family is
    /// not dense under this config (product overflow or past the
    /// limit). The single density predicate shared by the engine and
    /// the count-reuse layer, so they can never disagree.
    pub fn dense_cells(&self, child: usize, parents: &[usize]) -> Option<u64> {
        let mut q: u64 = 1;
        for &p in parents {
            q = q.checked_mul(self.data.card(p) as u64)?;
        }
        let cells = q.checked_mul(self.data.card(child) as u64)?;
        (cells <= self.cfg.dense_limit).then_some(cells)
    }

    /// Count the family through the engine's fast paths (or the
    /// reference, per [`CountConfig::mode`]). Identical tables to
    /// [`family_counts_with_limit`] on every input.
    pub fn family_counts(&self, child: usize, parents: &[usize]) -> FamilyCounts {
        if self.cfg.mode == CountMode::Reference {
            let fc = family_counts_with_limit(&self.data, child, parents, self.cfg.dense_limit);
            match fc.table {
                CountsTable::Dense(_) => self.stats.dense.inc(),
                _ => self.stats.sparse.inc(),
            };
            return fc;
        }
        let Some(cells) = self.dense_cells(child, parents) else {
            self.stats.sparse.inc();
            return family_counts_with_limit(&self.data, child, parents, self.cfg.dense_limit);
        };
        let r = self.data.card(child) as usize;
        let m = self.packed.n_rows();
        let counts = if self.popcount_eligible(child, parents, cells, m) {
            self.stats.popcount.inc();
            self.popcount_table(child, parents, cells as usize)
        } else if m >= self.cfg.par_rows && self.cfg.par_threads > 1 && cells <= BLOCKED_MAX_CELLS
        {
            self.stats.blocked.inc();
            self.blocked_table(child, parents, cells as usize)
        } else {
            self.stats.dense.inc();
            self.decode_range(child, parents, cells as usize, 0, m)
        };
        FamilyCounts { r, table: CountsTable::Dense(counts) }
    }

    /// Dense table of the family through the bounded contingency-table
    /// cache. Caller must have checked [`Counter::dense_cells`].
    pub fn dense_table(&self, child: usize, parents: &[usize]) -> Arc<Vec<u32>> {
        let key: TableKey = (child as u32, parents.iter().map(|&p| p as u32).collect());
        debug_assert!(key.1.windows(2).all(|w| w[0] < w[1]));
        if let Some(t) = self.tables.lock().expect("table cache poisoned").get(&key) {
            self.stats.table_hits.inc();
            return t.clone();
        }
        self.stats.table_misses.inc();
        let fc = self.family_counts(child, parents);
        let counts = match fc.table {
            CountsTable::Dense(v) => Arc::new(v),
            _ => unreachable!("dense_table caller must check dense_cells first"),
        };
        if counts.len() <= TABLE_CACHE_MAX_CELLS {
            let mut guard = self.tables.lock().expect("table cache poisoned");
            if guard.len() >= TABLE_CACHE_MAX_ENTRIES {
                guard.clear();
            }
            guard.insert(key, counts.clone());
        }
        counts
    }

    /// Marginalize parent `sup_cards[pos]` out of a dense superset
    /// table: the count-reuse layer's subset derivation. `sup` is laid
    /// out `cfg * r + k` with mixed-radix `cfg` over `sup_cards`
    /// (ascending strides); the result is the identical integer table a
    /// direct count of the reduced family would produce.
    pub fn derive_marginal(
        &self,
        sup: &[u32],
        r: usize,
        sup_cards: &[usize],
        pos: usize,
    ) -> Vec<u32> {
        self.stats.derived.inc();
        let cx = sup_cards[pos];
        // Configs below / above the removed digit.
        let low: usize = sup_cards[..pos].iter().product();
        let q_sup = sup.len() / r;
        let high = q_sup / (low * cx);
        let mut base = vec![0u32; (q_sup / cx) * r];
        // sup cfg = hi·(low·cx) + xs·low + lo  →  base cfg = hi·low + lo;
        // (lo, k) cells are contiguous, so each transfer is one slice add.
        let block = low * r;
        for hi in 0..high {
            let dst = &mut base[hi * block..(hi + 1) * block];
            for xs in 0..cx {
                let off = (hi * cx + xs) * block;
                for (d, s) in dst.iter_mut().zip(&sup[off..off + block]) {
                    *d += s;
                }
            }
        }
        base
    }

    /// Popcount-path gate: planes for every involved column, small
    /// table, and the word-work bounded by the scalar row-work.
    fn popcount_eligible(&self, child: usize, parents: &[usize], cells: u64, m: usize) -> bool {
        if parents.len() > 2 || cells > self.cfg.popcount_max_cells {
            return false;
        }
        if self.packed.col(child).planes().is_none()
            || parents.iter().any(|&p| self.packed.col(p).planes().is_none())
        {
            return false;
        }
        cells.saturating_mul(self.packed.words() as u64) <= POPCOUNT_ADVANTAGE * m as u64
    }

    /// Count via AND + popcount over state bit-planes (≤ 2 parents).
    fn popcount_table(&self, child: usize, parents: &[usize], cells: usize) -> Vec<u32> {
        let child_planes = self.packed.col(child).planes().expect("gate checked planes");
        let r = child_planes.len();
        let mut counts = vec![0u32; cells];
        match parents {
            [] => {
                for (k, ck) in child_planes.iter().enumerate() {
                    counts[k] = ck.iter().map(|w| w.count_ones()).sum();
                }
            }
            [p] => {
                let pp = self.packed.col(*p).planes().expect("gate checked planes");
                for (j, pj) in pp.iter().enumerate() {
                    for (k, ck) in child_planes.iter().enumerate() {
                        counts[j * r + k] =
                            pj.iter().zip(ck).map(|(a, b)| (a & b).count_ones()).sum();
                    }
                }
            }
            [p0, p1] => {
                let pl0 = self.packed.col(*p0).planes().expect("gate checked planes");
                let pl1 = self.packed.col(*p1).planes().expect("gate checked planes");
                let c0 = pl0.len();
                let mut and01 = vec![0u64; self.packed.words()];
                for (j1, pj1) in pl1.iter().enumerate() {
                    for (j0, pj0) in pl0.iter().enumerate() {
                        for ((w, a), b) in and01.iter_mut().zip(pj0).zip(pj1) {
                            *w = a & b;
                        }
                        let row = (j1 * c0 + j0) * r;
                        for (k, ck) in child_planes.iter().enumerate() {
                            counts[row + k] =
                                and01.iter().zip(ck).map(|(a, b)| (a & b).count_ones()).sum();
                        }
                    }
                }
            }
            _ => unreachable!("popcount gate admits at most 2 parents"),
        }
        counts
    }

    /// Row-block tiled counting: static row chunks, one partial table
    /// per worker, reduced by integer addition (order-independent, so
    /// the result is deterministic regardless of thread scheduling).
    fn blocked_table(&self, child: usize, parents: &[usize], cells: usize) -> Vec<u32> {
        let m = self.packed.n_rows();
        let threads = self.cfg.par_threads;
        let chunk = m.div_ceil(threads).max(1);
        let n_chunks = m.div_ceil(chunk);
        let partials = par_map_index(n_chunks, threads, |i| {
            let lo = i * chunk;
            let hi = (lo + chunk).min(m);
            self.decode_range(child, parents, cells, lo, hi)
        });
        let mut counts = vec![0u32; cells];
        for partial in partials {
            for (c, p) in counts.iter_mut().zip(&partial) {
                *c += p;
            }
        }
        counts
    }

    /// Scalar dense counting over rows `lo..hi`, decoding states from
    /// the packed codes (shift + mask instead of byte loads).
    fn decode_range(
        &self,
        child: usize,
        parents: &[usize],
        cells: usize,
        lo: usize,
        hi: usize,
    ) -> Vec<u32> {
        let cc = self.packed.col(child);
        let r = self.data.card(child) as usize;
        let mut counts = vec![0u32; cells];
        match parents.len() {
            0 => {
                for t in lo..hi {
                    counts[cc.code(t)] += 1;
                }
            }
            1 => {
                let p0 = self.packed.col(parents[0]);
                for t in lo..hi {
                    counts[p0.code(t) * r + cc.code(t)] += 1;
                }
            }
            _ => {
                let pcols: Vec<&crate::data::PackedCol> =
                    parents.iter().map(|&p| self.packed.col(p)).collect();
                let mut strides = Vec::with_capacity(parents.len());
                let mut s = 1usize;
                for pc in &pcols {
                    strides.push(s);
                    s *= pc.card() as usize;
                }
                for t in lo..hi {
                    let mut cfg = 0usize;
                    for (s, pc) in strides.iter().zip(&pcols) {
                        cfg += s * pc.code(t);
                    }
                    counts[cfg * r + cc.code(t)] += 1;
                }
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn toy() -> Dataset {
        // X0 (card 2), X1 (card 3), X2 (card 2)
        Dataset::unnamed(
            vec![2, 3, 2],
            vec![
                vec![0, 0, 1, 1, 0, 1],
                vec![0, 1, 2, 0, 1, 1],
                vec![0, 0, 1, 1, 1, 0],
            ],
        )
    }

    /// Dataset of `cols` columns with the given cardinality whose
    /// states only use `used` values — lets a family's *declared* q
    /// blow up while the data stays tiny.
    fn wide_decl(cols: usize, card: u32, used: u32, rows: usize) -> Dataset {
        let mut rng = Rng::new(7);
        let data = (0..cols)
            .map(|_| (0..rows).map(|_| rng.gen_range(used as usize) as u8).collect())
            .collect();
        Dataset::unnamed(vec![card; cols], data)
    }

    #[test]
    fn no_parent_counts() {
        let d = toy();
        let fc = family_counts(&d, 0, &[]);
        match &fc.table {
            CountsTable::Dense(v) => assert_eq!(v, &vec![3, 3]),
            _ => panic!("expected dense"),
        }
        assert_eq!(fc.total(), 6);
    }

    #[test]
    fn one_parent_counts() {
        let d = toy();
        let fc = family_counts(&d, 0, &[1]);
        // configs of X1 (0,1,2) x states of X0: rows (0,0),(0,1),(1,2),(1,0),(0,1),(1,1)
        // X1=0: X0 in {0, 1} -> [1,1]; X1=1: {0,0,1} -> [2,1]; X1=2: {1} -> [0,1]
        match &fc.table {
            CountsTable::Dense(v) => assert_eq!(v, &vec![1, 1, 2, 1, 0, 1]),
            _ => panic!("expected dense"),
        }
    }

    #[test]
    fn two_parent_total_preserved() {
        let d = toy();
        let fc = family_counts(&d, 0, &[1, 2]);
        assert_eq!(fc.total(), 6);
        let mut nconfigs = 0;
        fc.for_each_config(|_| nconfigs += 1);
        assert_eq!(nconfigs, 6); // q = 3 * 2 dense configs
    }

    #[test]
    fn injectable_limit_forces_sorted_sparse() {
        let d = toy();
        let dense = family_counts(&d, 0, &[1, 2]);
        let sparse = family_counts_with_limit(&d, 0, &[1, 2], 1);
        let CountsTable::Sparse(entries) = &sparse.table else {
            panic!("limit 1 must force sparse");
        };
        assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "sparse configs must be sorted");
        assert_eq!(sparse.total(), dense.total());
        // Sparse histograms = the dense table's non-empty configs, in order.
        let CountsTable::Dense(dv) = &dense.table else { unreachable!() };
        let dense_nonempty: Vec<&[u32]> = dv
            .chunks_exact(dense.r)
            .filter(|h| h.iter().any(|&x| x > 0))
            .collect();
        let sparse_hists: Vec<&[u32]> = entries.iter().map(|(_, h)| h.as_slice()).collect();
        assert_eq!(dense_nonempty, sparse_hists);
    }

    #[test]
    fn overflowing_cells_route_to_sparse() {
        // q = 64^10 = 2^60 fits u64, but q·r = 2^60 · 64 = 2^66
        // overflows — must go sparse, not alias in a wrapped table.
        let d = wide_decl(11, 64, 2, 40);
        let parents: Vec<usize> = (1..11).collect();
        let fc = family_counts(&d, 0, &parents);
        assert!(matches!(fc.table, CountsTable::Sparse(_)), "2^64-cell family must be sparse");
        assert_eq!(fc.total(), 40);
    }

    #[test]
    fn overflowing_q_routes_to_wide() {
        // q = 64^11 = 2^66 overflows u64 itself — tuple-keyed counter.
        let d = wide_decl(12, 64, 2, 40);
        let parents: Vec<usize> = (1..12).collect();
        let fc = family_counts(&d, 0, &parents);
        let CountsTable::Wide(entries) = &fc.table else {
            panic!("q-overflow family must use the wide counter");
        };
        assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "wide tuples must be sorted");
        assert_eq!(fc.total(), 40);
        let mut nconfigs = 0;
        fc.for_each_config(|_| nconfigs += 1);
        assert!(nconfigs <= 40, "at most one config per row");
    }

    #[test]
    fn engine_paths_match_reference_on_toy() {
        let d = Arc::new(toy());
        // Defaults (popcount eligible: all cards ≤ 8, tiny tables) and
        // a forced row-block tiled engine.
        let popcnt = Counter::new(d.clone(), CountConfig::default());
        let tiled = Counter::new(
            d.clone(),
            CountConfig { par_rows: 1, par_threads: 3, ..Default::default() },
        );
        for parents in [vec![], vec![1], vec![1, 2]] {
            let reference = family_counts(&d, 0, &parents);
            for eng in [&popcnt, &tiled] {
                let fc = eng.family_counts(0, &parents);
                let (CountsTable::Dense(a), CountsTable::Dense(b)) =
                    (&fc.table, &reference.table)
                else {
                    panic!("toy families are dense");
                };
                assert_eq!(a, b, "parents {parents:?}");
            }
        }
        assert!(popcnt.stats().popcount >= 2, "0/1-parent families must take the popcount path");
        assert!(tiled.stats().blocked >= 1, "par_rows=1 must engage the tiled path");
    }

    #[test]
    fn derive_marginal_matches_direct_count() {
        let d = Arc::new(toy());
        let eng = Counter::new(d.clone(), CountConfig::default());
        // Superset family 0 | {1, 2}; marginalize out each parent.
        let sup = match eng.family_counts(0, &[1, 2]).table {
            CountsTable::Dense(v) => v,
            _ => unreachable!(),
        };
        let sup_cards = [3usize, 2];
        for (pos, remaining) in [(0usize, vec![2usize]), (1, vec![1])] {
            let derived = eng.derive_marginal(&sup, 2, &sup_cards, pos);
            let direct = match family_counts(&d, 0, &remaining).table {
                CountsTable::Dense(v) => v,
                _ => unreachable!(),
            };
            assert_eq!(derived, direct, "marginalizing out digit {pos}");
        }
        assert_eq!(eng.stats().derived, 2);
    }

    #[test]
    fn dense_table_cache_hits_and_reuses() {
        let d = Arc::new(toy());
        let eng = Counter::new(d, CountConfig::default());
        assert!(eng.dense_cells(0, &[1, 2]).is_some());
        let a = eng.dense_table(0, &[1, 2]);
        let b = eng.dense_table(0, &[1, 2]);
        assert_eq!(a, b);
        let s = eng.stats();
        assert_eq!((s.table_hits, s.table_misses), (1, 1));
    }
}
