//! BDeu (Bayesian Dirichlet equivalent uniform) scorer — Eq. 3 of the
//! paper, with uniform structure prior (log P(G) = 0, constant across
//! candidates so it cancels in every comparison the search makes).
//!
//! Decomposable: the network score is the sum of per-family local
//! scores; all learners only ever ask for local scores and deltas.
//!
//! Counting runs through the word-parallel [`Counter`] engine
//! (`score::counts`); [`BdeuScorer::local_pair`] adds the count-reuse
//! layer on top — an Insert/Delete delta scores `child` under both
//! `base ∪ {x}` and `base`, and the `base` histogram is a marginal of
//! the `base ∪ {x}` contingency table, so one data pass (plus one
//! in-cache marginalization) serves both scores. All fast paths
//! produce bit-identical scores to the scalar reference because the
//! integer count tables are identical and the float operations run in
//! the same order (see [`bdeu_family_score`]).

use std::sync::Arc;

use crate::data::Dataset;
use crate::graph::Dag;
use crate::score::cache::ScoreCache;
use crate::score::counts::{CountConfig, CountMode, CountSnapshot, Counter, CountsTable, FamilyCounts};
use crate::score::lgamma::ln_gamma;

/// Probe-path inline capacity: parent sets up to this size are sorted
/// and deduplicated in stack buffers, so [`BdeuScorer::local`] and
/// [`BdeuScorer::local_pair`] reach the cache without touching the
/// heap. Wider sets (never seen under realistic `max_parents`) fall
/// back to `Vec`s.
const PROBE_INLINE: usize = 16;

/// BDeu scorer bound to one dataset. Cheap to clone (shares the cache
/// and the counting engine).
#[derive(Clone)]
pub struct BdeuScorer {
    data: Arc<Dataset>,
    ess: f64,
    cache: Arc<ScoreCache>,
    counter: Arc<Counter>,
}

impl BdeuScorer {
    /// Scorer with equivalent sample size `ess` (the paper's η).
    pub fn new(data: Arc<Dataset>, ess: f64) -> Self {
        Self::with_parts(data, ess, Arc::new(ScoreCache::new()), CountConfig::default())
    }

    /// Scorer sharing an existing cache (ring workers share one).
    pub fn with_cache(data: Arc<Dataset>, ess: f64, cache: Arc<ScoreCache>) -> Self {
        Self::with_parts(data, ess, cache, CountConfig::default())
    }

    /// Scorer with an explicit counting configuration (fresh cache).
    pub fn with_count_config(data: Arc<Dataset>, ess: f64, cfg: CountConfig) -> Self {
        Self::with_parts(data, ess, Arc::new(ScoreCache::new()), cfg)
    }

    /// Fully explicit constructor: shared cache + counting config.
    pub fn with_parts(
        data: Arc<Dataset>,
        ess: f64,
        cache: Arc<ScoreCache>,
        count_cfg: CountConfig,
    ) -> Self {
        let counter = Arc::new(Counter::new(data.clone(), count_cfg));
        BdeuScorer { data, ess, cache, counter }
    }

    /// The dataset this scorer is bound to.
    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// Equivalent sample size η.
    pub fn ess(&self) -> f64 {
        self.ess
    }

    /// Shared cache handle.
    pub fn cache(&self) -> &Arc<ScoreCache> {
        &self.cache
    }

    /// The counting engine (shared across clones).
    pub fn counter(&self) -> &Arc<Counter> {
        &self.counter
    }

    /// Counting-path statistics snapshot (telemetry / benches).
    pub fn count_stats(&self) -> CountSnapshot {
        self.counter.stats()
    }

    /// Register this scorer's live score-cache and counting-path
    /// counters with a metrics registry.
    pub fn bind_obs(&self, reg: &crate::obs::Registry) {
        self.cache.bind_obs(reg);
        self.counter.bind_obs(reg);
    }

    /// Local BDeu score of `child` with parent set `parents`
    /// (any order; deduplicated by sorting). Cached. Allocation-free
    /// up to the cache probe for ≤ [`PROBE_INLINE`] parents.
    pub fn local(&self, child: usize, parents: &[usize]) -> f64 {
        if parents.len() <= PROBE_INLINE {
            let mut buf = [0u32; PROBE_INLINE];
            for (slot, &p) in buf.iter_mut().zip(parents) {
                *slot = p as u32;
            }
            let len = sort_dedup(&mut buf[..parents.len()]);
            self.local_sorted(child, &buf[..len])
        } else {
            let mut ps: Vec<u32> = parents.iter().map(|&p| p as u32).collect();
            ps.sort_unstable();
            ps.dedup();
            self.local_sorted(child, &ps)
        }
    }

    /// Both halves of an operator delta in one probe: the local scores
    /// of `child` under `others ∪ {x}` and under `others` (order-free;
    /// `x` must not be in `others`). Returns `(with_x, without_x)`.
    ///
    /// When both families miss the cache and the superset family is
    /// dense, the engine counts the superset table **once** and derives
    /// the base histogram by marginalizing `x` out — bit-identical to
    /// two independent counts (the marginal of an exact contingency
    /// table *is* the exact reduced table) at roughly half the cost.
    pub fn local_pair(&self, child: usize, others: &[usize], x: usize) -> (f64, f64) {
        debug_assert!(!others.contains(&x));
        if others.len() + 1 > PROBE_INLINE {
            // Families this wide never pass the dense gate anyway.
            let mut with_x: Vec<usize> = others.to_vec();
            with_x.push(x);
            return (self.local(child, &with_x), self.local(child, others));
        }
        let mut base_buf = [0u32; PROBE_INLINE];
        for (slot, &p) in base_buf.iter_mut().zip(others) {
            *slot = p as u32;
        }
        let blen = sort_dedup(&mut base_buf[..others.len()]);
        let base = &base_buf[..blen];
        // Superset key: `base` with `x` spliced in at its sorted slot.
        let xv = x as u32;
        let pos = base.partition_point(|&p| p < xv);
        let mut sup_buf = [0u32; PROBE_INLINE];
        sup_buf[..pos].copy_from_slice(&base[..pos]);
        sup_buf[pos] = xv;
        sup_buf[pos + 1..=blen].copy_from_slice(&base[pos..]);
        let sup = &sup_buf[..blen + 1];

        let cached_sup = self.cache.get(child as u32, sup);
        let cached_base = self.cache.get(child as u32, base);
        if let (Some(s), Some(b)) = (cached_sup, cached_base) {
            return (s, b);
        }
        self.pair_uncached(child, base, sup, pos, cached_sup, cached_base)
    }

    /// Cold half of [`BdeuScorer::local_pair`]: count once, score both.
    fn pair_uncached(
        &self,
        child: usize,
        base: &[u32],
        sup: &[u32],
        pos: usize,
        cached_sup: Option<f64>,
        cached_base: Option<f64>,
    ) -> (f64, f64) {
        let sup_usize: Vec<usize> = sup.iter().map(|&p| p as usize).collect();
        let fused = self.counter.config().mode == CountMode::Packed
            && self.counter.dense_cells(child, &sup_usize).is_some();
        if !fused {
            let s = cached_sup.unwrap_or_else(|| self.compute_and_put(child, sup));
            let b = cached_base.unwrap_or_else(|| self.compute_and_put(child, base));
            return (s, b);
        }
        let r = self.data.card(child) as usize;
        let table = self.counter.dense_table(child, &sup_usize);
        let s = match cached_sup {
            Some(s) => s,
            None => {
                // Same table, same q product order (sorted), same score
                // function as a direct `local` — hence the same bits.
                let q: f64 = sup_usize.iter().map(|&p| self.data.card(p) as f64).product();
                let s = bdeu_dense_score(&table, r, q, self.ess);
                self.cache.put(child as u32, sup, s);
                s
            }
        };
        let b = match cached_base {
            Some(b) => b,
            None => {
                let sup_cards: Vec<usize> =
                    sup_usize.iter().map(|&p| self.data.card(p) as usize).collect();
                let base_table = self.counter.derive_marginal(&table, r, &sup_cards, pos);
                let q: f64 = base.iter().map(|&p| self.data.card(p as usize) as f64).product();
                let b = bdeu_dense_score(&base_table, r, q, self.ess);
                self.cache.put(child as u32, base, b);
                b
            }
        };
        (s, b)
    }

    /// Probe/compute with an already sorted, deduplicated parent set.
    fn local_sorted(&self, child: usize, ps: &[u32]) -> f64 {
        debug_assert!(!ps.contains(&(child as u32)));
        if let Some(s) = self.cache.get(child as u32, ps) {
            return s;
        }
        self.compute_and_put(child, ps)
    }

    fn compute_and_put(&self, child: usize, ps: &[u32]) -> f64 {
        let parents_usize: Vec<usize> = ps.iter().map(|&p| p as usize).collect();
        let s = self.local_uncached(child, &parents_usize);
        self.cache.put(child as u32, ps, s);
        s
    }

    /// Score without touching the cache (used by benches to measure the
    /// raw counting path).
    pub fn local_uncached(&self, child: usize, parents: &[usize]) -> f64 {
        let counts = self.counter.family_counts(child, parents);
        let q: f64 = parents.iter().map(|&p| self.data.card(p) as f64).product();
        bdeu_family_score(&counts, q, self.ess)
    }

    /// Delta of swapping `child`'s parent set `from` -> `to`.
    pub fn delta(&self, child: usize, from: &[usize], to: &[usize]) -> f64 {
        self.local(child, to) - self.local(child, from)
    }

    /// Decomposed score of a full DAG.
    pub fn score_dag(&self, g: &Dag) -> f64 {
        (0..g.n())
            .map(|v| {
                let pa: Vec<usize> = g.parents(v).iter().collect();
                self.local(v, &pa)
            })
            .sum()
    }

    /// Paper's table normalization: global score / n_rows.
    pub fn normalized_score(&self, g: &Dag) -> f64 {
        self.score_dag(g) / self.data.n_rows() as f64
    }
}

/// Sort + dedup `buf` in place, returning the deduplicated length.
#[inline]
fn sort_dedup(buf: &mut [u32]) -> usize {
    buf.sort_unstable();
    let mut w = 0;
    for i in 0..buf.len() {
        if w == 0 || buf[i] != buf[w - 1] {
            buf[w] = buf[i];
            w += 1;
        }
    }
    w
}

/// BDeu family score from a count table (Eq. 3 with the `q` parent-
/// configuration count passed in as an `f64` product — callers must
/// compute it over the same parent order for bit-equal results).
///
/// Dense and sparse tables produce `to_bits`-equal scores: sparse
/// tables iterate the same non-empty histograms in the same (ascending
/// config) order as a dense sweep, empty configs contribute exactly 0,
/// and both run the identical float sequence in [`accumulate_config`].
pub fn bdeu_family_score(counts: &FamilyCounts, q: f64, ess: f64) -> f64 {
    if let CountsTable::Dense(table) = &counts.table {
        return bdeu_dense_score(table, counts.r, q, ess);
    }
    let a_cfg = ess / q;
    let a_cell = ess / (q * counts.r as f64);
    let lg_cfg = ln_gamma(a_cfg);
    let lg_cell = ln_gamma(a_cell);
    let mut score = 0.0;
    counts.for_each_config(|hist| {
        accumulate_config(&mut score, hist, a_cfg, a_cell, lg_cfg, lg_cell);
    });
    score
}

/// [`bdeu_family_score`] for a raw dense table (`q·r` cells, child
/// stride `r`) — the count-reuse layer scores cached/derived tables
/// through this without wrapping them in [`FamilyCounts`].
pub fn bdeu_dense_score(table: &[u32], r: usize, q: f64, ess: f64) -> f64 {
    let a_cfg = ess / q;
    let a_cell = ess / (q * r as f64);
    let lg_cfg = ln_gamma(a_cfg);
    let lg_cell = ln_gamma(a_cell);
    let mut score = 0.0;
    for hist in table.chunks_exact(r) {
        accumulate_config(&mut score, hist, a_cfg, a_cell, lg_cfg, lg_cell);
    }
    score
}

/// One parent configuration's contribution, accumulated directly into
/// `score` — the single float sequence every scoring path shares.
#[inline]
fn accumulate_config(
    score: &mut f64,
    hist: &[u32],
    a_cfg: f64,
    a_cell: f64,
    lg_cfg: f64,
    lg_cell: f64,
) {
    let nj: u64 = hist.iter().map(|&x| x as u64).sum();
    if nj == 0 {
        return; // empty config contributes exactly 0
    }
    *score += lg_cfg - ln_gamma(nj as f64 + a_cfg);
    for &njk in hist {
        if njk > 0 {
            *score += ln_gamma(njk as f64 + a_cell) - lg_cell;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Arc<Dataset> {
        Arc::new(Dataset::unnamed(
            vec![2, 2],
            vec![vec![0, 0, 1, 1, 0, 1, 0, 0], vec![0, 0, 1, 1, 0, 1, 1, 0]],
        ))
    }

    /// Brute-force BDeu for a single family, straight from Eq. 3.
    fn naive_bdeu(data: &Dataset, child: usize, parents: &[usize], ess: f64) -> f64 {
        let r = data.card(child) as usize;
        let q: usize = parents.iter().map(|&p| data.card(p) as usize).product();
        let mut n = vec![vec![0u32; r]; q];
        for t in 0..data.n_rows() {
            let mut cfg = 0usize;
            let mut stride = 1usize;
            for &p in parents {
                cfg += stride * data.col(p)[t] as usize;
                stride *= data.card(p) as usize;
            }
            n[cfg][data.col(child)[t] as usize] += 1;
        }
        let mut s = 0.0;
        for hist in &n {
            let nj: u32 = hist.iter().sum();
            s += ln_gamma(ess / q as f64) - ln_gamma(nj as f64 + ess / q as f64);
            for &njk in hist {
                s += ln_gamma(njk as f64 + ess / (r * q) as f64)
                    - ln_gamma(ess / (r * q) as f64);
            }
        }
        s
    }

    #[test]
    fn matches_naive_formula() {
        let d = toy();
        let sc = BdeuScorer::new(d.clone(), 10.0);
        for (child, parents) in [(0usize, vec![]), (0, vec![1]), (1, vec![0])] {
            let fast = sc.local(child, &parents);
            let slow = naive_bdeu(&d, child, &parents, 10.0);
            assert!((fast - slow).abs() < 1e-10, "child {child} parents {parents:?}");
        }
    }

    #[test]
    fn score_equivalence_of_reversal() {
        // BDeu is score-equivalent: X -> Y and Y -> X score the same.
        let d = toy();
        let sc = BdeuScorer::new(d, 4.0);
        let fwd = sc.local(0, &[]) + sc.local(1, &[0]);
        let bwd = sc.local(1, &[]) + sc.local(0, &[1]);
        assert!((fwd - bwd).abs() < 1e-10);
    }

    #[test]
    fn correlated_edge_beats_empty() {
        // Columns are strongly correlated -> adding the edge must win.
        let d = toy();
        let sc = BdeuScorer::new(d, 1.0);
        assert!(sc.delta(1, &[], &[0]) > 0.0);
    }

    #[test]
    fn cache_consistency() {
        let d = toy();
        let sc = BdeuScorer::new(d, 2.0);
        let a = sc.local(1, &[0]);
        let b = sc.local(1, &[0]); // cached
        assert_eq!(a, b);
        let (h, m) = sc.cache().stats();
        assert_eq!((h, m), (1, 1));
        // Parent order must not matter.
        let d2 = Arc::new(Dataset::unnamed(
            vec![2, 2, 2],
            vec![vec![0, 1, 0, 1], vec![1, 1, 0, 0], vec![0, 1, 1, 0]],
        ));
        let sc2 = BdeuScorer::new(d2, 2.0);
        assert_eq!(sc2.local(0, &[1, 2]), sc2.local(0, &[2, 1]));
    }

    #[test]
    fn local_pair_matches_independent_locals_bitwise() {
        let d2 = Arc::new(Dataset::unnamed(
            vec![2, 3, 2, 2],
            vec![
                vec![0, 1, 0, 1, 1, 0, 0, 1],
                vec![1, 2, 0, 1, 2, 0, 1, 1],
                vec![0, 0, 1, 1, 0, 1, 1, 0],
                vec![1, 0, 1, 0, 0, 1, 0, 1],
            ],
        ));
        for (others, x) in [(vec![], 1usize), (vec![1], 2), (vec![3, 1], 2)] {
            // Fresh fused scorer vs fresh plain scorer: both cold.
            let fused = BdeuScorer::new(d2.clone(), 5.0);
            let plain = BdeuScorer::new(d2.clone(), 5.0);
            let (with_x, without_x) = fused.local_pair(0, &others, x);
            let mut sup = others.clone();
            sup.push(x);
            assert_eq!(
                with_x.to_bits(),
                plain.local(0, &sup).to_bits(),
                "with_x, others {others:?} x {x}"
            );
            assert_eq!(
                without_x.to_bits(),
                plain.local(0, &others).to_bits(),
                "without_x, others {others:?} x {x}"
            );
        }
    }

    #[test]
    fn local_pair_reuses_the_superset_table() {
        let d = toy();
        let sc = BdeuScorer::new(d, 2.0);
        let _ = sc.local_pair(0, &[], 1);
        let s = sc.count_stats();
        assert_eq!(s.derived, 1, "base score must come from a marginal, not a recount");
        // Second probe: both families cached, nothing recounted.
        let _ = sc.local_pair(0, &[], 1);
        assert_eq!(sc.count_stats().derived, 1);
    }

    #[test]
    fn reference_mode_matches_packed_bitwise() {
        let d = toy();
        let packed = BdeuScorer::new(d.clone(), 3.0);
        let reference = BdeuScorer::with_count_config(d, 3.0, CountConfig::reference());
        for (child, parents) in [(0usize, vec![]), (0, vec![1]), (1, vec![0])] {
            assert_eq!(
                packed.local(child, &parents).to_bits(),
                reference.local(child, &parents).to_bits(),
                "child {child} parents {parents:?}"
            );
        }
    }

    #[test]
    fn dag_score_decomposes() {
        let d = toy();
        let sc = BdeuScorer::new(d, 10.0);
        let g = Dag::from_edges(2, &[(0, 1)]);
        let total = sc.score_dag(&g);
        let manual = sc.local(0, &[]) + sc.local(1, &[0]);
        assert!((total - manual).abs() < 1e-12);
    }
}
