//! BDeu (Bayesian Dirichlet equivalent uniform) scorer — Eq. 3 of the
//! paper, with uniform structure prior (log P(G) = 0, constant across
//! candidates so it cancels in every comparison the search makes).
//!
//! Decomposable: the network score is the sum of per-family local
//! scores; all learners only ever ask for local scores and deltas.

use std::sync::Arc;

use crate::data::Dataset;
use crate::graph::Dag;
use crate::score::cache::ScoreCache;
use crate::score::counts::family_counts;
use crate::score::lgamma::ln_gamma;

/// BDeu scorer bound to one dataset. Cheap to clone (shares the cache).
#[derive(Clone)]
pub struct BdeuScorer {
    data: Arc<Dataset>,
    ess: f64,
    cache: Arc<ScoreCache>,
}

impl BdeuScorer {
    /// Scorer with equivalent sample size `ess` (the paper's η).
    pub fn new(data: Arc<Dataset>, ess: f64) -> Self {
        BdeuScorer { data, ess, cache: Arc::new(ScoreCache::new()) }
    }

    /// Scorer sharing an existing cache (ring workers share one).
    pub fn with_cache(data: Arc<Dataset>, ess: f64, cache: Arc<ScoreCache>) -> Self {
        BdeuScorer { data, ess, cache }
    }

    /// The dataset this scorer is bound to.
    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// Equivalent sample size η.
    pub fn ess(&self) -> f64 {
        self.ess
    }

    /// Shared cache handle.
    pub fn cache(&self) -> &Arc<ScoreCache> {
        &self.cache
    }

    /// Local BDeu score of `child` with parent set `parents`
    /// (any order; deduplicated by sorting). Cached.
    pub fn local(&self, child: usize, parents: &[usize]) -> f64 {
        let mut ps: Vec<u32> = parents.iter().map(|&p| p as u32).collect();
        ps.sort_unstable();
        ps.dedup();
        debug_assert!(!ps.contains(&(child as u32)));
        if let Some(s) = self.cache.get(child as u32, &ps) {
            return s;
        }
        let parents_usize: Vec<usize> = ps.iter().map(|&p| p as usize).collect();
        let s = self.local_uncached(child, &parents_usize);
        self.cache.put(child as u32, &ps, s);
        s
    }

    /// Score without touching the cache (used by benches to measure the
    /// raw counting path).
    pub fn local_uncached(&self, child: usize, parents: &[usize]) -> f64 {
        let r = self.data.card(child) as usize;
        let q: f64 = parents.iter().map(|&p| self.data.card(p) as f64).product();
        let a_cfg = self.ess / q;
        let a_cell = self.ess / (q * r as f64);

        let counts = family_counts(&self.data, child, parents);
        let lg_cfg = ln_gamma(a_cfg);
        let lg_cell = ln_gamma(a_cell);
        let mut score = 0.0;
        counts.for_each_config(|hist| {
            let nj: u64 = hist.iter().map(|&x| x as u64).sum();
            if nj == 0 {
                return; // empty config contributes exactly 0
            }
            score += lg_cfg - ln_gamma(nj as f64 + a_cfg);
            for &njk in hist {
                if njk > 0 {
                    score += ln_gamma(njk as f64 + a_cell) - lg_cell;
                }
            }
        });
        score
    }

    /// Delta of swapping `child`'s parent set `from` -> `to`.
    pub fn delta(&self, child: usize, from: &[usize], to: &[usize]) -> f64 {
        self.local(child, to) - self.local(child, from)
    }

    /// Decomposed score of a full DAG.
    pub fn score_dag(&self, g: &Dag) -> f64 {
        (0..g.n())
            .map(|v| {
                let pa: Vec<usize> = g.parents(v).iter().collect();
                self.local(v, &pa)
            })
            .sum()
    }

    /// Paper's table normalization: global score / n_rows.
    pub fn normalized_score(&self, g: &Dag) -> f64 {
        self.score_dag(g) / self.data.n_rows() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Arc<Dataset> {
        Arc::new(Dataset::unnamed(
            vec![2, 2],
            vec![vec![0, 0, 1, 1, 0, 1, 0, 0], vec![0, 0, 1, 1, 0, 1, 1, 0]],
        ))
    }

    /// Brute-force BDeu for a single family, straight from Eq. 3.
    fn naive_bdeu(data: &Dataset, child: usize, parents: &[usize], ess: f64) -> f64 {
        let r = data.card(child) as usize;
        let q: usize = parents.iter().map(|&p| data.card(p) as usize).product();
        let mut n = vec![vec![0u32; r]; q];
        for t in 0..data.n_rows() {
            let mut cfg = 0usize;
            let mut stride = 1usize;
            for &p in parents {
                cfg += stride * data.col(p)[t] as usize;
                stride *= data.card(p) as usize;
            }
            n[cfg][data.col(child)[t] as usize] += 1;
        }
        let mut s = 0.0;
        for hist in &n {
            let nj: u32 = hist.iter().sum();
            s += ln_gamma(ess / q as f64) - ln_gamma(nj as f64 + ess / q as f64);
            for &njk in hist {
                s += ln_gamma(njk as f64 + ess / (r * q) as f64)
                    - ln_gamma(ess / (r * q) as f64);
            }
        }
        s
    }

    #[test]
    fn matches_naive_formula() {
        let d = toy();
        let sc = BdeuScorer::new(d.clone(), 10.0);
        for (child, parents) in [(0usize, vec![]), (0, vec![1]), (1, vec![0])] {
            let fast = sc.local(child, &parents);
            let slow = naive_bdeu(&d, child, &parents, 10.0);
            assert!((fast - slow).abs() < 1e-10, "child {child} parents {parents:?}");
        }
    }

    #[test]
    fn score_equivalence_of_reversal() {
        // BDeu is score-equivalent: X -> Y and Y -> X score the same.
        let d = toy();
        let sc = BdeuScorer::new(d, 4.0);
        let fwd = sc.local(0, &[]) + sc.local(1, &[0]);
        let bwd = sc.local(1, &[]) + sc.local(0, &[1]);
        assert!((fwd - bwd).abs() < 1e-10);
    }

    #[test]
    fn correlated_edge_beats_empty() {
        // Columns are strongly correlated -> adding the edge must win.
        let d = toy();
        let sc = BdeuScorer::new(d, 1.0);
        assert!(sc.delta(1, &[], &[0]) > 0.0);
    }

    #[test]
    fn cache_consistency() {
        let d = toy();
        let sc = BdeuScorer::new(d, 2.0);
        let a = sc.local(1, &[0]);
        let b = sc.local(1, &[0]); // cached
        assert_eq!(a, b);
        let (h, m) = sc.cache().stats();
        assert_eq!((h, m), (1, 1));
        // Parent order must not matter.
        let d2 = Arc::new(Dataset::unnamed(
            vec![2, 2, 2],
            vec![vec![0, 1, 0, 1], vec![1, 1, 0, 0], vec![0, 1, 1, 0]],
        ));
        let sc2 = BdeuScorer::new(d2, 2.0);
        assert_eq!(sc2.local(0, &[1, 2]), sc2.local(0, &[2, 1]));
    }

    #[test]
    fn dag_score_decomposes() {
        let d = toy();
        let sc = BdeuScorer::new(d, 10.0);
        let g = Dag::from_edges(2, &[(0, 1)]);
        let total = sc.score_dag(&g);
        let manual = sc.local(0, &[]) + sc.local(1, &[0]);
        assert!((total - manual).abs() < 1e-12);
    }
}
