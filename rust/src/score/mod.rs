//! Scoring substrate: BDeu (Eq. 3), contingency counting, the shared
//! concurrent score cache, and the Rust fallback of the pairwise
//! similarity artifact.

pub mod bdeu;
pub mod cache;
pub mod counts;
pub mod lgamma;
pub mod pairwise;

pub use bdeu::BdeuScorer;
pub use cache::ScoreCache;
pub use counts::{family_counts, CountsTable, FamilyCounts};
pub use lgamma::ln_gamma;
pub use pairwise::{pairwise_similarity, PairwiseScores};
