//! Scoring substrate: BDeu (Eq. 3), contingency counting, the shared
//! concurrent score cache, and the Rust fallback of the pairwise
//! similarity artifact.

pub mod bdeu;
pub mod cache;
pub mod counts;
pub mod lgamma;
pub mod pairwise;

pub use bdeu::{bdeu_dense_score, bdeu_family_score, BdeuScorer};
pub use cache::ScoreCache;
pub use counts::{
    family_counts, family_counts_with_limit, CountConfig, CountMode, CountSnapshot, Counter,
    CountsTable, FamilyCounts,
};
pub use lgamma::ln_gamma;
pub use pairwise::{pairwise_similarity, PairwiseScores};
