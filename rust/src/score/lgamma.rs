//! ln Γ via the Lanczos approximation (g = 7, n = 9 coefficients).
//!
//! `std` exposes no `lgamma` and the offline registry has no `libm`, so
//! we carry our own. Absolute error is < 1e-13 over the range BDeu
//! touches (arguments in (0, ~1e6]), far below the score deltas the
//! search discriminates (~1e-6).

const G: f64 = 7.0;
const COEF: [f64; 9] = [
    0.99999999999980993,
    676.5203681218851,
    -1259.1392167224028,
    771.32342877765313,
    -176.61502916214059,
    12.507343278686905,
    -0.13857109526572012,
    9.9843695780195716e-6,
    1.5056327351493116e-7,
];

/// Natural log of the Gamma function for `x > 0`.
pub fn ln_gamma(x: f64) -> f64 {
    debug_assert!(x > 0.0, "ln_gamma domain: x > 0, got {x}");
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integer_factorials() {
        // Γ(n) = (n-1)!
        let facts = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
        for (n, f) in facts.iter().enumerate() {
            assert!(
                (ln_gamma((n + 1) as f64) - f64::ln(*f)).abs() < 1e-12,
                "n={}",
                n + 1
            );
        }
    }

    #[test]
    fn half_integers() {
        // Γ(1/2) = √π, Γ(3/2) = √π/2
        let spi = std::f64::consts::PI.sqrt();
        assert!((ln_gamma(0.5) - spi.ln()).abs() < 1e-12);
        assert!((ln_gamma(1.5) - (spi / 2.0).ln()).abs() < 1e-12);
    }

    #[test]
    fn recurrence_holds() {
        // ln Γ(x+1) = ln Γ(x) + ln x across magnitudes.
        for &x in &[1e-3, 0.3, 1.7, 10.0, 123.456, 5000.0, 1e6] {
            let lhs = ln_gamma(x + 1.0);
            let rhs = ln_gamma(x) + x.ln();
            assert!((lhs - rhs).abs() < 1e-9 * lhs.abs().max(1.0), "x={x}");
        }
    }

    #[test]
    fn large_argument_stirling() {
        // Compare to Stirling series at large x.
        let x: f64 = 1e5;
        let stirling = (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln()
            + 1.0 / (12.0 * x);
        assert!((ln_gamma(x) - stirling).abs() < 1e-6);
    }
}
