//! Lock-striped concurrent local-score cache.
//!
//! The paper: "all the processes store the scores computed in a
//! concurrent safe data structure to avoid unnecessary calculations" —
//! this is that structure. BDeu local scores are keyed by (child,
//! sorted parent set); the cache is shared across all ring workers and
//! all GES scoring threads, so the same family is never counted twice
//! anywhere in a run.
//!
//! No dashmap offline → 64 shards of `RwLock<HashMap>` with an FxHash-
//! style mixer selecting the shard; reads (the common case late in the
//! search) take a shared lock only.

use std::collections::HashMap;
use std::sync::RwLock;

use crate::obs;

const SHARDS: usize = 64;

/// Inline capacity of a family key: parent sets beyond this spill to
/// the heap. Learned networks here have ≤3-4 parents almost always, so
/// probes are allocation-free on the hot path (§Perf: the boxed-slice
/// key showed up as ~15% malloc/free time in the ring profile).
const INLINE: usize = 6;

/// Family key: child + sorted parents, inlined when small.
#[derive(Clone, PartialEq, Eq, Hash)]
enum Key {
    Inline { child: u32, len: u8, parents: [u32; INLINE] },
    Heap { child: u32, parents: Box<[u32]> },
}

impl Key {
    #[inline]
    fn new(child: u32, parents: &[u32]) -> Key {
        if parents.len() <= INLINE {
            let mut arr = [0u32; INLINE];
            arr[..parents.len()].copy_from_slice(parents);
            Key::Inline { child, len: parents.len() as u8, parents: arr }
        } else {
            Key::Heap { child, parents: parents.into() }
        }
    }
}

/// Concurrent map from families to local scores.
pub struct ScoreCache {
    shards: Vec<RwLock<HashMap<Key, f64>>>,
    hits: obs::Counter,
    misses: obs::Counter,
}

impl Default for ScoreCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ScoreCache {
    /// Empty cache.
    pub fn new() -> Self {
        ScoreCache {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            hits: obs::Counter::new(),
            misses: obs::Counter::new(),
        }
    }

    /// Register the live hit/miss counters with a metrics registry:
    /// snapshots then read this cache's probes without copying.
    pub fn bind_obs(&self, reg: &obs::Registry) {
        reg.register_counter("score_cache.hits", &self.hits);
        reg.register_counter("score_cache.misses", &self.misses);
    }

    #[inline]
    fn shard(&self, child: u32, parents: &[u32]) -> usize {
        // FxHash-style multiply-rotate mix of child and parents.
        let mut h = 0xcbf29ce484222325u64 ^ (child as u64).wrapping_mul(0x100000001b3);
        for &p in parents {
            h = (h.rotate_left(5) ^ (p as u64)).wrapping_mul(0x517cc1b727220a95);
        }
        (h >> 56) as usize & (SHARDS - 1)
    }

    /// Lookup; `parents` must be sorted ascending. Counts a hit or a
    /// miss — probes that never lead to an insert still show up in the
    /// hit-rate.
    pub fn get(&self, child: u32, parents: &[u32]) -> Option<f64> {
        debug_assert!(parents.windows(2).all(|w| w[0] < w[1]));
        let shard = &self.shards[self.shard(child, parents)];
        let guard = shard.read().expect("cache poisoned");
        let key = Key::new(child, parents); // allocation-free for ≤ INLINE parents
        let r = guard.get(&key).copied();
        drop(guard);
        if r.is_some() {
            self.hits.inc();
        } else {
            self.misses.inc();
        }
        r
    }

    /// Insert, plain (last write wins; scores are deterministic so
    /// races are benign). No counter side effects — the preceding
    /// `get` already recorded the miss.
    pub fn put(&self, child: u32, parents: &[u32], score: f64) {
        debug_assert!(parents.windows(2).all(|w| w[0] < w[1]));
        let shard = &self.shards[self.shard(child, parents)];
        shard.write().expect("cache poisoned").insert(Key::new(child, parents), score);
    }

    /// (hits, misses) probe counters for telemetry: every `get` ticks
    /// exactly one of the two. A thin view over the same [`obs`]
    /// counters that [`ScoreCache::bind_obs`] registers.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    /// Total cached families.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().expect("cache poisoned").len()).sum()
    }

    /// True if no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let c = ScoreCache::new();
        assert_eq!(c.get(3, &[1, 2]), None);
        c.put(3, &[1, 2], -12.5);
        assert_eq!(c.get(3, &[1, 2]), Some(-12.5));
        assert_eq!(c.get(3, &[1]), None);
        assert_eq!(c.get(2, &[1, 2]), None);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn bound_registry_reads_live_counters() {
        let c = ScoreCache::new();
        let reg = crate::obs::Registry::new();
        c.bind_obs(&reg);
        c.put(1, &[0], -1.0);
        assert_eq!(c.get(1, &[0]), Some(-1.0));
        assert_eq!(c.get(2, &[]), None);
        assert_eq!(reg.counter_value("score_cache.hits"), Some(1));
        assert_eq!(reg.counter_value("score_cache.misses"), Some(1));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn concurrent_consistency() {
        let c = std::sync::Arc::new(ScoreCache::new());
        std::thread::scope(|s| {
            for t in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for i in 0..1000u32 {
                        let child = (i + t) % 50;
                        let parents = [i % 7, 7 + i % 11];
                        let score = -((child + parents[0]) as f64);
                        c.put(child, &parents, score);
                        assert_eq!(c.get(child, &parents), Some(score));
                    }
                });
            }
        });
        // Every get above follows its put: 8000 hits, zero misses —
        // `put` must not tick a counter.
        let (h, m) = c.stats();
        assert_eq!((h, m), (8000, 0));
        // Probing absent families counts misses in `get` itself.
        for i in 0..10u32 {
            assert_eq!(c.get(1000 + i, &[]), None);
        }
        let (h, m) = c.stats();
        assert_eq!((h, m), (8000, 10));
    }
}
