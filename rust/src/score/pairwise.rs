//! Rust fallback for the L1/L2 pairwise-similarity artifact.
//!
//! Computes the same `(S, empty)` pair as the AOT-compiled XLA module
//! (`python/compile/model.py::similarity_model`): S[i][j] =
//! BDeu(Xi ← Xj) − BDeu(Xi ← ∅). Used when artifacts are absent, and
//! by the test-suite to cross-validate the XLA path bit-for-bit
//! (within f32 tolerance).
//!
//! Row-parallel: each worker owns a block of child variables; the
//! single-parent contingency tables reuse `score::counts`.

use crate::data::Dataset;
use crate::score::lgamma::ln_gamma;
use crate::util::par::par_map_index;

/// Full similarity matrix + per-variable empty scores.
pub struct PairwiseScores {
    /// S[i][j]: gain of adding X_j as the sole parent of X_i.
    pub s: Vec<Vec<f64>>,
    /// Local BDeu of each variable with no parents.
    pub empty: Vec<f64>,
}

/// Compute pairwise similarities with `threads` workers.
pub fn pairwise_similarity(data: &Dataset, ess: f64, threads: usize) -> PairwiseScores {
    let n = data.n_vars();
    let empty: Vec<f64> = (0..n).map(|i| empty_score(data, i, ess)).collect();

    let s = par_map_index(n, threads, |i| {
        let mut row = vec![0.0f64; n];
        let r = data.card(i) as usize;
        let ci = data.col(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            let q = data.card(j) as usize;
            // Joint histogram (j-state major, child minor), streaming
            // both columns once.
            let mut counts = vec![0u32; q * r];
            let cj = data.col(j);
            for t in 0..data.n_rows() {
                counts[cj[t] as usize * r + ci[t] as usize] += 1;
            }
            row[j] = family_score_from_counts(&counts, r, q, ess) - empty[i];
        }
        row
    });
    PairwiseScores { s, empty }
}

/// BDeu local score from a dense (q, r) histogram.
pub fn family_score_from_counts(counts: &[u32], r: usize, q: usize, ess: f64) -> f64 {
    let a_cfg = ess / q as f64;
    let a_cell = ess / (q * r) as f64;
    let lg_cfg = ln_gamma(a_cfg);
    let lg_cell = ln_gamma(a_cell);
    let mut score = 0.0;
    for hist in counts.chunks_exact(r) {
        let nj: u64 = hist.iter().map(|&x| x as u64).sum();
        if nj == 0 {
            continue;
        }
        score += lg_cfg - ln_gamma(nj as f64 + a_cfg);
        for &njk in hist {
            if njk > 0 {
                score += ln_gamma(njk as f64 + a_cell) - lg_cell;
            }
        }
    }
    score
}

/// Per-variable empty-graph local score.
pub fn empty_score(data: &Dataset, i: usize, ess: f64) -> f64 {
    let r = data.card(i) as usize;
    let mut hist = vec![0u32; r];
    for &s in data.col(i) {
        hist[s as usize] += 1;
    }
    family_score_from_counts(&hist, r, 1, ess)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::BdeuScorer;
    use std::sync::Arc;

    fn toy(seed: u64) -> Dataset {
        use crate::rng::Rng;
        let mut rng = Rng::new(seed);
        let n = 6;
        let m = 300;
        let cards: Vec<u32> = (0..n).map(|_| 2 + rng.gen_range(3) as u32).collect();
        let mut cols: Vec<Vec<u8>> = cards
            .iter()
            .map(|&c| (0..m).map(|_| rng.gen_range(c as usize) as u8).collect())
            .collect();
        // correlate column 1 with column 0
        for t in 0..m {
            if rng.bool(0.8) {
                cols[1][t] = cols[0][t] % cards[1] as u8;
            }
        }
        Dataset::unnamed(cards, cols)
    }

    #[test]
    fn matches_bdeu_scorer() {
        let d = toy(1);
        let ps = pairwise_similarity(&d, 10.0, 4);
        let sc = BdeuScorer::new(Arc::new(d.clone()), 10.0);
        for i in 0..d.n_vars() {
            assert!((ps.empty[i] - sc.local(i, &[])).abs() < 1e-9);
            for j in 0..d.n_vars() {
                if i == j {
                    continue;
                }
                let expect = sc.local(i, &[j]) - sc.local(i, &[]);
                assert!(
                    (ps.s[i][j] - expect).abs() < 1e-9,
                    "i={i} j={j}: {} vs {expect}",
                    ps.s[i][j]
                );
            }
        }
    }

    #[test]
    fn symmetric_by_score_equivalence() {
        let d = toy(2);
        let ps = pairwise_similarity(&d, 4.0, 2);
        for i in 0..d.n_vars() {
            for j in (i + 1)..d.n_vars() {
                assert!(
                    (ps.s[i][j] - ps.s[j][i]).abs() < 1e-8,
                    "asymmetry at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn correlated_pair_scores_high() {
        let d = toy(3);
        let ps = pairwise_similarity(&d, 10.0, 1);
        // the injected (0,1) correlation should dominate row 1
        let best = (0..d.n_vars())
            .filter(|&j| j != 1)
            .max_by(|&a, &b| ps.s[1][a].partial_cmp(&ps.s[1][b]).unwrap())
            .unwrap();
        assert_eq!(best, 0);
        assert!(ps.s[1][0] > 0.0);
    }
}
