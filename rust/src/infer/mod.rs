//! Probabilistic inference over fitted networks — the fifth pillar
//! (data → learn → fuse → eval → **infer**).
//!
//! A learned [`Dag`](crate::graph::Dag) becomes a queryable model via
//! [`bn::fit`](crate::bn::fit()); this module then answers `P(target |
//! evidence)` three ways, sharing one [`Factor`] substrate:
//!
//! * [`JoinTree`] — compile once (moralize → min-fill triangulate →
//!   clique tree), then each query is a two-pass sum-product sweep
//!   that yields *all* marginals plus log P(evidence). The serving
//!   engine.
//! * [`ve_marginal`] — one-shot variable elimination for ad-hoc single
//!   marginals, and the independent implementation the exactness tests
//!   pit against the join tree.
//! * [`likelihood_weighting`] — seeded sampling fallback for networks
//!   whose treewidth blows the exact budget.
//!
//! [`Engine`] picks between the exact and sampled paths from a clique
//! state-space budget, and [`QueryServer`] exposes the result over
//! newline-delimited JSON or length-prefixed TCP frames.
//!
//! The heavy machinery behind the exact path — the compiled
//! jointree, per-thread scratch buffers, joint MAP, batching and the
//! multi-client server — lives in [`engine`](crate::engine);
//! [`JoinTree`], [`Engine`] and [`QueryServer`] are the
//! single-threaded compatibility surface over it. The table
//! arithmetic every path shares — blocked products, fused
//! absorb-and-marginalize, in-place evidence masks — lives in
//! [`kernel`], with the original scalar odometers retained as
//! [`kernel::reference`], the bit-for-bit pinning oracle.

pub mod factor;
pub mod jointree;
pub mod json;
pub mod kernel;
pub mod lw;
pub mod serve;
pub mod triangulate;
pub mod ve;

pub use factor::Factor;
pub use jointree::JoinTree;
pub use lw::likelihood_weighting;
pub use serve::QueryServer;
pub use triangulate::{triangulate, Triangulation};
pub use ve::ve_marginal;

use anyhow::{anyhow, bail, ensure, Result};

use crate::bn::DiscreteBn;
use crate::graph::moral_graph;
use crate::rng::Rng;

/// Look up a variable by name (shared by the CLI and the serve
/// protocol so both reject unknowns with the same wording).
pub fn var_index(names: &[String], name: &str) -> Result<usize> {
    names
        .iter()
        .position(|n| n == name)
        .ok_or_else(|| anyhow!("unknown variable '{name}'"))
}

/// Parse a state written as an index (`"3"`) or an `s<k>` name
/// (`"s3"`), range-checked against the variable's cardinality.
pub fn parse_state(text: &str, card: u32) -> Result<usize> {
    let digits = text.strip_prefix('s').unwrap_or(text);
    let s: usize = digits
        .parse()
        .map_err(|_| anyhow!("cannot parse state '{text}' (use an index or s<k>)"))?;
    ensure!(s < card as usize, "state {s} out of range (cardinality {card})");
    Ok(s)
}

/// Posterior over every variable of a network for one evidence set.
#[derive(Clone, Debug)]
pub struct Posterior {
    /// Normalized per-variable marginals, indexed by variable.
    pub marginals: Vec<Vec<f64>>,
    /// ln P(evidence) — exact from the join tree, an estimate from
    /// likelihood weighting.
    pub log_evidence: f64,
}

impl Posterior {
    /// Marginal distribution of variable `v`.
    pub fn marginal(&self, v: usize) -> &[f64] {
        &self.marginals[v]
    }

    /// Posterior mode (argmax state) of variable `v`.
    ///
    /// Deterministic MAP tie-breaking: among equal maxima the *lowest
    /// state index* wins (strict `>` never displaces an earlier
    /// maximum), so `"map"` answers are byte-identical between
    /// concurrent and sequential serving, across batch orderings, and
    /// from run to run. The joint-MAP decode is deterministic by its
    /// own documented rule (lowest mixed-radix clique cell, see
    /// [`Factor::argmax_consistent`](crate::infer::factor::Factor::argmax_consistent)).
    pub fn mode(&self, v: usize) -> usize {
        let m = &self.marginals[v];
        let mut best = 0usize;
        for (s, &p) in m.iter().enumerate() {
            if p > m[best] {
                best = s;
            }
        }
        best
    }
}

/// Inference method selector (CLI `--method`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Exact when the treewidth budget allows, else likelihood
    /// weighting.
    Auto,
    /// Force the join tree.
    JoinTree,
    /// One-shot variable elimination (per-target; `query` only).
    Ve,
    /// Force likelihood weighting.
    Lw,
}

impl Method {
    /// Parse a CLI name.
    pub fn parse(name: &str) -> Option<Method> {
        match name {
            "auto" => Some(Method::Auto),
            "jointree" | "jt" => Some(Method::JoinTree),
            "ve" => Some(Method::Ve),
            "lw" => Some(Method::Lw),
            _ => None,
        }
    }
}

/// Engine construction parameters.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Method selector.
    pub method: Method,
    /// Max clique joint state space the exact engine may compile
    /// (`Auto` falls back to sampling past it).
    pub budget: u64,
    /// Particles per likelihood-weighting query.
    pub samples: usize,
    /// Base seed for the sampling engine.
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { method: Method::Auto, budget: 1 << 22, samples: 20_000, seed: 1 }
    }
}

/// A compiled inference engine: exact clique tree or seeded sampler.
pub enum Engine {
    /// Exact two-pass propagation.
    Exact(JoinTree),
    /// Likelihood weighting over a retained copy of the network.
    Sampled {
        /// The fitted network.
        bn: Box<DiscreteBn>,
        /// Particles per query.
        samples: usize,
        /// Per-query seed source.
        rng: Rng,
    },
}

impl Engine {
    /// Build an engine per `cfg`. `Method::Ve` has no persistent
    /// engine; callers run [`ve_marginal`] directly.
    pub fn build(bn: &DiscreteBn, cfg: &EngineConfig) -> Result<Engine> {
        let sampled = |cfg: &EngineConfig| Engine::Sampled {
            bn: Box::new(bn.clone()),
            samples: cfg.samples,
            rng: Rng::new(cfg.seed),
        };
        match cfg.method {
            Method::JoinTree => Ok(Engine::Exact(JoinTree::build(bn)?)),
            Method::Lw => Ok(sampled(cfg)),
            Method::Auto => {
                // Probe the treewidth before materializing potentials;
                // the same triangulation seeds the tree build.
                let tri = triangulate(&moral_graph(&bn.dag), &bn.cards);
                if tri.max_clique_states <= cfg.budget {
                    Ok(Engine::Exact(JoinTree::build_from(bn, tri)?))
                } else {
                    Ok(sampled(cfg))
                }
            }
            Method::Ve => bail!(
                "variable elimination is per-query; use `query --method ve` or ve_marginal()"
            ),
        }
    }

    /// Engine name for telemetry and responses.
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Exact(_) => "jointree",
            Engine::Sampled { .. } => "lw",
        }
    }

    /// Posterior for one evidence set. The sampling engine draws a
    /// fresh per-query seed so repeated identical queries are
    /// independent estimates (but the whole sequence is deterministic
    /// in the configured seed).
    pub fn posterior(&mut self, evidence: &[(usize, usize)]) -> Result<Posterior> {
        match self {
            Engine::Exact(jt) => jt.posterior(evidence),
            Engine::Sampled { bn, samples, rng } => {
                let seed = rng.next_u64();
                likelihood_weighting(bn, evidence, *samples, seed)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn auto_picks_exact_for_tiny_networks() {
        let bn = tiny_bn();
        let mut e = Engine::build(&bn, &EngineConfig::default()).unwrap();
        assert_eq!(e.name(), "jointree");
        let post = e.posterior(&[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn auto_falls_back_past_budget() {
        let bn = tiny_bn();
        let cfg = EngineConfig { budget: 1, samples: 50_000, ..Default::default() };
        let mut e = Engine::build(&bn, &cfg).unwrap();
        assert_eq!(e.name(), "lw");
        let post = e.posterior(&[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 0.02);
    }

    #[test]
    fn ve_method_has_no_engine() {
        let bn = tiny_bn();
        let cfg = EngineConfig { method: Method::Ve, ..Default::default() };
        assert!(Engine::build(&bn, &cfg).is_err());
    }

    #[test]
    fn method_parse_names() {
        assert_eq!(Method::parse("auto"), Some(Method::Auto));
        assert_eq!(Method::parse("jointree"), Some(Method::JoinTree));
        assert_eq!(Method::parse("jt"), Some(Method::JoinTree));
        assert_eq!(Method::parse("ve"), Some(Method::Ve));
        assert_eq!(Method::parse("lw"), Some(Method::Lw));
        assert_eq!(Method::parse("magic"), None);
    }

    #[test]
    fn posterior_mode_breaks_ties_low() {
        let p = Posterior { marginals: vec![vec![0.5, 0.5], vec![0.1, 0.9]], log_evidence: 0.0 };
        assert_eq!(p.mode(0), 0);
        assert_eq!(p.mode(1), 1);
        // Ties anywhere resolve to the lowest tied state, so MAP
        // answers are reproducible bit-for-bit.
        let p = Posterior {
            marginals: vec![vec![0.1, 0.45, 0.45], vec![0.25, 0.25, 0.25, 0.25]],
            log_evidence: 0.0,
        };
        assert_eq!(p.mode(0), 1);
        assert_eq!(p.mode(1), 0);
    }
}
