//! Discrete potentials: the common currency of exact inference.
//!
//! A [`Factor`] is a nonnegative table over a sorted set of variables,
//! stored mixed-radix exactly like a [`Cpt`](crate::bn::Cpt) row block
//! (first variable = least-significant digit). Junction-tree message
//! passing and variable elimination are both just `product` /
//! `marginalize_to` loops over this type, so the two exact engines
//! cannot disagree about table layout.
//!
//! The arithmetic itself lives in [`kernel`](crate::infer::kernel):
//! blocked walks that split every mixed-radix odometer into an outer
//! walk over non-contiguous digits and a stride-1 inner run, plus
//! `_into` variants that write into caller-owned buffers. The methods
//! here are the convenience layer — they build scopes and stride
//! vectors (linear merges over the already-sorted scopes, no quadratic
//! `contains` scans) and allocate the result; hot paths that must not
//! allocate (the serving engine) call the kernels directly with
//! precompiled plans. Results are bit-for-bit identical to the
//! retained scalar reference (`kernel::reference`) either way.

use crate::bn::DiscreteBn;
use crate::infer::kernel::{self, Split};

/// A nonnegative function over a set of discrete variables.
#[derive(Clone, Debug)]
pub struct Factor {
    /// Variable indices, strictly ascending.
    pub vars: Vec<usize>,
    /// Cardinalities, aligned with `vars`.
    pub cards: Vec<usize>,
    /// Mixed-radix table; `vars[0]` is the least-significant digit.
    pub table: Vec<f64>,
}

impl Factor {
    /// The scalar unit factor (empty scope, value 1).
    pub fn unit() -> Factor {
        Factor { vars: Vec::new(), cards: Vec::new(), table: vec![1.0] }
    }

    /// All-ones factor over `vars` (ascending), the identity for
    /// in-place potential accumulation.
    pub fn ones(vars: Vec<usize>, all_cards: &[u32]) -> Factor {
        debug_assert!(vars.windows(2).all(|w| w[0] < w[1]), "vars must be ascending");
        let cards: Vec<usize> = vars.iter().map(|&v| all_cards[v] as usize).collect();
        let size: usize = cards.iter().product();
        Factor { vars, cards, table: vec![1.0; size] }
    }

    /// Evidence indicator: 1 at `state` of `var`, 0 elsewhere.
    pub fn indicator(var: usize, card: usize, state: usize) -> Factor {
        debug_assert!(state < card);
        let mut table = vec![0.0; card];
        table[state] = 1.0;
        Factor { vars: vec![var], cards: vec![card], table }
    }

    /// The CPT of `bn`'s variable `v` as a factor over `{v} ∪ parents`.
    pub fn from_cpt(bn: &DiscreteBn, v: usize) -> Factor {
        let cpt = &bn.cpts[v];
        let mut vars: Vec<usize> = cpt.parents.clone();
        vars.push(v);
        vars.sort_unstable();
        let cards: Vec<usize> = vars.iter().map(|&x| bn.cards[x] as usize).collect();
        let size: usize = cards.iter().product();
        let mut table = vec![0.0; size];
        // Walk factor assignments; map each to the CPT's (config, state)
        // index. Both encodings list parents ascending with the first
        // parent least-significant, so the parent strides line up.
        let mut digits = vec![0usize; vars.len()];
        for cell in table.iter_mut() {
            let mut cfg = 0usize;
            let mut stride = 1usize;
            let mut k = 0usize;
            for (&d, &var) in digits.iter().zip(&vars) {
                if var == v {
                    k = d;
                } else {
                    cfg += stride * d;
                    stride *= bn.cards[var] as usize;
                }
            }
            *cell = cpt.table[cfg * cpt.r + k];
            for (d, &c) in digits.iter_mut().zip(&cards) {
                *d += 1;
                if *d < c {
                    break;
                }
                *d = 0;
            }
        }
        Factor { vars, cards, table }
    }

    /// Pointwise product `a · b` over the union of their scopes.
    pub fn product(a: &Factor, b: &Factor) -> Factor {
        let mut out = Factor { vars: Vec::new(), cards: Vec::new(), table: Vec::new() };
        Factor::product_into(a, b, &mut out);
        out
    }

    /// Pointwise product written into a caller-owned factor: `out`'s
    /// scope and table are rebuilt reusing their capacity, so a caller
    /// that keeps `out` across calls of the same shape pays no *table*
    /// allocation (two small per-call stride vectors are still built —
    /// the serving engine avoids even those via its precompiled
    /// plans). `out` must be a distinct object from both inputs.
    pub fn product_into(a: &Factor, b: &Factor, out: &mut Factor) {
        kernel::merge_union_into(
            &a.vars,
            &a.cards,
            &b.vars,
            &b.cards,
            &mut out.vars,
            &mut out.cards,
        );
        let size: usize = out.cards.iter().product();
        // Shape only — the kernel writes every cell, so no zero pass.
        if out.table.len() != size {
            out.table.resize(size, 0.0);
        }
        let mut sa = Vec::new();
        let mut sb = Vec::new();
        kernel::subset_strides_into(&out.vars, &out.cards, &a.vars, &mut sa);
        kernel::subset_strides_into(&out.vars, &out.cards, &b.vars, &mut sb);
        kernel::product_into(&mut out.table, &a.table, &b.table, &out.cards, &sa, &sb);
    }

    /// In-place absorb: `self ×= m`, requiring `m.vars ⊆ self.vars`
    /// (the clique-absorbs-message shape — no table allocation at all).
    pub fn absorb(&mut self, m: &Factor) {
        let mut sm = Vec::new();
        kernel::subset_strides_into(&self.vars, &self.cards, &m.vars, &mut sm);
        let split = Split::of(&self.cards, &sm);
        kernel::mul_assign(&mut self.table, &m.table, &self.cards, &sm, split);
    }

    /// Scope and strides of the sub-factor keeping `keep ∩ self.vars`
    /// (shared by the three marginalization entry points).
    fn kept_layout(&self, keep: &[usize]) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
        // Sorted lookup table over `keep` (which need not be sorted),
        // then one linear pass over the scope — no O(n·m) `contains`.
        let mut keep_sorted: Vec<usize> = keep.to_vec();
        keep_sorted.sort_unstable();
        let mut vars = Vec::new();
        let mut cards = Vec::new();
        for (&v, &c) in self.vars.iter().zip(&self.cards) {
            if keep_sorted.binary_search(&v).is_ok() {
                vars.push(v);
                cards.push(c);
            }
        }
        let mut so = Vec::new();
        kernel::subset_strides_into(&self.vars, &self.cards, &vars, &mut so);
        (vars, cards, so)
    }

    /// Sum out every variable not in `keep` (`keep` need not be sorted;
    /// only its intersection with the scope matters).
    pub fn marginalize_to(&self, keep: &[usize]) -> Factor {
        let mut out = Factor { vars: Vec::new(), cards: Vec::new(), table: Vec::new() };
        self.marginalize_into(keep, &mut out);
        out
    }

    /// Sum-marginalization written into a caller-owned factor: `out`'s
    /// table is rebuilt reusing its capacity, so repeated same-shape
    /// calls pay no table allocation (the kept-scope and stride
    /// vectors are still built per call; the serving engine avoids
    /// those via its precompiled plans).
    pub fn marginalize_into(&self, keep: &[usize], out: &mut Factor) {
        let (vars, cards, so) = self.kept_layout(keep);
        let size: usize = cards.iter().product();
        out.vars = vars;
        out.cards = cards;
        // Shape only — the kernel zero-fills before accumulating.
        if out.table.len() != size {
            out.table.resize(size, 0.0);
        }
        let split = Split::of(&self.cards, &so);
        kernel::marginalize_into(&mut out.table, &self.table, &self.cards, &so, split, false);
    }

    /// Max out every variable not in `keep` — the max-product analog
    /// of [`marginalize_to`](Factor::marginalize_to), used by the joint
    /// MAP pass. Tables are nonnegative, so 0 is the fold identity.
    pub fn max_marginalize_to(&self, keep: &[usize]) -> Factor {
        let (vars, cards, so) = self.kept_layout(keep);
        let size: usize = cards.iter().product();
        let mut table = vec![0.0; size];
        let split = Split::of(&self.cards, &so);
        kernel::marginalize_into(&mut table, &self.table, &self.cards, &so, split, true);
        Factor { vars, cards, table }
    }

    /// Largest cell among those consistent with `fixed` (a per-variable
    /// assignment indexed by *global* variable id; `None` = free), as
    /// `(digits aligned with self.vars, value)`. Deterministic: among
    /// equal maxima the lowest mixed-radix index wins — since the
    /// first variable is the least-significant digit, that is the
    /// assignment whose *highest*-indexed variables sit at their
    /// lowest tied states. Walks only the free digits (constrained
    /// strides are folded into the base index).
    pub fn argmax_consistent(&self, fixed: &[Option<usize>]) -> (Vec<usize>, f64) {
        let mut digits = vec![0usize; self.vars.len()];
        let best =
            kernel::argmax_consistent(&self.vars, &self.cards, &self.table, fixed, &mut digits);
        (digits, best)
    }

    /// Sum of all cells.
    pub fn total(&self) -> f64 {
        self.table.iter().sum()
    }

    /// Scale the table to sum to 1; returns the pre-normalization sum
    /// (0 leaves the table untouched — the caller decides how to fail).
    pub fn normalize(&mut self) -> f64 {
        let z = self.total();
        if z > 0.0 {
            let inv = 1.0 / z;
            self.table.iter_mut().for_each(|x| *x *= inv);
        }
        z
    }

    /// Normalized single-variable marginal (the variable must be in
    /// scope).
    pub fn marginal_of(&self, var: usize) -> Vec<f64> {
        let pos = self.vars.binary_search(&var).expect("marginal variable must be in scope");
        let mut m = vec![0.0; self.cards[pos]];
        kernel::single_marginal_into(&mut m, &self.table, &self.cards, pos);
        let z: f64 = m.iter().sum();
        if z > 0.0 {
            let inv = 1.0 / z;
            m.iter_mut().for_each(|x| *x *= inv);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn from_cpt_matches_joint() {
        let bn = tiny_bn();
        let fa = Factor::from_cpt(&bn, 0);
        let fb = Factor::from_cpt(&bn, 1);
        let joint = Factor::product(&fa, &fb);
        assert_eq!(joint.vars, vec![0, 1]);
        // table index = a + 2b; P(a,b) = P(a) P(b|a)
        let expect = [0.7 * 0.9, 0.3 * 0.2, 0.7 * 0.1, 0.3 * 0.8];
        for (got, want) in joint.table.iter().zip(expect) {
            assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        }
        assert!((joint.total() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn marginalization_sums_out() {
        let bn = tiny_bn();
        let joint = Factor::product(&Factor::from_cpt(&bn, 0), &Factor::from_cpt(&bn, 1));
        let pb = joint.marginalize_to(&[1]);
        assert_eq!(pb.vars, vec![1]);
        assert!((pb.table[0] - 0.69).abs() < 1e-12);
        assert!((pb.table[1] - 0.31).abs() < 1e-12);
        let scalar = joint.marginalize_to(&[]);
        assert!(scalar.vars.is_empty());
        assert!((scalar.table[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn indicator_reduces_via_product() {
        let bn = tiny_bn();
        let joint = Factor::product(&Factor::from_cpt(&bn, 0), &Factor::from_cpt(&bn, 1));
        let e = Factor::indicator(1, 2, 1); // observe b = 1
        let reduced = Factor::product(&joint, &e);
        // P(a | b=1) ∝ [0.7*0.1, 0.3*0.8]
        let pa = reduced.marginal_of(0);
        let z = 0.7 * 0.1 + 0.3 * 0.8;
        assert!((pa[0] - 0.07 / z).abs() < 1e-12);
        assert!((pa[1] - 0.24 / z).abs() < 1e-12);
    }

    #[test]
    fn product_is_commutative_and_unit_neutral() {
        let bn = tiny_bn();
        let fa = Factor::from_cpt(&bn, 0);
        let fb = Factor::from_cpt(&bn, 1);
        let ab = Factor::product(&fa, &fb);
        let ba = Factor::product(&fb, &fa);
        assert_eq!(ab.vars, ba.vars);
        for (x, y) in ab.table.iter().zip(&ba.table) {
            assert!((x - y).abs() < 1e-15);
        }
        let with_unit = Factor::product(&ab, &Factor::unit());
        assert_eq!(with_unit.table, ab.table);
    }

    #[test]
    fn in_place_variants_match_allocating_ones() {
        let bn = tiny_bn();
        let fa = Factor::from_cpt(&bn, 0);
        let fb = Factor::from_cpt(&bn, 1);
        let want = Factor::product(&fa, &fb);

        let mut out = Factor::unit();
        Factor::product_into(&fa, &fb, &mut out);
        assert_eq!(out.vars, want.vars);
        assert_eq!(out.table, want.table);

        // absorb over a subset scope equals a full product.
        let mut acc = want.clone();
        let e = Factor::indicator(1, 2, 1);
        acc.absorb(&e);
        let via_product = Factor::product(&want, &e);
        assert_eq!(acc.table, via_product.table);

        // marginalize_into reuses the buffer and matches marginalize_to.
        let mut m = Factor::unit();
        want.marginalize_into(&[0], &mut m);
        let m2 = want.marginalize_to(&[0]);
        assert_eq!(m.vars, m2.vars);
        assert_eq!(m.table, m2.table);
    }

    #[test]
    fn max_marginalize_keeps_cell_maxima() {
        let f = Factor { vars: vec![0, 1], cards: vec![2, 2], table: vec![0.1, 0.4, 0.3, 0.2] };
        let m0 = f.max_marginalize_to(&[0]);
        assert_eq!(m0.vars, vec![0]);
        assert!((m0.table[0] - 0.3).abs() < 1e-15); // max(0.1, 0.3)
        assert!((m0.table[1] - 0.4).abs() < 1e-15); // max(0.4, 0.2)
        let scalar = f.max_marginalize_to(&[]);
        assert!((scalar.table[0] - 0.4).abs() < 1e-15);
    }

    #[test]
    fn argmax_consistent_respects_constraints_and_ties() {
        let f = Factor { vars: vec![0, 2], cards: vec![2, 2], table: vec![0.4, 0.1, 0.2, 0.4] };
        // Unconstrained: 0.4 appears at cells (0,0) and (1,1); the
        // lowest mixed-radix index wins.
        let (digits, val) = f.argmax_consistent(&[None, None, None]);
        assert_eq!(digits, vec![0, 0]);
        assert!((val - 0.4).abs() < 1e-15);
        // Fixing global var 2 to state 1 restricts to cells (·, 1).
        let (digits, val) = f.argmax_consistent(&[None, None, Some(1)]);
        assert_eq!(digits, vec![1, 1]);
        assert!((val - 0.4).abs() < 1e-15);
    }

    #[test]
    fn three_way_product_any_order() {
        // Factors over {0,1}, {1,2}, {0,2} with card 2 each.
        let f1 = Factor { vars: vec![0, 1], cards: vec![2, 2], table: vec![0.1, 0.2, 0.3, 0.4] };
        let f2 = Factor { vars: vec![1, 2], cards: vec![2, 2], table: vec![0.5, 0.6, 0.7, 0.8] };
        let f3 = Factor { vars: vec![0, 2], cards: vec![2, 2], table: vec![0.9, 1.0, 1.1, 1.2] };
        let p1 = Factor::product(&Factor::product(&f1, &f2), &f3);
        let p2 = Factor::product(&f1, &Factor::product(&f2, &f3));
        assert_eq!(p1.vars, vec![0, 1, 2]);
        for (x, y) in p1.table.iter().zip(&p2.table) {
            assert!((x - y).abs() < 1e-12);
        }
        // Spot-check one cell by hand: (a=1, b=0, c=1) -> index a + 2b + 4c = 5.
        let idx = 5;
        let want = 0.2 * 0.7 * 1.2; // f1(1,0) f2(0,1) f3(1,1)
        assert!((p1.table[idx] - want).abs() < 1e-12);
    }
}
