//! Min-fill triangulation of the moral graph.
//!
//! Exact inference cost is governed by the elimination order: the
//! cliques created while eliminating are exactly the factor scopes the
//! junction tree and variable elimination will materialize. Min-fill —
//! repeatedly eliminate the node whose remaining neighbors need the
//! fewest marriage edges — is the standard greedy that keeps induced
//! width small on the sparse, locally-clustered structures both netgen
//! and the paper's bnlearn domains produce.
//!
//! Works on the symmetric adjacency rows produced by
//! [`moral_graph`](crate::graph::moral_graph); emits the elimination
//! order, the maximal cliques of the triangulated graph, and the
//! largest clique state space (the treewidth proxy every engine budget
//! check uses).

use crate::util::BitSet;

/// Result of triangulating an undirected graph.
pub struct Triangulation {
    /// Elimination order (first eliminated first).
    pub order: Vec<usize>,
    /// Maximal cliques of the triangulated graph, each sorted ascending.
    pub cliques: Vec<Vec<usize>>,
    /// Largest clique size in variables (induced width + 1).
    pub max_clique_vars: usize,
    /// Largest clique joint state space Π cards (saturating) — the
    /// memory/time proxy used by treewidth budgets.
    pub max_clique_states: u64,
}

/// Triangulate `adj` (symmetric adjacency rows) by min-fill
/// elimination. Deterministic: ties break toward the smaller
/// neighborhood, then the smaller node index.
pub fn triangulate(adj: &[BitSet], cards: &[u32]) -> Triangulation {
    let n = adj.len();
    debug_assert_eq!(n, cards.len());
    let mut work: Vec<BitSet> = adj.to_vec();
    let mut remaining = BitSet::from_iter(n, 0..n);
    let mut order = Vec::with_capacity(n);
    let mut elim_cliques: Vec<Vec<usize>> = Vec::with_capacity(n);

    for _ in 0..n {
        // Pick the remaining node with minimal fill-in.
        let mut best: Option<(usize, usize, usize)> = None; // (fill, degree, node)
        for v in remaining.iter() {
            let nb = work[v].intersection(&remaining);
            let mut missing_twice = 0usize;
            for a in nb.iter() {
                let mut non_adj = nb.difference(&work[a]);
                non_adj.remove(a);
                missing_twice += non_adj.count();
            }
            let key = (missing_twice / 2, nb.count(), v);
            let better = match best {
                None => true,
                Some(b) => key < b,
            };
            if better {
                best = Some(key);
            }
        }
        let (_, _, v) = best.expect("remaining is nonempty");

        let nb = work[v].intersection(&remaining);
        let mut clique: Vec<usize> = nb.iter().collect();
        clique.push(v);
        clique.sort_unstable();

        // Marry the remaining neighbors (the fill edges).
        let members: Vec<usize> = nb.iter().collect();
        for (i, &a) in members.iter().enumerate() {
            for &b in &members[i + 1..] {
                work[a].insert(b);
                work[b].insert(a);
            }
        }
        remaining.remove(v);
        order.push(v);
        elim_cliques.push(clique);
    }

    // Keep only maximal cliques (equal duplicates keep their first
    // occurrence).
    let sets: Vec<BitSet> =
        elim_cliques.iter().map(|c| BitSet::from_iter(n, c.iter().copied())).collect();
    let mut keep = vec![true; sets.len()];
    for i in 0..sets.len() {
        for j in 0..sets.len() {
            if i == j || !keep[i] {
                continue;
            }
            if sets[i].is_subset(&sets[j]) && (sets[i] != sets[j] || j < i) {
                keep[i] = false;
            }
        }
    }
    let cliques: Vec<Vec<usize>> = elim_cliques
        .into_iter()
        .zip(&keep)
        .filter_map(|(c, &k)| k.then_some(c))
        .collect();

    let max_clique_vars = cliques.iter().map(Vec::len).max().unwrap_or(0);
    let max_clique_states = cliques
        .iter()
        .map(|c| c.iter().fold(1u64, |acc, &v| acc.saturating_mul(cards[v] as u64)))
        .max()
        .unwrap_or(1);

    Triangulation { order, cliques, max_clique_vars, max_clique_states }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{moral_graph, Dag};

    fn adj_of(n: usize, edges: &[(usize, usize)]) -> Vec<BitSet> {
        let mut adj = vec![BitSet::new(n); n];
        for &(u, v) in edges {
            adj[u].insert(v);
            adj[v].insert(u);
        }
        adj
    }

    #[test]
    fn chain_has_edge_cliques() {
        // 0 - 1 - 2 - 3: already chordal; cliques are the three edges.
        let adj = adj_of(4, &[(0, 1), (1, 2), (2, 3)]);
        let t = triangulate(&adj, &[2, 2, 2, 2]);
        assert_eq!(t.order.len(), 4);
        assert_eq!(t.cliques.len(), 3);
        assert_eq!(t.max_clique_vars, 2);
        assert_eq!(t.max_clique_states, 4);
        for c in &t.cliques {
            assert_eq!(c.len(), 2);
        }
    }

    #[test]
    fn four_cycle_gets_one_fill_edge() {
        // 0-1-2-3-0: needs one chord; max clique becomes a triangle.
        let adj = adj_of(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let t = triangulate(&adj, &[2, 2, 2, 2]);
        assert_eq!(t.max_clique_vars, 3);
        assert_eq!(t.cliques.len(), 2);
        assert_eq!(t.max_clique_states, 8);
    }

    #[test]
    fn moral_star_collapses_to_family_clique() {
        // v-structure fan-in: 0,1,2 -> 3. Moralization marries all
        // parents, so {0,1,2,3} is one clique.
        let g = Dag::from_edges(4, &[(0, 3), (1, 3), (2, 3)]);
        let t = triangulate(&moral_graph(&g), &[2, 3, 2, 2]);
        assert_eq!(t.cliques.len(), 1);
        assert_eq!(t.cliques[0], vec![0, 1, 2, 3]);
        assert_eq!(t.max_clique_states, 24);
    }

    #[test]
    fn disconnected_graph_keeps_singletons() {
        let adj = adj_of(3, &[(0, 1)]);
        let t = triangulate(&adj, &[2, 2, 5]);
        // Cliques: {0,1} and the isolated {2}.
        assert_eq!(t.cliques.len(), 2);
        assert!(t.cliques.contains(&vec![0, 1]));
        assert!(t.cliques.contains(&vec![2]));
        assert_eq!(t.max_clique_states, 5);
    }

    #[test]
    fn deterministic_order() {
        let adj = adj_of(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]);
        let a = triangulate(&adj, &[2; 5]);
        let b = triangulate(&adj, &[2; 5]);
        assert_eq!(a.order, b.order);
        assert_eq!(a.cliques, b.cliques);
    }
}
