//! Likelihood-weighted sampling: the anytime fallback for networks
//! whose treewidth puts exact propagation past the budget.
//!
//! Forward-samples non-evidence variables in topological order and
//! weights each particle by the likelihood of the clamped evidence,
//! accumulating weighted state histograms for *every* variable in one
//! pass — the same all-marginals shape the join tree produces, so the
//! serve path can swap engines without changing its response format.
//! Deterministic in the seed via [`Rng`](crate::rng::Rng).

use anyhow::{bail, ensure, Result};

use crate::bn::DiscreteBn;
use crate::infer::Posterior;
use crate::rng::Rng;

/// Approximate posterior via likelihood weighting with `samples`
/// particles. `log_evidence` is the log of the mean particle weight —
/// a consistent estimator of log P(evidence).
pub fn likelihood_weighting(
    bn: &DiscreteBn,
    evidence: &[(usize, usize)],
    samples: usize,
    seed: u64,
) -> Result<Posterior> {
    let n = bn.n();
    ensure!(samples > 0, "need at least one sample");
    let mut clamped: Vec<Option<usize>> = vec![None; n];
    for &(v, s) in evidence {
        ensure!(v < n, "evidence variable {v} out of range (n = {n})");
        ensure!(
            s < bn.cards[v] as usize,
            "evidence state {s} out of range for variable {v} (cardinality {})",
            bn.cards[v]
        );
        if let Some(prev) = clamped[v] {
            ensure!(prev == s, "conflicting evidence for variable {v}: {prev} vs {s}");
        }
        clamped[v] = Some(s);
    }
    let order = bn
        .dag
        .topological_order()
        .ok_or_else(|| anyhow::anyhow!("network structure is cyclic"))?;

    let mut acc: Vec<Vec<f64>> = bn.cards.iter().map(|&c| vec![0.0; c as usize]).collect();
    let mut rng = Rng::new(seed);
    let mut states = vec![0u8; n];
    let mut weight_sum = 0.0f64;
    for _ in 0..samples {
        let mut w = 1.0f64;
        for &v in &order {
            let cfg = bn.parent_config(v, &states, &bn.cards);
            let row = bn.cpts[v].row(cfg);
            match clamped[v] {
                Some(s) => {
                    states[v] = s as u8;
                    w *= row[s];
                }
                None => {
                    states[v] = rng.categorical(row) as u8;
                }
            }
        }
        if w > 0.0 {
            weight_sum += w;
            for (hist, &s) in acc.iter_mut().zip(&states) {
                hist[s as usize] += w;
            }
        }
    }
    if weight_sum <= 0.0 {
        bail!("all {samples} particles had zero weight — evidence looks impossible");
    }

    let inv = 1.0 / weight_sum;
    for hist in &mut acc {
        hist.iter_mut().for_each(|x| *x *= inv);
    }
    Ok(Posterior { marginals: acc, log_evidence: (weight_sum / samples as f64).ln() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn converges_to_exact_posterior() {
        let bn = tiny_bn();
        let post = likelihood_weighting(&bn, &[(1, 1)], 200_000, 42).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8;
        assert!((post.marginal(0)[0] - 0.07 / pe).abs() < 0.01);
        assert!((post.marginal(1)[1] - 1.0).abs() < 1e-9);
        assert!((post.log_evidence - pe.ln()).abs() < 0.05);
    }

    #[test]
    fn deterministic_in_seed() {
        let bn = tiny_bn();
        let a = likelihood_weighting(&bn, &[(1, 0)], 5000, 7).unwrap();
        let b = likelihood_weighting(&bn, &[(1, 0)], 5000, 7).unwrap();
        let c = likelihood_weighting(&bn, &[(1, 0)], 5000, 8).unwrap();
        assert_eq!(a.marginals, b.marginals);
        assert!(a.marginal(0)[0] != c.marginal(0)[0]);
    }

    #[test]
    fn rejects_conflicts_and_ranges() {
        let bn = tiny_bn();
        assert!(likelihood_weighting(&bn, &[(0, 0), (0, 1)], 100, 1).is_err());
        assert!(likelihood_weighting(&bn, &[(9, 0)], 100, 1).is_err());
        assert!(likelihood_weighting(&bn, &[], 0, 1).is_err());
    }
}
