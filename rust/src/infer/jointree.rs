//! Junction-tree (clique-tree) exact inference.
//!
//! Build once per network, query many times: `build` moralizes,
//! triangulates (min-fill), extracts maximal cliques, connects them
//! into a maximum-separator-weight spanning forest (which gives the
//! running-intersection property on chordal graphs), and multiplies
//! each variable's CPT into the smallest clique containing its family.
//! `posterior` then answers one evidence set with a single two-pass
//! sum-product sweep — collect to a root, distribute back — yielding
//! *every* single-variable marginal plus the evidence log-probability,
//! which is exactly the shape a query-serving path wants: one
//! propagation amortizes over all targets of a request.
//!
//! Evidence is absorbed as indicator factors multiplied into one
//! clique per observed variable, so clique scopes never change and the
//! prebuilt potentials are reusable across queries. Collect-pass
//! messages are normalized with their log-normalizers accumulated;
//! the product of those normalizers times the root belief mass
//! telescopes to P(evidence), kept in log space to survive many-
//! evidence queries on large networks.

use anyhow::{bail, ensure, Result};

use crate::bn::DiscreteBn;
use crate::graph::moral_graph;
use crate::infer::factor::Factor;
use crate::infer::triangulate::{triangulate, Triangulation};
use crate::infer::Posterior;
use crate::util::BitSet;

/// A compiled clique tree over one discrete Bayesian network.
pub struct JoinTree {
    cards: Vec<usize>,
    cliques: Vec<Vec<usize>>,
    /// Tree edges: `(clique_a, clique_b, separator vars)`.
    edges: Vec<(usize, usize, Vec<usize>)>,
    /// Per clique: `(neighbor clique, edge index)`.
    neighbors: Vec<Vec<(usize, usize)>>,
    /// Evidence-free clique potentials (CPTs multiplied in).
    base: Vec<Factor>,
    /// For each variable, a clique containing its whole family.
    var_home: Vec<usize>,
    max_clique_states: u64,
}

impl JoinTree {
    /// Compile `bn` into a clique tree (moralizes and triangulates
    /// internally).
    pub fn build(bn: &DiscreteBn) -> Result<JoinTree> {
        let tri = triangulate(&moral_graph(&bn.dag), &bn.cards);
        Self::build_from(bn, tri)
    }

    /// Compile from a precomputed triangulation of `bn`'s moral graph
    /// (lets budget probes reuse their triangulation instead of
    /// running min-fill twice).
    pub fn build_from(bn: &DiscreteBn, tri: Triangulation) -> Result<JoinTree> {
        let n = bn.n();
        ensure!(n > 0, "cannot build a join tree over zero variables");
        let cards: Vec<usize> = bn.cards.iter().map(|&c| c as usize).collect();
        let cliques = tri.cliques;
        let nc = cliques.len();
        let clique_sets: Vec<BitSet> =
            cliques.iter().map(|c| BitSet::from_iter(n, c.iter().copied())).collect();

        // Maximum-weight spanning forest over separator sizes (Kruskal):
        // on a chordal graph's maximal cliques this yields a valid
        // junction tree (running intersection property).
        let mut candidates: Vec<(usize, usize, usize)> = Vec::new(); // (sep_size, i, j)
        for i in 0..nc {
            for j in (i + 1)..nc {
                let sep = clique_sets[i].intersection(&clique_sets[j]).count();
                if sep > 0 {
                    candidates.push((sep, i, j));
                }
            }
        }
        candidates.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));

        let mut uf: Vec<usize> = (0..nc).collect();
        fn find(uf: &mut [usize], mut x: usize) -> usize {
            while uf[x] != x {
                uf[x] = uf[uf[x]];
                x = uf[x];
            }
            x
        }
        let mut edges: Vec<(usize, usize, Vec<usize>)> = Vec::with_capacity(nc.saturating_sub(1));
        let mut neighbors: Vec<Vec<(usize, usize)>> = vec![Vec::new(); nc];
        for (_, i, j) in candidates {
            let (ri, rj) = (find(&mut uf, i), find(&mut uf, j));
            if ri == rj {
                continue;
            }
            uf[ri] = rj;
            let sep: Vec<usize> = clique_sets[i].intersection(&clique_sets[j]).to_vec();
            let e = edges.len();
            neighbors[i].push((j, e));
            neighbors[j].push((i, e));
            edges.push((i, j, sep));
        }

        // Assign each family to the smallest containing clique and
        // multiply its CPT in.
        let mut base: Vec<Factor> =
            cliques.iter().map(|c| Factor::ones(c.clone(), &bn.cards)).collect();
        let mut var_home = vec![usize::MAX; n];
        for v in 0..n {
            let mut fam = BitSet::new(n);
            fam.insert(v);
            fam.union_with(bn.dag.parents(v));
            let mut chosen: Option<(u64, usize)> = None; // (state space, clique)
            for (ci, cs) in clique_sets.iter().enumerate() {
                if !fam.is_subset(cs) {
                    continue;
                }
                let weight = cliques[ci]
                    .iter()
                    .fold(1u64, |acc, &x| acc.saturating_mul(cards[x] as u64));
                let better = match chosen {
                    None => true,
                    Some((w, _)) => weight < w,
                };
                if better {
                    chosen = Some((weight, ci));
                }
            }
            let Some((_, ci)) = chosen else {
                bail!(
                    "family of variable {v} fits no clique — triangulation is inconsistent"
                );
            };
            var_home[v] = ci;
            base[ci] = Factor::product(&base[ci], &Factor::from_cpt(bn, v));
        }

        Ok(JoinTree {
            cards,
            cliques,
            edges,
            neighbors,
            base,
            var_home,
            max_clique_states: tri.max_clique_states,
        })
    }

    /// Number of variables.
    pub fn n_vars(&self) -> usize {
        self.cards.len()
    }

    /// Number of cliques.
    pub fn n_cliques(&self) -> usize {
        self.cliques.len()
    }

    /// Largest clique joint state space (treewidth proxy).
    pub fn max_clique_states(&self) -> u64 {
        self.max_clique_states
    }

    /// Directed message slot for edge `e` leaving clique `from`.
    fn dir(&self, e: usize, from: usize) -> usize {
        if self.edges[e].0 == from {
            2 * e
        } else {
            2 * e + 1
        }
    }

    /// Exact posterior over every variable given `evidence`
    /// (`(variable, state)` pairs). Errors on out-of-range evidence or
    /// evidence of probability zero.
    pub fn posterior(&self, evidence: &[(usize, usize)]) -> Result<Posterior> {
        let n = self.cards.len();
        for &(v, s) in evidence {
            ensure!(v < n, "evidence variable {v} out of range (n = {n})");
            ensure!(
                s < self.cards[v],
                "evidence state {s} out of range for variable {v} (cardinality {})",
                self.cards[v]
            );
        }

        let mut pots = self.base.clone();
        for &(v, s) in evidence {
            let c = self.var_home[v];
            pots[c] = Factor::product(&pots[c], &Factor::indicator(v, self.cards[v], s));
        }

        let nc = self.cliques.len();
        let mut msgs: Vec<Option<Factor>> = vec![None; 2 * self.edges.len()];
        let mut visited = vec![false; nc];
        let mut log_evidence = 0.0f64;

        for root in 0..nc {
            if visited[root] {
                continue;
            }
            // BFS tree of this component.
            let mut order = vec![root];
            let mut parent_edge: Vec<Option<(usize, usize)>> = vec![None; nc];
            visited[root] = true;
            let mut head = 0;
            while head < order.len() {
                let c = order[head];
                head += 1;
                for &(o, e) in &self.neighbors[c] {
                    if !visited[o] {
                        visited[o] = true;
                        parent_edge[o] = Some((c, e));
                        order.push(o);
                    }
                }
            }

            // Collect: leaves toward the root.
            for &c in order.iter().rev() {
                let Some((p, e)) = parent_edge[c] else { continue };
                let mut f = pots[c].clone();
                for &(o, e2) in &self.neighbors[c] {
                    if o == p && e2 == e {
                        continue;
                    }
                    let inc = msgs[self.dir(e2, o)].as_ref().expect("child message ready");
                    f = Factor::product(&f, inc);
                }
                let mut m = f.marginalize_to(&self.edges[e].2);
                let z = m.normalize();
                if z <= 0.0 {
                    bail!("evidence has probability zero");
                }
                log_evidence += z.ln();
                msgs[self.dir(e, c)] = Some(m);
            }

            // Root belief mass closes the component's evidence mass.
            let mut root_belief = pots[root].clone();
            for &(o, e) in &self.neighbors[root] {
                let inc = msgs[self.dir(e, o)].as_ref().expect("root message ready");
                root_belief = Factor::product(&root_belief, inc);
            }
            let z_root = root_belief.total();
            if z_root <= 0.0 {
                bail!("evidence has probability zero");
            }
            log_evidence += z_root.ln();

            // Distribute: root toward the leaves.
            for &c in &order {
                for &(o, e) in &self.neighbors[c] {
                    let downstream = matches!(parent_edge[o], Some((p, pe)) if p == c && pe == e);
                    if !downstream {
                        continue;
                    }
                    let mut f = pots[c].clone();
                    for &(o2, e2) in &self.neighbors[c] {
                        if o2 == o && e2 == e {
                            continue;
                        }
                        let inc = msgs[self.dir(e2, o2)].as_ref().expect("incoming message ready");
                        f = Factor::product(&f, inc);
                    }
                    let mut m = f.marginalize_to(&self.edges[e].2);
                    if m.normalize() <= 0.0 {
                        bail!("evidence has probability zero");
                    }
                    msgs[self.dir(e, c)] = Some(m);
                }
            }
        }

        // Calibrated beliefs -> all single-variable marginals.
        let mut beliefs: Vec<Option<Factor>> = vec![None; nc];
        let mut marginals: Vec<Vec<f64>> = Vec::with_capacity(n);
        for v in 0..n {
            let c = self.var_home[v];
            if beliefs[c].is_none() {
                let mut b = pots[c].clone();
                for &(o, e) in &self.neighbors[c] {
                    let inc = msgs[self.dir(e, o)].as_ref().expect("calibrated message ready");
                    b = Factor::product(&b, inc);
                }
                beliefs[c] = Some(b);
            }
            let belief = beliefs[c].as_ref().expect("belief just built");
            marginals.push(belief.marginal_of(v));
        }

        Ok(Posterior { marginals, log_evidence })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn prior_marginals_of_tiny_bn() {
        let bn = tiny_bn();
        let jt = JoinTree::build(&bn).unwrap();
        assert_eq!(jt.n_vars(), 2);
        let post = jt.posterior(&[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
        assert!((post.marginal(1)[0] - 0.69).abs() < 1e-12);
        assert!(post.log_evidence.abs() < 1e-12, "no evidence -> log P = 0");
    }

    #[test]
    fn evidence_conditioning_and_log_evidence() {
        let bn = tiny_bn();
        let jt = JoinTree::build(&bn).unwrap();
        let post = jt.posterior(&[(1, 1)]).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8; // P(b=1)
        assert!((post.log_evidence - pe.ln()).abs() < 1e-12);
        assert!((post.marginal(0)[0] - 0.07 / pe).abs() < 1e-12);
        assert!((post.marginal(1)[1] - 1.0).abs() < 1e-12);
        assert_eq!(post.mode(0), 1);
    }

    #[test]
    fn rejects_bad_evidence() {
        let bn = tiny_bn();
        let jt = JoinTree::build(&bn).unwrap();
        assert!(jt.posterior(&[(5, 0)]).is_err());
        assert!(jt.posterior(&[(0, 9)]).is_err());
        // Contradictory evidence on one variable has probability zero.
        assert!(jt.posterior(&[(0, 0), (0, 1)]).is_err());
    }
}
