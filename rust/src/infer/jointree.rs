//! Junction-tree (clique-tree) exact inference — compatibility shim.
//!
//! The compilation (moralize → min-fill triangulate → maximal cliques
//! → maximum-separator Kruskal forest → CPT assignment → frozen
//! message schedule) and the two-pass propagation now live in
//! [`engine::CompiledModel`](crate::engine::CompiledModel), the
//! `Send + Sync` half of the compiled/scratch split that concurrent
//! serving shares across threads. [`JoinTree`] keeps the original
//! build-once/query-many API for single-threaded callers: each
//! [`posterior`](JoinTree::posterior) call runs in a private
//! [`Scratch`](crate::engine::Scratch), so `&self` stays lock-free
//! and results are identical to the shared path (same code runs).
//!
//! Callers that answer many queries or serve traffic should use the
//! model directly: [`JoinTree::model`] exposes it, and
//! `CompiledModel::new_scratch` amortizes both the buffer arena
//! (steady-state queries allocate no tables at all) and the
//! collect-message cache across queries; a per-call scratch as used
//! here pays the arena allocation on every query.

use anyhow::Result;

use crate::bn::DiscreteBn;
use crate::engine::CompiledModel;
use crate::infer::triangulate::Triangulation;
use crate::infer::Posterior;

/// A compiled clique tree over one discrete Bayesian network.
pub struct JoinTree {
    model: CompiledModel,
}

impl JoinTree {
    /// Compile `bn` into a clique tree (moralizes and triangulates
    /// internally).
    pub fn build(bn: &DiscreteBn) -> Result<JoinTree> {
        Ok(JoinTree { model: CompiledModel::compile(bn)? })
    }

    /// Compile from a precomputed triangulation of `bn`'s moral graph
    /// (lets budget probes reuse their triangulation instead of
    /// running min-fill twice).
    pub fn build_from(bn: &DiscreteBn, tri: Triangulation) -> Result<JoinTree> {
        Ok(JoinTree { model: CompiledModel::compile_from(bn, tri)? })
    }

    /// The underlying shared-serving model.
    pub fn model(&self) -> &CompiledModel {
        &self.model
    }

    /// Unwrap into the shared-serving model.
    pub fn into_model(self) -> CompiledModel {
        self.model
    }

    /// Number of variables.
    pub fn n_vars(&self) -> usize {
        self.model.n_vars()
    }

    /// Number of cliques.
    pub fn n_cliques(&self) -> usize {
        self.model.n_cliques()
    }

    /// Largest clique joint state space (treewidth proxy).
    pub fn max_clique_states(&self) -> u64 {
        self.model.max_clique_states()
    }

    /// Exact posterior over every variable given `evidence`
    /// (`(variable, state)` pairs). Errors on out-of-range evidence or
    /// evidence of probability zero. Runs in a private scratch; hot
    /// paths should hold their own via
    /// [`CompiledModel::new_scratch`].
    pub fn posterior(&self, evidence: &[(usize, usize)]) -> Result<Posterior> {
        let mut scratch = self.model.new_scratch();
        self.model.marginals(&mut scratch, evidence)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn prior_marginals_of_tiny_bn() {
        let bn = tiny_bn();
        let jt = JoinTree::build(&bn).unwrap();
        assert_eq!(jt.n_vars(), 2);
        let post = jt.posterior(&[]).unwrap();
        assert!((post.marginal(0)[0] - 0.7).abs() < 1e-12);
        assert!((post.marginal(1)[0] - 0.69).abs() < 1e-12);
        assert!(post.log_evidence.abs() < 1e-12, "no evidence -> log P = 0");
    }

    #[test]
    fn evidence_conditioning_and_log_evidence() {
        let bn = tiny_bn();
        let jt = JoinTree::build(&bn).unwrap();
        let post = jt.posterior(&[(1, 1)]).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8; // P(b=1)
        assert!((post.log_evidence - pe.ln()).abs() < 1e-12);
        assert!((post.marginal(0)[0] - 0.07 / pe).abs() < 1e-12);
        assert!((post.marginal(1)[1] - 1.0).abs() < 1e-12);
        assert_eq!(post.mode(0), 1);
    }

    #[test]
    fn rejects_bad_evidence() {
        let bn = tiny_bn();
        let jt = JoinTree::build(&bn).unwrap();
        assert!(jt.posterior(&[(5, 0)]).is_err());
        assert!(jt.posterior(&[(0, 9)]).is_err());
        // Contradictory evidence on one variable has probability zero.
        assert!(jt.posterior(&[(0, 0), (0, 1)]).is_err());
    }
}
