//! One-shot variable elimination: ad-hoc exact marginals without
//! compiling a join tree.
//!
//! The right tool when a caller wants a single `P(target | evidence)`
//! and will not amortize a clique-tree build: factors are the CPTs plus
//! evidence indicators, and variables are summed out greedily by
//! minimum product-scope weight (the state-space analog of min-fill).
//! The serve path prefers the jointree; the CLI `query --method ve`
//! and the correctness tests (jointree and VE must agree to 1e-9) use
//! this as the independent second implementation. The factor products
//! and marginalizations run on the same blocked kernels
//! ([`infer::kernel`](crate::infer::kernel)) as the serving engine,
//! so VE speeds up with them for free.

use anyhow::{bail, ensure, Result};

use crate::bn::DiscreteBn;
use crate::infer::factor::Factor;

/// Refuse to materialize an intermediate factor beyond this many
/// cells — past it, likelihood weighting is the sane fallback.
const VE_MAX_CELLS: u64 = 1 << 26;

/// Exact normalized marginal `P(target | evidence)` by variable
/// elimination.
pub fn ve_marginal(
    bn: &DiscreteBn,
    target: usize,
    evidence: &[(usize, usize)],
) -> Result<Vec<f64>> {
    let n = bn.n();
    ensure!(target < n, "target variable {target} out of range (n = {n})");
    for &(v, s) in evidence {
        ensure!(v < n, "evidence variable {v} out of range (n = {n})");
        ensure!(
            s < bn.cards[v] as usize,
            "evidence state {s} out of range for variable {v} (cardinality {})",
            bn.cards[v]
        );
    }

    let mut factors: Vec<Factor> = (0..n).map(|v| Factor::from_cpt(bn, v)).collect();
    for &(v, s) in evidence {
        factors.push(Factor::indicator(v, bn.cards[v] as usize, s));
    }

    let mut to_elim: Vec<usize> = (0..n).filter(|&v| v != target).collect();
    while !to_elim.is_empty() {
        // Greedy min-weight: eliminate the variable whose merged factor
        // scope has the smallest joint state space.
        let mut best: Option<(u64, usize, usize)> = None; // (weight, var, position)
        for (pos, &v) in to_elim.iter().enumerate() {
            // Factor scopes are sorted, so membership is a binary
            // search and the merged scope a sorted insert.
            let mut scope: Vec<usize> = Vec::new();
            for f in &factors {
                if f.vars.binary_search(&v).is_ok() {
                    for &x in &f.vars {
                        if let Err(i) = scope.binary_search(&x) {
                            scope.insert(i, x);
                        }
                    }
                }
            }
            let weight = scope
                .iter()
                .fold(1u64, |acc, &x| acc.saturating_mul(bn.cards[x] as u64));
            let key = (weight, v, pos);
            let better = match best {
                None => true,
                Some(b) => key < b,
            };
            if better {
                best = Some(key);
            }
        }
        let (weight, v, pos) = best.expect("to_elim is nonempty");
        if weight > VE_MAX_CELLS {
            bail!(
                "eliminating variable {v} needs a {weight}-cell factor (cap {VE_MAX_CELLS}); \
                 use likelihood weighting for this query"
            );
        }
        to_elim.swap_remove(pos);

        let mut merged = Factor::unit();
        let mut rest: Vec<Factor> = Vec::with_capacity(factors.len());
        for f in factors {
            if f.vars.binary_search(&v).is_ok() {
                merged = Factor::product(&merged, &f);
            } else {
                rest.push(f);
            }
        }
        let keep: Vec<usize> = merged.vars.iter().copied().filter(|&x| x != v).collect();
        rest.push(merged.marginalize_to(&keep));
        factors = rest;
    }

    let mut result = Factor::unit();
    for f in &factors {
        result = Factor::product(&result, f);
    }
    let mut m = result.marginalize_to(&[target]);
    if m.normalize() <= 0.0 {
        bail!("evidence has probability zero");
    }
    Ok(m.table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    #[test]
    fn prior_and_posterior_on_tiny_bn() {
        let bn = tiny_bn();
        let pb = ve_marginal(&bn, 1, &[]).unwrap();
        assert!((pb[0] - 0.69).abs() < 1e-12);
        let pa = ve_marginal(&bn, 0, &[(1, 1)]).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8;
        assert!((pa[0] - 0.07 / pe).abs() < 1e-12);
        assert!((pa[1] - 0.24 / pe).abs() < 1e-12);
    }

    #[test]
    fn target_with_evidence_on_itself_is_degenerate() {
        let bn = tiny_bn();
        let p = ve_marginal(&bn, 0, &[(0, 1)]).unwrap();
        assert!(p[0] == 0.0 && (p[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_out_of_range() {
        let bn = tiny_bn();
        assert!(ve_marginal(&bn, 7, &[]).is_err());
        assert!(ve_marginal(&bn, 0, &[(1, 5)]).is_err());
        assert!(ve_marginal(&bn, 0, &[(0, 0), (0, 1)]).is_err());
    }
}
