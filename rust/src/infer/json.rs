//! Minimal JSON value type, parser and writer.
//!
//! The offline registry has no `serde`, and the serve protocol
//! (newline-delimited / length-prefixed JSON queries) only needs the
//! core grammar: objects, arrays, strings with escapes, f64 numbers,
//! booleans and null. Object keys keep insertion order so responses
//! are byte-stable for a given request — convenient for tests and for
//! diffing server logs.

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always carried as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object as ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing non-whitespace is an
    /// error).
    pub fn parse(text: &str) -> Result<Json> {
        let b = text.as_bytes();
        let mut p = 0usize;
        let v = parse_value(b, &mut p)?;
        skip_ws(b, &mut p);
        if p != b.len() {
            bail!("trailing characters at byte {p}");
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer payload, if this is a whole number.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9e15 => Some(*x as usize),
            _ => None,
        }
    }

    /// Boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Object entries, if this is an object.
    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Some(kv.as_slice()),
            _ => None,
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Inf; null is the least-bad spelling.
                    f.write_str("null")
                } else if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(kv) => {
                f.write_str("{")?;
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

fn skip_ws(b: &[u8], p: &mut usize) {
    while *p < b.len() && matches!(b[*p], b' ' | b'\t' | b'\n' | b'\r') {
        *p += 1;
    }
}

fn parse_value(b: &[u8], p: &mut usize) -> Result<Json> {
    skip_ws(b, p);
    if *p >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*p] {
        b'{' => parse_object(b, p),
        b'[' => parse_array(b, p),
        b'"' => Ok(Json::Str(parse_string(b, p)?)),
        b't' => parse_literal(b, p, "true", Json::Bool(true)),
        b'f' => parse_literal(b, p, "false", Json::Bool(false)),
        b'n' => parse_literal(b, p, "null", Json::Null),
        _ => parse_number(b, p),
    }
}

fn parse_literal(b: &[u8], p: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if b.len() - *p >= lit.len() && &b[*p..*p + lit.len()] == lit.as_bytes() {
        *p += lit.len();
        Ok(value)
    } else {
        bail!("invalid literal at byte {}", *p)
    }
}

fn parse_number(b: &[u8], p: &mut usize) -> Result<Json> {
    let start = *p;
    if *p < b.len() && b[*p] == b'-' {
        *p += 1;
    }
    while *p < b.len() && matches!(b[*p], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *p += 1;
    }
    let text = std::str::from_utf8(&b[start..*p]).expect("digits are ASCII");
    match text.parse::<f64>() {
        Ok(x) => Ok(Json::Num(x)),
        Err(_) => bail!("invalid number '{text}' at byte {start}"),
    }
}

fn parse_string(b: &[u8], p: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*p], b'"');
    *p += 1;
    let mut out: Vec<u8> = Vec::new();
    loop {
        if *p >= b.len() {
            bail!("unterminated string");
        }
        match b[*p] {
            b'"' => {
                *p += 1;
                return String::from_utf8(out).map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"));
            }
            b'\\' => {
                *p += 1;
                if *p >= b.len() {
                    bail!("unterminated escape");
                }
                let esc = b[*p];
                *p += 1;
                match esc {
                    b'"' => out.push(b'"'),
                    b'\\' => out.push(b'\\'),
                    b'/' => out.push(b'/'),
                    b'b' => out.push(0x08),
                    b'f' => out.push(0x0c),
                    b'n' => out.push(b'\n'),
                    b'r' => out.push(b'\r'),
                    b't' => out.push(b'\t'),
                    b'u' => {
                        let hi = parse_hex4(b, p)?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair: expect a following \uXXXX low half.
                            if b.len() - *p >= 2 && b[*p] == b'\\' && b[*p + 1] == b'u' {
                                *p += 2;
                                let lo = parse_hex4(b, p)?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    0xFFFD
                                }
                            } else {
                                0xFFFD
                            }
                        } else if (0xDC00..0xE000).contains(&hi) {
                            0xFFFD // unpaired low surrogate
                        } else {
                            hi
                        };
                        let c = char::from_u32(code).unwrap_or('\u{FFFD}');
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                    }
                    other => bail!("invalid escape '\\{}'", other as char),
                }
            }
            c => {
                out.push(c);
                *p += 1;
            }
        }
    }
}

fn parse_hex4(b: &[u8], p: &mut usize) -> Result<u32> {
    if b.len() - *p < 4 {
        bail!("truncated \\u escape");
    }
    let mut code = 0u32;
    for _ in 0..4 {
        let d = match b[*p] {
            c @ b'0'..=b'9' => (c - b'0') as u32,
            c @ b'a'..=b'f' => (c - b'a' + 10) as u32,
            c @ b'A'..=b'F' => (c - b'A' + 10) as u32,
            other => bail!("invalid hex digit '{}' in \\u escape", other as char),
        };
        code = (code << 4) | d;
        *p += 1;
    }
    Ok(code)
}

fn parse_array(b: &[u8], p: &mut usize) -> Result<Json> {
    debug_assert_eq!(b[*p], b'[');
    *p += 1;
    let mut items = Vec::new();
    skip_ws(b, p);
    if *p < b.len() && b[*p] == b']' {
        *p += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, p)?);
        skip_ws(b, p);
        if *p >= b.len() {
            bail!("unterminated array");
        }
        match b[*p] {
            b',' => *p += 1,
            b']' => {
                *p += 1;
                return Ok(Json::Arr(items));
            }
            other => bail!("expected ',' or ']' in array, got '{}'", other as char),
        }
    }
}

fn parse_object(b: &[u8], p: &mut usize) -> Result<Json> {
    debug_assert_eq!(b[*p], b'{');
    *p += 1;
    let mut kv = Vec::new();
    skip_ws(b, p);
    if *p < b.len() && b[*p] == b'}' {
        *p += 1;
        return Ok(Json::Obj(kv));
    }
    loop {
        skip_ws(b, p);
        if *p >= b.len() || b[*p] != b'"' {
            bail!("expected object key at byte {}", *p);
        }
        let key = parse_string(b, p)?;
        skip_ws(b, p);
        if *p >= b.len() || b[*p] != b':' {
            bail!("expected ':' after object key '{key}'");
        }
        *p += 1;
        let value = parse_value(b, p)?;
        kv.push((key, value));
        skip_ws(b, p);
        if *p >= b.len() {
            bail!("unterminated object");
        }
        match b[*p] {
            b',' => *p += 1,
            b'}' => {
                *p += 1;
                return Ok(Json::Obj(kv));
            }
            other => bail!("expected ',' or '}}' in object, got '{}'", other as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let doc = r#" {"id": 3, "ok": true, "xs": [1, -2.5, null], "s": "a\"b\n", "o": {}} "#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(3));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let xs = v.get("xs").and_then(Json::as_array).unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[1].as_f64(), Some(-2.5));
        assert_eq!(xs[2], Json::Null);
        assert_eq!(v.get("s").and_then(Json::as_str), Some("a\"b\n"));
        assert!(v.get("o").and_then(Json::as_object).unwrap().is_empty());
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn roundtrips_through_display() {
        let doc = r#"{"a":[1,2.5,true,null],"b":{"c":"x\ty"}}"#;
        let v = Json::parse(doc).unwrap();
        let printed = v.to_string();
        assert_eq!(printed, doc);
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        // Raw UTF-8 passes through; \u escapes (incl. surrogate pairs) decode.
        let v = Json::parse(r#""é€😀""#).unwrap();
        assert_eq!(v, Json::Str("é€😀".to_string()));
        let e = Json::parse(r#""\u00e9 \ud83d\ude00""#).unwrap();
        assert_eq!(e, Json::Str("é 😀".to_string()));
        let unpaired = Json::parse(r#""\ud83d""#).unwrap();
        assert_eq!(unpaired, Json::Str("\u{FFFD}".to_string()));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }
}
