//! Query serving: the learn → fit → **answer traffic** endpoint.
//!
//! [`QueryServer`] owns a compiled inference [`Engine`] and speaks a
//! one-JSON-per-request protocol over two media:
//!
//! * **lines** — newline-delimited JSON on any `BufRead`/`Write` pair
//!   (the CLI wires stdin/stdout), one response line per request line;
//! * **TCP** — a loopback listener where each request/response is a
//!   `u32` little-endian length prefix plus a JSON payload, the same
//!   framing (and oversized-frame guard) idiom as the ring's
//!   [`transport`](crate::coordinator::transport) wire format.
//!
//! Request shape (`targets` defaults to every variable; evidence
//! states are indices or `s<k>` names):
//!
//! ```json
//! {"id": 1, "type": "marginal", "targets": ["X3"], "evidence": {"X0": 0}}
//! {"id": 2, "type": "map", "evidence": {"X1": "s1"}}
//! ```
//!
//! Responses echo `id`, report the engine and `log_evidence`, and
//! carry either `"marginals": {name: [p...]}` or `"map": {name:
//! state}` (per-variable posterior modes). Failures answer `{"ok":
//! false, "error": ...}` instead of closing the stream.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::bn::DiscreteBn;
use crate::infer::json::Json;
use crate::infer::{Engine, EngineConfig, Posterior};

/// Hard cap on one framed request/response (guards against corrupt
/// length prefixes, as in the ring transport).
const MAX_FRAME_BYTES: u32 = 1 << 20;

/// A stateful query server bound to one fitted network.
pub struct QueryServer {
    names: Vec<String>,
    cards: Vec<u32>,
    engine: Engine,
}

impl QueryServer {
    /// Compile an engine for `bn` per `cfg` and wrap it for serving.
    pub fn new(bn: &DiscreteBn, cfg: &EngineConfig) -> Result<QueryServer> {
        Ok(QueryServer {
            names: bn.names.clone(),
            cards: bn.cards.clone(),
            engine: Engine::build(bn, cfg)?,
        })
    }

    /// Which engine backs this server (`"jointree"` or `"lw"`).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Answer one JSON request line with one JSON response line.
    pub fn handle(&mut self, request: &str) -> String {
        let parsed = match Json::parse(request) {
            Ok(v) => v,
            Err(e) => return error_response(Json::Null, &format!("bad json: {e:#}")),
        };
        let id = parsed.get("id").cloned().unwrap_or(Json::Null);
        match self.answer(&parsed) {
            Ok(body) => body.to_string(),
            Err(e) => error_response(id, &format!("{e:#}")),
        }
    }

    fn answer(&mut self, req: &Json) -> Result<Json> {
        let id = req.get("id").cloned().unwrap_or(Json::Null);
        let qtype = match req.get("type") {
            None => "marginal",
            Some(t) => t.as_str().ok_or_else(|| anyhow!("'type' must be a string"))?,
        };
        ensure!(
            qtype == "marginal" || qtype == "map",
            "unknown query type '{qtype}' (marginal|map)"
        );

        let targets: Vec<usize> = match req.get("targets") {
            None => (0..self.names.len()).collect(),
            Some(t) => {
                let items = t.as_array().ok_or_else(|| anyhow!("'targets' must be an array"))?;
                if items.is_empty() {
                    (0..self.names.len()).collect()
                } else {
                    items
                        .iter()
                        .map(|x| {
                            let name =
                                x.as_str().ok_or_else(|| anyhow!("target must be a string"))?;
                            self.var_index(name)
                        })
                        .collect::<Result<_>>()?
                }
            }
        };

        let mut evidence: Vec<(usize, usize)> = Vec::new();
        if let Some(ev) = req.get("evidence") {
            let entries =
                ev.as_object().ok_or_else(|| anyhow!("'evidence' must be an object"))?;
            for (name, val) in entries {
                let v = self.var_index(name)?;
                let s = state_index(val, self.cards[v])
                    .with_context(|| format!("evidence for '{name}'"))?;
                evidence.push((v, s));
            }
        }

        let post = self.engine.posterior(&evidence)?;
        Ok(self.compose(id, qtype, &targets, &post))
    }

    fn compose(&self, id: Json, qtype: &str, targets: &[usize], post: &Posterior) -> Json {
        let mut fields: Vec<(String, Json)> = vec![
            ("id".to_string(), id),
            ("ok".to_string(), Json::Bool(true)),
            ("engine".to_string(), Json::Str(self.engine.name().to_string())),
            ("log_evidence".to_string(), Json::Num(post.log_evidence)),
        ];
        if qtype == "map" {
            let modes: Vec<(String, Json)> = targets
                .iter()
                .map(|&v| (self.names[v].clone(), Json::Num(post.mode(v) as f64)))
                .collect();
            fields.push(("map".to_string(), Json::Obj(modes)));
        } else {
            let margs: Vec<(String, Json)> = targets
                .iter()
                .map(|&v| {
                    let dist: Vec<Json> =
                        post.marginal(v).iter().map(|&p| Json::Num(p)).collect();
                    (self.names[v].clone(), Json::Arr(dist))
                })
                .collect();
            fields.push(("marginals".to_string(), Json::Obj(margs)));
        }
        Json::Obj(fields)
    }

    fn var_index(&self, name: &str) -> Result<usize> {
        crate::infer::var_index(&self.names, name)
    }

    /// Serve newline-delimited JSON until the reader closes; returns
    /// the number of requests answered.
    pub fn serve_lines<R: BufRead, W: Write>(&mut self, reader: R, mut writer: W) -> Result<usize> {
        let mut served = 0usize;
        for line in reader.lines() {
            let line = line.context("read request line")?;
            if line.trim().is_empty() {
                continue;
            }
            let response = self.handle(&line);
            writeln!(writer, "{response}").context("write response")?;
            writer.flush().context("flush response")?;
            served += 1;
        }
        Ok(served)
    }

    /// Serve length-prefixed JSON frames over TCP, one connection at a
    /// time. `max_conns` bounds the accept loop (tests); `None` serves
    /// forever.
    pub fn serve_tcp(&mut self, listener: &TcpListener, max_conns: Option<usize>) -> Result<()> {
        let mut conns = 0usize;
        loop {
            if let Some(m) = max_conns {
                if conns >= m {
                    return Ok(());
                }
            }
            let (stream, peer) = listener.accept().context("accept query connection")?;
            conns += 1;
            if let Err(e) = self.serve_conn(stream) {
                eprintln!("connection {peer}: {e:#}");
            }
        }
    }

    fn serve_conn(&mut self, stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
        let mut writer = BufWriter::new(stream);
        loop {
            let mut len_bytes = [0u8; 4];
            match reader.read_exact(&mut len_bytes) {
                Ok(()) => {}
                // Clean EOF between frames = client done.
                Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
                Err(e) => return Err(e).context("read frame length"),
            }
            let len = u32::from_le_bytes(len_bytes);
            if len > MAX_FRAME_BYTES {
                bail!("incoming frame of {len} bytes exceeds cap {MAX_FRAME_BYTES}");
            }
            let mut payload = vec![0u8; len as usize];
            reader.read_exact(&mut payload).context("read frame payload")?;
            let text = String::from_utf8(payload).context("request frame is not UTF-8")?;

            let response = self.handle(&text);
            let out = response.as_bytes();
            let out_len = u32::try_from(out.len()).context("response too large for u32 prefix")?;
            if out_len > MAX_FRAME_BYTES {
                bail!("response frame of {out_len} bytes exceeds cap {MAX_FRAME_BYTES}");
            }
            writer.write_all(&out_len.to_le_bytes()).context("write response length")?;
            writer.write_all(out).context("write response payload")?;
            writer.flush().context("flush response")?;
        }
    }
}

/// Parse an evidence state: a non-negative integer, or an `s<k>` /
/// integer string (string forms share [`crate::infer::parse_state`]
/// with the CLI).
fn state_index(val: &Json, card: u32) -> Result<usize> {
    match val {
        Json::Num(_) => {
            let s = val
                .as_usize()
                .ok_or_else(|| anyhow!("state must be a non-negative integer"))?;
            ensure!(s < card as usize, "state {s} out of range (cardinality {card})");
            Ok(s)
        }
        Json::Str(text) => crate::infer::parse_state(text, card),
        _ => bail!("state must be an integer or a state name"),
    }
}

fn error_response(id: Json, message: &str) -> String {
    Json::Obj(vec![
        ("id".to_string(), id),
        ("ok".to_string(), Json::Bool(false)),
        ("error".to_string(), Json::Str(message.to_string())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;

    fn server() -> QueryServer {
        QueryServer::new(&tiny_bn(), &EngineConfig::default()).unwrap()
    }

    #[test]
    fn marginal_request_roundtrip() {
        let mut s = server();
        assert_eq!(s.engine_name(), "jointree");
        let resp = s.handle(r#"{"id": 7, "type": "marginal", "targets": ["a"], "evidence": {"b": 1}}"#);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(7));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("engine").and_then(Json::as_str), Some("jointree"));
        let margs = v.get("marginals").unwrap();
        let pa = margs.get("a").and_then(Json::as_array).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8;
        assert!((pa[0].as_f64().unwrap() - 0.07 / pe).abs() < 1e-9);
        let le = v.get("log_evidence").and_then(Json::as_f64).unwrap();
        assert!((le - pe.ln()).abs() < 1e-9);
    }

    #[test]
    fn map_and_default_targets() {
        let mut s = server();
        let resp = s.handle(r#"{"type": "map", "evidence": {"b": "s1"}}"#);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let map = v.get("map").unwrap();
        // P(a=1 | b=1) > P(a=0 | b=1) and b is clamped to 1.
        assert_eq!(map.get("a").and_then(Json::as_usize), Some(1));
        assert_eq!(map.get("b").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn errors_are_reported_not_fatal() {
        let mut s = server();
        for bad in [
            "not json at all",
            r#"{"id": 1, "targets": ["nope"]}"#,
            r#"{"id": 1, "evidence": {"a": 9}}"#,
            r#"{"id": 1, "type": "mystery"}"#,
        ] {
            let v = Json::parse(&s.handle(bad)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
            assert!(!v.get("error").and_then(Json::as_str).unwrap().is_empty());
        }
        // The server still answers after errors.
        let v = Json::parse(&s.handle(r#"{"id": 2}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn line_protocol_counts_requests() {
        let mut s = server();
        let input = b"{\"id\":1}\n\n{\"id\":2,\"targets\":[\"b\"]}\n".to_vec();
        let mut out = Vec::new();
        let served = s.serve_lines(&input[..], &mut out).unwrap();
        assert_eq!(served, 2);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        }
    }
}
