//! Query serving — compatibility shim over [`engine`](crate::engine).
//!
//! [`QueryServer`] keeps PR 2's single-threaded serving API (owned
//! engine, `&mut self` handlers) while delegating everything to the
//! concurrent [`engine::Server`](crate::engine::Server): the same
//! [`protocol`](crate::engine::protocol) answers requests, the same
//! framing moves bytes, so a caller migrating to the multi-client
//! server sees byte-identical responses. The shim holds one
//! [`Scratch`](crate::engine::Scratch) for [`handle`](QueryServer::handle),
//! which makes consecutive requests share the collect-message cache —
//! the single-threaded degenerate case of the serving pool.
//!
//! Request shape (`targets` defaults to every variable; evidence
//! states are indices or `s<k>` names):
//!
//! ```json
//! {"id": 1, "type": "marginal", "targets": ["X3"], "evidence": {"X0": 0}}
//! {"id": 2, "type": "map", "evidence": {"X1": "s1"}}
//! {"id": 3, "type": "joint_map", "evidence": {"X1": 1}}
//! {"id": 4, "type": "batch", "queries": [...]}
//! ```
//!
//! Responses echo `id`, report the engine and `log_evidence`, and
//! carry `"marginals"`, `"map"` (per-variable posterior modes, ties to
//! the lowest state), `"assignment"` + `"log_prob"` (joint MAP) or
//! `"results"` (batch). Failures answer `{"ok": false, "error": ...}`
//! instead of closing the stream.

use std::io::{BufRead, Write};
use std::net::TcpListener;

use anyhow::Result;

use crate::bn::DiscreteBn;
use crate::engine::{Scratch, ServeConfig, Server};
use crate::infer::EngineConfig;

/// A stateful query server bound to one fitted network
/// (single-threaded compatibility wrapper; new callers should use
/// [`engine::Server`](crate::engine::Server) directly).
pub struct QueryServer {
    inner: Server,
    scratch: Scratch,
}

impl QueryServer {
    /// Compile an engine for `bn` per `cfg` and wrap it for serving.
    pub fn new(bn: &DiscreteBn, cfg: &EngineConfig) -> Result<QueryServer> {
        let inner = Server::new(bn, cfg, ServeConfig::default())?;
        let scratch = inner.new_scratch();
        Ok(QueryServer { inner, scratch })
    }

    /// Which engine backs this server (`"jointree"` or `"lw"`).
    pub fn engine_name(&self) -> &'static str {
        self.inner.engine_name()
    }

    /// The serving metrics registry — the same snapshot a
    /// `{"type": "stats"}` request answers with.
    pub fn registry(&self) -> &crate::obs::Registry {
        self.inner.registry()
    }

    /// Answer one JSON request line with one JSON response line.
    pub fn handle(&mut self, request: &str) -> String {
        self.inner.handle(&mut self.scratch, request)
    }

    /// Serve newline-delimited JSON until the reader closes; returns
    /// the number of requests answered.
    pub fn serve_lines<R: BufRead, W: Write>(&mut self, reader: R, writer: W) -> Result<usize> {
        self.inner.serve_lines(reader, writer)
    }

    /// Serve length-prefixed JSON frames over TCP (the pool has one
    /// thread under the default [`ServeConfig`]). `max_conns` bounds
    /// the accept loop (tests); `None` serves until the shutdown
    /// sentinel.
    pub fn serve_tcp(&mut self, listener: &TcpListener, max_conns: Option<usize>) -> Result<()> {
        self.inner.serve_tcp(listener, max_conns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bn::network::tiny_bn;
    use crate::infer::json::Json;

    fn server() -> QueryServer {
        QueryServer::new(&tiny_bn(), &EngineConfig::default()).unwrap()
    }

    #[test]
    fn marginal_request_roundtrip() {
        let mut s = server();
        assert_eq!(s.engine_name(), "jointree");
        let resp = s.handle(r#"{"id": 7, "type": "marginal", "targets": ["a"], "evidence": {"b": 1}}"#);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(7));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("engine").and_then(Json::as_str), Some("jointree"));
        let margs = v.get("marginals").unwrap();
        let pa = margs.get("a").and_then(Json::as_array).unwrap();
        let pe = 0.7 * 0.1 + 0.3 * 0.8;
        assert!((pa[0].as_f64().unwrap() - 0.07 / pe).abs() < 1e-9);
        let le = v.get("log_evidence").and_then(Json::as_f64).unwrap();
        assert!((le - pe.ln()).abs() < 1e-9);
    }

    #[test]
    fn map_and_default_targets() {
        let mut s = server();
        let resp = s.handle(r#"{"type": "map", "evidence": {"b": "s1"}}"#);
        let v = Json::parse(&resp).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        let map = v.get("map").unwrap();
        // P(a=1 | b=1) > P(a=0 | b=1) and b is clamped to 1.
        assert_eq!(map.get("a").and_then(Json::as_usize), Some(1));
        assert_eq!(map.get("b").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn errors_are_reported_not_fatal() {
        let mut s = server();
        for bad in [
            "not json at all",
            r#"{"id": 1, "targets": ["nope"]}"#,
            r#"{"id": 1, "evidence": {"a": 9}}"#,
            r#"{"id": 1, "type": "mystery"}"#,
        ] {
            let v = Json::parse(&s.handle(bad)).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
            assert!(!v.get("error").and_then(Json::as_str).unwrap().is_empty());
        }
        // The server still answers after errors.
        let v = Json::parse(&s.handle(r#"{"id": 2}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn stats_surface_reaches_through_the_shim() {
        let mut s = server();
        s.handle(r#"{"id": 1}"#);
        assert!(s.registry().counter_value("serve.requests").unwrap_or(0) >= 1);
        let v = Json::parse(&s.handle(r#"{"type": "stats"}"#)).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert!(v.get("stats").is_some());
    }

    #[test]
    fn line_protocol_counts_requests() {
        let mut s = server();
        let input = b"{\"id\":1}\n\n{\"id\":2,\"targets\":[\"b\"]}\n".to_vec();
        let mut out = Vec::new();
        let served = s.serve_lines(&input[..], &mut out).unwrap();
        assert_eq!(served, 2);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        }
    }
}
