//! Blocked, allocation-free factor kernels.
//!
//! Every exact-inference operation in this crate — clique products,
//! separator marginalization, evidence absorption, MAP maxima — is a
//! mixed-radix walk over one or more aligned tables. The original
//! kernels ([`reference`]) advance a scalar odometer per cell: correct,
//! but each step is a chain of data-dependent adds and branches that
//! defeats autovectorization, and each call allocates a fresh output
//! table.
//!
//! The kernels here split every walk in two:
//!
//! * an **inner stride-1 block** over the longest run of
//!   least-significant walk digits on which each operand is *uniform* —
//!   either it contains every variable of the run (so its index
//!   advances by exactly 1 per cell, because a sorted subset scope's
//!   leading variables are the walk's leading variables with the same
//!   radices) or it contains none of them (so its index is constant
//!   over the block). Inside the block every loop is a plain slice
//!   traversal LLVM can unroll and vectorize;
//! * an **outer mixed-radix odometer** over the remaining digits,
//!   advancing per *block* instead of per cell.
//!
//! All kernels write into caller-owned buffers, so a caller that keeps
//! its buffers (see `engine::Scratch`) performs zero heap allocations
//! in steady state. And all of them are **bit-for-bit identical** to
//! [`reference`]: per-cell multiplications are the same operations, and
//! every accumulator (sum or max) receives its contributions in the
//! same order the scalar walk would deliver them — blocking changes
//! the loop structure, never the float arithmetic. The property tests
//! in `tests/properties.rs` pin this down to `to_bits` equality.

/// Hard cap on walk digits. A table over more than 64 variables of
/// cardinality ≥ 2 could not be materialized in memory, so this is a
/// structural bound, not a tuning knob.
pub const MAX_DIGITS: usize = 64;

/// Blocked split of one strided view against a walk: how many leading
/// digits form the contiguous inner block, how many cells that is, and
/// whether the view advances through the block or stands still.
///
/// Precompute once per (walk, target) pair — `engine::CompiledModel`
/// stores one per schedule edge — and reuse on every query.
#[derive(Clone, Copy, Debug, Default)]
pub struct Split {
    /// Number of leading walk digits inside the block.
    pub digits: usize,
    /// Block length in cells (product of those digits' cards).
    pub len: usize,
    /// Whether the view contains the block variables (stride-1 inner
    /// run) or none of them (constant index over the block).
    pub contiguous: bool,
}

impl Split {
    /// The split of one view (per-digit `strides`, 0 = absent) against
    /// a walk with the given `cards`.
    pub fn of(cards: &[usize], strides: &[usize]) -> Split {
        if cards.is_empty() {
            return Split { digits: 0, len: 1, contiguous: false };
        }
        let contiguous = strides[0] != 0;
        let mut digits = 0usize;
        let mut len = 1usize;
        while digits < cards.len() && (strides[digits] != 0) == contiguous {
            len *= cards[digits];
            digits += 1;
        }
        Split { digits, len, contiguous }
    }
}

/// Merge two strictly ascending scopes (with their cards) into their
/// sorted union, written into `vars`/`cards` (cleared first, capacity
/// reused). Linear two-pointer merge — no `contains` scans. When both
/// scopes carry a variable, `a`'s card wins (they agree on any valid
/// input).
pub fn merge_union_into(
    a_vars: &[usize],
    a_cards: &[usize],
    b_vars: &[usize],
    b_cards: &[usize],
    vars: &mut Vec<usize>,
    cards: &mut Vec<usize>,
) {
    vars.clear();
    cards.clear();
    let (mut i, mut j) = (0usize, 0usize);
    while i < a_vars.len() || j < b_vars.len() {
        let take_a = j == b_vars.len() || (i < a_vars.len() && a_vars[i] <= b_vars[j]);
        if take_a {
            if j < b_vars.len() && b_vars[j] == a_vars[i] {
                j += 1;
            }
            vars.push(a_vars[i]);
            cards.push(a_cards[i]);
            i += 1;
        } else {
            vars.push(b_vars[j]);
            cards.push(b_cards[j]);
            j += 1;
        }
    }
}

/// Per-walk-digit strides of a target table along a walk scope, written
/// into `out` (cleared first, capacity reused): `out[i]` is the stride
/// of walk digit `i` in the target, 0 when the target does not mention
/// it. Both scopes must be strictly ascending and the target must be a
/// subset of the walk; linear two-pointer, no `position` scans.
pub fn subset_strides_into(
    walk_vars: &[usize],
    walk_cards: &[usize],
    target_vars: &[usize],
    out: &mut Vec<usize>,
) {
    out.clear();
    out.resize(walk_vars.len(), 0);
    let mut stride = 1usize;
    let mut j = 0usize;
    for (i, &v) in walk_vars.iter().enumerate() {
        if j < target_vars.len() && target_vars[j] == v {
            out[i] = stride;
            stride *= walk_cards[i];
            j += 1;
        }
    }
    assert!(j == target_vars.len(), "target scope must be a subset of the walk scope");
}

/// Pointwise product over a walk scope: `out[i] = a[ia(i)] · b[ib(i)]`,
/// with `out` contiguous over the walk (its scope *is* the walk) and
/// `sa`/`sb` the per-digit strides of each operand (0 = absent). `out`
/// must not alias either operand. Bit-identical to the scalar walk:
/// one multiplication per cell, same operands.
pub fn product_into(
    out: &mut [f64],
    a: &[f64],
    b: &[f64],
    cards: &[usize],
    sa: &[usize],
    sb: &[usize],
) {
    let n = cards.len();
    assert!(n <= MAX_DIGITS, "factor scope exceeds {MAX_DIGITS} digits");
    debug_assert_eq!(out.len(), cards.iter().product::<usize>());
    let (a_in, b_in) = if n == 0 { (false, false) } else { (sa[0] != 0, sb[0] != 0) };
    let mut nd = 0usize;
    let mut len = 1usize;
    while nd < n && (sa[nd] != 0) == a_in && (sb[nd] != 0) == b_in {
        len *= cards[nd];
        nd += 1;
    }
    let oc = &cards[nd..];
    let osa = &sa[nd..];
    let osb = &sb[nd..];
    let mut digits = [0usize; MAX_DIGITS];
    let (mut ia, mut ib, mut off) = (0usize, 0usize, 0usize);
    loop {
        let ob = &mut out[off..off + len];
        match (a_in, b_in) {
            (true, true) => {
                let av = &a[ia..ia + len];
                let bv = &b[ib..ib + len];
                for ((o, &x), &y) in ob.iter_mut().zip(av).zip(bv) {
                    *o = x * y;
                }
            }
            (true, false) => {
                let av = &a[ia..ia + len];
                let y = b[ib];
                for (o, &x) in ob.iter_mut().zip(av) {
                    *o = x * y;
                }
            }
            (false, true) => {
                let x = a[ia];
                let bv = &b[ib..ib + len];
                for (o, &y) in ob.iter_mut().zip(bv) {
                    *o = x * y;
                }
            }
            (false, false) => ob.fill(a[ia] * b[ib]),
        }
        off += len;
        let mut i = 0usize;
        loop {
            if i == oc.len() {
                return;
            }
            digits[i] += 1;
            ia += osa[i];
            ib += osb[i];
            if digits[i] < oc[i] {
                break;
            }
            digits[i] = 0;
            ia -= osa[i] * oc[i];
            ib -= osb[i] * oc[i];
            i += 1;
        }
    }
}

/// In-place absorb: `acc[i] *= m[im(i)]` over the walk that is `acc`'s
/// own scope, `sm` the strides of `m` (scope ⊆ walk) and `split` its
/// precomputed blocked split (`Split::of(cards, sm)`).
pub fn mul_assign(acc: &mut [f64], m: &[f64], cards: &[usize], sm: &[usize], split: Split) {
    let n = cards.len();
    assert!(n <= MAX_DIGITS, "factor scope exceeds {MAX_DIGITS} digits");
    debug_assert_eq!(acc.len(), cards.iter().product::<usize>());
    let (nd, len, m_in) = (split.digits, split.len, split.contiguous);
    let oc = &cards[nd..];
    let osm = &sm[nd..];
    let mut digits = [0usize; MAX_DIGITS];
    let (mut im, mut off) = (0usize, 0usize);
    loop {
        let ab = &mut acc[off..off + len];
        if m_in {
            let mv = &m[im..im + len];
            for (x, &y) in ab.iter_mut().zip(mv) {
                *x *= y;
            }
        } else {
            let y = m[im];
            for x in ab.iter_mut() {
                *x *= y;
            }
        }
        off += len;
        let mut i = 0usize;
        loop {
            if i == oc.len() {
                return;
            }
            digits[i] += 1;
            im += osm[i];
            if digits[i] < oc[i] {
                break;
            }
            digits[i] = 0;
            im -= osm[i] * oc[i];
            i += 1;
        }
    }
}

/// Multiply an evidence indicator into `acc` in place: keep the cells
/// whose `digit`-th coordinate equals `state`, zero the rest. Exactly
/// `acc ×= indicator(state)` for the nonnegative finite tables this
/// crate builds (`x · 1 = x` and `x · 0 = +0` bit-for-bit).
pub fn mask_assign(acc: &mut [f64], cards: &[usize], digit: usize, state: usize) {
    let below: usize = cards[..digit].iter().product();
    let card = cards[digit];
    debug_assert!(state < card);
    let keep_lo = below * state;
    let keep_hi = below * (state + 1);
    for chunk in acc.chunks_mut(below * card) {
        chunk[..keep_lo].fill(0.0);
        chunk[keep_hi..].fill(0.0);
    }
}

/// Marginalize a walk-scoped table into a subset-scoped output:
/// `out[io(i)] ⊕= src[i]` with ⊕ = `+` (`max = false`) or `max`
/// (`max = true`; tables are nonnegative so 0 is the fold identity).
/// `so` gives the output strides (0 = summed/maxed out), `split` their
/// precomputed blocked split. `out` is overwritten (zero-filled
/// first). Accumulation order per output cell is the ascending-source
/// order of the scalar walk, so results are bit-identical to
/// [`reference::marginalize_to`].
pub fn marginalize_into(
    out: &mut [f64],
    src: &[f64],
    cards: &[usize],
    so: &[usize],
    split: Split,
    max: bool,
) {
    let n = cards.len();
    assert!(n <= MAX_DIGITS, "factor scope exceeds {MAX_DIGITS} digits");
    debug_assert_eq!(src.len(), cards.iter().product::<usize>());
    out.fill(0.0);
    let (nd, len, o_in) = (split.digits, split.len, split.contiguous);
    let oc = &cards[nd..];
    let oso = &so[nd..];
    let mut digits = [0usize; MAX_DIGITS];
    let (mut io, mut off) = (0usize, 0usize);
    loop {
        let sv = &src[off..off + len];
        match (o_in, max) {
            (true, false) => {
                let ov = &mut out[io..io + len];
                for (o, &x) in ov.iter_mut().zip(sv) {
                    *o += x;
                }
            }
            (true, true) => {
                let ov = &mut out[io..io + len];
                for (o, &x) in ov.iter_mut().zip(sv) {
                    if x > *o {
                        *o = x;
                    }
                }
            }
            (false, false) => {
                let mut acc = out[io];
                for &x in sv {
                    acc += x;
                }
                out[io] = acc;
            }
            (false, true) => {
                let mut acc = out[io];
                for &x in sv {
                    if x > acc {
                        acc = x;
                    }
                }
                out[io] = acc;
            }
        }
        off += len;
        let mut i = 0usize;
        loop {
            if i == oc.len() {
                return;
            }
            digits[i] += 1;
            io += oso[i];
            if digits[i] < oc[i] {
                break;
            }
            digits[i] = 0;
            io -= oso[i] * oc[i];
            i += 1;
        }
    }
}

/// Fused absorb-and-marginalize: `out[io(i)] ⊕= src[i] · m[im(i)]`
/// over the walk, without materializing the product table. This is the
/// separator-message kernel: when the separator (and the message
/// scope) is a prefix or suffix of the clique scope, every inner loop
/// is a pure slice operation. `out` is overwritten. Bit-identical to
/// `reference::product` followed by `reference::marginalize_to` /
/// `reference::max_marginalize_to`: same per-cell multiply, same
/// accumulation order.
pub fn absorb_marginalize_into(
    out: &mut [f64],
    src: &[f64],
    m: &[f64],
    cards: &[usize],
    sm: &[usize],
    so: &[usize],
    max: bool,
) {
    let n = cards.len();
    assert!(n <= MAX_DIGITS, "factor scope exceeds {MAX_DIGITS} digits");
    debug_assert_eq!(src.len(), cards.iter().product::<usize>());
    out.fill(0.0);
    let (m_in, o_in) = if n == 0 { (false, false) } else { (sm[0] != 0, so[0] != 0) };
    let mut nd = 0usize;
    let mut len = 1usize;
    while nd < n && (sm[nd] != 0) == m_in && (so[nd] != 0) == o_in {
        len *= cards[nd];
        nd += 1;
    }
    let oc = &cards[nd..];
    let osm = &sm[nd..];
    let oso = &so[nd..];
    let mut digits = [0usize; MAX_DIGITS];
    let (mut im, mut io, mut off) = (0usize, 0usize, 0usize);
    loop {
        let sv = &src[off..off + len];
        match (m_in, o_in) {
            (true, true) => {
                let mv = &m[im..im + len];
                let ov = &mut out[io..io + len];
                if max {
                    for ((o, &x), &y) in ov.iter_mut().zip(sv).zip(mv) {
                        let v = x * y;
                        if v > *o {
                            *o = v;
                        }
                    }
                } else {
                    for ((o, &x), &y) in ov.iter_mut().zip(sv).zip(mv) {
                        *o += x * y;
                    }
                }
            }
            (true, false) => {
                let mv = &m[im..im + len];
                let mut acc = out[io];
                if max {
                    for (&x, &y) in sv.iter().zip(mv) {
                        let v = x * y;
                        if v > acc {
                            acc = v;
                        }
                    }
                } else {
                    for (&x, &y) in sv.iter().zip(mv) {
                        acc += x * y;
                    }
                }
                out[io] = acc;
            }
            (false, true) => {
                let y = m[im];
                let ov = &mut out[io..io + len];
                if max {
                    for (o, &x) in ov.iter_mut().zip(sv) {
                        let v = x * y;
                        if v > *o {
                            *o = v;
                        }
                    }
                } else {
                    for (o, &x) in ov.iter_mut().zip(sv) {
                        *o += x * y;
                    }
                }
            }
            (false, false) => {
                let y = m[im];
                let mut acc = out[io];
                if max {
                    for &x in sv {
                        let v = x * y;
                        if v > acc {
                            acc = v;
                        }
                    }
                } else {
                    for &x in sv {
                        acc += x * y;
                    }
                }
                out[io] = acc;
            }
        }
        off += len;
        let mut i = 0usize;
        loop {
            if i == oc.len() {
                return;
            }
            digits[i] += 1;
            im += osm[i];
            io += oso[i];
            if digits[i] < oc[i] {
                break;
            }
            digits[i] = 0;
            im -= osm[i] * oc[i];
            io -= oso[i] * oc[i];
            i += 1;
        }
    }
}

/// Unnormalized single-variable marginal: `out[s] += Σ src` over all
/// cells whose `digit`-th coordinate is `s`. The belief → posterior
/// extraction kernel; contributions arrive in ascending-source order
/// (bit-identical to `reference::marginalize_to(&[var])`).
pub fn single_marginal_into(out: &mut [f64], src: &[f64], cards: &[usize], digit: usize) {
    let below: usize = cards[..digit].iter().product();
    let card = cards[digit];
    debug_assert_eq!(out.len(), card);
    out.fill(0.0);
    for chunk in src.chunks(below * card) {
        for (s, o) in out.iter_mut().enumerate() {
            let run = &chunk[s * below..(s + 1) * below];
            let mut acc = *o;
            for &x in run {
                acc += x;
            }
            *o = acc;
        }
    }
}

/// Largest cell of a `(vars, cards, table)` factor among those
/// consistent with `fixed` (per *global* variable id; `None` = free),
/// walking only the free digits — O(free cells), not O(all cells ×
/// scope). Writes the winning per-digit assignment into `digits_out`
/// (length `vars.len()`) and returns the value; ties break toward the
/// lowest mixed-radix table index, exactly like the scalar reference.
/// Returns `f64::NEG_INFINITY` (with `digits_out` unspecified) when no
/// cell is consistent.
pub fn argmax_consistent(
    vars: &[usize],
    cards: &[usize],
    table: &[f64],
    fixed: &[Option<usize>],
    digits_out: &mut [usize],
) -> f64 {
    let n = vars.len();
    assert!(n <= MAX_DIGITS, "factor scope exceeds {MAX_DIGITS} digits");
    debug_assert_eq!(digits_out.len(), n);
    let mut base = 0usize;
    let mut free = 0usize;
    let mut fpos = [0usize; MAX_DIGITS];
    let mut fcard = [0usize; MAX_DIGITS];
    let mut fstride = [0usize; MAX_DIGITS];
    let mut stride = 1usize;
    for i in 0..n {
        let c = cards[i];
        match fixed.get(vars[i]).copied().flatten() {
            Some(s) => {
                if s >= c {
                    return f64::NEG_INFINITY;
                }
                digits_out[i] = s;
                base += s * stride;
            }
            None => {
                digits_out[i] = 0;
                fpos[free] = i;
                fcard[free] = c;
                fstride[free] = stride;
                free += 1;
            }
        }
        stride *= c;
    }
    let mut best = f64::NEG_INFINITY;
    let mut idx = base;
    let mut fd = [0usize; MAX_DIGITS];
    loop {
        let val = table[idx];
        if val > best {
            best = val;
            for j in 0..free {
                digits_out[fpos[j]] = fd[j];
            }
        }
        let mut j = 0usize;
        loop {
            if j == free {
                return best;
            }
            fd[j] += 1;
            idx += fstride[j];
            if fd[j] < fcard[j] {
                break;
            }
            fd[j] = 0;
            idx -= fstride[j] * fcard[j];
            j += 1;
        }
    }
}

pub mod reference {
    //! The original scalar kernels, retained verbatim as the pinning
    //! oracle: per-cell mixed-radix odometers, a fresh table per call.
    //! `tests/properties.rs` asserts the blocked kernels above are
    //! bit-identical to these on randomized scopes; `benches/kernels.rs`
    //! measures the throughput gap. Not for production paths.

    use crate::infer::factor::Factor;

    /// Stride, in the table described by `(target_vars, target_cards)`,
    /// of each variable of `walk_vars` (0 when the target does not
    /// mention it). Every target variable must appear in `walk_vars`.
    fn strides_into(
        walk_vars: &[usize],
        target_vars: &[usize],
        target_cards: &[usize],
    ) -> Vec<usize> {
        let mut out = vec![0usize; walk_vars.len()];
        let mut stride = 1usize;
        for (v, c) in target_vars.iter().zip(target_cards) {
            let i = walk_vars.iter().position(|x| x == v).expect("target var missing from walk");
            out[i] = stride;
            stride *= c;
        }
        out
    }

    /// Scalar pointwise product `a · b` over the union of their scopes.
    pub fn product(a: &Factor, b: &Factor) -> Factor {
        let mut vars: Vec<usize> = a.vars.clone();
        for &v in &b.vars {
            if !vars.contains(&v) {
                vars.push(v);
            }
        }
        vars.sort_unstable();
        let cards: Vec<usize> = vars
            .iter()
            .map(|&v| {
                a.vars
                    .iter()
                    .position(|&x| x == v)
                    .map(|i| a.cards[i])
                    .or_else(|| b.vars.iter().position(|&x| x == v).map(|i| b.cards[i]))
                    .expect("union var must come from an input")
            })
            .collect();
        let size: usize = cards.iter().product();
        let sa = strides_into(&vars, &a.vars, &a.cards);
        let sb = strides_into(&vars, &b.vars, &b.cards);
        let mut table = vec![0.0; size];
        let mut digits = vec![0usize; vars.len()];
        let mut ia = 0usize;
        let mut ib = 0usize;
        for cell in table.iter_mut() {
            *cell = a.table[ia] * b.table[ib];
            for i in 0..digits.len() {
                digits[i] += 1;
                ia += sa[i];
                ib += sb[i];
                if digits[i] < cards[i] {
                    break;
                }
                digits[i] = 0;
                ia -= sa[i] * cards[i];
                ib -= sb[i] * cards[i];
            }
        }
        Factor { vars, cards, table }
    }

    /// Shared scalar walk behind the two marginalizations.
    fn fold_to(f: &Factor, keep: &[usize], max: bool) -> Factor {
        let vars: Vec<usize> = f.vars.iter().copied().filter(|v| keep.contains(v)).collect();
        let cards: Vec<usize> = vars
            .iter()
            .map(|&v| {
                let i = f.vars.iter().position(|&x| x == v).expect("kept var is in scope");
                f.cards[i]
            })
            .collect();
        let size: usize = cards.iter().product();
        let so = strides_into(&f.vars, &vars, &cards);
        let mut table = vec![0.0; size];
        let mut digits = vec![0usize; f.vars.len()];
        let mut io = 0usize;
        for &val in &f.table {
            if max {
                if val > table[io] {
                    table[io] = val;
                }
            } else {
                table[io] += val;
            }
            for i in 0..digits.len() {
                digits[i] += 1;
                io += so[i];
                if digits[i] < f.cards[i] {
                    break;
                }
                digits[i] = 0;
                io -= so[i] * f.cards[i];
            }
        }
        Factor { vars, cards, table }
    }

    /// Scalar sum-marginalization onto `keep`.
    pub fn marginalize_to(f: &Factor, keep: &[usize]) -> Factor {
        fold_to(f, keep, false)
    }

    /// Scalar max-marginalization onto `keep`.
    pub fn max_marginalize_to(f: &Factor, keep: &[usize]) -> Factor {
        fold_to(f, keep, true)
    }

    /// Scalar constrained argmax: walks *every* cell and tests the
    /// constraint per cell.
    pub fn argmax_consistent(f: &Factor, fixed: &[Option<usize>]) -> (Vec<usize>, f64) {
        let constrained: Vec<Option<usize>> =
            f.vars.iter().map(|&v| fixed.get(v).copied().flatten()).collect();
        let mut best_digits = vec![0usize; f.vars.len()];
        let mut best = f64::NEG_INFINITY;
        let mut digits = vec![0usize; f.vars.len()];
        for &val in &f.table {
            let ok = digits.iter().zip(&constrained).all(|(&d, &c)| match c {
                Some(s) => s == d,
                None => true,
            });
            if ok && val > best {
                best = val;
                best_digits.copy_from_slice(&digits);
            }
            for (d, &c) in digits.iter_mut().zip(&f.cards) {
                *d += 1;
                if *d < c {
                    break;
                }
                *d = 0;
            }
        }
        (best_digits, best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_of_prefix_suffix_and_mixed() {
        // Target holds the two leading digits: contiguous block of 6.
        let s = Split::of(&[2, 3, 4], &[1, 2, 0]);
        assert!(s.contiguous && s.digits == 2 && s.len == 6);
        // Target holds only the trailing digit: skip block of 6.
        let s = Split::of(&[2, 3, 4], &[0, 0, 1]);
        assert!(!s.contiguous && s.digits == 2 && s.len == 6);
        // Empty walk: one scalar block.
        let s = Split::of(&[], &[]);
        assert!(s.digits == 0 && s.len == 1);
    }

    #[test]
    fn merge_union_is_sorted_merge() {
        let mut vars = Vec::new();
        let mut cards = Vec::new();
        merge_union_into(&[1, 4, 7], &[2, 3, 4], &[0, 4, 9], &[5, 3, 2], &mut vars, &mut cards);
        assert_eq!(vars, vec![0, 1, 4, 7, 9]);
        assert_eq!(cards, vec![5, 2, 3, 4, 2]);
    }

    #[test]
    fn subset_strides_match_reference_layout() {
        let mut out = Vec::new();
        subset_strides_into(&[0, 2, 5], &[2, 3, 4], &[0, 5], &mut out);
        assert_eq!(out, vec![1, 0, 2]);
        subset_strides_into(&[0, 2, 5], &[2, 3, 4], &[], &mut out);
        assert_eq!(out, vec![0, 0, 0]);
    }

    #[test]
    fn mask_assign_is_indicator_product() {
        // Scope {a: 2, b: 3}; keep b = 1 → cells with index in [2, 4).
        let mut t: Vec<f64> = (1..=6).map(|x| x as f64).collect();
        mask_assign(&mut t, &[2, 3], 1, 1);
        assert_eq!(t, vec![0.0, 0.0, 3.0, 4.0, 0.0, 0.0]);
    }

    #[test]
    fn single_marginal_sums_slices() {
        // Scope {a: 2, b: 2}, table [1, 2, 3, 4]; marginal of b = [3, 7].
        let mut out = vec![0.0; 2];
        single_marginal_into(&mut out, &[1.0, 2.0, 3.0, 4.0], &[2, 2], 1);
        assert_eq!(out, vec![3.0, 7.0]);
        // Marginal of a = [4, 6].
        single_marginal_into(&mut out, &[1.0, 2.0, 3.0, 4.0], &[2, 2], 0);
        assert_eq!(out, vec![4.0, 6.0]);
    }
}
