//! Leveled stderr logging with a `CGES_LOG` environment filter.
//!
//! Deliberately tiny: four levels, one env var, stderr only. The
//! level is read from `CGES_LOG` (`error` | `warn` | `info` | `debug`,
//! any case) once on first use and cached in an atomic; [`set_level`]
//! overrides it at runtime (used by tests and by anything that wants
//! a verbosity flag). Default level is `info`; nothing silences
//! errors — `CGES_LOG=error` silences `warn`/`info`/`debug`. An
//! unrecognized value falls back to `info` and is reported once on
//! stderr rather than silently changing behavior.
//!
//! Tests that need to assert on log *content* (e.g. "ring healing
//! warns exactly once per dead worker") use [`capture_start`] /
//! [`capture_take`], which mirror every log line into an in-process
//! buffer on top of stderr. The mirror ignores the level filter
//! (stderr does not), so content assertions stay deterministic even
//! while another test toggles the global level.

use std::fmt::Arguments;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

/// Unset sentinel: the env var has not been consulted yet.
const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

/// Mirror buffer for tests: `Some(lines)` while a capture is active.
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);

fn parse(text: &str) -> Option<Level> {
    match text.trim().to_ascii_lowercase().as_str() {
        "error" | "err" | "0" => Some(Level::Error),
        "warn" | "warning" | "1" => Some(Level::Warn),
        "info" | "2" => Some(Level::Info),
        "debug" | "3" => Some(Level::Debug),
        _ => None,
    }
}

/// Resolve an env-var value to a level, plus the warning to print
/// when the value is present but unrecognized. Empty (or blank)
/// values count as unset, not as errors.
fn resolve(var: Option<&str>) -> (Level, Option<String>) {
    match var {
        None => (Level::Info, None),
        Some(v) if v.trim().is_empty() => (Level::Info, None),
        Some(v) => match parse(v) {
            Some(l) => (l, None),
            None => (
                Level::Info,
                Some(format!(
                    "unrecognized CGES_LOG value '{}' (want error|warn|info|debug); using info",
                    v.trim()
                )),
            ),
        },
    }
}

/// Current log level (reads `CGES_LOG` on first call; default `info`).
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNSET => {
            let var = std::env::var("CGES_LOG").ok();
            let (l, warning) = resolve(var.as_deref());
            // Only the caller that wins the store prints the warning,
            // so a bad value is reported exactly once per process. The
            // level is already cached by then, so the nested `error`
            // call can't recurse back into this branch.
            if LEVEL
                .compare_exchange(UNSET, l as u8, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                if let Some(w) = warning {
                    error(format_args!("{w}"));
                }
            }
            l
        }
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Override the level (wins over the environment from now on).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would a message at `l` currently be printed?
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Start mirroring emitted lines into an in-process buffer (tests).
/// Any previously captured lines are discarded.
pub fn capture_start() {
    *CAPTURE.lock().unwrap() = Some(Vec::new());
}

/// Stop capturing and return the lines mirrored since
/// [`capture_start`]. Returns an empty vec if no capture was active.
pub fn capture_take() -> Vec<String> {
    CAPTURE.lock().unwrap().take().unwrap_or_default()
}

fn emit(l: Level, tag: &str, msg: Arguments<'_>) {
    let on = enabled(l);
    let mut cap = CAPTURE.lock().unwrap();
    if !on && cap.is_none() {
        return;
    }
    let line = format!("[cges:{tag}] {msg}");
    if let Some(buf) = cap.as_mut() {
        // The mirror records regardless of the current level, so
        // content assertions don't race other tests toggling it.
        buf.push(line.clone());
    }
    drop(cap);
    if on {
        eprintln!("{line}");
    }
}

/// Log at error level (`obs::log::error(format_args!(...))`).
pub fn error(msg: Arguments<'_>) {
    emit(Level::Error, "error", msg);
}

/// Log at warn level (skipped rounds, healed workers, frame retries).
pub fn warn(msg: Arguments<'_>) {
    emit(Level::Warn, "warn", msg);
}

/// Log at info level.
pub fn info(msg: Arguments<'_>) {
    emit(Level::Info, "info", msg);
}

/// Log at debug level.
pub fn debug(msg: Arguments<'_>) {
    emit(Level::Debug, "debug", msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_names_and_digits() {
        assert_eq!(parse("error"), Some(Level::Error));
        assert_eq!(parse(" ERR "), Some(Level::Error));
        assert_eq!(parse("warn"), Some(Level::Warn));
        assert_eq!(parse("Warning"), Some(Level::Warn));
        assert_eq!(parse("info"), Some(Level::Info));
        assert_eq!(parse("Debug"), Some(Level::Debug));
        assert_eq!(parse("DEBUG"), Some(Level::Debug));
        assert_eq!(parse("InFo"), Some(Level::Info));
        assert_eq!(parse("1"), Some(Level::Warn));
        assert_eq!(parse("3"), Some(Level::Debug));
        assert_eq!(parse("verbose"), None);
        assert_eq!(parse(""), None);
    }

    #[test]
    fn resolve_defaults_and_warns_on_garbage_only() {
        // Unset and blank values: quiet info default.
        assert_eq!(resolve(None), (Level::Info, None));
        assert_eq!(resolve(Some("")), (Level::Info, None));
        assert_eq!(resolve(Some("   ")), (Level::Info, None));
        // Recognized values, any case: no warning.
        assert_eq!(resolve(Some("ERROR")), (Level::Error, None));
        assert_eq!(resolve(Some("WaRn")), (Level::Warn, None));
        assert_eq!(resolve(Some("dEbUg")), (Level::Debug, None));
        // Garbage: info default plus a warning naming the bad value.
        let (l, w) = resolve(Some("verbose"));
        assert_eq!(l, Level::Info);
        let w = w.expect("unrecognized value must warn");
        assert!(w.contains("verbose") && w.contains("CGES_LOG"), "{w}");
    }

    #[test]
    fn levels_filter_monotonically() {
        // Global state: exercise the ordering through set_level, then
        // restore a permissive default for other tests in-process.
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Debug);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(enabled(Level::Debug));
        error(format_args!("test error line"));
        set_level(Level::Info);
    }
}
