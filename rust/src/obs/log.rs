//! Leveled stderr logging with a `CGES_LOG` environment filter.
//!
//! Deliberately tiny: three levels, one env var, stderr only. The
//! level is read from `CGES_LOG` (`error` | `info` | `debug`) once on
//! first use and cached in an atomic; [`set_level`] overrides it at
//! runtime (used by tests and by anything that wants a verbosity
//! flag). Default level is `info`, so `error`-level messages — like
//! the server's per-connection failures — are always visible unless
//! explicitly silenced with `CGES_LOG=` ... nothing silences errors;
//! `CGES_LOG=error` silences `info`/`debug`.

use std::fmt::Arguments;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Info = 1,
    Debug = 2,
}

/// Unset sentinel: the env var has not been consulted yet.
const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn parse(text: &str) -> Option<Level> {
    match text.trim().to_ascii_lowercase().as_str() {
        "error" | "err" | "0" => Some(Level::Error),
        "info" | "1" => Some(Level::Info),
        "debug" | "2" => Some(Level::Debug),
        _ => None,
    }
}

/// Current log level (reads `CGES_LOG` on first call; default `info`).
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNSET => {
            let l = std::env::var("CGES_LOG").ok().and_then(|v| parse(&v)).unwrap_or(Level::Info);
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
        0 => Level::Error,
        1 => Level::Info,
        _ => Level::Debug,
    }
}

/// Override the level (wins over the environment from now on).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would a message at `l` currently be printed?
pub fn enabled(l: Level) -> bool {
    l <= level()
}

fn emit(l: Level, tag: &str, msg: Arguments<'_>) {
    if enabled(l) {
        eprintln!("[cges:{tag}] {msg}");
    }
}

/// Log at error level (`obs::log::error(format_args!(...))`).
pub fn error(msg: Arguments<'_>) {
    emit(Level::Error, "error", msg);
}

/// Log at info level.
pub fn info(msg: Arguments<'_>) {
    emit(Level::Info, "info", msg);
}

/// Log at debug level.
pub fn debug(msg: Arguments<'_>) {
    emit(Level::Debug, "debug", msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_names_and_digits() {
        assert_eq!(parse("error"), Some(Level::Error));
        assert_eq!(parse(" ERR "), Some(Level::Error));
        assert_eq!(parse("info"), Some(Level::Info));
        assert_eq!(parse("Debug"), Some(Level::Debug));
        assert_eq!(parse("2"), Some(Level::Debug));
        assert_eq!(parse("warn"), None);
        assert_eq!(parse(""), None);
    }

    #[test]
    fn levels_filter_monotonically() {
        // Global state: exercise the ordering through set_level, then
        // restore a permissive default for other tests in-process.
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(enabled(Level::Debug));
        error(format_args!("test error line"));
        set_level(Level::Info);
    }
}
