//! Explicitly-passed metrics registry: counters, gauges, histograms.
//!
//! No process-global state — a [`Registry`] is created by whoever owns
//! the run (CLI, server, test) and handed down. Handles ([`Counter`],
//! [`Gauge`], [`Hist`]) are cheap `Arc` clones of the underlying
//! atomics, so a subsystem can keep its own handle embedded in a hot
//! struct (e.g. the score cache's hit counter) and *register* that same
//! handle under a name: the registry snapshot then reads live values
//! without the subsystem knowing about naming at all.
//!
//! Snapshots serialize through the crate's own [`Json`] value type
//! (no serde offline); counters above 2^53 lose precision in JSON, an
//! acceptable trade for a debug surface.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};

use super::hist::{HistCursor, HistDelta, Histogram};
use crate::infer::json::Json;

/// Monotonic event counter (relaxed atomic `u64`).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// New counter at zero.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Zero the counter.
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins `f64` gauge (bit-stored in an atomic `u64`).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// New gauge at 0.0.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Add `delta` (negative to decrement) atomically — the
    /// level-tracking form used by e.g. open-connection gauges, where
    /// several threads raise and lower the same value concurrently.
    pub fn add(&self, delta: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + delta).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Reset to 0.0.
    pub fn reset(&self) {
        self.set(0.0);
    }
}

/// Shared handle to a log-bucketed [`Histogram`].
#[derive(Clone, Debug, Default)]
pub struct Hist(Arc<Histogram>);

impl Hist {
    /// New empty histogram handle.
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Record one sample.
    #[inline]
    pub fn record(&self, v: u64) {
        self.0.record(v);
    }

    /// Record a duration in seconds as nanoseconds.
    #[inline]
    pub fn record_secs(&self, secs: f64) {
        self.0.record_secs(secs);
    }

    /// The underlying histogram (for quantiles/summaries).
    pub fn inner(&self) -> &Histogram {
        &self.0
    }
}

#[derive(Default)]
struct Inner {
    counters: RwLock<BTreeMap<String, Counter>>,
    gauges: RwLock<BTreeMap<String, Gauge>>,
    hists: RwLock<BTreeMap<String, Hist>>,
}

/// What changed in a [`Registry`] between two [`RegistryCursor`]
/// reads — one worker's metric shipment on the ring's obs wire.
///
/// Counters carry increments, gauges their current value (last-write
/// -wins, shipped only when the bits changed), histograms a
/// [`HistDelta`] each.
#[derive(Clone, Debug, Default)]
pub struct RegistryDelta {
    /// `(name, increment)` for counters that grew.
    pub counters: Vec<(String, u64)>,
    /// `(name, current value)` for gauges whose bits changed.
    pub gauges: Vec<(String, f64)>,
    /// `(name, delta)` for histograms with new samples.
    pub hists: Vec<(String, HistDelta)>,
}

impl RegistryDelta {
    /// True when nothing changed since the cursor.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }
}

/// Last-shipped state of one registry, advanced by
/// [`Registry::delta_since`]. One cursor per (registry, shipper).
#[derive(Clone, Debug, Default)]
pub struct RegistryCursor {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, u64>,
    hists: BTreeMap<String, HistCursor>,
}

/// Named collection of metrics; `Clone` shares the same store.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let c = self.inner.counters.read().expect("registry poisoned").len();
        let g = self.inner.gauges.read().expect("registry poisoned").len();
        let h = self.inner.hists.read().expect("registry poisoned").len();
        write!(f, "Registry({c} counters, {g} gauges, {h} hists)")
    }
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        if let Some(c) = self.inner.counters.read().expect("registry poisoned").get(name) {
            return c.clone();
        }
        let mut w = self.inner.counters.write().expect("registry poisoned");
        w.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        if let Some(g) = self.inner.gauges.read().expect("registry poisoned").get(name) {
            return g.clone();
        }
        let mut w = self.inner.gauges.write().expect("registry poisoned");
        w.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the histogram named `name`.
    pub fn hist(&self, name: &str) -> Hist {
        if let Some(h) = self.inner.hists.read().expect("registry poisoned").get(name) {
            return h.clone();
        }
        let mut w = self.inner.hists.write().expect("registry poisoned");
        w.entry(name.to_string()).or_default().clone()
    }

    /// Adopt an existing counter handle under `name` (last wins): the
    /// migration path for subsystems that own their counters.
    pub fn register_counter(&self, name: &str, c: &Counter) {
        self.inner
            .counters
            .write()
            .expect("registry poisoned")
            .insert(name.to_string(), c.clone());
    }

    /// Adopt an existing gauge handle under `name` (last wins).
    pub fn register_gauge(&self, name: &str, g: &Gauge) {
        self.inner
            .gauges
            .write()
            .expect("registry poisoned")
            .insert(name.to_string(), g.clone());
    }

    /// Adopt an existing histogram handle under `name` (last wins).
    pub fn register_hist(&self, name: &str, h: &Hist) {
        self.inner.hists.write().expect("registry poisoned").insert(name.to_string(), h.clone());
    }

    /// Value of a named counter, if registered.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        self.inner.counters.read().expect("registry poisoned").get(name).map(Counter::get)
    }

    /// Value of a named gauge, if registered.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.inner.gauges.read().expect("registry poisoned").get(name).map(Gauge::get)
    }

    /// Zero every registered metric (counters, gauges, histograms).
    pub fn reset(&self) {
        for c in self.inner.counters.read().expect("registry poisoned").values() {
            c.reset();
        }
        for g in self.inner.gauges.read().expect("registry poisoned").values() {
            g.reset();
        }
        for h in self.inner.hists.read().expect("registry poisoned").values() {
            h.inner().reset();
        }
    }

    /// Point-in-time snapshot:
    /// `{"counters": {..}, "gauges": {..}, "histograms": {..}}` where
    /// each histogram reports count/sum/mean/min/max/p50/p90/p99 and
    /// its non-empty `[lo, hi, n]` buckets.
    pub fn snapshot(&self) -> Json {
        let counters: Vec<(String, Json)> = self
            .inner
            .counters
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, c)| (k.clone(), Json::Num(c.get() as f64)))
            .collect();
        let gauges: Vec<(String, Json)> = self
            .inner
            .gauges
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, g)| (k.clone(), Json::Num(g.get())))
            .collect();
        let hists: Vec<(String, Json)> = self
            .inner
            .hists
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, h)| {
                let hh = h.inner();
                let s = hh.summary();
                let buckets = hh
                    .nonzero_buckets()
                    .into_iter()
                    .map(|(lo, hi, n)| {
                        Json::Arr(vec![
                            Json::Num(lo as f64),
                            Json::Num(hi as f64),
                            Json::Num(n as f64),
                        ])
                    })
                    .collect();
                let obj = Json::Obj(vec![
                    ("count".into(), Json::Num(s.count as f64)),
                    ("sum".into(), Json::Num(s.sum as f64)),
                    ("mean".into(), Json::Num(hh.mean())),
                    ("min".into(), Json::Num(s.min as f64)),
                    ("max".into(), Json::Num(s.max as f64)),
                    ("p50".into(), Json::Num(s.p50 as f64)),
                    ("p90".into(), Json::Num(s.p90 as f64)),
                    ("p99".into(), Json::Num(s.p99 as f64)),
                    ("buckets".into(), Json::Arr(buckets)),
                ]);
                (k.clone(), obj)
            })
            .collect();
        Json::Obj(vec![
            ("counters".into(), Json::Obj(counters)),
            ("gauges".into(), Json::Obj(gauges)),
            ("histograms".into(), Json::Obj(hists)),
        ])
    }

    /// Snapshot serialized to a compact JSON string.
    pub fn to_json_string(&self) -> String {
        self.snapshot().to_string()
    }

    /// Write the snapshot JSON to `path`.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json_string())
    }

    /// Registered counters as sorted `(name, value)` pairs.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.inner
            .counters
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, c)| (k.clone(), c.get()))
            .collect()
    }

    /// Registered gauges as sorted `(name, value)` pairs.
    pub fn gauges(&self) -> Vec<(String, f64)> {
        self.inner
            .gauges
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, g)| (k.clone(), g.get()))
            .collect()
    }

    /// Registered histogram handles, sorted by name.
    pub fn hists(&self) -> Vec<(String, Hist)> {
        self.inner
            .hists
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, h)| (k.clone(), h.clone()))
            .collect()
    }

    /// What changed since `cursor` last saw this registry; the cursor
    /// advances to the current state. Metrics created after the last
    /// read ship in full (the cursor starts them at zero).
    pub fn delta_since(&self, cursor: &mut RegistryCursor) -> RegistryDelta {
        let mut out = RegistryDelta::default();
        for (name, c) in self.inner.counters.read().expect("registry poisoned").iter() {
            let v = c.get();
            let prev = cursor.counters.insert(name.clone(), v).unwrap_or(0);
            if v > prev {
                out.counters.push((name.clone(), v - prev));
            }
        }
        for (name, g) in self.inner.gauges.read().expect("registry poisoned").iter() {
            let bits = g.get().to_bits();
            let prev = cursor.gauges.insert(name.clone(), bits);
            // Ship on first sight too (prev None), even if the value
            // is the 0.0 default — the receiver learns the gauge exists.
            if prev != Some(bits) {
                out.gauges.push((name.clone(), f64::from_bits(bits)));
            }
        }
        for (name, h) in self.inner.hists.read().expect("registry poisoned").iter() {
            let hc = cursor.hists.entry(name.clone()).or_default();
            let d = h.inner().delta_since(hc);
            if !d.is_empty() {
                out.hists.push((name.clone(), d));
            }
        }
        out
    }

    /// Merge a delta into this registry with every name prefixed (the
    /// coordinator files worker shipments under `worker<k>.`).
    pub fn absorb_prefixed(&self, prefix: &str, delta: &RegistryDelta) {
        for (name, inc) in &delta.counters {
            self.counter(&format!("{prefix}{name}")).add(*inc);
        }
        for (name, v) in &delta.gauges {
            self.gauge(&format!("{prefix}{name}")).set(*v);
        }
        for (name, d) in &delta.hists {
            self.hist(&format!("{prefix}{name}")).inner().absorb(d);
        }
    }

    /// Merge a snapshot produced by [`Registry::snapshot`] /
    /// [`Registry::write_json`] under `prefix` — the offline
    /// `obs merge` path. Histograms are rebuilt from their
    /// `[lo, hi, n]` bucket triples plus the exact
    /// `count`/`sum`/`min`/`max` fields; values above 2^53 went
    /// through JSON `f64`s, so extreme counters round accordingly.
    pub fn absorb_snapshot(&self, prefix: &str, snap: &Json) -> Result<()> {
        fn section<'a>(snap: &'a Json, key: &str) -> Result<&'a [(String, Json)]> {
            match snap.get(key) {
                None => Ok(&[]),
                Some(v) => v
                    .as_object()
                    .with_context(|| format!("snapshot field '{key}' is not an object")),
            }
        }
        for (name, v) in section(snap, "counters")? {
            let n = v
                .as_f64()
                .with_context(|| format!("counter '{name}' is not a number"))?;
            self.counter(&format!("{prefix}{name}")).add(n.max(0.0) as u64);
        }
        for (name, v) in section(snap, "gauges")? {
            let n = v
                .as_f64()
                .with_context(|| format!("gauge '{name}' is not a number"))?;
            self.gauge(&format!("{prefix}{name}")).set(n);
        }
        for (name, h) in section(snap, "histograms")? {
            let num = |key: &str| -> Result<u64> {
                h.get(key)
                    .and_then(Json::as_f64)
                    .map(|v| v.max(0.0) as u64)
                    .with_context(|| format!("histogram '{name}' lacks numeric '{key}'"))
            };
            let count = num("count")?;
            if count == 0 {
                continue;
            }
            let mut buckets = Vec::new();
            for triple in h.get("buckets").and_then(Json::as_array).unwrap_or(&[]) {
                let t = triple.as_array().unwrap_or(&[]);
                let (Some(lo), Some(n)) = (
                    t.first().and_then(Json::as_f64),
                    t.get(2).and_then(Json::as_f64),
                ) else {
                    bail!("histogram '{name}' has a malformed bucket triple");
                };
                buckets.push((
                    Histogram::bucket_index(lo.max(0.0) as u64) as u8,
                    n.max(0.0) as u64,
                ));
            }
            let delta = HistDelta {
                buckets,
                sum: num("sum")?,
                count,
                max: num("max")?,
                min: num("min")?,
            };
            self.hist(&format!("{prefix}{name}")).inner().absorb(&delta);
        }
        Ok(())
    }

    /// Prometheus text exposition (format 0.0.4) of the current state.
    pub fn to_prometheus(&self) -> String {
        super::prometheus::render(self)
    }

    /// Write [`Registry::to_prometheus`] to `path`.
    pub fn write_prometheus(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_prometheus())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_create_returns_shared_handles() {
        let reg = Registry::new();
        let a = reg.counter("x");
        let b = reg.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter_value("x"), Some(3));
        assert_eq!(a.get(), 3);

        let g = reg.gauge("load");
        g.set(0.75);
        assert_eq!(reg.gauge("load").get(), 0.75);
        assert_eq!(reg.gauge_value("load"), Some(0.75));
        assert_eq!(reg.gauge_value("absent"), None);

        let h = reg.hist("lat");
        h.record(10);
        assert_eq!(reg.hist("lat").inner().count(), 1);
    }

    #[test]
    fn gauge_add_tracks_levels_under_contention() {
        let g = Gauge::new();
        g.add(1.0);
        g.add(1.0);
        g.add(-1.0);
        assert_eq!(g.get(), 1.0);

        // 8 threads × (100 up + 100 down) nets to the starting level.
        let shared = g.clone();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let g = shared.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        g.add(1.0);
                        g.add(-1.0);
                    }
                });
            }
        });
        assert_eq!(g.get(), 1.0);
    }

    #[test]
    fn registered_external_handle_reads_live() {
        let reg = Registry::new();
        let mine = Counter::new();
        mine.add(5);
        reg.register_counter("ext.hits", &mine);
        assert_eq!(reg.counter_value("ext.hits"), Some(5));
        mine.inc();
        assert_eq!(reg.counter_value("ext.hits"), Some(6));
    }

    #[test]
    fn delta_since_ships_changes_and_absorb_prefixed_files_them() {
        let src = Registry::new();
        let dst = Registry::new();
        let mut cursor = RegistryCursor::default();

        src.counter("ring.hops").add(3);
        src.gauge("load").set(0.5);
        src.hist("wait_ns").record(100);
        let d1 = src.delta_since(&mut cursor);
        assert_eq!(d1.counters, vec![("ring.hops".to_string(), 3)]);
        dst.absorb_prefixed("worker1.", &d1);
        assert_eq!(dst.counter_value("worker1.ring.hops"), Some(3));
        assert_eq!(dst.gauge("worker1.load").get(), 0.5);
        assert_eq!(dst.hist("worker1.wait_ns").inner().count(), 1);

        // quiescent source -> empty delta
        assert!(src.delta_since(&mut cursor).is_empty());

        // only the increments ship the second time
        src.counter("ring.hops").add(2);
        src.hist("wait_ns").record(7);
        let d2 = src.delta_since(&mut cursor);
        assert_eq!(d2.counters, vec![("ring.hops".to_string(), 2)]);
        assert!(d2.gauges.is_empty(), "unchanged gauge must not re-ship");
        dst.absorb_prefixed("worker1.", &d2);
        assert_eq!(dst.counter_value("worker1.ring.hops"), Some(5));
        let h = dst.hist("worker1.wait_ns");
        assert_eq!(h.inner().count(), 2);
        assert_eq!(h.inner().sum(), 107);
        assert_eq!(h.inner().min(), 7);
        assert_eq!(h.inner().max(), 100);
    }

    #[test]
    fn absorb_snapshot_rebuilds_histograms_exactly() {
        let src = Registry::new();
        src.counter("c").add(41);
        src.gauge("g").set(-2.25);
        let h = src.hist("lat");
        for v in [1u64, 5, 5, 900] {
            h.record(v);
        }
        let snap = Json::parse(&src.to_json_string()).expect("valid snapshot");

        let dst = Registry::new();
        dst.absorb_snapshot("proc0.", &snap).expect("absorb");
        assert_eq!(dst.counter_value("proc0.c"), Some(41));
        assert_eq!(dst.gauge("proc0.g").get(), -2.25);
        let got = dst.hist("proc0.lat");
        assert_eq!(got.inner().count(), 4);
        assert_eq!(got.inner().sum(), 911);
        assert_eq!(got.inner().min(), 1);
        assert_eq!(got.inner().max(), 900);
        assert_eq!(got.inner().nonzero_buckets(), h.inner().nonzero_buckets());

        assert!(
            dst.absorb_snapshot("p.", &Json::parse("{\"counters\": 3}").unwrap()).is_err(),
            "malformed snapshot must be rejected"
        );
    }

    #[test]
    fn snapshot_roundtrips_through_json_and_reset_zeroes() {
        let reg = Registry::new();
        reg.counter("c").add(7);
        reg.gauge("g").set(1.5);
        let h = reg.hist("h");
        for v in [1u64, 2, 3, 1000] {
            h.record(v);
        }
        let text = reg.to_json_string();
        let v = Json::parse(&text).expect("snapshot must be valid JSON");
        assert_eq!(v.get("counters").and_then(|c| c.get("c")).and_then(Json::as_f64), Some(7.0));
        assert_eq!(v.get("gauges").and_then(|g| g.get("g")).and_then(Json::as_f64), Some(1.5));
        let hist = v.get("histograms").and_then(|h| h.get("h")).expect("hist present");
        assert_eq!(hist.get("count").and_then(Json::as_f64), Some(4.0));
        assert_eq!(hist.get("max").and_then(Json::as_f64), Some(1000.0));
        assert!(hist.get("buckets").and_then(Json::as_array).is_some_and(|b| !b.is_empty()));

        reg.reset();
        assert_eq!(reg.counter_value("c"), Some(0));
        assert_eq!(reg.gauge("g").get(), 0.0);
        assert_eq!(reg.hist("h").inner().count(), 0);
    }
}
